"""Pipeline parallelism: modeled planner arms + schedule validation (ISSUE 4).

Three sections, all ``name,us_per_call,derived`` rows:

  * ``pipeline/schedule/...`` — 1F1B timeline validation: the
    dependency-driven simulation of the canonical schedule lands exactly on
    ``(M + S - 1)(t_f + t_b)`` for uniform stages, i.e. the bubble fraction
    matches the closed form ``(S-1)/(S-1+M)`` the planner charges.

  * ``pipeline/modeled/...`` — for full-size archs × link regimes, the
    modeled step time of the two fixed DP arms (every-step replicated and
    every-step sharded), the best pipeline(S, M) arm, and the free-search
    winner.  Asserted acceptance inequalities: auto ≤ every arm and every
    fixed baseline, and on at least one (arch, link) point the planner
    SELECTS a pipeline arm under a memory budget — with its modeled time
    strictly below BOTH fixed DP arms (the tentpole acceptance criterion).

  * ``pipeline/measured/...`` — on the host mesh (device-count gated): the
    measured wall time of a 1F1B step for a reduced arch vs the same
    session's single-stage micro-batched step.  Wall-clock honesty note:
    on a host CPU mesh the lockstep slots serialize, so this row is a
    smoke check of the executor, not a speedup claim — the speedup lives
    in the modeled DP-edge numbers above.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import LINK_PRESETS, emit, time_fn
from repro.configs import get_config
from repro.core.pipeline import bubble_fraction, simulate_1f1b
from repro.core.schedule import (PipelineAxis, fixed_config_plan,
                                 plan_rounds, profiles_from_grads)
from repro.core.schedule.planner import FIXED_BASELINES

ARCHS = ("gemma-2b", "chameleon-34b")
REGIMES = ("fast_ici", "commodity")
PEAK_FLOPS = 197e12
TOKENS = 4096
WORLD = 256
OPT = "adam"


def _schedule():
    for S, M in ((2, 4), (4, 8), (8, 32)):
        t = simulate_1f1b(S, M, 1e-3, 2e-3)
        ideal = M * 3e-3
        bub = (t - ideal) / t
        closed = bubble_fraction(S, M)
        assert abs(bub - closed) < 1e-12, (S, M, bub, closed)
        emit(f"pipeline/schedule/S{S}_M{M}", t * 1e6,
             f"bubble={bub:.4f} closed_form={closed:.4f}")


def _modeled():
    from repro.models import Model
    pipeline_won = []
    for arch in ARCHS:
        cfg = get_config(arch)
        params = Model(cfg).abstract_params()
        n_params = sum(int(np.prod(p.shape))
                       for p in jax.tree.leaves(params))
        t_backward = 4.0 * n_params * TOKENS / PEAK_FLOPS
        profiles = profiles_from_grads(params, t_backward)
        pa = PipelineAxis(global_tokens=float(TOKENS * WORLD),
                          bytes_per_token=float(cfg.d_model * 4))
        for regime in REGIMES:
            link = LINK_PRESETS[regime]
            best, arms = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                     pipeline=pa)
            fixed_dp = {k: arms[k] for k in ("every_step",
                                             "every_step_sharded")}
            for k, a in fixed_dp.items():
                emit(f"pipeline/modeled/{arch}/{regime}/{k}",
                     a.modeled_step_s * 1e6,
                     f"opt_mem_mib={a.opt_mem_bytes / 2**20:.0f}")
            pipes = [a for a in arms.values() if a.pipeline_stages > 1]
            assert pipes, "no pipeline arms priced"
            pbest = min(pipes, key=lambda a: a.modeled_step_s)
            emit(f"pipeline/modeled/{arch}/{regime}/pipeline_best",
                 pbest.modeled_step_s * 1e6,
                 f"arm={pbest.key} bubble={pbest.bubble:.3f} "
                 f"p2p_ms={pbest.pipe_p2p_s * 1e3:.2f} "
                 f"opt_mem_mib={pbest.opt_mem_bytes / 2**20:.0f}")
            # the planner's invariant extends to the parallelism axis
            assert all(best.modeled_step_s <= a.modeled_step_s + 1e-12
                       for a in arms.values()), (arch, regime)
            for name, (comp, algo, cargs) in FIXED_BASELINES.items():
                fp = fixed_config_plan(profiles, link, WORLD, comp, algo,
                                       compressor_args=cargs)
                assert best.modeled_step_s <= fp.modeled_step_s + 1e-12, \
                    (arch, regime, name)
            emit(f"pipeline/modeled/{arch}/{regime}/auto",
                 best.modeled_step_s * 1e6, f"arm={best.key}")

            # memory budget below replicated moments: local-SGD and
            # replicated every-step drop out; the pipeline arm wins iff it
            # beats the sharded arm on modeled wall clock
            budget = arms["every_step"].opt_mem_bytes * 0.5
            tight, _ = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                   pipeline=pa,
                                   memory_budget_bytes=budget)
            emit(f"pipeline/modeled/{arch}/{regime}/auto_budget",
                 tight.modeled_step_s * 1e6,
                 f"arm={tight.key} budget_mib={budget / 2**20:.0f}")
            if tight.pipeline_stages > 1:
                # the acceptance win: strictly below BOTH fixed DP arms
                assert tight.modeled_step_s < \
                    fixed_dp["every_step"].modeled_step_s, (arch, regime)
                assert tight.modeled_step_s < \
                    fixed_dp["every_step_sharded"].modeled_step_s, \
                    (arch, regime)
                pipeline_won.append((arch, regime))
    assert pipeline_won, \
        "planner never selected a pipeline arm on any (arch, link, budget)"
    emit("pipeline/modeled/wins", float(len(pipeline_won)),
         ";".join(f"{a}/{r}" for a, r in pipeline_won))


def _measured():
    import jax.numpy as jnp

    from repro.configs import reduced
    from repro.core import GradientSynchronizer, SyncConfig
    from repro.core.pipeline import StagedModel
    from repro.data import DataConfig, SyntheticPipeline
    from repro.launch.mesh import make_pipe_mesh
    from repro.launch.steps import make_pipeline_train_step
    from repro.models import Model
    from repro.optim import make_optimizer

    n_dev = len(jax.devices())
    stage_counts = [s for s in (1, 2) if n_dev % s == 0 and s <= n_dev]
    arch = "gemma-2b"
    cfg = reduced(get_config(arch))
    M = 4
    for S in stage_counts:
        dp = n_dev // S
        model = Model(cfg)
        staged = StagedModel(model, S)
        mesh = make_pipe_mesh(S, dp)
        params = model.init(jax.random.PRNGKey(0))
        shared, rows = staged.split(params)
        p = {"shared": shared, "rows": rows}
        opt = make_optimizer(OPT, lr=1e-3)
        engine = GradientSynchronizer(SyncConfig(bucket_bytes=0), ("data",))
        step_fn, init_opt, init_ss = make_pipeline_train_step(
            staged, opt, engine, mesh, M)
        o, ss = init_opt(p), init_ss(p)
        data = SyntheticPipeline(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32,
            global_batch=M * max(dp, 1)))
        batch = jax.tree.map(jnp.asarray, data.batch(0))
        jit = jax.jit(step_fn)
        us = time_fn(lambda: jit(p, o, ss, batch, jnp.zeros((), jnp.int32),
                                 jax.random.PRNGKey(1)),
                     iters=3, warmup=1)
        emit(f"pipeline/measured/{arch}/S{S}_M{M}", us,
             f"devices={n_dev} dp={dp} "
             f"bubble={bubble_fraction(S, M):.3f}")


def run():
    _schedule()
    _modeled()
    _measured()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
