"""TP × PP × DP × EP placement search: modeled planner arms (ISSUE 9).

All rows are ``name,us_per_call,derived``:

  * ``parallelism/modeled/<arch>/<topology>/...`` — for each tracked
    (arch, tiered-topology) point, the best budget-eligible arm of each
    family under a half-replicated optimizer-memory budget: ``dp_best``
    (rounds × bits × shard axes only), ``pp_best`` (pipeline arms),
    ``model_best`` (tp/ep arms), and ``auto_budget`` (what
    ``plan_rounds`` actually picks).  The budget is the regime where
    model axes earn their keep — replicated every-step and local-SGD
    carry full moments and drop out, so the contest is sharded-DP's
    params-gather tail vs the pipeline bubble vs the tp/ep activation
    edges on the PLACED tier.

  * Acceptance (the tentpole criterion): on every point marked
    ``must_win`` — and at least two points overall — the best tp/ep arm
    is STRICTLY faster than both the best DP-only arm and the best
    PP-only arm.  The winning points are MoE-shaped archs: ~30 GB of
    expert-heavy parameters behind a 2k-wide activation stream, so the
    DP gradient edge and the pipeline bubble both scale with the fat
    parameter tensor while the tp/ep activation edges ride the thin
    token stream on the fastest tier.

  * Tier-awareness: for every model-axis family the fast-tier placement
    must price at or below every slow-tier placement of the same size
    (``ep(8)@device`` vs ``ep(8)@node`` differ ~10× on the commodity
    cluster — the placement axis is load-bearing, not cosmetic).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.schedule import (ExpertAxis, PipelineAxis, TensorAxis,
                                 Topology, plan_rounds, profiles_from_grads)

PEAK_FLOPS = 197e12
TOKENS = 4096
OPT = "adam"

# (arch, topology spec, must_win).  The two commodity-cluster MoE points
# and the multi-pod point are the acceptance wins; jamba rides along as
# the hybrid-MoE data point.
POINTS = (
    ("qwen3-moe-30b-a3b", "node:32@commodity,device:8@fast_ici", True),
    ("qwen3-moe-30b-a3b", "pod:2@datacenter,chip:256@fast_ici", True),
    ("deepseek-v2-lite-16b", "node:32@commodity,device:8@fast_ici", True),
    ("jamba-v0.1-52b", "node:32@commodity,device:8@fast_ici", False),
)


def _moe_axis_stats(params):
    """(expert_fraction, n_moe_layers) from the abstract param tree: the
    expert weights are the stacked ``(layers, experts, d, f)`` leaves
    under ``ffn`` (scanned layer stacks), everything else is dense."""
    leaves = jax.tree_util.tree_leaves_with_path(params)
    total = sum(int(np.prod(p.shape)) for _, p in leaves)
    expert, n_layers = 0, 0
    for path, p in leaves:
        if "ffn" in jax.tree_util.keystr(path) and p.ndim == 4:
            expert += int(np.prod(p.shape))
            n_layers = max(n_layers, int(p.shape[0]))
    return expert / total, n_layers


def build_point(arch: str, spec: str):
    """(profiles, topology, axes-kwargs) for one tracked point — shared
    with scripts/bench_ci.py so the gated numbers are these numbers."""
    from repro.models import Model
    cfg = get_config(arch)
    params = Model(cfg).abstract_params()
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    t_backward = 4.0 * n_params * TOKENS / PEAK_FLOPS
    profiles = profiles_from_grads(params, t_backward)
    topo = Topology.from_spec(spec)
    gt = float(TOKENS * topo.world)
    axes = {
        "pipeline": PipelineAxis(global_tokens=gt,
                                 bytes_per_token=float(cfg.d_model * 4)),
        "tensor": TensorAxis(global_tokens=gt,
                             bytes_per_token=float(cfg.d_model * 4),
                             n_layers=cfg.num_layers),
    }
    frac, n_moe = _moe_axis_stats(params)
    if n_moe:
        axes["expert"] = ExpertAxis(
            global_tokens=gt,
            bytes_per_token=float(cfg.top_k * cfg.d_model * 4),
            n_moe_layers=n_moe, expert_fraction=frac)
    return profiles, topo, axes


def best_by_family(arms, budget):
    """Best budget-eligible arm per family: (dp, pp, model) — any may be
    ``None`` when nothing in the family fits."""
    fits = [a for a in arms.values() if a.opt_mem_bytes <= budget]

    def pick(pred):
        sel = [a for a in fits if pred(a)]
        return min(sel, key=lambda a: a.modeled_step_s) if sel else None

    dp = pick(lambda a: a.pipeline_stages == 1 and a.tp == 1 and a.ep == 1)
    pp = pick(lambda a: a.pipeline_stages > 1)
    model = pick(lambda a: a.tp > 1 or a.ep > 1)
    return dp, pp, model


def _modeled():
    wins = []
    for arch, spec, must_win in POINTS:
        profiles, topo, axes = build_point(arch, spec)
        key = f"{arch}/{topo.spec()}"
        best, arms = plan_rounds(profiles, topo, topo.world, opt_name=OPT,
                                 **axes)
        # planner invariant carries over to the model axes
        assert all(best.modeled_step_s <= a.modeled_step_s + 1e-12
                   for a in arms.values()), key

        # tier-awareness: same-size model-axis arms, fast tier vs slow
        placed = {}
        for a in arms.values():
            ax = ("tp", a.tp) if a.tp > 1 else (("ep", a.ep) if a.ep > 1
                                                else None)
            if ax and (a.tp_tier or a.ep_tier):
                placed.setdefault(ax, []).append(a)
        for (ax, size), group in placed.items():
            group.sort(key=lambda a: a.modeled_step_s)
            fast = group[0]
            assert all(fast.modeled_step_s <= a.modeled_step_s + 1e-12
                       for a in group), (key, ax, size)
            if len(group) > 1:
                emit(f"parallelism/modeled/{key}/{ax}({size})_placement",
                     fast.modeled_step_s * 1e6,
                     f"fast={fast.key} slowest={group[-1].key} "
                     f"ratio={group[-1].modeled_step_s / fast.modeled_step_s:.1f}x")

        budget = arms["every_step"].opt_mem_bytes * 0.5
        dp, pp, model = best_by_family(arms, budget)
        assert dp is not None and pp is not None and model is not None, key
        for tag, a in (("dp_best", dp), ("pp_best", pp),
                       ("model_best", model)):
            emit(f"parallelism/modeled/{key}/{tag}",
                 a.modeled_step_s * 1e6,
                 f"arm={a.key} opt_mem_mib={a.opt_mem_bytes / 2**20:.0f}")
        tight, _ = plan_rounds(profiles, topo, topo.world, opt_name=OPT,
                               memory_budget_bytes=budget, **axes)
        emit(f"parallelism/modeled/{key}/auto_budget",
             tight.modeled_step_s * 1e6,
             f"arm={tight.key} budget_mib={budget / 2**20:.0f}")

        won = (model.modeled_step_s < dp.modeled_step_s
               and model.modeled_step_s < pp.modeled_step_s)
        if must_win:
            # the tentpole acceptance: the 3D placement strictly beats
            # the best DP-only AND the best PP-only arm at this point
            assert won, (key, model.key, dp.key, pp.key)
        if won:
            # the budgeted auto pick must then BE a model-axis arm
            assert tight.tp > 1 or tight.ep > 1, (key, tight.key)
            wins.append(key)
    assert len(wins) >= 2, f"model axes won only at {wins}"
    emit("parallelism/modeled/wins", float(len(wins)), ";".join(wins))


def run():
    _modeled()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
