"""Communication planner vs fixed configs (tentpole acceptance table).

Two views, both emitted as ``name,us_per_call,derived`` rows:

  * ``planner/modeled/...`` — for ≥3 FULL-SIZE archs × ≥2 link regimes, the
    planner's modeled iteration time next to the fixed single-strategy
    baselines {psum/dense, ring/topk, ring/int8} on the same α-β simulator.
    ``derived`` carries the speedup of auto over the best fixed config
    (≥1.00x by construction — the planner's search space contains them).

  * ``planner/measured/...`` — for ≥3 reduced archs on the host mesh,
    MEASURED wall time per train step for the auto plan vs the fixed
    configs.  On a 1-device host the collective degenerates, so this
    measures executor overhead (compression compute, bucketing): the
    planner correctly goes dense when communication is free, so auto must
    not be slower than the compressed fixed configs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import LINK_PRESETS, emit, time_fn
from repro.configs import get_config, reduced
from repro.core.schedule import fixed_config_plan, plan, profiles_from_grads
from repro.core.schedule.planner import FIXED_BASELINES

ARCHS = ("xlstm-125m", "gemma-2b", "chameleon-34b")
REGIMES = ("fast_ici", "commodity")
# emit-name-safe spellings of the shared baseline table
FIXED = {name.replace("/", "_"): spec
         for name, spec in FIXED_BASELINES.items()}
PEAK_FLOPS = 197e12     # per-chip bf16 (launch.mesh roofline constant)
TOKENS = 4096           # per-chip tokens per step for the modeled backward


def _modeled():
    from repro.models import Model
    world = 256
    for arch in ARCHS:
        cfg = get_config(arch)
        model = Model(cfg)
        params = model.abstract_params()
        n_params = sum(int(jnp.prod(jnp.asarray(p.shape)))
                       for p in jax.tree.leaves(params))
        # backward ≈ 2× forward ≈ 4·N·tokens flops
        t_backward = 4.0 * n_params * TOKENS / PEAK_FLOPS
        profiles = profiles_from_grads(params, t_backward)
        for regime in REGIMES:
            link = LINK_PRESETS[regime]
            auto = plan(profiles, link, world)
            fixed_times = {}
            for name, (comp, algo, cargs) in FIXED.items():
                fp = fixed_config_plan(profiles, link, world, comp, algo,
                                       compressor_args=cargs)
                fixed_times[name] = fp.modeled_step_s
                emit(f"planner/modeled/{arch}/{regime}/{name}",
                     fp.modeled_step_s * 1e6, "")
            best = min(fixed_times, key=fixed_times.get)
            emit(f"planner/modeled/{arch}/{regime}/auto",
                 auto.modeled_step_s * 1e6,
                 f"n_buckets={auto.n_buckets} "
                 f"speedup_vs_best_fixed={fixed_times[best] / auto.modeled_step_s:.2f}x"
                 f" best_fixed={best}")


def _measured():
    from repro.core import SyncConfig
    from repro.data import DataConfig, SyntheticPipeline
    from repro.launch.mesh import data_axes, make_host_mesh
    from repro.launch.steps import (make_comm_optimized_train_step,
                                    make_planned_train_step)
    from repro.models import Model
    from repro.optim import make_optimizer

    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    axes = data_axes(mesh)
    world = 1
    for a in axes:
        world *= mesh.shape[a]
    for arch in ARCHS:
        cfg = reduced(get_config(arch))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = make_optimizer("adam", lr=1e-3)
        data = SyntheticPipeline(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=2 * world,
            embedding_dim=cfg.d_model if cfg.embedding_inputs else 0))
        batch = jax.tree.map(jnp.asarray, data.batch(0))
        rng = jax.random.PRNGKey(1)

        def run_one(tag, step_builder):
            step_fn, _, init_state = step_builder()
            opt_state = opt.init(params)
            sync_state = init_state(params)
            jit_step = jax.jit(step_fn)
            step_i = jnp.zeros((), jnp.int32)

            def call():
                return jit_step(params, opt_state, sync_state, batch,
                                step_i, rng)

            us = time_fn(call, iters=5, warmup=1)
            emit(f"planner/measured/{arch}/{tag}", us, f"world={world}")

        profiles = profiles_from_grads(params, t_backward_s=1e-3)
        auto_plan = plan(profiles, LINK_PRESETS["fast_ici"], world)
        run_one("auto", lambda: make_planned_train_step(
            model, auto_plan, opt, mesh, axes))
        for name, (comp, algo, cargs) in FIXED.items():
            sync_cfg = SyncConfig(compressor=comp, algo=algo,
                                  compressor_args=cargs)
            run_one(name, lambda: make_comm_optimized_train_step(
                model, opt, sync_cfg, mesh, axes))


def run():
    _modeled()
    _measured()
