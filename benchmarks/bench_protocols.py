"""Paper §4.3 — network protocol comparison as an alpha/beta parameter study.

RDMA/IPoIB/TCP have no TPU analogue (DESIGN.md §5): their effect is lower
per-message latency and higher effective bandwidth, so the survey's
comparison (e.g. IPoIB 53% vs RDMA 96% scaling of Inception-v3 on 100 GPUs)
is reproduced by sweeping (alpha, beta) through published protocol numbers
and reporting the predicted scaling efficiency of a ring allreduce-per-step
training loop."""
from __future__ import annotations

from benchmarks.common import LINK_PRESETS, LinkParams, emit
from repro.core.collectives import allreduce_cost_s

PROTOCOLS = {
    # alpha (latency), beta (1/bandwidth) — representative published values.
    # tpu_ici deliberately coincides with cost.LINK_PRESETS["fast_ici"].
    "tcp_socket": (50e-6, 1 / 1.2e9),
    "ipoib": (20e-6, 1 / 4e9),
    "rdma_verbs": (2e-6, 1 / 11e9),
    "tpu_ici": (LINK_PRESETS["fast_ici"].alpha_s,
                LINK_PRESETS["fast_ici"].beta_s_per_byte),
}

STEP_COMPUTE_S = 0.25     # Inception-v3-ish step
GRAD_BYTES = 95e6         # ~24M params fp32


def run():
    for name, (a, b) in PROTOCOLS.items():
        link = LinkParams(alpha_s=a, beta_s_per_byte=b)
        for p in (8, 100):
            t_comm = allreduce_cost_s("ring", GRAD_BYTES, p, link)
            eff = STEP_COMPUTE_S / (STEP_COMPUTE_S + t_comm)
            emit(f"protocols/{name}/p{p}", t_comm * 1e6,
                 f"scaling_eff={eff:.2%}")
