"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig7]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (bench_collectives, bench_compression,
                        bench_large_batch, bench_overlap, bench_parallelism,
                        bench_periodic, bench_pipeline, bench_planner,
                        bench_protocols, bench_serving, bench_sharded,
                        bench_topology)

SUITES = {
    "table1": bench_large_batch,
    "table2": bench_periodic,
    "fig7": bench_compression,
    "fig8": bench_overlap,
    "fig10": bench_collectives,
    "protocols": bench_protocols,
    "planner": bench_planner,
    "sharded": bench_sharded,
    "pipeline": bench_pipeline,
    "parallelism": bench_parallelism,
    "topology": bench_topology,
    "serving": bench_serving,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(SUITES), default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in SUITES.items():
        if args.only and name != args.only:
            continue
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"{name},nan,SUITE FAILED", file=sys.stdout)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
