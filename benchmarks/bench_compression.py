"""Paper Fig. 7 + §3.2 — compression schemes side by side.

For each compressor: wire bits per step (the figure's visual), measured
compress+decompress cost, and one-shot reconstruction error on an identical
gradient — plus the Pallas fused-EF kernels' timings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.compression import get_compressor

SHAPES = [(1024, 1024)]     # a ~1M-element layer gradient (fp32: 4 MB)


def run():
    rng = jax.random.PRNGKey(0)
    g = jax.random.normal(rng, SHAPES[0]) * 0.01
    dense_bits = g.size * 32
    cases = [
        ("none", {}), ("sign", {}), ("terngrad", {}),
        ("qsgd", {"levels": 127}), ("qsgd4", None), ("int8", {}),
        ("topk", {"ratio": 0.01}), ("randomk", {"ratio": 0.01}),
        ("powersgd", {"rank": 4}), ("svd", {"rank": 4}),
    ]
    for name, kwargs in cases:
        if name == "qsgd4":
            comp = get_compressor("qsgd", levels=7)   # ~4-bit QSGD
        else:
            comp = get_compressor(name, **kwargs)

        def roundtrip(g, r):
            if comp.name == "powersgd":
                payload, meta = comp.compress(g, rng=r)
                return comp.decompress(payload, meta)
            payload, meta = comp.compress(g, r)
            return comp.decompress(payload, meta)

        f = jax.jit(roundtrip)
        us = time_fn(f, g, rng)
        g_hat = f(g, rng)
        err = float(jnp.linalg.norm(g_hat - g) / jnp.linalg.norm(g))
        bits = comp.payload_bits(g.shape)
        emit(f"fig7/{name}", us,
             f"ratio={dense_bits / bits:.1f}x;rel_err={err:.4f};bits={bits}")

    # Pallas fused kernels (interpret mode on CPU)
    from repro.kernels import ops
    flat = g.reshape(-1)
    e = jnp.zeros_like(flat)
    emit("fig7/pallas_quantize_ef", time_fn(ops.quantize_ef, flat, e),
         "fused EF+int8 kernel")
    emit("fig7/pallas_topk_mask",
         time_fn(lambda x: ops.topk_mask(x, ratio=0.01), flat),
         "block top-k kernel")
