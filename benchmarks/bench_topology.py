"""Tiered-topology planner table (ISSUE 5 tentpole acceptance).

Emits ``topology/modeled/...`` rows and ASSERTS the two halves of the
acceptance criterion:

  * on the two-tier acceptance network (``node:4@datacenter`` over
    ``device:8@fast_ici``), ``plan_rounds`` selects a TIER-AWARE arm —
    hierarchical/2D buckets, or a pipeline arm with an explicit pipe-axis
    tier placement — that is modeled STRICTLY faster than the best
    flat-ring arm (the best plan restricted to ring/psum collectives,
    i.e. the best any non-tier-aware traversal can do: a flat ring is
    gated by the slow inter-node fabric every step, Zhang et al. 2020);

  * on a HOMOGENEOUS network the tiered model changes nothing: the
    fixed ring plan priced on a two-tier topology whose tiers share one
    link is BIT-IDENTICAL to the same plan on ``Topology.flat`` (the
    bottleneck tier is the link), and the free search lands within 2%
    (hierarchical's default k differs: sqrt(p) flat vs the tier size).

The rounds axis is pinned to every-step (``tau_grid=(1,)``) so the
comparison isolates the NETWORK axis — local-SGD amortization would win
some corners for reasons orthogonal to tiering.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.schedule import (LINK_PRESETS, PipelineAxis, Topology,
                                 fixed_config_plan, plan, plan_rounds,
                                 profiles_from_grads)
from repro.core.schedule.planner import FLAT_RING_CANDIDATES

ARCHS = ("xlstm-125m", "gemma-2b", "chameleon-34b")
TIERED_SPEC = "node:4@datacenter,device:8@fast_ici"   # acceptance network
HOMO_SPEC = "node:4@fast_ici,device:8@fast_ici"
PEAK_FLOPS = 197e12
TOKENS = 4096           # per-chip tokens per step for the modeled backward



def _tier_aware(arm) -> bool:
    if arm.pipeline_stages > 1:
        return bool(arm.pipe_tier)
    return any(b.algo in ("hierarchical", "mesh2d", "mesh2d_split")
               for b in arm.comm.buckets)


def _profiles(arch):
    from repro.models import Model
    cfg = get_config(arch)
    params = Model(cfg).abstract_params()
    # np.prod (int64), NOT jnp.prod: chameleon-34b's 34e9 params overflow
    # int32 and a negative t_backward silently flips every plan
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    t_backward = 4.0 * n * TOKENS / PEAK_FLOPS
    return cfg, profiles_from_grads(params, t_backward)


def run():
    tiered = Topology.from_spec(TIERED_SPEC)
    homo = Topology.from_spec(HOMO_SPEC)
    flat = Topology.flat(homo.world, LINK_PRESETS["fast_ici"])
    world = tiered.world

    for arch in ARCHS:
        cfg, profiles = _profiles(arch)
        pa = PipelineAxis(global_tokens=float(TOKENS * world),
                          bytes_per_token=float(cfg.d_model * 4))

        # the best any flat (non-tier-aware) traversal can do on the
        # tiered network: full search, collectives restricted to ring/psum
        flat_ring = plan(profiles, tiered, world,
                         candidates=FLAT_RING_CANDIDATES)
        emit(f"topology/modeled/{arch}/two_tier/best_flat_ring",
             flat_ring.modeled_step_s * 1e6, "ring/psum-restricted")

        best, arms = plan_rounds(profiles, tiered, world, tau_grid=(1,),
                                 pipeline=pa)
        es = arms["every_step"]
        emit(f"topology/modeled/{arch}/two_tier/every_step",
             es.modeled_step_s * 1e6,
             "algos=" + "+".join(sorted({b.algo for b in es.comm.buckets})))
        emit(f"topology/modeled/{arch}/two_tier/auto",
             best.modeled_step_s * 1e6,
             f"arm={best.key} "
             f"speedup_vs_flat_ring="
             f"{flat_ring.modeled_step_s / best.modeled_step_s:.2f}x")

        assert best.modeled_step_s < flat_ring.modeled_step_s, (
            arch, best.key, best.modeled_step_s, flat_ring.modeled_step_s)
        assert _tier_aware(best), (arch, best.key)
        # the every-step arm alone must already be tier-aware here: the
        # per-bucket search discovers hierarchical once the inner ring is
        # priced on the fast tier
        assert _tier_aware(es), (arch, {b.algo for b in es.comm.buckets})

        # homogeneous two-tier network == flat network
        for comp, algo, cargs in (("none", "ring", ()), ("none", "psum", ()),
                                  ("int8", "ring", ())):
            fh = fixed_config_plan(profiles, homo, homo.world, comp, algo,
                                   compressor_args=cargs)
            ff = fixed_config_plan(profiles, flat, flat.world, comp, algo,
                                   compressor_args=cargs)
            assert fh.modeled_step_s == ff.modeled_step_s, (
                arch, comp, algo, fh.modeled_step_s, ff.modeled_step_s)
        ah = plan(profiles, homo, homo.world)
        af = plan(profiles, flat, flat.world)
        rel = abs(ah.modeled_step_s - af.modeled_step_s) \
            / max(af.modeled_step_s, 1e-12)
        assert rel < 0.02, (arch, ah.modeled_step_s, af.modeled_step_s)
        emit(f"topology/modeled/{arch}/homogeneous/auto_vs_flat",
             ah.modeled_step_s * 1e6,
             f"flat={af.modeled_step_s * 1e6:.1f}us rel_diff={rel:.4f}")
