"""Paper Table 2 + §3.1.2 — periodic communication (local SGD), LAG, and
asymmetric push/pull.

Reproduces (a) the communication-round counts of Table 2's schemes as a
function of tau, (b) convergence-vs-rounds of local SGD on a shared convex
problem across simulated workers, (c) the LAG experiment: rounds used vs
vanilla on a linear-regression task (the paper reports 5283 -> 1756), and
(d) Dean-style asymmetric push/pull through the registered ``push_pull``
round scheduler: rounds per cadence pair and convergence on the shared
quadratic when pushes and fetches are decoupled."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (AsymmetricPushPullConfig, LocalSGDConfig,
                        communication_rounds, get_scheduler, init_lag_state,
                        lag_trigger, lag_update_state)

T = 2000
K = 8  # workers


def run():
    # (a) Table 2 round complexities
    for tau in (1, 10, 100, T):
        cfg = LocalSGDConfig(period=tau)
        emit(f"table2/rounds/tau{tau}", 0.0,
             f"rounds={communication_rounds(T, cfg)};T={T}")

    # (b) local SGD convergence vs tau (simulated K workers, quadratic)
    w_star = np.random.default_rng(0).normal(size=32)
    for tau in (1, 8, 64, T):
        rng = np.random.default_rng(1)
        w = np.zeros((K, 32))
        rounds = 0
        for t in range(600):
            noise = rng.normal(size=(K, 32)) * 0.8
            g = 2 * (w - w_star) + noise
            w = w - 0.05 * g
            if (t + 1) % tau == 0:
                w[:] = w.mean(0)
                rounds += 1
        err = float(np.linalg.norm(w.mean(0) - w_star) / np.linalg.norm(w_star))
        emit(f"table2/local_sgd/tau{tau}", 0.0,
             f"rel_err={err:.4f};rounds={rounds}")

    # (c) LAG on linear regression: rounds saved at equal final loss
    rng = np.random.default_rng(2)
    X = rng.normal(size=(256, 16))
    y = X @ rng.normal(size=16)
    w = jnp.zeros(16)
    state = init_lag_state({"w": w})
    rounds_lag, steps = 0, 1200
    for t in range(steps):
        g = {"w": jnp.asarray(2 / len(X) * X.T @ (np.asarray(X @ w) - y))}
        if bool(lag_trigger(g, state["g_last"], 0.05)):
            state = lag_update_state(state, g, True)
            rounds_lag += 1
            used = g
        else:
            used = state["g_last"]
            used = {"w": used["w"]}
        w = w - 0.1 * used["w"]
    loss = float(np.mean((np.asarray(X @ w) - y) ** 2))
    emit("table2/lag/linear_regression", 0.0,
         f"rounds={rounds_lag};vanilla_rounds={steps};final_mse={loss:.2e}")

    # (d) asymmetric push/pull (Dean et al. 2012) via the registered
    # scheduler: push = sync gradients across workers, fetch = re-average
    # parameters; steps that do neither run purely locally.
    w_star = np.random.default_rng(0).normal(size=32)
    T_pp = 600
    for n_push, n_fetch in ((1, 1), (2, 4), (4, 2), (8, 8)):
        cfg = AsymmetricPushPullConfig(n_push=n_push, n_fetch=n_fetch)
        sched = get_scheduler("push_pull", cfg=cfg)
        state = sched.init_state({})
        rng = np.random.default_rng(1)
        w = np.zeros((K, 32))
        grad_rounds = fetch_rounds = 0
        for t in range(T_pp):
            noise = rng.normal(size=(K, 32)) * 0.8
            g = 2 * (w - w_star) + noise
            action, state = sched.round(t, state)
            if action.compute == "sync":      # push: synced gradient
                g[:] = g.mean(0)
                grad_rounds += 1
            w = w - 0.05 * g
            if action.param_round:            # fetch: re-averaged params
                w[:] = w.mean(0)
                fetch_rounds += 1
        err = float(np.linalg.norm(w.mean(0) - w_star)
                    / np.linalg.norm(w_star))
        expect = cfg.rounds(T_pp)
        assert grad_rounds == expect["push"], (grad_rounds, expect)
        assert fetch_rounds == expect["fetch"], (fetch_rounds, expect)
        emit(f"table2/push_pull/p{n_push}_f{n_fetch}", 0.0,
             f"rel_err={err:.4f};push_rounds={grad_rounds};"
             f"fetch_rounds={fetch_rounds};T={T_pp}")
