"""Shared benchmark utilities: timing + CSV emission + link regimes.

Every bench prints ``name,us_per_call,derived`` rows (benchmarks/run.py
contract); ``derived`` carries the table-specific metric.

``LINK_PRESETS`` re-exports the canonical α-β regimes from
``repro.core.schedule.cost`` so every bench sweeps the SAME (α, β) points —
the per-bench literal copies used to drift.
"""
from __future__ import annotations

import time

import jax

from repro.core.schedule.cost import LINK_PRESETS, LinkParams  # noqa: F401


def time_fn(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (results blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
