"""Paper Fig. 8 + §3.3 — computation-communication overlap schedules.

The analytic WFBP/MG-WFBP/P3 model over a realistic transformer layer
profile, swept across network regimes (the figure's three cases), plus the
measured effect of grad-sync bucket size (tensor fusion) on payload
structure."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import LINK_PRESETS, emit
from repro.core.grad_sync import bucketize
from repro.core.schedule import (LayerProfile, iteration_time_fifo,
                                 iteration_time_mg_wfbp, iteration_time_p3,
                                 iteration_time_wfbp, wfbp_case)


def transformer_profile(layers=24, d=2048, ff=8192, t_flop=197e12, tokens=2048):
    per_layer_flops = tokens * (8 * d * d + 6 * d * ff)
    grad_bytes = (4 * d * d + 3 * d * ff) * 4
    return [LayerProfile(per_layer_flops / t_flop * 3, grad_bytes)] * layers


def run():
    layers = transformer_profile()
    # canonical α-β regimes (commodity ≈ the survey's 10 GbE setting)
    regimes = {name: (l.alpha_s, l.beta_s_per_byte)
               for name, l in LINK_PRESETS.items()}
    for name, (a, b) in regimes.items():
        fifo = iteration_time_fifo(layers, a, b)
        wfbp = iteration_time_wfbp(layers, a, b)
        mg = iteration_time_mg_wfbp(layers, a, b, bucket_bytes=64 * 2**20)
        p3 = iteration_time_p3(layers, a, b, slice_bytes=4 * 2**20)
        case = wfbp_case(layers, a, b)
        emit(f"fig8/{name}/fifo", fifo * 1e6, f"case={case}")
        emit(f"fig8/{name}/wfbp", wfbp * 1e6,
             f"speedup={fifo / wfbp:.2f}x")
        emit(f"fig8/{name}/mg_wfbp", mg * 1e6,
             f"speedup={fifo / mg:.2f}x")
        emit(f"fig8/{name}/p3", p3 * 1e6, f"speedup={fifo / p3:.2f}x")

    # bucket-size sweep on a real gradient pytree (tensor fusion, §4.2)
    grads = {f"layer{i}": jnp.zeros((512, 512)) for i in range(32)}
    for mb in (1, 4, 32, 256):
        defs, _, _ = bucketize(grads, mb * 2**20)
        emit(f"fig8/buckets/{mb}MiB", 0.0, f"n_buckets={len(defs)}")
