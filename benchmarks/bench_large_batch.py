"""Paper Table 1 / Fig. 4 — large-batch training.

The survey's Table 1 compares ResNet-50 wall-clocks across batch sizes and
LR recipes; the transferable quantities here are (a) comm rounds and bytes
per epoch as batch grows (Eq. 1: batch x iters = dataset), (b) the LR that
each scaling rule + warmup produces, and (c) the measured per-step cost of
the large-batch optimizers (SGD/LARS/LAMB) on an identical model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.optim import (apply_updates, make_optimizer, scale_lr_for_batch,
                         warmup_cosine, legw_warmup_steps)

DATASET = 1_281_167          # ImageNet-1k, as in the paper's example
BASE_BATCH, BASE_LR = 256, 0.1
GRAD_BYTES = 97 * 2**20      # ResNet-50 fp32 gradients (the paper's 97 MB)


def run():
    # (a) rounds/bytes per epoch vs batch (survey Eq. 1)
    for batch in (256, 1024, 8192, 32768, 65536):
        iters = DATASET // batch
        lr_lin = scale_lr_for_batch(BASE_LR, BASE_BATCH, batch, "linear")
        lr_sqrt = scale_lr_for_batch(BASE_LR, BASE_BATCH, batch, "sqrt")
        warm = legw_warmup_steps(5 * (DATASET // BASE_BATCH) // 100,
                                 BASE_BATCH, batch)
        emit(f"table1/rounds_per_epoch/b{batch}", 0.0,
             f"iters={iters};bytes={iters * GRAD_BYTES:.3e};"
             f"lr_linear={lr_lin:.3f};lr_sqrt={lr_sqrt:.3f};legw_warmup={warm}")

    # (c) optimizer step cost at fixed model size
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (512, 512)),
              "b": jnp.zeros((512,))}
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 1e-3, params)
    for name in ("sgd", "adam", "lars", "lamb"):
        opt = make_optimizer(name, lr=warmup_cosine(0.1, 10, 100))
        state = opt.init(params)

        @jax.jit
        def step(p, s, g):
            u, s = opt.update(g, s, p, jnp.asarray(1))
            return apply_updates(p, u), s

        us = time_fn(step, params, state, grads)
        emit(f"table1/opt_step/{name}", us, "per-step optimizer cost")
