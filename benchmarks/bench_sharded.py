"""Sharded data parallelism: modeled + measured comparison (ISSUE 3).

Two views, both emitted as ``name,us_per_call,derived`` rows:

  * ``sharded/modeled/...`` — for full-size archs × link regimes, the
    modeled iteration time and per-worker optimizer-state memory of the
    fixed REPLICATED dense mode, the fixed SHARDED dense mode, and the
    planner's auto composite with the shard axis enabled.  Asserted
    acceptance inequalities: auto is never modeled slower than either
    fixed mode, the sharded fixed mode is never modeled faster than the
    replicated one (the gather tail is pure wall-clock cost), and the
    sharded memory is ~(moments+1)/(moments·world) of replicated.  A
    budget-constrained row shows the planner flipping to the shard arm
    when replicated optimizer state does not fit.

  * ``sharded/measured/...`` — on the host mesh, MEASURED wall time per
    train step for the sharded vs replicated execution of the same dense
    plan on a reduced arch, plus the measured per-worker bytes of the
    partitioned state arrays (exact nbytes, not a model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import LINK_PRESETS, emit, time_fn
from repro.configs import get_config, reduced
from repro.core.schedule import (fixed_config_plan, opt_state_bytes_per_worker,
                                 plan_rounds, profiles_from_grads)

ARCHS = ("xlstm-125m", "gemma-2b", "chameleon-34b")
REGIMES = ("fast_ici", "commodity")
PEAK_FLOPS = 197e12
TOKENS = 4096
WORLD = 256
OPT = "adam"


def _modeled():
    from repro.models import Model
    for arch in ARCHS:
        cfg = get_config(arch)
        params = Model(cfg).abstract_params()
        # np.prod, not jnp: stacked MoE leaves exceed int32 and jnp.prod's
        # default dtype silently wrapped negative (t_backward < 0)
        n_params = sum(int(np.prod(p.shape))
                       for p in jax.tree.leaves(params))
        t_backward = 4.0 * n_params * TOKENS / PEAK_FLOPS
        profiles = profiles_from_grads(params, t_backward)
        pb = float(sum(p.grad_bytes for p in profiles))
        for regime in REGIMES:
            link = LINK_PRESETS[regime]
            fixed = {}
            for shard in (False, True):
                fp = fixed_config_plan(profiles, link, WORLD, "none", "ring",
                                       shard_state=shard)
                mem = opt_state_bytes_per_worker(OPT, pb, WORLD, shard)
                tag = "sharded" if shard else "replicated"
                fixed[shard] = fp.modeled_step_s
                emit(f"sharded/modeled/{arch}/{regime}/fixed_{tag}",
                     fp.modeled_step_s * 1e6,
                     f"opt_mem_mib={mem / 2**20:.1f}")
            # the gather tail is pure cost: fixed sharded >= fixed replicated
            assert fixed[True] >= fixed[False] - 1e-15, (arch, regime)
            # memory identity: ~(mom+1)/(mom*world)
            ratio = (opt_state_bytes_per_worker(OPT, pb, WORLD, True)
                     / opt_state_bytes_per_worker(OPT, pb, WORLD, False))
            assert abs(ratio - 1.5 / WORLD) < 1e-12, ratio

            best, arms = plan_rounds(profiles, link, WORLD, opt_name=OPT)
            assert best.modeled_step_s <= min(fixed.values()) + 1e-12, \
                (arch, regime)
            emit(f"sharded/modeled/{arch}/{regime}/auto",
                 best.modeled_step_s * 1e6,
                 f"schedule={best.schedule.key} shard={best.shard_state} "
                 f"speedup_vs_best_fixed="
                 f"{min(fixed.values()) / best.modeled_step_s:.2f}x")

            # a budget below the replicated footprint forces the shard arm
            budget = opt_state_bytes_per_worker(OPT, pb, WORLD, False) / 2
            tight, _ = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                   memory_budget_bytes=budget)
            assert tight.shard_state, (arch, regime)
            assert tight.opt_mem_bytes <= budget, (arch, regime)
            assert tight.modeled_step_s <= fixed[True] + 1e-12, (arch, regime)
            emit(f"sharded/modeled/{arch}/{regime}/auto_budget",
                 tight.modeled_step_s * 1e6,
                 f"budget_mib={budget / 2**20:.0f} "
                 f"opt_mem_mib={tight.opt_mem_bytes / 2**20:.1f}")


def _measured():
    from repro.core import PlanExecutor, ShardLayout, SyncConfig
    from repro.core.grad_sync import sharded_plan_from_config
    from repro.data import DataConfig, SyntheticPipeline
    from repro.launch.mesh import data_axes, make_host_mesh
    from repro.launch.steps import (_make_synced_train_step,
                                    make_sharded_train_step)
    from repro.optim import make_optimizer, make_sharded_optimizer

    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    axes = data_axes(mesh)
    world = 1
    for a in axes:
        world *= mesh.shape[a]
    arch = "xlstm-125m"
    cfg = reduced(get_config(arch))
    from repro.models import Model
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=2 * world))
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    rng = jax.random.PRNGKey(1)
    step_i = jnp.zeros((), jnp.int32)
    plan_ = sharded_plan_from_config(SyncConfig(), params)

    opt = make_optimizer(OPT, lr=1e-3)
    step_fn, _, init_ss = _make_synced_train_step(
        model, opt, PlanExecutor(plan_, axes), mesh, axes)
    opt_state, sync_state = opt.init(params), init_ss(params)
    jit_r = jax.jit(step_fn)
    us_r = time_fn(lambda: jit_r(params, opt_state, sync_state, batch,
                                 step_i, rng), iters=5, warmup=1)
    rep_bytes = sum(np.asarray(x).nbytes
                    for x in jax.tree.leaves(opt_state))
    emit(f"sharded/measured/{arch}/replicated", us_r,
         f"world={world} opt_bytes={rep_bytes}")

    axis_sizes = tuple(mesh.shape[a] for a in axes)
    layout = ShardLayout.from_plan(plan_, params, axis_sizes)
    shopt = make_sharded_optimizer(OPT, layout, axes, lr=1e-3)
    sfn, init_rows, init_ss2 = make_sharded_train_step(
        model, PlanExecutor(plan_, axes), layout, shopt, mesh, axes)
    rows, sync_state2 = init_rows(params), init_ss2(params)
    jit_s = jax.jit(sfn)
    us_s = time_fn(lambda: jit_s(params, rows, sync_state2, batch,
                                 step_i, rng), iters=5, warmup=1)
    # exact per-worker bytes of the partitioned arrays (master + moments)
    shard_bytes = sum(np.asarray(x).nbytes
                      for x in jax.tree.leaves(rows)) // world
    emit(f"sharded/measured/{arch}/sharded", us_s,
         f"world={world} opt_bytes_per_worker={shard_bytes} "
         f"overhead_vs_replicated={us_s / us_r:.2f}x")


def run():
    _modeled()
    _measured()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
