"""Paper Fig. 10-12 + §4.1 — allreduce algorithm comparison.

(a) the alpha-beta cost model across p and message size (ring vs tree/PS vs
hierarchical vs 2D-mesh — Tables/figures 10-12's shapes), (b) MEASURED
wall times of our ppermute implementations on an 8-device host mesh, run in
a subprocess so this process keeps its 1-device view, and (c) the
PER-BUCKET {compress, permute, decompress} breakdown of the fused
compressed wires (DESIGN.md §11) — fused one-pass kernels vs the
decomposed op chain, per wire × bucket size.

Standalone invocation can additionally record the measured compression
cost table the planner consumes (``plan_auto(compression_costs=...)``)::

    PYTHONPATH=src python -m benchmarks.bench_collectives \
        --write-compression-costs artifacts/compression_costs.json
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from benchmarks.common import LINK_PRESETS, emit
from repro.core.collectives import allreduce_cost_s

MEASURE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
import repro.compat  # AxisType/shard_map shims on old JAX
from jax.sharding import PartitionSpec as P, AxisType
from repro.core.collectives import allreduce

def median_us(f, *args):
    jax.block_until_ready(f(*args))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(f(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[2] * 1e6

mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1 << 20))
for algo in ("psum", "ring", "tree", "hierarchical", "ring_fused"):
    f = jax.jit(jax.shard_map(lambda v: allreduce(v, algo, ("data",)),
                mesh=mesh, in_specs=P("data", None), out_specs=P(None),
                axis_names={"data"}, check_vma=False))
    print(f"MEASURED,{algo},{median_us(f, x):.1f}")
# the fused int8 gather wire's permute phase: all-gather of the (q int8,
# per-tile f32 scales) payload — the wire grad_sync actually moves for the
# int8_fused gather pattern (a quarter of the dense bytes + scales)
q = jnp.zeros((8, 1 << 20), jnp.int8)
sc = jnp.ones((8, (1 << 20) // 1024), jnp.float32)
g = jax.jit(jax.shard_map(
    lambda a, b: (jax.lax.all_gather(a, "data"),
                  jax.lax.all_gather(b, "data")),
    mesh=mesh, in_specs=(P("data", None), P("data", None)),
    out_specs=(P(None), P(None)), axis_names={"data"}, check_vma=False))
print(f"MEASURED,gather_int8_payload,{median_us(g, q, sc):.1f}")
"""

# Bucket sizes of the kernel breakdown (f32 elements): 1 MiB shows the
# cache-resident regime (below the LLC the decomposed chain's extra
# passes are nearly free on CPU backends and can even win — the off-TPU
# gap DESIGN.md §11 documents); 32 MiB is the planner's DEFAULT bucket
# size, above the LLC, where one-pass fusion wins on every backend and
# scripts/bench_ci.py gates the ratio.
KERNEL_SIZES = ((1 << 18, "1MiB"), (1 << 23, "32MiB"))
KERNEL_WORLD = 8


def _best_us(fn, *args, repeats: int = 5) -> float:
    import jax
    jax.block_until_ready(fn(*args))          # compile / warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def fused_wire_breakdown():
    """Rows ``fig10/kernels/<wire>/<size>/<stage>``: fused one-pass kernels
    vs the decomposed chain (one jitted op per stage, every intermediate
    materialized — the multi-pass HBM traffic the fusion removes)."""
    import jax
    import jax.numpy as jnp

    from repro.core.compression import get_compressor
    from repro.kernels import ops
    from repro.kernels import ref as kref

    tile = ops.TILE
    add = jax.jit(jnp.add)
    sub = jax.jit(jnp.subtract)
    quant = jax.jit(lambda c: kref.quantize_tiles_ref(c, tile=tile))
    deq = jax.jit(lambda q, s: kref.dequantize_ref(q, s, tile=tile))
    mask = jax.jit(lambda c: kref.topk_mask_bisect_ref(c, ratio=0.01,
                                                       tile=tile, iters=16))
    i8 = get_compressor("int8_fused")
    tk = get_compressor("topk_fused")
    f_enc_i8 = jax.jit(lambda g, e: i8.fused_ef_compress(g, e, 1.0))
    f_enc_tk = jax.jit(lambda g, e: tk.fused_ef_compress(g, e, 1.0))

    for n, tag in KERNEL_SIZES:
        g = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
        e = jnp.zeros_like(g)

        def unfused_enc_i8(g, e):
            c = add(g, e)
            q, s = quant(c)
            return q, s, sub(c, deq(q, s))

        def unfused_enc_tk(g, e):
            c = add(g, e)
            y = mask(c)
            return y, sub(c, y)

        fu = _best_us(f_enc_i8, g, e)
        uu = _best_us(unfused_enc_i8, g, e)
        emit(f"fig10/kernels/int8_fused/{tag}/compress", fu,
             f"one-pass quantize+pack+EF; decomposed {uu:.1f}us "
             f"(x{uu / fu:.2f})")
        fu = _best_us(f_enc_tk, g, e)
        uu = _best_us(unfused_enc_tk, g, e)
        emit(f"fig10/kernels/topk_fused/{tag}/compress", fu,
             f"one-pass bisect-topk+EF; decomposed {uu:.1f}us "
             f"(x{uu / fu:.2f})")

        (q1, s1), meta, _ = i8.fused_ef_compress(g, e, 1.0)
        qg = jnp.stack([q1] * KERNEL_WORLD)
        sg = jnp.stack([s1] * KERNEL_WORLD)
        f_dec = jax.jit(lambda q, s: i8.fused_decode_sum((q, s), meta))

        def unfused_dec(q, s):
            acc = jnp.zeros((n,), jnp.float32)
            for w in range(KERNEL_WORLD):
                acc = add(acc, deq(q[w], s[w]))
            return acc

        fu = _best_us(f_dec, qg, sg)
        uu = _best_us(unfused_dec, qg, sg)
        emit(f"fig10/kernels/int8_fused/{tag}/decompress", fu,
             f"one-pass dequant+accum x{KERNEL_WORLD} payloads; "
             f"decomposed {uu:.1f}us (x{uu / fu:.2f})")


def run():
    link = LINK_PRESETS["fast_ici"]
    for p in (16, 256, 512):
        for nbytes, tag in ((1e4, "10KB"), (1e8, "100MB")):
            for algo in ("ring", "tree", "hierarchical", "mesh2d",
                         "mesh2d_split"):
                t = allreduce_cost_s(algo, nbytes, p, link)
                emit(f"fig10/{algo}/p{p}/{tag}", t * 1e6,
                     f"alpha-beta model")
    fused_wire_breakdown()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", MEASURE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    for line in res.stdout.splitlines():
        if line.startswith("MEASURED,"):
            _, algo, us = line.split(",")
            what = ("int8+scales payload permute" if algo ==
                    "gather_int8_payload" else "4MiB allreduce")
            emit(f"fig10/measured_8dev/{algo}", float(us), what)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write-compression-costs", default="", metavar="PATH",
                    help="measure per-compressor encode/decode fits "
                         "(schedule/calibration.py) and record the cost "
                         "table the planner consumes "
                         "(train --compression-costs PATH)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run()
    if args.write_compression_costs:
        from repro.core.schedule import measure_compression_costs
        table = measure_compression_costs()
        os.makedirs(os.path.dirname(
            os.path.abspath(args.write_compression_costs)), exist_ok=True)
        table.save(args.write_compression_costs)
        print(f"compression cost table written: "
              f"{args.write_compression_costs} "
              f"({len(table.entries)} stage fits)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
