"""Paper Fig. 10-12 + §4.1 — allreduce algorithm comparison.

(a) the alpha-beta cost model across p and message size (ring vs tree/PS vs
hierarchical vs 2D-mesh — Tables/figures 10-12's shapes), and (b) MEASURED
wall times of our ppermute implementations on an 8-device host mesh, run in
a subprocess so this process keeps its 1-device view."""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import LINK_PRESETS, emit
from repro.core.collectives import allreduce_cost_s

MEASURE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax, jax.numpy as jnp
import repro.compat  # AxisType/shard_map shims on old JAX
from jax.sharding import PartitionSpec as P, AxisType
from repro.core.collectives import allreduce
mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1 << 20))
for algo in ("psum", "ring", "tree", "hierarchical"):
    f = jax.jit(jax.shard_map(lambda v: allreduce(v, algo, ("data",)),
                mesh=mesh, in_specs=P("data", None), out_specs=P(None),
                axis_names={"data"}, check_vma=False))
    jax.block_until_ready(f(x))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(f(x))
        ts.append(time.perf_counter() - t0)
    print(f"MEASURED,{algo},{sorted(ts)[2]*1e6:.1f}")
"""


def run():
    link = LINK_PRESETS["fast_ici"]
    for p in (16, 256, 512):
        for nbytes, tag in ((1e4, "10KB"), (1e8, "100MB")):
            for algo in ("ring", "tree", "hierarchical", "mesh2d",
                         "mesh2d_split"):
                t = allreduce_cost_s(algo, nbytes, p, link)
                emit(f"fig10/{algo}/p{p}/{tag}", t * 1e6,
                     f"alpha-beta model")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", MEASURE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    for line in res.stdout.splitlines():
        if line.startswith("MEASURED,"):
            _, algo, us = line.split(",")
            emit(f"fig10/measured_8dev/{algo}", float(us), "4MiB allreduce")
