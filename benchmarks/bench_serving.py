"""Serving engine: continuous vs static batching (DESIGN.md §12).

Two views, both emitted as ``name,us_per_call,derived`` rows:

  * ``serving/measured/...`` — on reduced gemma-2b, a bimodal trace
    (mostly short generations, occasional long ones) run through the
    continuous-batching engine and the static FCFS-batch baseline with
    compilation warmed out of both.  Asserted acceptance criteria:
    continuous delivers >= 1.5x the tokens/s of static at
    equal-or-better p99 per-token latency — continuous batching retires
    short rows early and backfills the freed slots, while static decodes
    every batch to its longest member.

  * ``serving/modeled/...`` — the planner's tp x tier x replicas search
    (``plan_serving``) for full-size gemma-2b on the two_tier_pod
    topology: per-arm decode step time and aggregate tokens/s, plus the
    latency-budgeted choice flipping from pure replication to TP on the
    fast tier.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.core.schedule import (TOPOLOGY_PRESETS, Topology, plan_serving)
from repro.models import Model
from repro.models.model import count_params
from repro.serve import Engine, Request, ServeConfig, run_static
from repro.serve.engine import latency_summary, static_compiled

ARCH = "gemma-2b"
MAX_BATCH = 4
PROMPT = 8
MAX_LEN = 32
PAGE = 8
N_REQ = 16
# bimodal generation lengths, mostly short: a long request in a static
# batch makes every row pay its padding tax; Poisson arrivals (~2 ms mean
# interarrival, on the order of one decode tick) keep the queue fed.  The
# seed is part of the committed benchmark definition — the ratio depends
# on where the long requests land in the trace (a tail of longs hurts
# both schedulers alike), so CI gates one fixed representative trace.
GENS = (4, 4, 24)
MEAN_ARRIVAL_S = 2e-3
TRACE_SEED = 2


def _trace(vocab, seed=TRACE_SEED):
    from repro.serve.engine import poisson_trace
    return poisson_trace(N_REQ, MEAN_ARRIVAL_S, PROMPT, GENS, vocab,
                         seed=seed)


def _shift(reqs, t0):
    return [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                    arrival_s=r.arrival_s + t0) for r in reqs]


def _measured():
    cfg = reduced(get_config(ARCH))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    warm = _trace(cfg.vocab_size, seed=1)[:2]

    eng = Engine(model, params, ServeConfig(
        max_batch=MAX_BATCH, max_len=MAX_LEN, page_size=PAGE))
    eng.run(warm)                               # compile out of the loop
    cont = latency_summary(eng.run(_shift(_trace(cfg.vocab_size),
                                          eng.clock.now())))

    jits = static_compiled(model)
    from repro.serve.engine import Clock
    clock = Clock()
    run_static(model, params, warm, MAX_BATCH, MAX_LEN, clock=clock,
               compiled=jits)
    stat = latency_summary(run_static(
        model, params, _shift(_trace(cfg.vocab_size), clock.now()),
        MAX_BATCH, MAX_LEN, clock=clock, compiled=jits))

    for tag, s in (("continuous", cont), ("static", stat)):
        emit(f"serving/measured/{ARCH}/{tag}", s["makespan_s"] * 1e6,
             f"tokens_per_s={s['tokens_per_s']:.1f} "
             f"p50_ms={s['p50_s'] * 1e3:.2f} p99_ms={s['p99_s'] * 1e3:.2f}")
    ratio = cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-12)
    emit(f"serving/measured/{ARCH}/speedup", 0.0,
         f"continuous_over_static={ratio:.2f}")
    assert cont["tokens"] == stat["tokens"], "same trace, same tokens"
    assert ratio >= 1.5, \
        f"continuous only {ratio:.2f}x static (need >= 1.5x)"
    assert cont["p99_s"] <= stat["p99_s"], \
        (f"continuous p99 {cont['p99_s'] * 1e3:.2f} ms worse than static "
         f"{stat['p99_s'] * 1e3:.2f} ms")


def _modeled():
    cfg = get_config(ARCH)
    pb = count_params(cfg) * 2.0
    net = Topology.from_spec(TOPOLOGY_PRESETS["two_tier_pod"])
    best, arms = plan_serving(net, net.world, pb, cfg.num_layers,
                              cfg.d_model, batch=8)
    for a in sorted(arms, key=lambda a: -a.tokens_per_s):
        mark = "<- best" if a.key() == best.key() else ""
        emit(f"serving/modeled/{ARCH}/two_tier_pod/{a.key()}",
             a.step_s * 1e6, f"tokens_per_s={a.tokens_per_s:.0f} {mark}")
    budgeted, _ = plan_serving(net, net.world, pb, cfg.num_layers,
                               cfg.d_model, batch=8,
                               latency_budget_s=best.step_s / 3)
    emit(f"serving/modeled/{ARCH}/two_tier_pod/budgeted",
         budgeted.step_s * 1e6,
         f"arm={budgeted.key()} budget={best.step_s / 3 * 1e3:.3f}ms")
    assert budgeted.tp > 1, "a tight latency budget must force TP"
    assert "device" in (budgeted.tp_tier or "device"), \
        "TP collectives belong on the fast tier"


def run() -> None:
    t0 = time.time()
    _modeled()
    _measured()
    emit("serving/bench_wall_s", (time.time() - t0) * 1e6, "")
