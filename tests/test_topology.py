"""Topology-first network model (core/schedule/topology.py, ISSUE 5).

Covers: spec parsing/presets, the FLAT REGRESSION PINS (Topology.flat and
bare LinkParams must reproduce the pre-redesign cost model bit-for-bit,
for every algorithm — this is what keeps the committed benchmark
baselines green), tiered per-phase pricing, the axis-placement
primitives, the tree-candidate self-filter on non-power-of-two worlds,
and the acceptance criterion: on the two-tier network the planner's pick
is tier-aware and strictly beats the best flat-ring arm.
"""
import numpy as np
import pytest

from repro.core.schedule import (LINK_PRESETS, LayerProfile, LinkParams,
                                 PipelineAxis, Topology, allreduce_cost_s,
                                 allgather_cost_s, bucket_sync_cost_s,
                                 bucket_sync_phases, p2p_cost_s, plan,
                                 plan_rounds, reduce_scatter_cost_s,
                                 serial_round_plan)
from repro.core.schedule.planner import (DEFAULT_CANDIDATES, Candidate,
                                         pipeline_placements)
from repro.core.schedule.topology import TOPOLOGY_PRESETS, as_topology

ALGOS = ("ring", "psum", "tree", "hierarchical", "mesh2d", "mesh2d_split")
TWO_TIER = "node:4@datacenter,device:8@fast_ici"


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

def test_from_spec_and_presets():
    t = Topology.from_spec(TWO_TIER)
    assert t.world == 32 and t.n_tiers == 2 and not t.is_flat
    assert t.outermost.name == "node" and t.innermost.name == "device"
    assert t.inner_size == 8
    assert t.spec() == TWO_TIER          # round-trips through preset names
    assert t == Topology.from_spec(t.spec())
    for name in TOPOLOGY_PRESETS:        # every preset parses and its
        p = Topology.from_spec(name)     # links join LINK_PRESETS
        assert p.world > 1
        for tier in p.tiers:
            assert tier.link is LINK_PRESETS[tier.link_name]


def test_from_spec_rejects_garbage():
    with pytest.raises(ValueError, match="name:size@link"):
        Topology.from_spec("node4datacenter")
    with pytest.raises(ValueError, match="unknown link preset"):
        Topology.from_spec("node:4@warp_drive")
    with pytest.raises(ValueError, match="duplicate tier"):
        Topology.from_spec("a:2@fast_ici,a:2@fast_ici")
    with pytest.raises(ValueError, match="at least one tier"):
        Topology(())


def test_as_topology_world_mismatch_raises():
    t = Topology.from_spec(TWO_TIER)
    assert as_topology(t, 32) is t
    with pytest.raises(ValueError, match="world"):
        as_topology(t, 256)
    flat = as_topology(LINK_PRESETS["fast_ici"], 8)
    assert flat.is_flat and flat.world == 8


# ---------------------------------------------------------------------------
# Flat regression pins (satellite: pre-redesign values, all algos)
# ---------------------------------------------------------------------------

def _old_allreduce(algo, n, p, link, k=None):
    """The pre-topology closed forms, re-typed verbatim as the pin."""
    a, b = link.alpha_s, link.beta_s_per_byte
    if p <= 1:
        return 0.0
    if algo in ("ring", "psum"):
        return 2 * (p - 1) * (a + (n / p) * b)
    if algo == "tree":
        return 2 * np.log2(p) * (a + n * b)
    if algo == "hierarchical":
        k = k or int(np.sqrt(p))
        inner = 2 * (k - 1) * (a + (n / k) * b)
        outer = 2 * (p // k - 1) * (a + (n / k / (p // k)) * b)
        return inner + outer + 2 * (k - 1) * a
    px = int(np.sqrt(p))
    py = p // px
    t = (2 * (px - 1) * (a + (n / px) * b)
         + 2 * (py - 1) * (a + (n / px / py) * b))
    return t / (2 if algo == "mesh2d_split" else 1)


@pytest.mark.parametrize("preset", sorted(LINK_PRESETS))
@pytest.mark.parametrize("p", [2, 6, 8, 32, 256])
def test_flat_topology_pins_pre_redesign_costs(preset, p):
    link = LINK_PRESETS[preset]
    flat = Topology.flat(p, link)
    for n in (512.0, 64 * 1024.0, 4 * 2**20, 137 * 2**20 + 123):
        for algo in ALGOS:
            want = _old_allreduce(algo, n, p, link)
            assert allreduce_cost_s(algo, n, p, link) == want
            assert allreduce_cost_s(algo, n, p, flat) == want
        # p2p / gather / reduce-scatter pins
        a, b = link.alpha_s, link.beta_s_per_byte
        assert p2p_cost_s(n, link) == a + n * b
        assert p2p_cost_s(n, flat) == a + n * b
        assert allgather_cost_s(n, p, flat) == (p - 1) * (a + n * b)
        assert reduce_scatter_cost_s("tree", n, p, flat) == \
            _old_allreduce("ring", n, p, link) / 2.0
        # the full bucket metric, dense and compressed
        for comp, args in (("none", ()), ("int8", ()), ("sign", ()),
                           ("topk", (("ratio", 0.01),))):
            assert bucket_sync_cost_s(comp, args, "ring", n, p, link) == \
                bucket_sync_cost_s(comp, args, "ring", n, p, flat)


def test_flat_plans_identical_to_linkparams_plans():
    """The whole search, not just the primitives: planning on
    Topology.flat returns the same buckets and the same modeled time as
    planning on the bare LinkParams."""
    profs = [LayerProfile(2e-4, 4 * 2**20) for _ in range(12)]
    for preset in ("fast_ici", "commodity"):
        link = LINK_PRESETS[preset]
        a = plan(profs, link, 64)
        b = plan(profs, Topology.flat(64, link), 64)
        assert a.modeled_step_s == b.modeled_step_s
        assert [(x.leaves, x.algo, x.compressor) for x in a.buckets] == \
            [(x.leaves, x.algo, x.compressor) for x in b.buckets]


# ---------------------------------------------------------------------------
# Tiered pricing
# ---------------------------------------------------------------------------

def test_ring_is_gated_by_the_bottleneck_tier():
    """A flat ring across a tiered network pays the slow fabric every
    lockstep step (Zhang et al. 2020): its cost equals the ring priced on
    the slow link alone."""
    topo = Topology.from_spec(TWO_TIER)
    slow = LINK_PRESETS["datacenter"]
    n = 64 * 2**20
    assert allreduce_cost_s("ring", n, 32, topo) == \
        allreduce_cost_s("ring", n, 32, slow)


def test_hierarchical_moves_bandwidth_to_the_fast_tier():
    """On the two-tier network, hierarchical's inner phase runs on the
    fast tier and the slow tier only carries the 1/k shard — so it beats
    the flat ring for bandwidth-bound sizes, and its slow-tier phase cost
    is the outer ring of the shard."""
    topo = Topology.from_spec(TWO_TIER)
    n = 256 * 2**20
    hier = allreduce_cost_s("hierarchical", n, 32, topo)
    ring = allreduce_cost_s("ring", n, 32, topo)
    assert hier < ring
    phases = dict()
    for name, s in bucket_sync_phases("none", (), "hierarchical", n, 32,
                                      topo):
        phases[name] = phases.get(name, 0.0) + s
    assert set(phases) == {"node", "device"}
    # slow-tier traffic is the n/k shard over the 4 nodes
    slow = LINK_PRESETS["datacenter"]
    k = 8
    want = 2 * (4 - 1) * (slow.alpha_s + (n / k / 4) * slow.beta_s_per_byte)
    assert phases["node"] == want


def test_phases_sum_to_totals():
    topo = Topology.from_spec(TWO_TIER)
    for algo in ALGOS:
        for comp, args in (("none", ()), ("int8", ()),
                           ("topk", (("ratio", 0.01),))):
            for shard in (False, True):
                total = bucket_sync_cost_s(comp, args, algo, 8 * 2**20, 32,
                                           topo, shard_state=shard)
                parts = sum(s for _, s in bucket_sync_phases(
                    comp, args, algo, 8 * 2**20, 32, topo,
                    shard_state=shard))
                assert abs(total - parts) <= 1e-12 * max(total, 1.0), \
                    (algo, comp, shard)


def test_three_tier_hierarchical_prices_every_tier():
    """A 3-tier network: the n/k shard rings over BOTH outer tiers (the
    middle tier must not be silently priced at the fast link), and
    mesh2d — a two-axis collective — is rejected by pricing and filtered
    by the planner."""
    from repro.core.schedule.planner import _algo_usable
    topo = Topology.from_spec(
        "pod:2@datacenter,node:4@commodity,device:8@fast_ici")
    n = 64 * 2**20
    names = [nm for nm, _ in bucket_sync_phases("none", (), "hierarchical",
                                                n, 64, topo)]
    assert set(names) == {"pod", "node", "device"}
    # the middle (commodity) ring of the n/8 shard, priced on ITS link
    phases = dict()
    for nm, s in bucket_sync_phases("none", (), "hierarchical", n, 64,
                                    topo):
        phases[nm] = phases.get(nm, 0.0) + s
    mid = LINK_PRESETS["commodity"]
    want = 2 * (4 - 1) * (mid.alpha_s + (n / 8 / 4) * mid.beta_s_per_byte)
    assert phases["node"] == want
    with pytest.raises(ValueError, match="two-axis"):
        allreduce_cost_s("mesh2d", n, 64, topo)
    assert not _algo_usable("mesh2d", 64, topo)
    assert _algo_usable("mesh2d", 64, LINK_PRESETS["fast_ici"])
    # the full search runs clean on 3 tiers (mesh2d/tree filtered as needed)
    profs = [LayerProfile(2e-4, 8 * 2**20) for _ in range(8)]
    p = plan(profs, topo, 64)
    assert all(b.algo not in ("mesh2d", "mesh2d_split") for b in p.buckets)


def test_homogeneous_two_tier_ties_flat_ring():
    link = LINK_PRESETS["fast_ici"]
    homo = Topology.from_spec("node:4@fast_ici,device:8@fast_ici")
    flat = Topology.flat(32, link)
    n = 32 * 2**20
    assert allreduce_cost_s("ring", n, 32, homo) == \
        allreduce_cost_s("ring", n, 32, flat)
    assert allreduce_cost_s("tree", n, 32, homo) == pytest.approx(
        allreduce_cost_s("tree", n, 32, flat), rel=1e-12)


# ---------------------------------------------------------------------------
# Axis placement
# ---------------------------------------------------------------------------

def test_place_consumes_a_tier():
    topo = Topology.from_spec(TWO_TIER)
    placed, rest = topo.place(4, 0)          # pipe across all 4 nodes
    assert placed.size == 4 and placed.link is LINK_PRESETS["datacenter"]
    assert rest.spec() == "device:8@fast_ici" and rest.world == 8
    placed, rest = topo.place(2, 1)          # pipe inside the node
    assert placed.link is LINK_PRESETS["fast_ici"]
    assert rest.world == 16 and rest.tiers[1].size == 4
    with pytest.raises(ValueError, match="does not divide"):
        topo.place(3, 0)


def test_pipeline_placements_flat_and_tiered():
    link = LINK_PRESETS["commodity"]
    flat = pipeline_placements(link, 32, 4)
    assert flat == [("", link, link)]        # the historical single arm
    topo = Topology.from_spec(TWO_TIER)
    named = {p[0]: p for p in pipeline_placements(topo, 32, 4)}
    assert set(named) == {"node", "device"}  # S=4 fits either tier
    name, dp_net, p2p_net = named["node"]
    assert p2p_net is LINK_PRESETS["datacenter"]
    assert dp_net.spec() == "device:8@fast_ici"
    # S=8 only fits the device tier; S=3 fits none
    assert [p[0] for p in pipeline_placements(topo, 32, 8)] == ["device"]
    assert pipeline_placements(topo, 32, 3) == []


# ---------------------------------------------------------------------------
# Tree self-filter (satellite)
# ---------------------------------------------------------------------------

def test_tree_candidates_self_filter_on_non_pow2_worlds():
    profs = [LayerProfile(2e-4, 4 * 2**20) for _ in range(8)]
    for net, world in ((LINK_PRESETS["commodity"], 6),
                       (Topology.from_spec("node:3@datacenter,"
                                           "device:2@fast_ici"), 6)):
        p = plan(profs, net, world)
        assert all(b.algo != "tree" for b in p.buckets), (net, world)
        rp = serial_round_plan(profs, net, world)
        assert all(b.algo != "tree" for b in rp.buckets), (net, world)
    # power-of-two worlds keep tree in the pool (it can win on latency)
    small = [LayerProfile(1e-6, 256.0) for _ in range(4)]
    p = plan(small, LINK_PRESETS["commodity"], 64)
    assert p.modeled_step_s > 0  # tree allowed — search just must not crash
    with pytest.raises(ValueError, match="no candidate"):
        plan(profs, LINK_PRESETS["commodity"], 6,
             candidates=[Candidate("none", (), "tree")])


def test_tree_collective_raises_value_error_not_assert():
    """The executed guard survives ``python -O`` (a bare assert would
    not): the source must raise ValueError."""
    import inspect

    from repro.core.collectives import tree
    src = inspect.getsource(tree.tree_reduce_to_root)
    assert "raise ValueError" in src
    assert "\n    assert p" not in src


# ---------------------------------------------------------------------------
# Acceptance: tier-aware arms win on tiered networks
# ---------------------------------------------------------------------------

def test_plan_rounds_picks_tier_aware_arm_on_two_tier_network():
    topo = Topology.from_spec(TWO_TIER)
    # a heavy model on a modest backward: communication-dominated
    profs = [LayerProfile(5e-4, 64 * 2**20) for _ in range(24)]
    pa = PipelineAxis(global_tokens=4096.0 * 32, bytes_per_token=4096.0)
    ring_only = tuple(c for c in DEFAULT_CANDIDATES
                      if c.algo in ("ring", "psum"))
    flat_ring = plan(profs, topo, 32, candidates=ring_only)
    best, arms = plan_rounds(profs, topo, 32, tau_grid=(1,), pipeline=pa)
    assert best.modeled_step_s < flat_ring.modeled_step_s
    if best.pipeline_stages > 1:
        assert best.pipe_tier in ("node", "device")
    else:
        assert any(b.algo in ("hierarchical", "mesh2d", "mesh2d_split")
                   for b in best.comm.buckets)
    # the every-step arm alone is tier-aware -- or, since PR 6, takes the
    # fused compressed ring that moves ~4x fewer bytes over the slow tier;
    # with dense wires only, hierarchical must still win the arm
    assert any(b.algo in ("hierarchical", "mesh2d", "mesh2d_split",
                          "ring_fused")
               for b in arms["every_step"].comm.buckets)
    dense_only = tuple(c for c in DEFAULT_CANDIDATES
                       if c.compressor == "none")
    _, arms_d = plan_rounds(profs, topo, 32, tau_grid=(1,), pipeline=pa,
                            candidates=dense_only)
    assert any(b.algo == "hierarchical"
               for b in arms_d["every_step"].comm.buckets)


def test_plan_rounds_world_must_match_topology():
    topo = Topology.from_spec(TWO_TIER)
    profs = [LayerProfile(2e-4, 2**20) for _ in range(8)]
    with pytest.raises(ValueError, match="world"):
        plan_rounds(profs, topo, 256)


# ---------------------------------------------------------------------------
# Session integration: --plan-world deprecation, report, records
# ---------------------------------------------------------------------------

def test_plan_auto_prefers_topology_over_plan_world(capsys):
    from repro.api import SessionConfig, TrainSession
    sess = TrainSession(SessionConfig(arch="xlstm-125m", reduced=True,
                                      batch=2, seq=16, steps=4))
    sp = sess.plan_auto(topology=TWO_TIER, plan_world=999,
                        t_backward_s=0.02)
    out = capsys.readouterr().out
    assert "disagrees with the topology" in out
    assert "deprecated" in out
    # arm worlds: 32 (dp), world/S (pipe), world/tp / world/ep (model axes)
    assert sess.planned["strategy_plan"].comm.world in (32, 16, 8, 4)
    # every arm was priced at the topology's world, not 999
    assert all(a.comm.world in (32, 16, 8, 4)
               for a in sess.planned["arms"].values())
    assert sp.modeled_step_s > 0


def test_strategy_plan_report_and_record_carry_tiers(tmp_path, monkeypatch):
    from repro.core.schedule import fixed_config_plan
    from repro.launch import report
    from repro.launch.report import (comm_plan_record, render_comm_plan,
                                     tier_cost_breakdown)
    topo = Topology.from_spec(TWO_TIER)
    profs = [LayerProfile(2e-4, 16 * 2**20) for _ in range(8)]
    cp = fixed_config_plan(profs, topo, 32, "none", "hierarchical")
    txt = render_comm_plan(cp)
    assert "topology node:4" in txt
    assert "tier node" in txt and "tier device" in txt
    rec = comm_plan_record(cp)
    assert rec["topology"]["spec"] == TWO_TIER
    assert set(rec["topology"]["tier_cost_s"]) >= {"node", "device"}
    bd = tier_cost_breakdown(cp)
    assert bd["node"] > 0 and bd["device"] > 0
    # flat records keep the exact pre-topology schema (acceptance)
    flat = fixed_config_plan(profs, LINK_PRESETS["fast_ici"], 32, "none",
                             "ring")
    assert "topology" not in comm_plan_record(flat)
    assert "tier " not in render_comm_plan(flat)
