"""Seeded-determinism regression tests for the synthetic data pipeline
(ISSUE 3 satellite): the conformance suite compares two independently
constructed training runs step by step, which is only meaningful if
``SyntheticPipeline`` is a pure function of (seed, step, host) — same seed
-> identical batches across fresh pipelines and fresh iterators, different
seeds/steps/hosts -> different batches, and host shards partition the
global batch deterministically.
"""
import numpy as np

from repro.data import DataConfig, SyntheticPipeline


def _cfg(**kw):
    base = dict(vocab_size=128, seq_len=32, global_batch=8, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_same_seed_identical_batches_across_fresh_pipelines():
    a, b = SyntheticPipeline(_cfg()), SyntheticPipeline(_cfg())
    for step in range(5):
        ba, bb = a.batch(step), b.batch(step)
        assert sorted(ba) == sorted(bb)
        for k in ba:
            np.testing.assert_array_equal(ba[k], bb[k])


def test_same_seed_identical_batches_across_fresh_iterators():
    pipe = SyntheticPipeline(_cfg())
    first = [b["tokens"].copy() for _, b in zip(range(4), iter(pipe))]
    second = [b["tokens"].copy() for _, b in zip(range(4), iter(pipe))]
    for x, y in zip(first, second):
        np.testing.assert_array_equal(x, y)
    # and the iterator agrees with random access
    for step, x in enumerate(first):
        np.testing.assert_array_equal(x, pipe.batch(step)["tokens"])


def test_different_seed_and_step_differ():
    a = SyntheticPipeline(_cfg())
    b = SyntheticPipeline(_cfg(seed=8))
    assert not np.array_equal(a.batch(0)["tokens"], b.batch(0)["tokens"])
    assert not np.array_equal(a.batch(0)["tokens"], a.batch(1)["tokens"])


def test_host_sharding_is_deterministic_and_seekable():
    """Each host draws its own (seed, step, host_id) stream — resuming
    mid-run on any host must reproduce exactly what that host would have
    seen (the checkpoint-resume contract)."""
    pipe = SyntheticPipeline(_cfg(global_batch=8))
    for step in (0, 3):
        shards = [pipe.batch(step, host_id=h, num_hosts=4) for h in range(4)]
        for s in shards:
            assert s["tokens"].shape == (2, 32)
        again = [pipe.batch(step, host_id=h, num_hosts=4) for h in range(4)]
        for s, t in zip(shards, again):
            np.testing.assert_array_equal(s["tokens"], t["tokens"])
        # hosts must not see each other's rows
        for h in range(1, 4):
            assert not np.array_equal(shards[0]["tokens"],
                                      shards[h]["tokens"])


def test_embedding_stream_is_deterministic():
    cfg = _cfg(embedding_dim=16)
    a, b = SyntheticPipeline(cfg), SyntheticPipeline(cfg)
    for step in range(3):
        np.testing.assert_array_equal(a.batch(step)["src"],
                                      b.batch(step)["src"])
