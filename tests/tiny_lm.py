"""Tiny embedding+linear LM, duck-typed like ``repro.models.Model`` (has
``loss(params, batch)`` over {'tokens': (B, T)}): the conformance suite's
workhorse — big enough to fuse into multiple buckets, small enough that a
strategy × wire × mode sweep trains in seconds.  Shared by
test_conformance.py and the multi_device_checks.py subprocess.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class TinyLM:
    def __init__(self, vocab: int = 64, d: int = 16):
        self.vocab, self.d = vocab, d

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {"emb": jax.random.normal(k1, (self.vocab, self.d)) * 0.1,
                "out": jax.random.normal(k2, (self.d, self.vocab)) * 0.1,
                "b": jnp.zeros((self.vocab,))}

    def loss(self, params, batch):
        toks = batch["tokens"]
        x = params["emb"][toks[:, :-1]]
        logits = x @ params["out"] + params["b"]
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, toks[:, 1:][..., None], -1))


def tiny_batch(step: int, batch: int = 8, seq: int = 16, vocab: int = 64):
    return {"tokens": jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(42), step),
        (batch, seq), 0, vocab)}


class TinyStackLM:
    """TinyLM with a homogeneous stack of residual MLP blocks — the
    pipeline conformance workhorse.  Exposes BOTH surfaces:

      * ``loss(params, batch)`` — the single-program reference path;
      * the staged surface ``make_pipeline_train_step`` consumes
        (``layout`` / ``split`` / ``merge`` / ``embed_mb`` /
        ``stage_apply`` / ``loss_tail`` / ``aux_coef``), with blocks
        stored stacked ``(R, ...)`` and cut into ``n_stages`` row groups.

    ``loss`` is by construction the composition
    ``loss_tail(shared, stage_apply(all rows, embed_mb(...)), tokens)`` so
    the S=1 pipeline step computes the same math.
    """

    def __init__(self, vocab: int = 64, d: int = 16, hidden: int = 32,
                 blocks: int = 4, n_stages: int = 1):
        from repro.core.pipeline import StageLayout
        if blocks % n_stages:
            raise ValueError((blocks, n_stages))
        self.vocab, self.d, self.hidden = vocab, d, hidden
        self.layout = StageLayout(n_stages=n_stages, rows=blocks,
                                  rows_per_stage=blocks // n_stages)
        self.aux_coef = 0.0

    def init(self, rng):
        ks = jax.random.split(rng, 4)
        R, d, h = self.layout.rows, self.d, self.hidden
        return {
            "emb": jax.random.normal(ks[0], (self.vocab, d)) * 0.1,
            "blocks": {
                "w1": jax.random.normal(ks[1], (R, d, h)) * 0.3,
                "b1": jnp.zeros((R, h)),
                "w2": jax.random.normal(ks[2], (R, h, d)) * 0.3,
            },
            "out": jax.random.normal(ks[3], (d, self.vocab)) * 0.1,
            "b": jnp.zeros((self.vocab,)),
        }

    # -- staged surface ------------------------------------------------------

    def split(self, params):
        S, rps = self.layout.n_stages, self.layout.rows_per_stage
        shared = {k: v for k, v in params.items() if k != "blocks"}
        rows = jax.tree.map(
            lambda x: x.reshape((S, rps) + x.shape[1:]), params["blocks"])
        return shared, rows

    def merge(self, shared, rows_stacked):
        R = self.layout.rows
        out = dict(shared)
        out["blocks"] = jax.tree.map(
            lambda x: x.reshape((R,) + x.shape[2:]), rows_stacked)
        return out

    def embed_mb(self, shared, tokens):
        return shared["emb"][tokens[:, :-1]]

    def stage_apply(self, rows, h):
        for i in range(self.layout.rows_per_stage):
            w1, b1, w2 = rows["w1"][i], rows["b1"][i], rows["w2"][i]
            # row-boundary barrier: keeps XLA fusion from crossing cut
            # points, so the rows' subgraphs (and their backward) compile
            # identically whether a ppermute sits between them or not —
            # the stage-count bit-exactness contract (DESIGN.md §9)
            h = jax.lax.optimization_barrier(
                h + jnp.tanh(h @ w1 + b1) @ w2)
        return h, jnp.zeros((), jnp.float32)

    def loss_tail(self, shared, h, tokens):
        logits = h @ shared["out"] + shared["b"]
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(
            lp, tokens[:, 1:][..., None], -1))

    # -- reference single-program path --------------------------------------

    def loss(self, params, batch):
        shared, rows = self.split(params)
        rows = jax.tree.map(
            lambda x: x.reshape((self.layout.rows,) + x.shape[2:]), rows)
        h = self.embed_mb(shared, batch["tokens"])
        for i in range(self.layout.rows):
            w1, b1, w2 = rows["w1"][i], rows["b1"][i], rows["w2"][i]
            h = h + jnp.tanh(h @ w1 + b1) @ w2
        return self.loss_tail(shared, h, batch["tokens"])
