"""Tiny embedding+linear LM, duck-typed like ``repro.models.Model`` (has
``loss(params, batch)`` over {'tokens': (B, T)}): the conformance suite's
workhorse — big enough to fuse into multiple buckets, small enough that a
strategy × wire × mode sweep trains in seconds.  Shared by
test_conformance.py and the multi_device_checks.py subprocess.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class TinyLM:
    def __init__(self, vocab: int = 64, d: int = 16):
        self.vocab, self.d = vocab, d

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {"emb": jax.random.normal(k1, (self.vocab, self.d)) * 0.1,
                "out": jax.random.normal(k2, (self.d, self.vocab)) * 0.1,
                "b": jnp.zeros((self.vocab,))}

    def loss(self, params, batch):
        toks = batch["tokens"]
        x = params["emb"][toks[:, :-1]]
        logits = x @ params["out"] + params["b"]
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, toks[:, 1:][..., None], -1))


def tiny_batch(step: int, batch: int = 8, seq: int = 16, vocab: int = 64):
    return {"tokens": jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(42), step),
        (batch, seq), 0, vocab)}
