"""Elastic fault-tolerant runtime (DESIGN.md §15, ISSUE 10).

Covers the three pillars end to end plus their unit surfaces:

  * **conformance**: kill one device per node at step 3 under a seeded
    FaultSchedule, reshard 8→6 through the portable checkpoint WITHOUT a
    process restart, restore the fleet at step 6 — and the full loss
    trajectory must match the unfaulted reference bit for bit (on a
    1-device host the world is a planning model, so the executed math is
    world-independent; any difference is a restore bug);
  * **checkpoint integrity**: atomic temp+rename writes, content
    checksums verified BEFORE deserialization (a truncated real
    checkpoint raises ``ValueError``), legacy manifests still load;
  * **straggler demotion**: per-worker backpressure stretches the
    installed scheduler's cadence (local-SGD τ), and — when the
    scheduler has no cadence lever — escalates to a straggler-priced
    re-plan that INSTALLS the demoted arm (every_step↔local_sgd and
    pinned-LAG swaps, the drift-replan follow-through).
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.api import SessionConfig, TrainSession
from repro.core import SyncStrategy
from repro.core.schedule import (LayerProfile, Topology, plan_rounds,
                                 straggler_penalty_s)
from repro.core.strategy import get_scheduler
from repro.elastic import (ElasticConfig, ElasticRuntime, FaultEvent,
                           FaultSchedule, replay_world_sizes,
                           surviving_topology)

ARCH_KW = dict(arch="xlstm-125m", reduced=True, batch=2, seq=16, seed=0)
TOPO8 = "node:2@datacenter,device:4@fast_ici"


def _factory(steps=10, **kw):
    def make():
        return TrainSession(SessionConfig(steps=steps, **ARCH_KW, **kw))
    return make


# ---------------------------------------------------------------------------
# FaultSchedule: parsing, validation, determinism
# ---------------------------------------------------------------------------

def test_fault_schedule_spec_roundtrip_and_order():
    s = FaultSchedule.from_spec("restore:3@9,kill:3@5,slow:1x4@3", world=8)
    assert [e.describe() for e in s.events] == \
        ["slow:1x4@3", "kill:3@5", "restore:3@9"]
    assert FaultSchedule.from_spec(s.spec(), world=8) == s
    assert s.last_step == 9
    assert [e.kind for e in s.events_at(5)] == ["kill"]
    # JSON round trip (the committed-trace format)
    assert FaultSchedule.from_json(s.to_json()) == s


def test_fault_schedule_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(step=0, worker=0, kind="pause")
    with pytest.raises(ValueError, match="factor must be > 1"):
        FaultEvent(step=0, worker=0, kind="slow", factor=1.0)
    with pytest.raises(ValueError, match="out of range"):
        FaultSchedule.from_spec("kill:8@1", world=8)
    with pytest.raises(ValueError, match="already dead"):
        FaultSchedule.from_spec("kill:1@1,kill:1@2", world=4)
    with pytest.raises(ValueError, match="not dead"):
        FaultSchedule.from_spec("restore:1@1", world=4)
    with pytest.raises(ValueError, match="no survivors"):
        FaultSchedule.from_spec("kill:0@1,kill:1@1", world=2)
    with pytest.raises(ValueError, match="dead worker"):
        FaultSchedule.from_spec("kill:1@1,slow:1x2@2", world=4)
    with pytest.raises(ValueError, match="cannot parse"):
        FaultSchedule.from_spec("kill3@", world=4)


def test_fault_schedule_random_is_seeded():
    a = FaultSchedule.random(world=8, steps=20, n_faults=6, seed=42)
    b = FaultSchedule.random(world=8, steps=20, n_faults=6, seed=42)
    c = FaultSchedule.random(world=8, steps=20, n_faults=6, seed=43)
    assert a == b
    assert a != c                    # overwhelmingly likely with 6 faults
    FaultSchedule(events=a.events, world=8)   # replays valid


def test_replay_world_sizes():
    s = FaultSchedule.from_spec(
        "kill:3@3,kill:7@3,restore:3@6,restore:7@6", world=8)
    sizes, changes = replay_world_sizes(s, 10)
    assert sizes == [8, 8, 8, 6, 6, 6, 8, 8, 8, 8]
    assert changes == [3, 6]


# ---------------------------------------------------------------------------
# surviving_topology
# ---------------------------------------------------------------------------

def test_surviving_topology_shapes():
    topo = Topology.from_spec(TOPO8)
    # uniform partial loss (one device per node): tiered shape survives
    t = surviving_topology(topo, {3, 7})
    assert t.spec() == "node:2@datacenter,device:3@fast_ici"
    # whole group gone: inner stack intact, outer tier dropped
    t = surviving_topology(topo, {4, 5, 6, 7})
    assert t.spec() == "device:4@fast_ici"
    # irregular loss: conservative flat fallback on the SLOWEST link
    t = surviving_topology(topo, {5})
    assert t.is_flat and t.world == 7
    assert t.tiers[0].link_name == "datacenter"
    # no dead -> unchanged; flat topology just shrinks
    assert surviving_topology(topo, set()) is topo
    flat = Topology.from_spec("device:8@fast_ici")
    assert surviving_topology(flat, {0, 1}).world == 6
    with pytest.raises(ValueError, match="out of range"):
        surviving_topology(topo, {8})
    with pytest.raises(ValueError, match="no survivors"):
        surviving_topology(flat, set(range(8)))


# ---------------------------------------------------------------------------
# Checkpoint integrity (atomic writes + checksums)
# ---------------------------------------------------------------------------

def test_checkpoint_truncation_detected(tmp_path):
    """Satellite (b): truncate a REAL checkpoint mid-payload — the
    checksum must fail verification BEFORE deserialization with a loud
    ValueError, from both verify() and the session restore path."""
    from repro import checkpoint as ckpt
    s = TrainSession(SessionConfig(steps=2, **ARCH_KW))
    path = str(tmp_path / "ck")
    s.save_checkpoint(path)
    ckpt.verify(path)                              # intact: no raise
    payload = path + ".npz"
    n = os.path.getsize(payload)
    with open(payload, "rb") as f:
        head = f.read(n // 2)
    with open(payload, "wb") as f:
        f.write(head)                              # truncated write
    with pytest.raises(ValueError, match="truncated or corrupt"):
        ckpt.verify(path)
    fresh = TrainSession(SessionConfig(steps=2, **ARCH_KW))
    with pytest.raises(ValueError, match="truncated or corrupt"):
        fresh.load_checkpoint(path)


def test_checkpoint_atomic_and_legacy(tmp_path):
    """Writes are temp+rename (no partial files left beside the
    checkpoint) and a pre-checksum manifest still loads — verify() skips
    rather than rejecting history."""
    from repro import checkpoint as ckpt
    s = TrainSession(SessionConfig(steps=2, **ARCH_KW))
    path = str(tmp_path / "ck")
    s.save_checkpoint(path)
    s.save_checkpoint(path)                        # overwrite is clean
    assert sorted(os.listdir(tmp_path)) == ["ck.json", "ck.npz"]
    with open(path + ".json") as f:
        manifest = json.load(f)
    assert "sha256" in manifest
    legacy = {k: v for k, v in manifest.items() if k != "sha256"}
    with open(path + ".json", "w") as f:
        json.dump(legacy, f)
    ckpt.verify(path)                              # legacy: no raise
    fresh = TrainSession(SessionConfig(steps=2, **ARCH_KW))
    assert fresh.load_checkpoint(path) == 0
    import jax
    for a, b in zip(jax.tree.leaves(fresh.params),
                    jax.tree.leaves(s.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# The conformance run: kill at step k, 8 -> 6 -> 8, bit-for-bit resume
# ---------------------------------------------------------------------------

def test_elastic_reshard_conformance_bit_for_bit(tmp_path):
    sched = FaultSchedule.from_spec(
        "kill:3@3,kill:7@3,restore:3@6,restore:7@6", world=8)
    rt = ElasticRuntime(_factory(), sched, ElasticConfig(
        topology=TOPO8, checkpoint_dir=str(tmp_path)))
    losses = rt.run(8)
    assert len(losses) == 8
    # the runtime went 8 -> 6 -> 8 without a process restart
    kinds = [(e.step, e.kind, e.old_world, e.new_world) for e in rt.events]
    assert kinds == [(3, "reshard", 8, 6), (6, "reshard", 6, 8)]
    assert rt.events[0].topology == "node:2@datacenter,device:3@fast_ici"
    # round accounting survives session generations (BSP: 1 grad round
    # per step, aggregated across all three sessions)
    assert rt.grad_rounds == 8
    # post-recovery trajectory matches the unfaulted reference EXACTLY
    ref = _factory()()
    ref_losses = [ref.step_once() for _ in range(8)]
    np.testing.assert_array_equal(np.asarray(losses),
                                  np.asarray(ref_losses))


def test_elastic_replan_on_reshard_carries_topology_block(tmp_path):
    """plan=True: resharding re-runs the planner on the SURVIVING fabric
    and the plan record carries the re-planned topology block (the
    acceptance criterion's record contract)."""
    from repro.launch.report import comm_plan_record
    sched = FaultSchedule.from_spec("kill:3@2,kill:7@2", world=8)
    rt = ElasticRuntime(_factory(steps=4), sched, ElasticConfig(
        topology=TOPO8, checkpoint_dir=str(tmp_path), plan=True,
        t_backward_s=0.05))
    rt.run(4)
    ev = [e for e in rt.events if e.kind == "reshard"]
    assert len(ev) == 1 and ev[0].new_world == 6
    assert ev[0].plan_key          # a plan was installed post-reshard
    sp = rt.session.planned["strategy_plan"]
    rec = comm_plan_record(sp.comm)
    assert "topology" in rec, "re-planned record lost the topology block"
    assert rec["topology"]["spec"] == "node:2@datacenter,device:3@fast_ici"
    assert rec["world"] == 6


# ---------------------------------------------------------------------------
# Straggler demotion: backpressure and the re-plan escalation
# ---------------------------------------------------------------------------

def test_scheduler_backpressure_units():
    ls = get_scheduler("local_sgd", period=4)
    assert ls.supports_backpressure and ls.backpressure(2.0)
    assert ls.cfg.period == 8
    lag = get_scheduler("lag", threshold=0.5)
    assert lag.supports_backpressure and lag.backpressure(3.0)
    assert lag.cfg.threshold == pytest.approx(1.5)
    pp = get_scheduler("push_pull", n_push=2, n_fetch=4)
    assert pp.supports_backpressure and pp.backpressure(2.0)
    assert (pp.cfg.n_push, pp.cfg.n_fetch) == (4, 8)
    es = get_scheduler("every_step")
    assert not es.supports_backpressure and not es.backpressure(2.0)


def test_runtime_backpressure_demotes_local_sgd_cadence(tmp_path):
    def factory():
        s = TrainSession(SessionConfig(steps=6, **ARCH_KW))
        s.strategy = SyncStrategy(
            scheduler=get_scheduler("local_sgd", period=2))
        return s
    sched = FaultSchedule.from_spec("slow:1x4@1", world=8)
    rt = ElasticRuntime(factory, sched, ElasticConfig(
        topology=TOPO8, checkpoint_dir=str(tmp_path)))
    rt.run(6)
    ev = [e for e in rt.events if e.kind == "backpressure"]
    assert len(ev) == 1, "one demotion per straggler episode"
    assert "local_sgd" in ev[0].note
    # the installed scheduler's cadence was stretched, not the bus stalled
    assert rt.session.strategy.scheduler.cfg.period == 4


def test_replan_now_installs_cadence_swap():
    """Satellite (f), session level: a straggler-priced re-plan INSTALLS
    an every_step -> local_sgd swap (not just records it) — the planner's
    cadence demotion reaches the executed strategy."""
    s = TrainSession(SessionConfig(steps=4, **ARCH_KW))
    s.apply_topology("device:8@fast_ici")
    sp = s.plan_auto(t_backward_s=0.5)        # compute-bound: every-step
    assert sp.schedule.kind == "every_step"
    ev = s.replan_now(straggler_s=2.0, t_backward_s=0.5)
    assert ev["applied"] and ev["straggler_s"] == 2.0
    assert s.strategy.scheduler.name == "local_sgd"
    # the swapped strategy executes (rebuild from leaf-shaped params)
    assert np.isfinite(s.step_once())


def test_replan_now_swaps_pinned_lag():
    """Satellite (f): the stash now covers PINNED schedulers, so a
    straggler re-plan can demote a LAG session to a τ-round cadence."""
    s = TrainSession(SessionConfig(steps=4, **ARCH_KW))
    s.apply_topology("device:8@fast_ici")
    s.plan_auto(scheduler=get_scheduler("lag", threshold=0.5),
                t_backward_s=0.5)
    assert s.strategy.scheduler.name == "lag"
    assert s._plan_kwargs is not None, "pinned-scheduler plan not stashed"
    s.step_once()                              # build + run LAG once
    ev = s.replan_now(straggler_s=2.0, t_backward_s=0.5)
    assert ev["applied"], ev
    assert s.strategy.scheduler.name in ("every_step", "local_sgd")
    assert np.isfinite(s.step_once())


def test_runtime_escalates_to_replan(tmp_path):
    """Runtime level: every-step has no cadence lever, so a persistent
    straggler escalates to the straggler-priced re-plan and the installed
    cadence CHANGES mid-run."""
    def factory():
        return TrainSession(SessionConfig(steps=6, **ARCH_KW))
    sched = FaultSchedule.from_spec("slow:1x6@1", world=8)
    rt = ElasticRuntime(factory, sched, ElasticConfig(
        topology="device:8@fast_ici", checkpoint_dir=str(tmp_path),
        plan=True, t_backward_s=0.5))
    assert rt.session.strategy.scheduler.name == "every_step"
    rt.run(5)
    ev = [e for e in rt.events if e.kind == "replan"]
    assert len(ev) == 1 and "installed" in ev[0].note
    assert rt.session.strategy.scheduler.name == "local_sgd"


# ---------------------------------------------------------------------------
# Straggler pricing units
# ---------------------------------------------------------------------------

def test_straggler_penalty_units():
    assert straggler_penalty_s(0.0) == 0.0
    assert straggler_penalty_s(-1.0, 4.0) == 0.0
    assert straggler_penalty_s(0.2) == pytest.approx(0.2)
    # a tau-round cadence amortizes the skew: skew/tau per step
    assert straggler_penalty_s(0.2, 1.0 / 8) == pytest.approx(0.025)


def test_plan_rounds_straggler_zero_is_identity():
    profs = [LayerProfile(t_backward_s=2e-4, grad_bytes=4 * 2**20)
             for _ in range(8)]
    topo = Topology.from_spec("node:2@datacenter,device:4@fast_ici")
    b0, a0 = plan_rounds(profs, topo, 8, opt_name="adam")
    b1, a1 = plan_rounds(profs, topo, 8, opt_name="adam", straggler_s=0.0)
    assert b0.key == b1.key
    assert {k: a.modeled_step_s for k, a in a0.items()} == \
        {k: a.modeled_step_s for k, a in a1.items()}


def test_plan_rounds_straggler_prices_every_step_hardest():
    profs = [LayerProfile(t_backward_s=5e-3, grad_bytes=4 * 2**20)
             for _ in range(8)]
    topo = Topology.from_spec("device:8@fast_ici")
    _, a0 = plan_rounds(profs, topo, 8, opt_name="adam")
    skew = 0.05
    _, a1 = plan_rounds(profs, topo, 8, opt_name="adam", straggler_s=skew)
    # every-step pays the full skew; a tau-round arm pays skew/tau
    assert a1["every_step"].modeled_step_s == pytest.approx(
        a0["every_step"].modeled_step_s + skew)
    for key in a0:
        if a0[key].schedule.kind == "local_sgd":
            tau = a0[key].schedule.period
            assert a1[key].modeled_step_s == pytest.approx(
                a0[key].modeled_step_s + skew / tau)


def test_render_elastic_events():
    from repro.elastic.runtime import ReshardEvent
    from repro.launch.report import render_elastic_events
    assert "no membership changes" in render_elastic_events([])
    out = render_elastic_events([ReshardEvent(
        step=3, kind="reshard", old_world=8, new_world=6,
        topology="node:2@datacenter,device:3@fast_ici",
        note="dead=[3, 7]")])
    assert "8→6" in out and "device:3" in out and "dead=[3, 7]" in out
