"""Collective calibration + modeled↔measured drift (ISSUE 8).

Covers: the least-squares affine fit with confidence bounds (and the
regression pin on the old two-point ``_fit`` silently clamping noisy
fits to a through-origin model), the per-tier α/β link fit recovering a
synthetic fabric's ground truth within its reported bounds, the
versioned ``CompressionCostTable`` schema (v2+ requires ``cal_world``,
legacy files warn), the drift-report math, and the plan-record schema
staying byte-compatible when no calibration rode along.
"""
import json
import math

import numpy as np
import pytest

from repro.core.schedule import (AffineFit, CalibratedTopology,
                                 CompressionCostTable, LinkParams, Topology,
                                 allreduce_cost_s, calibrate_topology,
                                 drift_fraction, fit_affine,
                                 modeled_wall_step_s, plan_comm_error_s,
                                 resolve_calibration)
from repro.core.schedule.calibration import (CAL_LINK_SIZES, _fit,
                                             _phase_coeffs)

TWO_TIER = "node:4@datacenter,device:8@fast_ici"


def _fabric_timer(links, noise_s=0.0, seed=0):
    """A fake collective fabric: exact phase-formula timings from known
    per-tier (α, β), plus seeded ADDITIVE gaussian noise of ``noise_s``
    seconds — additive because that is the homoscedastic error model the
    least-squares confidence bounds assume (what a min-of-N timing floor
    approximates)."""
    rng = np.random.RandomState(seed)

    def timer(algo, tier, p, n_bytes):
        a, b = links[tier]
        ca, cb = _phase_coeffs(algo, p, n_bytes) or (1.0, 0.0)
        return ca * a + cb * b + (rng.normal(0.0, noise_s)
                                  if noise_s else 0.0)

    return timer


# ---------------------------------------------------------------------------
# fit_affine / _fit: least squares over >=3 sizes, with a residual
# ---------------------------------------------------------------------------

def test_fit_affine_recovers_line():
    pts = [(x, 2e-10 * x + 5e-5) for x in (1e4, 1e5, 1e6, 1e7)]
    f = fit_affine(pts)
    assert f.slope == pytest.approx(2e-10, rel=1e-9)
    assert f.intercept == pytest.approx(5e-5, rel=1e-6)
    assert f.rms_s == pytest.approx(0.0, abs=1e-12)
    assert f.r2 == pytest.approx(1.0)
    assert not f.degenerate
    # noise-free overdetermined fit: tiny but FINITE standard errors
    assert math.isfinite(f.slope_err) and math.isfinite(f.intercept_err)


def test_fit_affine_noisy_errors_cover_truth():
    rng = np.random.RandomState(7)
    slope, icpt = 1e-10, 2e-4
    xs = np.logspace(4, 7, 12)
    pts = [(x, slope * x + icpt + rng.normal(0, 2e-5)) for x in xs]
    f = fit_affine(pts)
    # property: the reported 1-sigma bounds cover the truth within 4 sigma
    assert abs(f.slope - slope) < 4 * f.slope_err
    assert abs(f.intercept - icpt) < 4 * f.intercept_err
    assert f.rms_s > 0


def test_fit_affine_two_points_has_infinite_errors():
    f = fit_affine([(1.0, 1.0), (2.0, 2.0)])
    assert f.slope == pytest.approx(1.0)
    assert f.slope_err == float("inf") and f.intercept_err == float("inf")


def test_fit_clamp_warns_and_flags():
    # regression: non-monotone timings (noise swamps size) used to clamp
    # silently to a through-origin model reported as if measured.  Now the
    # clamp still happens (the planner needs positive bandwidth) but it
    # WARNS and the returned fit is flagged degenerate.
    pts = [(1e6, 3e-3), (2e6, 2e-3), (8e6, 2.5e-3)]   # non-monotone
    with pytest.warns(UserWarning, match="degenerated"):
        bw, c0, fit = _fit(pts)
    assert c0 == 0.0                    # through-origin fallback
    assert bw == pytest.approx(8e6 / 2.5e-3)
    assert fit.degenerate
    # a clean monotone set neither warns nor flags
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")
        bw, c0, fit = _fit([(x, 1e-10 * x + 1e-4)
                            for x in (1e6, 2e6, 8e6)])
    assert not fit.degenerate and c0 > 0


def test_measure_compression_costs_records_quality():
    from repro.core.schedule import measure_compression_costs
    tab = measure_compression_costs(compressors=(("int8", ()),),
                                    sizes=(1 << 12, 1 << 13, 1 << 14),
                                    repeats=1)
    assert tab.stage_s("int8", "encode", 1e6) is not None
    q = tab.fit_quality("int8/encode")
    assert q is not None
    rms, r2, deg = q
    assert rms >= 0 and isinstance(deg, bool)


# ---------------------------------------------------------------------------
# CompressionCostTable: versioned schema (satellite 3)
# ---------------------------------------------------------------------------

def test_cost_table_roundtrip_v2():
    tab = CompressionCostTable(
        entries=(("int8/encode", 1e9, 1e-5),),
        cal_world=16,
        quality=(("int8/encode", 1e-6, 0.99, False),))
    obj = tab.to_json()
    assert obj["version"] == CompressionCostTable.SCHEMA_VERSION == 2
    assert obj["cal_world"] == 16
    back = CompressionCostTable.from_json(obj)
    assert back.entries == tab.entries
    assert back.cal_world == 16
    assert back.fit_quality("int8/encode") == (1e-6, 0.99, False)


def test_cost_table_v2_requires_cal_world():
    obj = {"version": 2, "entries": [
        {"key": "int8/encode", "bw_bytes_per_s": 1e9, "overhead_s": 0.0}]}
    with pytest.raises(ValueError, match="cal_world"):
        CompressionCostTable.from_json(obj)


def test_cost_table_legacy_warns_and_defaults():
    legacy = {"entries": [{"key": "int8/encode", "bw_bytes_per_s": 1e9,
                           "overhead_s": 0.0}]}          # no version field
    with pytest.warns(UserWarning, match="legacy"):
        tab = CompressionCostTable.from_json(legacy)
    assert tab.cal_world == 8
    # legacy file that DOES carry cal_world: used, no warning
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")
        tab = CompressionCostTable.from_json(dict(legacy, cal_world=4))
    assert tab.cal_world == 4


# ---------------------------------------------------------------------------
# tentpole: per-tier link fit recovers a synthetic fabric (satellite 4)
# ---------------------------------------------------------------------------

TRUTH = {"node": (5e-6, 1e-10), "device": (1e-6, 2e-11)}


def test_calibrate_recovers_ground_truth_exactly():
    cal = calibrate_topology(Topology.from_spec(TWO_TIER),
                             timer=_fabric_timer(TRUTH))
    assert cal.world == 32
    assert [t.link_name for t in cal.topology.tiers] == ["calibrated"] * 2
    for name, (a, b) in TRUTH.items():
        fit = cal.fit_for(name)
        assert fit.alpha_s == pytest.approx(a, rel=1e-6)
        assert fit.beta_s_per_byte == pytest.approx(b, rel=1e-6)
        assert fit.r2 == pytest.approx(1.0)
        assert not fit.degenerate
    # samples were kept for offline refits (the CI suite replays these)
    assert len(cal.samples) == 2 * 2 * len(CAL_LINK_SIZES)


def test_calibrate_noisy_within_reported_bounds():
    # property: with 1% multiplicative noise the fitted coefficients land
    # within 4 reported sigmas of the truth — confidence bounds are
    # honest, not decorative
    cal = calibrate_topology(Topology.from_spec(TWO_TIER),
                             timer=_fabric_timer(TRUTH, noise_s=2e-7))
    for name, (a, b) in TRUTH.items():
        fit = cal.fit_for(name)
        assert math.isfinite(fit.alpha_err_s)
        assert abs(fit.alpha_s - a) < 4 * max(fit.alpha_err_s, 1e-12)
        assert abs(fit.beta_s_per_byte - b) < \
            4 * max(fit.beta_err_s_per_byte, 1e-18)
        assert fit.rms_s > 0


def test_calibrated_topology_prices_and_errors():
    cal = calibrate_topology(Topology.from_spec(TWO_TIER),
                             timer=_fabric_timer(TRUTH))
    # a CalibratedTopology IS a net: as_topology unwraps it
    t = allreduce_cost_s("ring", 1 << 20, 32, cal)
    a, b = TRUTH["node"]               # bottleneck: the slow fabric
    expect = 2 * 31 * (a + (1 << 20) / 32 * b)
    assert t == pytest.approx(expect, rel=1e-6)
    # noise-free fit: propagated error is ~0 but well-defined
    assert cal.allreduce_error_s(1 << 20, 32) >= 0.0
    assert cal.allreduce_error_s(1 << 20, 1) == 0.0


def test_calibrated_topology_json_roundtrip(tmp_path):
    cal = calibrate_topology(Topology.from_spec(TWO_TIER),
                             timer=_fabric_timer(TRUTH, noise_s=2e-7))
    path = str(tmp_path / "fabric.cal.json")
    cal.save(path)
    back = resolve_calibration(path)
    assert back.topology == cal.topology
    assert back.fits == cal.fits
    assert back.samples == cal.samples


def test_one_rank_tier_fits_degenerate():
    cal = calibrate_topology(Topology.flat(1, LinkParams(), name="solo"),
                             timer=lambda algo, tier, p, n: 1e-5 + n * 1e-12)
    fit = cal.fit_for("solo")
    assert fit.degenerate                 # 1-rank: no wire signal
    assert fit.alpha_s == pytest.approx(1e-5, rel=1e-6)


def test_calibrate_world_mismatch_raises():
    import jax
    big = Topology.flat(len(jax.devices()) + 1, LinkParams(), name="data")
    with pytest.raises(ValueError, match="cannot calibrate"):
        calibrate_topology(big)           # default timer, wrong world


# ---------------------------------------------------------------------------
# drift math (satellite 4): exact on canned records
# ---------------------------------------------------------------------------

def test_drift_fraction_exact():
    assert drift_fraction(10e-3, 12e-3) == pytest.approx(0.2)
    assert drift_fraction(10e-3, 12e-3) * 100 == pytest.approx(20.0)
    assert drift_fraction(2.0, 1.5) == pytest.approx(-0.25)
    assert drift_fraction(1.0, 1.0) == 0.0
    with pytest.raises(ValueError):
        drift_fraction(0.0, 1.0)


def test_modeled_wall_step_exact():
    # wall step = overlap-window model + fwd (= backward / 2)
    assert modeled_wall_step_s(8e-3, 4e-3) == pytest.approx(0.01)
    assert modeled_wall_step_s(0.0, 1.0) == pytest.approx(0.5)


def test_plan_comm_error_sums_buckets():
    from repro.core.schedule import LayerProfile, plan
    cal = calibrate_topology(Topology.from_spec(TWO_TIER),
                             timer=_fabric_timer(TRUTH, noise_s=2e-7))
    profiles = [LayerProfile(t_backward_s=1e-3, grad_bytes=4 << 20)
                for _ in range(4)]
    cp = plan(profiles, cal.topology, 32)
    err = plan_comm_error_s(cp, cal)
    assert err == pytest.approx(sum(
        cal.allreduce_error_s(b.bucket_bytes, cp.world)
        for b in cp.buckets))
    assert err > 0
    assert plan_comm_error_s(cp, None) == 0.0


def test_render_drift_table():
    from repro.launch.report import render_drift_table
    drift = {
        "plan_key": "every_step", "modeled_step_s": 8e-3,
        "modeled_wall_step_s": 10e-3, "measured_step_s": 12e-3,
        "steps_measured": 5, "drift_frac": 0.2, "drift_pct": 20.0,
        "comm_fit_err_s": 1e-4, "t_backward_err_s": 5e-4,
        "measured_spread_s": 2e-3, "fit_error_s": 2.6e-3,
        "within_fit_error": True, "replans": 1,
        "replan_events": [{"step": 25, "drift_frac": 0.2,
                           "new_key": "every_step", "applied": False,
                           "note": "re-plan kept the incumbent arm"}],
        "arms": {"every_step": {"modeled_step_s": 8e-3,
                                "modeled_wall_step_s": 10e-3,
                                "drift_pct": 20.0}}}
    txt = render_drift_table(drift)
    assert "+20.0%" in txt and "within" in txt
    assert "every_step ←" in txt
    assert "replan @step 25" in txt


# ---------------------------------------------------------------------------
# session integration: --calibrate leaves the plan-record schema intact
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def planned_session():
    from repro.api import SessionConfig, TrainSession
    sess = TrainSession(SessionConfig(arch="xlstm-125m", reduced=True,
                                      batch=2, seq=16, steps=4))
    cal = calibrate_topology(
        Topology.flat(sess.world, LinkParams(), name="data"),
        timer=_fabric_timer({"data": (5e-6, 1e-10)}))
    sess.plan_auto(calibration=cal)
    sess.run(steps=3)
    return sess


def test_plan_auto_consumes_calibration(planned_session):
    sess = planned_session
    assert sess.calibration is not None
    assert sess.topology is not None
    assert sess.topology.innermost.link_name == "calibrated"
    # the plan was priced on the fitted link, not a preset
    lk = sess.planned["strategy_plan"].comm.link
    fitted = sess.calibration.topology.innermost.link
    assert Topology.flat(sess.world, fitted) == \
        Topology.flat(sess.world, lk.innermost.link
                      if isinstance(lk, Topology) else lk)


def test_drift_report_math(planned_session):
    sess = planned_session
    d = sess.drift_report()
    sp = sess.planned["strategy_plan"]
    wall = modeled_wall_step_s(sp.modeled_step_s, sp.t_backward_s)
    assert d["modeled_wall_step_s"] == pytest.approx(wall)
    assert d["drift_frac"] == pytest.approx(
        drift_fraction(wall, d["measured_step_s"]))
    assert d["drift_pct"] == pytest.approx(d["drift_frac"] * 100)
    assert d["steps_measured"] >= 1
    assert d["fit_error_s"] >= d["comm_fit_err_s"]
    assert set(d["arms"]) == set(sess.planned["arms"])
    for key, arm in d["arms"].items():
        a = sess.planned["arms"][key]
        w = modeled_wall_step_s(a.modeled_step_s, a.t_backward_s)
        assert arm["drift_pct"] == pytest.approx(
            drift_fraction(w, d["measured_step_s"]) * 100)


def test_plan_record_schema_unchanged_without_calibration(
        planned_session, tmp_path):
    # acceptance criterion: records written WITHOUT calibration keep the
    # exact pre-calibration key set; calibration/drift are purely additive
    from repro.launch import report
    import repro.launch.paths as paths
    sess = planned_session
    sp = sess.planned["strategy_plan"]
    old = paths.COMM_PLANS
    paths.COMM_PLANS = str(tmp_path)
    try:
        with open(report.save_strategy_plan(sp, "base")) as f:
            base = json.load(f)
        with open(report.save_strategy_plan(
                sp, "cal", calibration=sess.calibration,
                drift=sess.drift_report())) as f:
            cal_rec = json.load(f)
    finally:
        paths.COMM_PLANS = old
    expect = {"world", "modeled_step_s", "shard_state", "n_buckets",
              "buckets", "schedule", "round_cost_s", "t_backward_s"}
    assert expect <= set(base)
    assert set(base) <= expect | {"opt_mem_bytes_per_worker", "pipeline",
                                  "topology"}
    assert set(cal_rec) == set(base) | {"calibration", "drift"}
    assert cal_rec["calibration"]["tiers"][0]["alpha_s"] == \
        pytest.approx(5e-6, rel=1e-6)
    assert "samples" not in cal_rec["calibration"]
    assert cal_rec["drift"]["measured_step_s"] > 0
    assert {k: v for k, v in cal_rec.items()
            if k not in ("calibration", "drift")} == base


def test_bench_ci_calibration_gate():
    # the CI calibration suite refits COMMITTED timing fixtures (never
    # live timings): bit-deterministic, green against the committed
    # baseline, and the gate trips on an injected 20% regression
    import copy
    import os
    import sys
    scripts = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "scripts")
    sys.path.insert(0, scripts)
    try:
        import bench_ci
    finally:
        sys.path.remove(scripts)
    recs = bench_ci.collect_calibration()
    assert recs == bench_ci.collect_calibration()    # bit-deterministic
    assert recs["drift/canned_20pct"]["drift_pct"] == pytest.approx(20.0)
    assert recs["drift/modeled_wall"]["modeled_wall_ms"] == 10.0
    # the refit recovers the fixture's documented ground truth
    assert recs["node:4@datacenter,device:8@fast_ici/node/alpha"][
        "alpha_us"] == pytest.approx(5.0, rel=0.05)
    basedir = os.path.join(os.path.dirname(scripts), "benchmarks",
                           "baselines")
    assert not bench_ci.gate({"calibration": recs}, basedir, 0.10)
    bad = copy.deepcopy(recs)
    for r in bad.values():
        r[r["metric"]] *= 1.2
    assert bench_ci.gate({"calibration": bad}, basedir, 0.10)


def test_plan_auto_topology_mismatch_keeps_presets(capsys):
    from repro.api import SessionConfig, TrainSession
    sess = TrainSession(SessionConfig(arch="xlstm-125m", reduced=True,
                                      batch=2, seq=16, steps=4))
    sess.apply_topology(TWO_TIER)
    cal = calibrate_topology(
        Topology.flat(8, LinkParams(), name="data"),
        timer=_fabric_timer({"data": (5e-6, 1e-10)}))
    sess.plan_auto(calibration=cal, t_backward_s=0.02)
    out = capsys.readouterr().out
    assert "fitted links apply only" in out
    assert sess.topology.innermost.link_name != "calibrated"
