"""Pipeline parallelism (core/pipeline.py + the planner's parallelism axis
+ launch/steps.make_pipeline_train_step) — ISSUE 4.

Covers: the canonical 1F1B op order, the bubble-fraction ↔ simulated-
timeline identity, stage-cut balance properties (hypothesis), micro-batch
gradient accumulation bit-exactness vs the scan-accumulated reference,
the planner's pipeline arms (pricing, budget wins, invariants), staged-
model split/merge round-trips, and the bench-regression gate
(scripts/bench_ci.py) including the injected-perturbation negative test.
The 8-device pipeline-vs-DP bit-exactness lives in multi_device_checks.py.
"""
import importlib.util
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from hyp_compat import given, settings, st  # noqa: E402
from tiny_lm import TinyStackLM, tiny_batch  # noqa: E402

from repro.core.pipeline import (PIPE_FWD_FRACTION, StagedModel,  # noqa: E402
                                 aligned_order, aligned_ticks, balanced_cuts,
                                 bubble_fraction, schedule_1f1b,
                                 simulate_1f1b, stage_costs)
from repro.core.schedule import (LINK_PRESETS, LayerProfile,  # noqa: E402
                                 PipelineAxis, pipeline_arm, plan_rounds,
                                 profiles_from_sizes)

LINK = LINK_PRESETS["commodity"]


# ---------------------------------------------------------------------------
# 1F1B schedule
# ---------------------------------------------------------------------------

def test_schedule_1f1b_canonical_2x4():
    sched = schedule_1f1b(2, 4)
    assert sched[0] == [("F", 0), ("F", 1), ("B", 0), ("F", 2), ("B", 1),
                        ("F", 3), ("B", 2), ("B", 3)]
    assert sched[1] == [("F", 0), ("B", 0), ("F", 1), ("B", 1), ("F", 2),
                        ("B", 2), ("F", 3), ("B", 3)]


def test_schedule_1f1b_canonical_4x8():
    sched = schedule_1f1b(4, 8)
    # stage s warms up with S-1-s forwards, then strictly alternates
    for s, ops in enumerate(sched):
        warm = 4 - 1 - s
        assert ops[:warm] == [("F", m) for m in range(warm)]
        steady = ops[warm:]
        # alternation: F(warm), B(0), F(warm+1), B(1), ... then B-drain
        fs = [m for op, m in ops if op == "F"]
        bs = [m for op, m in ops if op == "B"]
        assert fs == list(range(8)) and bs == list(range(8))
        # memory bound: at most S - s micro-batches in flight
        flight = peak = 0
        for op, _ in ops:
            flight += 1 if op == "F" else -1
            peak = max(peak, flight)
        assert peak == 4 - s
    assert sched[3] == [("F", 0), ("B", 0), ("F", 1), ("B", 1), ("F", 2),
                        ("B", 2), ("F", 3), ("B", 3), ("F", 4), ("B", 4),
                        ("F", 5), ("B", 5), ("F", 6), ("B", 6), ("F", 7),
                        ("B", 7)]


@pytest.mark.parametrize("S,M", [(2, 4), (4, 8), (3, 5), (8, 32), (1, 6)])
def test_bubble_formula_matches_simulated_timeline(S, M):
    t_f, t_b = 1.0, 2.0
    makespan = simulate_1f1b(S, M, t_f, t_b)
    ideal = M * (t_f + t_b)
    assert makespan == pytest.approx((M + S - 1) * (t_f + t_b))
    assert (makespan - ideal) / makespan == pytest.approx(
        bubble_fraction(S, M))


def test_simulate_1f1b_send_cost_only_on_boundary_hops():
    # S=1: no boundary, sends are free regardless
    assert simulate_1f1b(1, 4, 1.0, 1.0, t_send=5.0) == \
        simulate_1f1b(1, 4, 1.0, 1.0)
    assert simulate_1f1b(2, 4, 1.0, 1.0, t_send=0.5) > \
        simulate_1f1b(2, 4, 1.0, 1.0)


@pytest.mark.parametrize("S,M", [(2, 4), (4, 8)])
def test_aligned_order_consistent_with_canonical(S, M):
    """The SPMD slot grid preserves the canonical per-stage F/B structure:
    same F order, same B order, F(m) strictly before B(m), and the O(S)
    in-flight bound 2(S-1-s)+1."""
    assert aligned_ticks(S, M) == M + 2 * (S - 1)
    aligned = aligned_order(S, M)
    canon = schedule_1f1b(S, M)
    for s in range(S):
        assert [x for x in aligned[s] if x[0] == "F"] == \
            [x for x in canon[s] if x[0] == "F"]
        assert [x for x in aligned[s] if x[0] == "B"] == \
            [x for x in canon[s] if x[0] == "B"]
        pos = {op: i for i, op in enumerate(aligned[s])}
        for m in range(M):
            assert pos[("F", m)] < pos[("B", m)]
        flight = peak = 0
        for op, _ in aligned[s]:
            flight += 1 if op == "F" else -1
            peak = max(peak, flight)
        assert peak <= 2 * (S - 1 - s) + 1
    # last stage is identical to canonical 1F1B
    assert aligned[S - 1] == canon[S - 1]


# ---------------------------------------------------------------------------
# Stage cuts
# ---------------------------------------------------------------------------

def _brute_min_max(costs, S):
    import itertools
    n = len(costs)
    best = float("inf")
    for bounds in itertools.combinations(range(1, n), S - 1):
        cuts = (0,) + bounds + (n,)
        best = min(best, max(sum(costs[cuts[i]:cuts[i + 1]])
                             for i in range(S)))
    return best


@given(st.lists(st.floats(min_value=0.0, max_value=100.0), min_size=1,
                max_size=9),
       st.integers(min_value=1, max_value=4))
@settings(max_examples=60, deadline=None)
def test_balanced_cuts_properties(costs, S):
    if len(costs) < S:
        with pytest.raises(ValueError):
            balanced_cuts(costs, S)
        return
    cuts = balanced_cuts(costs, S)
    assert cuts[0] == 0 and cuts[-1] == len(costs)
    assert len(cuts) == S + 1
    assert all(a < b for a, b in zip(cuts, cuts[1:]))   # non-empty stages
    got = max(stage_costs(costs, cuts))
    assert got == pytest.approx(_brute_min_max(tuple(costs), S))


def test_balanced_cuts_monotone_in_stages():
    costs = [5.0, 1.0, 3.0, 2.0, 4.0, 1.0, 2.0, 6.0]
    prev = float("inf")
    for S in (1, 2, 3, 4):
        cur = max(stage_costs(costs, balanced_cuts(costs, S)))
        assert cur <= prev + 1e-12
        prev = cur


# ---------------------------------------------------------------------------
# Micro-batch gradient accumulation (world=1)
# ---------------------------------------------------------------------------

def _pipeline_step_once(model, params, batch, M, opt_name="sgd", lr=0.1):
    from repro.core import GradientSynchronizer, SyncConfig
    from repro.launch.mesh import make_pipe_mesh
    from repro.launch.steps import make_pipeline_train_step
    from repro.optim import make_optimizer

    mesh = make_pipe_mesh(1, 1)
    opt = make_optimizer(opt_name, lr=lr)
    engine = GradientSynchronizer(SyncConfig(bucket_bytes=0), ("data",))
    step_fn, init_opt, init_ss = make_pipeline_train_step(model, opt, engine,
                                                          mesh, M)
    shared, rows = model.split(params)
    p = {"shared": shared, "rows": rows}
    o, ss = init_opt(p), init_ss(p)
    p2, _, _, loss = jax.jit(step_fn)(p, o, ss, batch,
                                      jnp.zeros((), jnp.int32),
                                      jax.random.PRNGKey(1))
    return model.merge(p2["shared"], p2["rows"]), float(loss)


def test_microbatch_accumulation_bit_exact_vs_scan_reference():
    """The S=1 pipeline step's gradient = ascending-order micro-batch
    accumulation — bit-exact against the hand-rolled scan reference run
    through the SAME optimizer step."""
    from repro.optim import apply_updates, make_optimizer

    M = 4
    model = TinyStackLM(blocks=4, n_stages=1)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(0, batch=8, seq=16)
    got, loss = _pipeline_step_once(model, params, batch, M)

    toks = batch["tokens"]
    mb = toks.shape[0] // M

    @jax.jit
    def ref(params):
        g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        ls = jnp.zeros(())
        for m in range(M):
            l, gm = jax.value_and_grad(model.loss)(
                params, {"tokens": toks[m * mb:(m + 1) * mb]})
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g, gm)
            ls = ls + l
        g = jax.tree.map(lambda a: a / M, g)
        opt = make_optimizer("sgd", lr=0.1)
        upd, _ = opt.update(g, opt.init(params), params,
                            jnp.zeros((), jnp.int32))
        return apply_updates(params, upd), ls / M

    want, ref_loss = ref(params)
    assert loss == pytest.approx(float(ref_loss), rel=1e-6)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(got),
            jax.tree_util.tree_leaves_with_path(want)):
        a, b = np.asarray(a), np.asarray(b)
        # world=1: XLA may contract the update-add differently per graph
        # (DESIGN.md §8/§9) — ulp-tight here; 8-device checks assert exact
        np.testing.assert_allclose(a, b, rtol=3e-6, atol=1e-7,
                                   err_msg=jax.tree_util.keystr(pa))


def test_microbatch_accumulation_close_to_full_batch():
    """Mean-of-micro-batch-means ≈ full-batch grad (equal only in exact
    arithmetic; the tokens-per-micro-batch counts are equal here)."""
    model = TinyStackLM(blocks=2, n_stages=1)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(0, batch=8, seq=16)
    got, _ = _pipeline_step_once(model, params, batch, 4, lr=0.1)
    full, _ = _pipeline_step_once(model, params, batch, 1, lr=0.1)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Staged models
# ---------------------------------------------------------------------------

def test_staged_model_split_merge_roundtrip():
    from repro.configs import get_config, reduced
    from repro.models import Model

    model = Model(reduced(get_config("gemma-2b")))
    staged = StagedModel(model, 2)
    params = model.init(jax.random.PRNGKey(0))
    shared, rows = staged.split(params)
    merged = staged.merge(shared, rows)
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(merged)):
        assert a.shape == b.shape, jax.tree_util.keystr(pa)
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_staged_model_rejects_heterogeneous_and_encdec():
    from repro.configs import get_config, reduced
    from repro.models import Model

    # xlstm reduced: one 4-layer heterogeneous period, repeats=1
    with pytest.raises(ValueError, match="divisible|single-row"):
        StagedModel(Model(reduced(get_config("xlstm-125m"))), 2)
    with pytest.raises(ValueError, match="decoder-only"):
        StagedModel(Model(reduced(get_config("seamless-m4t-large-v2"))), 2)
    with pytest.raises(ValueError, match="divisible"):
        StagedModel(Model(reduced(get_config("gemma-2b"))), 3)


def test_tiny_stack_loss_is_staged_composition():
    """TinyStackLM.loss == loss_tail(shared, stage(rows, embed(...)))."""
    model = TinyStackLM(blocks=4, n_stages=2)
    params = model.init(jax.random.PRNGKey(3))
    batch = tiny_batch(1)
    shared, rows = model.split(params)
    h = model.embed_mb(shared, batch["tokens"])
    flat = jax.tree.map(lambda x: x.reshape((4,) + x.shape[2:]), rows)
    h2, _ = TinyStackLM(blocks=4, n_stages=1).stage_apply(flat, h)
    want = model.loss_tail(shared, h2, batch["tokens"])
    got = model.loss(params, batch)
    assert float(got) == float(want)


# ---------------------------------------------------------------------------
# The planner's parallelism axis
# ---------------------------------------------------------------------------

def _profiles(n=24, mb=8.0, t=1e-3):
    return profiles_from_sizes([mb * 2**20] * n, t)


def test_pipeline_arm_pricing_fields():
    arm = pipeline_arm(_profiles(), LINK, 64, 4, 8, act_bytes_mb=1e6)
    assert arm.pipeline_stages == 4 and arm.micro_batches == 8
    assert arm.bubble == pytest.approx(bubble_fraction(4, 8))
    assert arm.key == "pipeline(S=4,M=8)"
    # bubble + p2p are charged on top of the DP edge plan
    assert arm.modeled_step_s >= arm.comm.modeled_step_s + arm.pipe_p2p_s
    assert arm.comm.world == 16          # world/S replicas on the DP edge


def test_pipeline_arm_rejects_bad_factorization():
    with pytest.raises(ValueError):
        pipeline_arm(_profiles(), LINK, 6, 4, 8, 1e6)    # 6 % 4 != 0
    with pytest.raises(ValueError):
        pipeline_arm(_profiles(), LINK, 8, 8, 8, 1e6)    # dp would be 1
    with pytest.raises(ValueError):
        pipeline_arm(_profiles(n=2), LINK, 64, 4, 8, 1e6)  # 2 leaves, S=4


def test_pipeline_bubble_shrinks_with_micro_batches():
    prev = float("inf")
    for M in (4, 8, 16, 32):
        arm = pipeline_arm(_profiles(), LINK, 64, 4, M, act_bytes_mb=1e4)
        assert arm.bubble < prev
        prev = arm.bubble


def test_plan_rounds_prices_pipeline_arms_only_with_axis():
    profiles = _profiles()
    _, arms = plan_rounds(profiles, LINK, 64)
    assert not any(a.pipeline_stages > 1 for a in arms.values())
    pa = PipelineAxis(global_tokens=4096.0 * 64, bytes_per_token=4096.0)
    best, arms = plan_rounds(profiles, LINK, 64, pipeline=pa)
    pipes = [a for a in arms.values() if a.pipeline_stages > 1]
    assert pipes
    # winner is never modeled slower than any arm (invariant extends)
    assert all(best.modeled_step_s <= a.modeled_step_s + 1e-12
               for a in arms.values())


def test_plan_rounds_pipeline_respects_world_divisibility():
    pa = PipelineAxis(global_tokens=4096.0 * 6, bytes_per_token=4096.0)
    _, arms = plan_rounds(_profiles(), LINK, 6, pipeline=pa)
    # 6 only factors into pipe(2) x data(3); S=4, S=8 must be absent
    keys = {a.pipeline_stages for a in arms.values()
            if a.pipeline_stages > 1}
    assert keys == {2}


def test_pipeline_wins_under_memory_budget_when_comm_dominates():
    """Big comm-dominated model on a slow link + a budget below replicated
    moments: local-SGD and replicated every-step drop, and the pipeline
    arm must beat the sharded arm (whose serial gather tail is priced on
    the same slow link) — the tentpole's planner acceptance point."""
    profiles = _profiles(n=32, mb=64.0, t=1e-4)   # 2 GiB model, fast bwd
    pa = PipelineAxis(global_tokens=4096.0 * 64, bytes_per_token=4096.0)
    pb = sum(p.grad_bytes for p in profiles)
    budget = 2.0 * pb / 2                          # half of adam's moments
    best, arms = plan_rounds(profiles, LINK, 64, pipeline=pa,
                             memory_budget_bytes=budget)
    assert best.pipeline_stages > 1, best.key
    assert best.opt_mem_bytes <= budget
    assert best.modeled_step_s < arms["every_step"].modeled_step_s
    assert best.modeled_step_s < arms["every_step_sharded"].modeled_step_s


def test_strategy_from_plan_pipeline_arm():
    from repro.api import strategy_from_plan
    from repro.core import GradientSynchronizer

    arm = pipeline_arm(_profiles(), LINK, 64, 2, 8, act_bytes_mb=1e5)
    st_ = strategy_from_plan(arm)
    assert st_.pipeline_stages == 2 and st_.micro_batches == 8
    assert isinstance(st_.grad_reducer, GradientSynchronizer)
    assert st_.grad_reducer.cfg.bucket_bytes == 0    # per-row granularity


def test_sync_strategy_rejects_bad_pipeline_compositions():
    from repro.core import SyncStrategy, get_scheduler

    with pytest.raises(ValueError, match="shard_state|pipeline"):
        SyncStrategy(scheduler=get_scheduler("every_step"),
                     pipeline_stages=2, shard_state=True)
    with pytest.raises(ValueError):
        SyncStrategy(scheduler=get_scheduler("every_step"),
                     pipeline_stages=0)

    from repro.api import SessionConfig, TrainSession
    sess = TrainSession(
        SessionConfig(arch="xlstm-125m", reduced=True, batch=4, seq=16),
        strategy=SyncStrategy(scheduler=get_scheduler("local_sgd", period=2),
                              pipeline_stages=2))
    with pytest.raises(ValueError, match="every-step"):
        sess.step_once()


def test_report_renders_pipeline_arm(tmp_path):
    from repro.launch import report

    arm = pipeline_arm(_profiles(), LINK, 64, 4, 8, act_bytes_mb=1e5)
    txt = report.render_strategy_plan(arm, arms={arm.key: arm,
                                                 "every_step": arm})
    assert "pipeline: 4 stages × 8 micro-batches" in txt
    assert "bubble" in txt
    rec = report.comm_plan_record(arm.comm)
    assert rec["world"] == 16
    # the saved strategy record carries the pipeline block
    import repro.launch.paths as paths
    old = paths.COMM_PLANS
    paths.COMM_PLANS = str(tmp_path)
    try:
        p = report.save_strategy_plan(arm, "testarch")
        with open(p) as f:
            saved = json.load(f)
        assert saved["pipeline"]["stages"] == 4
        assert saved["pipeline"]["bubble_fraction"] == pytest.approx(
            bubble_fraction(4, 8))
    finally:
        paths.COMM_PLANS = old


# ---------------------------------------------------------------------------
# scripts/bench_ci.py — the regression gate
# ---------------------------------------------------------------------------

def _load_bench_ci():
    path = os.path.join(os.path.dirname(__file__), "..", "scripts",
                        "bench_ci.py")
    spec = importlib.util.spec_from_file_location("bench_ci", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_ci_gate_trips_on_regression(tmp_path):
    bench_ci = _load_bench_ci()
    base = {"a/b/auto": {"modeled_step_ms": 10.0, "arm": "x"},
            "a/b/fixed": {"modeled_step_ms": 20.0, "arm": "y"}}
    bdir = tmp_path / "baselines"
    bdir.mkdir()
    (bdir / "BENCH_planner.json").write_text(json.dumps(base))

    ok = {"planner": {k: dict(v) for k, v in base.items()}}
    assert bench_ci.gate(ok, str(bdir), 0.10) == []

    # +5% passes, +20% trips, vanished number trips
    mild = {"planner": {k: {"modeled_step_ms": v["modeled_step_ms"] * 1.05,
                            "arm": v["arm"]} for k, v in base.items()}}
    assert bench_ci.gate(mild, str(bdir), 0.10) == []
    bad = {"planner": {k: {"modeled_step_ms": v["modeled_step_ms"] * 1.20,
                           "arm": v["arm"]} for k, v in base.items()}}
    fails = bench_ci.gate(bad, str(bdir), 0.10)
    assert len(fails) == 2 and all("+20.0%" in f for f in fails)
    gone = {"planner": {"a/b/auto": base["a/b/auto"]}}
    assert any("vanished" in f for f in bench_ci.gate(gone, str(bdir), 0.10))
    # missing baseline file is itself a failure
    assert bench_ci.gate({"sharded": {}}, str(bdir), 0.10)


def test_bench_ci_committed_baselines_exist_and_match_schema():
    bdir = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines")
    for suite in ("planner", "sharded", "pipeline", "topology"):
        path = os.path.join(bdir, f"BENCH_{suite}.json")
        assert os.path.exists(path), f"missing committed baseline {path}"
        with open(path) as f:
            recs = json.load(f)
        assert recs, path
        for name, r in recs.items():
            assert "modeled_step_ms" in r and r["modeled_step_ms"] > 0, name
