"""WFBP/MG-WFBP/P3 analytic overlap model (survey §3.3, Fig. 8) — property
tests with hypothesis."""
import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.core.schedule import (LayerProfile, iteration_time_fifo,
                                 iteration_time_mg_wfbp, iteration_time_p3,
                                 iteration_time_wfbp, wfbp_case)

profiles = st.lists(
    st.tuples(st.floats(1e-5, 1e-2), st.floats(1e3, 1e8)).map(
        lambda t: LayerProfile(t_backward_s=t[0], grad_bytes=t[1])),
    min_size=1, max_size=24)

link = st.tuples(st.floats(1e-7, 1e-4), st.floats(1e-11, 1e-9))


@given(profiles, link)
@settings(max_examples=80, deadline=None)
def test_wfbp_never_worse_than_fifo(layers, ab):
    a, b = ab
    assert iteration_time_wfbp(layers, a, b) <= \
        iteration_time_fifo(layers, a, b) + 1e-12


@given(profiles, link)
@settings(max_examples=80, deadline=None)
def test_wfbp_lower_bounds(layers, ab):
    """Iteration can never beat max(total backward, total comm)."""
    a, b = ab
    tb = sum(l.t_backward_s for l in layers)
    tc = sum(a + l.grad_bytes * b for l in layers)
    t = iteration_time_wfbp(layers, a, b)
    assert t >= tb - 1e-12
    assert t >= tc - 1e-12


@given(profiles, link)
@settings(max_examples=80, deadline=None)
def test_mg_wfbp_saves_alpha(layers, ab):
    """With a huge bucket (one merged message), MG-WFBP pays one alpha
    instead of L — so it is at least as good as WFBP when alpha dominates."""
    a, b = ab
    big_bucket = sum(l.grad_bytes for l in layers) + 1
    merged = iteration_time_mg_wfbp(layers, a, b, big_bucket)
    tb = sum(l.t_backward_s for l in layers)
    tc_merged = a + sum(l.grad_bytes for l in layers) * b
    assert merged <= tb + tc_merged + 1e-9


@given(profiles, link)
@settings(max_examples=50, deadline=None)
def test_p3_not_slower_than_serial(layers, ab):
    a, b = ab
    t = iteration_time_p3(layers, a, b, slice_bytes=4e6)
    assert t <= iteration_time_fifo(layers, a, b) * (1 + 1e-9) + \
        a * len(layers)  # slicing can add at most per-layer latency terms


def test_fig8_cases():
    """Reconstruct the survey's three overlap regimes."""
    a, b = 5e-6, 1 / 10e9
    fast_net = [LayerProfile(1e-3, 1e5)] * 10       # comm tiny: case 1
    balanced = [LayerProfile(1e-3, 5e6)] * 10       # comparable: case 2/3
    slow_net = [LayerProfile(1e-4, 2e7)] * 10       # comm dominates: case 3
    assert wfbp_case(fast_net, a, b) == 1
    assert wfbp_case(slow_net, a, b) == 3
    assert wfbp_case(balanced, a, b) >= 2


def test_mg_wfbp_beats_wfbp_in_latency_bound_regime():
    """Shi et al.'s observation: many small messages -> merging wins."""
    a, b = 1e-3, 1 / 50e9                            # very high latency
    layers = [LayerProfile(1e-4, 1e4)] * 50
    wfbp = iteration_time_wfbp(layers, a, b)
    merged = iteration_time_mg_wfbp(layers, a, b, bucket_bytes=1e9)
    assert merged < wfbp * 0.25
