"""Collective algorithms + distributed grad-sync correctness.

The multi-device checks need 8 host devices, which must be configured
BEFORE jax initializes — so they run in a subprocess
(tests/multi_device_checks.py); this process keeps its 1-device view.
Single-device (degenerate, world=1) behaviour is tested inline.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GradientSynchronizer, SyncConfig, bucketize
from repro.core.collectives import LinkParams, allreduce_cost_s


def test_multi_device_suite():
    script = os.path.join(os.path.dirname(__file__), "multi_device_checks.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    res = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "ALL MULTI-DEVICE CHECKS PASSED" in res.stdout


def test_grad_sync_single_device_degenerate():
    """world=1: every compressor + EF behaves like local compression."""
    from jax.sharding import AxisType
    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 8))}
    for comp in ("none", "int8", "topk", "powersgd"):
        sync = GradientSynchronizer(SyncConfig(compressor=comp, algo="ring"),
                                    ("data",))
        from jax.sharding import PartitionSpec as P

        def body(g, rng):
            st = sync.init_state(g)
            out, st2 = sync(g, st, rng)
            return out

        f = jax.shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                          axis_names={"data"}, check_vma=False)
        out = jax.jit(f)(grads, jax.random.PRNGKey(1))
        assert jnp.all(jnp.isfinite(out["w"]))


def test_bucketize_roundtrip():
    pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
    import hypothesis.strategies as st
    from hypothesis import given, settings

    @given(st.lists(st.integers(1, 500), min_size=1, max_size=12),
           st.integers(256, 8192))
    @settings(max_examples=25, deadline=None)
    def run(sizes, bucket_bytes):
        grads = {f"p{i}": jnp.arange(n, dtype=jnp.float32) + i
                 for i, n in enumerate(sizes)}
        defs, pack, unpack = bucketize(grads, bucket_bytes)
        restored = unpack(pack(grads))
        for k in grads:
            np.testing.assert_allclose(np.asarray(restored[k]),
                                       np.asarray(grads[k]))
        # every leaf appears exactly once
        seen = sorted(i for b in defs for i, _ in b)
        assert seen == list(range(len(sizes)))

    run()


def test_alpha_beta_cost_model():
    """Survey Fig. 10/12: ring is bandwidth-optimal for large messages; tree
    (PS) wins at small sizes / high latency; hierarchical sits between."""
    link = LinkParams(alpha_s=5e-6, beta_s_per_byte=1 / 50e9)
    big, small = 1e9, 1e3
    p = 256
    assert allreduce_cost_s("ring", big, p, link) < \
        allreduce_cost_s("tree", big, p, link)
    assert allreduce_cost_s("tree", small, p, link) < \
        allreduce_cost_s("ring", small, p, link)
    h = allreduce_cost_s("hierarchical", big, p, link, k=16)
    assert h < allreduce_cost_s("tree", big, p, link)
    # 2D-mesh split halves the single-phase time (Ying et al.)
    m = allreduce_cost_s("mesh2d", big, p, link)
    ms = allreduce_cost_s("mesh2d_split", big, p, link)
    assert abs(ms - m / 2) < 1e-9
