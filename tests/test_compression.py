"""Compression properties (survey §3.2), including hypothesis-driven
invariants: quantizer reconstruction bounds, unbiasedness of stochastic
schemes, error-feedback contraction over steps, top-k selection, PowerSGD
exactness on low-rank inputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.core.compression import (apply_with_feedback, get_compressor)

RNG = jax.random.PRNGKey(0)

# exclude subnormals/tiny values: mean|g| underflow makes sign*scale
# round to zero in f32, which is numerics, not semantics
arrays = st.integers(2, 6).flatmap(
    lambda n: st.lists(
        st.floats(-100, 100, allow_nan=False, width=32,
                  allow_subnormal=False).filter(
            lambda v: v == 0 or abs(v) > 1e-6),
        min_size=n * 4, max_size=n * 4))


@given(arrays)
@settings(max_examples=30, deadline=None)
def test_sign_reconstruction_direction(vals):
    """sign compressor preserves elementwise sign (where nonzero)."""
    g = jnp.asarray(vals, jnp.float32)
    comp = get_compressor("sign")
    g_hat = comp.roundtrip(g)
    nz = np.asarray(g) != 0
    assert np.all(np.sign(np.asarray(g_hat))[nz] == np.sign(np.asarray(g))[nz])


@given(arrays, st.integers(1, 100))
@settings(max_examples=30, deadline=None)
def test_qsgd_bound(vals, levels):
    """|Q(g) - g| <= ||g||_2 / levels elementwise (uniform level spacing)."""
    g = jnp.asarray(vals, jnp.float32)
    comp = get_compressor("qsgd", levels=min(levels, 127))
    g_hat = comp.roundtrip(g, RNG)
    norm = float(jnp.linalg.norm(g))
    bound = norm / min(levels, 127) + 1e-5
    assert float(jnp.max(jnp.abs(g_hat - g))) <= bound


@pytest.mark.parametrize("name,kwargs", [
    ("qsgd", {"levels": 63}), ("terngrad", {}), ("randomk", {"ratio": 0.5}),
])
def test_stochastic_unbiasedness(name, kwargs):
    """E[decompress(compress(g))] == g (statistical, 4000 trials)."""
    comp = get_compressor(name, **kwargs)
    g = jax.random.normal(RNG, (16,))

    def one(key):
        return comp.roundtrip(g, key)

    keys = jax.random.split(jax.random.PRNGKey(42), 4000)
    mean = jnp.mean(jax.vmap(one)(keys), axis=0)
    err = float(jnp.max(jnp.abs(mean - g)))
    scale = float(jnp.max(jnp.abs(g)))
    assert err < 0.12 * scale, (name, err, scale)


@pytest.mark.parametrize("name,kwargs", [
    ("sign", {}), ("int8", {}), ("topk", {"ratio": 0.1}),
    ("powersgd", {"rank": 2}),
])
def test_error_feedback_contracts(name, kwargs):
    """Compressing a CONSTANT gradient with EF: the cumulative transmitted
    mass converges to the true gradient (Karimireddy et al. 2019)."""
    comp = get_compressor(name, **kwargs)
    g = jax.random.normal(RNG, (32, 16)) * 2.0
    e = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    q_prev = None
    for i in range(40):
        corrected = g + e
        if name == "powersgd":
            payload, meta = comp.compress(corrected, q_prev=q_prev)
            q_prev = meta[1]
            g_hat = comp.decompress(payload, meta)
        else:
            payload, meta = comp.compress(corrected, jax.random.fold_in(RNG, i))
            g_hat = comp.decompress(payload, meta)
        e = corrected - g_hat
        sent = sent + g_hat
    avg_sent = sent / 40.0
    rel = float(jnp.linalg.norm(avg_sent - g) / jnp.linalg.norm(g))
    assert rel < 0.15, (name, rel)


def test_topk_keeps_largest():
    g = jnp.asarray(np.random.default_rng(0).normal(size=1000), jnp.float32)
    comp = get_compressor("topk", ratio=0.05)
    g_hat = np.asarray(comp.roundtrip(g))
    kept = np.flatnonzero(g_hat)
    assert len(kept) == 50
    thresh = np.sort(np.abs(np.asarray(g)))[-50]
    assert np.all(np.abs(np.asarray(g))[kept] >= thresh - 1e-6)


def test_powersgd_exact_on_low_rank():
    """A rank-r matrix is reconstructed (near-)exactly by rank-r PowerSGD
    after a couple of warm-started iterations."""
    a = jax.random.normal(RNG, (32, 4))
    b = jax.random.normal(jax.random.fold_in(RNG, 1), (4, 24))
    m = a @ b
    comp = get_compressor("powersgd", rank=4)
    q_prev = None
    for _ in range(3):
        payload, meta = comp.compress(m, rng=RNG, q_prev=q_prev)
        q_prev = meta[1]
    approx = comp.decompress(payload, meta)
    rel = float(jnp.linalg.norm(approx - m) / jnp.linalg.norm(m))
    assert rel < 1e-4


def test_svd_oracle_beats_powersgd_on_full_rank():
    m = jax.random.normal(RNG, (32, 32))
    svd = get_compressor("svd", rank=4)
    psgd = get_compressor("powersgd", rank=4)
    e_svd = float(jnp.linalg.norm(svd.roundtrip(m) - m))
    e_psgd = float(jnp.linalg.norm(psgd.roundtrip(m, RNG) - m))
    assert e_svd <= e_psgd + 1e-4  # SVD is the optimal rank-4 approximation


@given(st.integers(8, 2048))
@settings(max_examples=20, deadline=None)
def test_payload_bits_ordering(n):
    """Wire sizes: sign < terngrad < qsgd(127) < int8(=qsgd bits) < dense."""
    shape = (n,)
    bits = {name: get_compressor(name).payload_bits(shape)
            for name in ("sign", "terngrad", "int8", "none")}
    bits["qsgd"] = get_compressor("qsgd", levels=127).payload_bits(shape)
    assert bits["sign"] < bits["terngrad"] < bits["qsgd"] <= bits["int8"] \
        < bits["none"]


def test_threshold_zeroes_small():
    comp = get_compressor("threshold", tau=0.5)
    g = jnp.asarray([-1.0, -0.4, 0.0, 0.3, 0.9])
    out = np.asarray(comp.roundtrip(g))
    np.testing.assert_allclose(out, [-1.0, 0.0, 0.0, 0.0, 0.9], atol=1e-6)


# ---------------------------------------------------------------------------
# Fused wires (DESIGN.md §11): the one-pass hooks must be BIT-IDENTICAL to
# the decomposed reference chain under jit — payload AND residual — across
# ragged lengths, 2-D leaves and bf16 inputs.
# ---------------------------------------------------------------------------

FUSED = [("int8_fused", {}), ("topk_fused", {"ratio": 0.25})]


@pytest.mark.parametrize("name,kw", FUSED, ids=[f[0] for f in FUSED])
@pytest.mark.parametrize("shape", [(2500,), (64, 33)],
                         ids=["ragged-1d", "2d"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_fused_hooks_bit_identical_to_chain(name, kw, shape, dtype):
    comp = get_compressor(name, tile=1024, **kw)
    g = jax.random.normal(RNG, shape, getattr(jnp, dtype))
    e = jax.random.normal(jax.random.fold_in(RNG, 1), shape,
                          jnp.float32) * 0.1

    @jax.jit
    def fused(g, e):
        return comp.fused_ef_compress(g, e, 1.0)

    @jax.jit
    def chain(g, e):
        corrected = g.astype(jnp.float32) + 1.0 * e
        payload, meta = comp.compress(corrected, None)
        return payload, meta, corrected - comp.decompress(payload, meta)

    pf, mf, ef = fused(g, e)
    pu, mu, eu = chain(g, e)
    assert mf == mu
    for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pu)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} payload")
    np.testing.assert_array_equal(np.asarray(ef), np.asarray(eu),
                                  err_msg=f"{name} residual")
    assert ef.shape == g.shape and ef.dtype == jnp.float32


def test_fused_decode_sum_matches_per_rank_loop():
    """One fused dequantize+accumulate pass over the gathered payloads ==
    the per-rank decompress loop (up to f32 summation order)."""
    comp = get_compressor("int8_fused", tile=1024)
    n, w = 2500, 8
    payloads, metas = [], []
    for i in range(w):
        g = jax.random.normal(jax.random.fold_in(RNG, i), (n,)) * (1 + i)
        p, m = comp.compress(g, None)
        payloads.append(p)
        metas.append(m)
    gathered = jax.tree.map(lambda *xs: jnp.stack(xs), *payloads)
    got = comp.fused_decode_sum(gathered, metas[0])
    want = sum(comp.decompress(p, m) for p, m in zip(payloads, metas))
    assert got.shape == (n,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_fused_payload_bits():
    """int8_fused: 8 bits/elem + one f32 scale per tile; topk_fused: the
    survey's (value, index) accounting, 64 bits per kept element."""
    i8 = get_compressor("int8_fused", tile=1024)
    assert i8.payload_bits((2048,)) == 2048 * 8 + 2 * 32
    assert i8.payload_bits((1000,)) == 1000 * 8 + 32      # ragged: 1 tile
    assert not i8.aggregatable
    tk = get_compressor("topk_fused", ratio=0.25, tile=1024)
    assert tk.payload_bits((2048,)) == 2 * 256 * 64
    assert tk.aggregatable


def test_fused_ef_decay_applied_before_quantize():
    """The decay factor scales the carried residual INSIDE the one-pass
    kernel: fused(decay) == chain on g + decay*e."""
    comp = get_compressor("int8_fused", tile=1024)
    g = jax.random.normal(RNG, (2048,))
    e = jax.random.normal(jax.random.fold_in(RNG, 1), (2048,))
    (q, sc), _, e_new = jax.jit(
        lambda g, e: comp.fused_ef_compress(g, e, 0.9))(g, e)
    corrected = g + 0.9 * e
    q2, sc2 = jax.jit(lambda c: comp.compress(c, None)[0])(corrected)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(sc), np.asarray(sc2))
    np.testing.assert_allclose(
        np.asarray(e_new),
        np.asarray(corrected - comp.decompress((q, sc), (2048,))),
        atol=1e-6)
