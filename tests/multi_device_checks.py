"""Multi-device correctness checks, run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests must not pollute
the main process's device count — smoke tests see 1 device).

Exit code 0 = all checks passed.  Invoked by test_collectives.py.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import repro.compat  # noqa: E402,F401  (AxisType/shard_map shims on old JAX)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P  # noqa: E402


def check_collectives():
    from repro.core.collectives import allreduce, ALGOS
    mesh = jax.make_mesh((4, 2), ("data", "pod"), axis_types=(AxisType.Auto,) * 2)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 37))
    ref = np.asarray(x).reshape(4, 2, 37).sum(axis=(0, 1))
    for algo in ALGOS:
        f = jax.shard_map(lambda v: allreduce(v, algo, ("data", "pod")),
                          mesh=mesh, in_specs=P(("data", "pod"), None),
                          out_specs=P(None, None),
                          axis_names={"data", "pod"}, check_vma=False)
        out = np.asarray(jax.jit(f)(x))[0]
        if algo == "ring_fused":
            # the compressed ring is LOSSY by design (int8 wire with
            # per-hop requantization of partial sums, DESIGN.md §11):
            # bounded relative error, not exact.  Rank agreement is
            # checked with per-rank out_specs in check_ring_fused.
            rel = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
            assert rel < 0.05, ("ring_fused", rel)
        else:
            assert np.allclose(out, ref, atol=1e-4), algo
        # the manual algorithms must NOT lower to a plain all-reduce
        txt = jax.jit(f).lower(x).compile().as_text()
        if algo not in ("psum",):
            assert "collective-permute" in txt, algo
    print("collectives ok")


def check_grad_sync():
    from repro.core import GradientSynchronizer, SyncConfig
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 64, 32)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (8, 33))}
    ref = jax.tree.map(lambda g: np.asarray(g).mean(0), grads)
    configs = [
        SyncConfig(compressor="none", algo="ring"),
        SyncConfig(compressor="int8", algo="hierarchical"),
        SyncConfig(compressor="qsgd", algo="ring"),
        SyncConfig(compressor="topk", algo="ring",
                   compressor_args=(("ratio", 0.5),)),
        SyncConfig(compressor="powersgd", algo="mesh2d",
                   compressor_args=(("rank", 16),)),
        # the fused Pallas wires (DESIGN.md §11), including the lossy
        # compressed-ring transport for the int8 payload
        SyncConfig(compressor="int8_fused", algo="ring"),
        SyncConfig(compressor="int8_fused", algo="ring_fused"),
        SyncConfig(compressor="topk_fused", algo="ring",
                   compressor_args=(("ratio", 0.25),)),
    ]
    for cfg in configs:
        sync = GradientSynchronizer(cfg, ("data",))

        def body(g, rng):
            g = jax.tree.map(lambda x: x[0], g)
            st = sync.init_state(g)
            out, _ = sync(g, st, rng)
            return out

        f = jax.shard_map(body, mesh=mesh,
                          in_specs=({"w": P("data", None, None),
                                     "b": P("data", None)}, P()),
                          out_specs={"w": P(None, None), "b": P(None)},
                          axis_names={"data"}, check_vma=False)
        out = jax.jit(f)(grads, jax.random.PRNGKey(0))
        for k in ref:
            denom = np.abs(ref[k]).max() + 1e-9
            rel = float(jnp.max(jnp.abs(out[k] - ref[k]))) / denom
            limit = 1e-5 if cfg.compressor == "none" else 1.2
            assert rel < limit, (cfg.compressor, rel)
    print("grad_sync ok")


def check_error_feedback_converges_distributed():
    """EF-compressed SGD on a shared quadratic reaches the optimum even with
    1-bit sign compression (the survey's §3.2.1 headline result)."""
    from repro.core import GradientSynchronizer, SyncConfig
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    w_star = jax.random.normal(jax.random.PRNGKey(5), (64,))
    sync = GradientSynchronizer(
        SyncConfig(compressor="sign", algo="ring"), ("data",))

    def run(noise):
        def body(noise):
            w = jnp.zeros((64,))
            st = sync.init_state({"w": w})

            def step(carry, i):
                w, st = carry
                # per-worker noisy gradient of ||w - w*||^2 / 2
                g = (w - w_star) + noise[0, i % 16]
                synced, st = sync({"w": g}, st, jax.random.fold_in(
                    jax.random.PRNGKey(0), i))
                w = w - 0.3 * synced["w"]
                return (w, st), None

            (w, _), _ = jax.lax.scan(step, (w, st), jnp.arange(300))
            return w

        f = jax.shard_map(body, mesh=mesh,
                          in_specs=P("data", None, None),
                          out_specs=P(None), axis_names={"data"},
                          check_vma=False)
        return jax.jit(f)(noise)

    noise = jax.random.normal(jax.random.PRNGKey(6), (8, 16, 64)) * 0.5
    # zero-mean noise across workers
    noise = noise - noise.mean(axis=0, keepdims=True)
    w = run(noise)
    rel = float(jnp.linalg.norm(w - w_star) / jnp.linalg.norm(w_star))
    assert rel < 0.05, rel
    print("EF sign-SGD convergence ok, rel err", rel)


def check_ring_fused():
    """The compressed-ring prototype on 8 REAL ranks (DESIGN.md §11):
    every rank reconstructs the SAME lossy sum (the all-gather phase
    circulates one quantized payload per chunk, owner included — any
    per-rank dequantization asymmetry would diverge replicas), the error
    is within the per-hop requantization bound, and the wire actually
    lowers to ppermute steps, not a hidden all-reduce."""
    from repro.core.collectives import allreduce
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    x = jax.random.normal(jax.random.PRNGKey(30), (8, 5000))
    ref = np.asarray(x).sum(0)

    f = jax.jit(jax.shard_map(
        lambda v: allreduce(v[0], "ring_fused", ("data",))[None],
        mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
        axis_names={"data"}, check_vma=False))
    per_rank = np.asarray(f(x))                 # (8, 5000), one row per rank
    assert np.all(per_rank == per_rank[0:1]), "ranks disagree"
    rel = np.abs(per_rank[0] - ref).max() / (np.abs(ref).max() + 1e-9)
    assert rel < 0.05, rel
    txt = f.lower(x).compile().as_text()
    assert "collective-permute" in txt and "all-reduce" not in txt
    print(f"ring_fused ok (8 ranks agree bitwise, rel err {rel:.4f})")


def check_fused_bit_trajectory():
    """THE fused-wire acceptance criterion: the one-pass kernels vs the
    SAME plan with ``fused=False`` (decomposed reference chain) on the
    REAL 8-device mesh, 3 sync rounds — EF residual trajectories must be
    bit-identical for both wires (int8 tiles + scales, bisection top-k).
    Payload equality per call is pinned at the compressor level in
    test_compression.py; residual equality across steps proves the
    executor's fused dispatch feeds the kernels identical buffers and
    carries identical state.  Synced sums: bit-equal for the aggregatable
    top-k; the int8 gather wire's fused decode is one reduction over the
    payload axis vs the loop's sequential adds — 2-ulp bound, the
    documented summation-order difference."""
    import dataclasses
    from repro.core import PlanExecutor, SyncConfig
    from repro.core.grad_sync import plan_from_config

    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    tmpl = {"w": jnp.zeros((64, 33)), "b": jnp.zeros((17,))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(31), (8, 3, 64, 33)),
             "b": jax.random.normal(jax.random.PRNGKey(32), (8, 3, 17))}

    for name, args in (("int8_fused", ()), ("topk_fused",
                                            (("ratio", 0.25),))):
        plan_f = plan_from_config(
            SyncConfig(compressor=name, algo="ring", bucket_bytes=2048,
                       compressor_args=args), tmpl)
        assert all(b.fused for b in plan_f.buckets)
        plan_u = dataclasses.replace(plan_f, buckets=tuple(
            dataclasses.replace(b, fused=False) for b in plan_f.buckets))
        outs = {}
        for tag, plan in (("fused", plan_f), ("unfused", plan_u)):
            ex = PlanExecutor(plan, ("data",))

            def body(g):
                g0 = jax.tree.map(lambda x: x[0], g)
                st = ex.init_state(jax.tree.map(lambda x: x[0], g0))
                res, errs = [], []
                for s in range(3):
                    out, st = ex(jax.tree.map(lambda x: x[s], g0), st,
                                 jax.random.PRNGKey(0))
                    res.append(out)
                    errs.append([e for e in st["error"] if e is not None])
                return res, errs

            f = jax.shard_map(body, mesh=mesh,
                              in_specs=({"w": P("data", None, None, None),
                                         "b": P("data", None, None)},),
                              out_specs=(P(None), P(None)),
                              axis_names={"data"}, check_vma=False)
            outs[tag] = jax.jit(f)(grads)
        (res_f, errs_f), (res_u, errs_u) = outs["fused"], outs["unfused"]
        for s in range(3):
            assert len(errs_f[s]) == len(errs_u[s]) > 0
            for j, (a, b) in enumerate(zip(errs_f[s], errs_u[s])):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{name} step {s} EF[{j}]")
            for k in ("w", "b"):
                a = np.asarray(res_f[s][k], np.float32)
                b = np.asarray(res_u[s][k], np.float32)
                if name == "topk_fused":
                    np.testing.assert_array_equal(
                        a, b, err_msg=f"{name} step {s} {k}")
                else:
                    tol = 2 * np.finfo(np.float32).eps * max(
                        1.0, np.abs(b).max())
                    assert np.abs(a - b).max() <= tol, (name, s, k)
    print("fused-vs-unfused bit trajectory ok (EF residuals bit-equal "
          "over 3 steps, int8 + topk, 8 ranks)")


def check_plan_executor_heterogeneous():
    """A CommPlan mixing dense/psum, packed int8/ring, and per-leaf topk
    must approximate the all-worker mean on a real 8-device mesh."""
    from repro.core import PlanExecutor
    from repro.core.schedule.planner import BucketPlan, CommPlan
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(11), (8, 64, 32)),
             "b": jax.random.normal(jax.random.PRNGKey(12), (8, 33))}
    ref = jax.tree.map(lambda g: np.asarray(g).mean(0), grads)
    # leaf order: b, w
    plan = CommPlan(buckets=(
        BucketPlan(leaves=(0,), compressor="none", algo="psum",
                   bucket_bytes=4 * 33),
        BucketPlan(leaves=(1,), compressor="int8", algo="ring",
                   bucket_bytes=4 * 64 * 32, pack=True),
    ))
    ex = PlanExecutor(plan, ("data",))

    def body(g, rng):
        g = jax.tree.map(lambda x: x[0], g)
        st = ex.init_state(g)
        out, st2 = ex(g, st, rng)
        return out

    f = jax.shard_map(body, mesh=mesh,
                      in_specs=({"w": P("data", None, None),
                                 "b": P("data", None)}, P()),
                      out_specs={"w": P(None, None), "b": P(None)},
                      axis_names={"data"}, check_vma=False)
    out = jax.jit(f)(grads, jax.random.PRNGKey(0))
    # dense psum bucket: exact; int8 bucket: close
    np.testing.assert_allclose(np.asarray(out["b"]), ref["b"], atol=1e-5)
    rel = float(jnp.max(jnp.abs(out["w"] - ref["w"]))) / \
        (np.abs(ref["w"]).max() + 1e-9)
    assert rel < 1.2, rel
    print("heterogeneous plan executor ok")


def check_local_sgd():
    from repro.core import average_params
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    params = {"w": jax.random.normal(jax.random.PRNGKey(7), (8, 16))}
    f = jax.shard_map(lambda p: average_params(p, ("data",)),
                      mesh=mesh, in_specs=({"w": P("data", None)},),
                      out_specs={"w": P(None)}, axis_names={"data"},
                      check_vma=False)
    out = jax.jit(f)(params)
    np.testing.assert_allclose(np.asarray(out["w"])[0],
                               np.asarray(params["w"]).mean(0), atol=1e-5)
    print("local sgd averaging ok")


def check_param_round_strategy():
    """SyncStrategy param round on 8 REAL workers (DESIGN.md §7): per-worker
    diverged params go in with a leading worker axis, one anchor-delta
    round brings every worker to (≈, for the compressed plan) the mean."""
    from repro.core import PlanExecutor, SyncConfig, plan_from_config
    from repro.launch.steps import make_param_round_step

    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    anchor = {"w": jax.random.normal(jax.random.PRNGKey(11), (16, 8))}
    noise = jax.random.normal(jax.random.PRNGKey(12), (8, 16, 8)) * 0.01
    params_w = {"w": anchor["w"][None] + noise}   # 8 diverged workers

    for comp, tol in (("none", 1e-6), ("int8", 2e-3)):
        reducer = PlanExecutor(
            plan_from_config(SyncConfig(compressor=comp, bucket_bytes=0),
                             anchor), ("data",))
        round_fn = jax.jit(make_param_round_step(reducer, mesh, ("data",)))
        red_state = jax.tree.map(
            lambda s: jnp.broadcast_to(s, (8,) + s.shape),
            reducer.init_state(anchor))
        out, new_anchor, _ = round_fn(params_w, anchor, red_state,
                                      jax.random.PRNGKey(0))
        got = np.asarray(out["w"])
        want = np.asarray(params_w["w"]).mean(0)
        assert np.all(got == got[0:1]), f"{comp}: workers disagree"
        np.testing.assert_allclose(got[0], want, atol=tol)
        np.testing.assert_allclose(np.asarray(new_anchor["w"]), got[0],
                                   atol=1e-6)
    print("strategy param round ok")


from tiny_lm import TinyLM as _TinyLM, tiny_batch as _tiny_batch  # noqa: E402
from tiny_lm import TinyStackLM as _TinyStackLM  # noqa: E402


def check_pipeline_bit_exact():
    """ISSUE 4's tentpole acceptance criterion: the pipeline(S=2, M=4)
    1F1B train step on the 8-device pipe(2) x data(4) mesh must match the
    single-stage DP step (pipe(1) x data(4), same global batch, same M
    micro-batches) BIT-EXACTLY — params and optimizer state over 3 steps,
    adam + sgd — including under int8/top-k DP-edge compression (the
    per-row sync granularity makes the compressed wire stage-count
    invariant; matching params+moments over 3 steps implies the EF
    residual trajectories agree, since residuals feed every later step).

    What makes this exact (DESIGN.md §9): row-boundary optimization
    barriers keep XLA fusion from crossing potential cut points (so a
    row's forward/backward compiles identically at every stage count), and
    the optimizer updates the per-row-unstacked tree (same leaf shapes at
    every S).  Should the XLA-owned psum wire ever reorder its reduction
    between the two programs, the documented fallback is the §8 ulp
    tolerance — flip ``exact`` for that row.
    """
    from repro.core import GradientSynchronizer, SyncConfig
    from repro.launch.mesh import make_pipe_mesh
    from repro.launch.steps import make_pipeline_train_step
    from repro.optim import make_optimizer

    M = 4

    def run(S, opt_name, comp, algo):
        model = _TinyStackLM(blocks=2, n_stages=S)
        params = model.init(jax.random.PRNGKey(0))
        mesh = make_pipe_mesh(S, 4)
        opt = make_optimizer(opt_name, lr=0.05)
        engine = GradientSynchronizer(
            SyncConfig(compressor=comp, algo=algo, bucket_bytes=0),
            ("data",))
        step_fn, init_opt, init_ss = make_pipeline_train_step(
            model, opt, engine, mesh, M)
        shared, rows = model.split(params)
        p = {"shared": shared, "rows": rows}
        o, ss = init_opt(p), init_ss(p)
        jit = jax.jit(step_fn)
        rng = jax.random.PRNGKey(1)
        for s in range(3):
            p, o, ss, loss = jit(p, o, ss, _tiny_batch(s, batch=16, seq=12),
                                 jnp.asarray(s, jnp.int32),
                                 jax.random.fold_in(rng, s))
        from repro.launch.steps import merge_opt_rows
        merged = model.merge(p["shared"], p["rows"])
        return merged, merge_opt_rows(o, model.layout.rows), float(loss)

    for opt_name, comp, algo, exact in (
            ("adam", "none", "psum", True),
            ("adam", "none", "ring", True),
            ("adam", "int8", "ring", True),
            ("adam", "topk", "ring", True),
            ("sgd", "none", "ring", True),
            ("sgd", "none", "psum", True)):
        p1, o1, l1 = run(1, opt_name, comp, algo)
        p2, o2, l2 = run(2, opt_name, comp, algo)
        for (path, a), (_, b) in list(zip(
                jax.tree_util.tree_leaves_with_path(p1),
                jax.tree_util.tree_leaves_with_path(p2))) + list(zip(
                jax.tree_util.tree_leaves_with_path(o1),
                jax.tree_util.tree_leaves_with_path(o2))):
            a, b = np.asarray(a), np.asarray(b)
            what = (opt_name, comp, algo, jax.tree_util.keystr(path))
            if exact:
                assert np.array_equal(a, b), \
                    (what, np.abs(a - b).max())
            else:
                np.testing.assert_allclose(a, b, rtol=3e-5, atol=1e-7,
                                           err_msg=str(what))
        assert abs(l1 - l2) < 1e-5, (opt_name, comp, algo, l1, l2)
    print("pipeline S=2 bit-exact vs single-stage DP ok (adam/sgd x "
          "psum/ring/int8/topk, params + opt state, 3 steps)")


def check_pipeline_matches_classic_dp_step():
    """Anchor for the S=1 reference itself: the degenerate pipeline step
    (S=1, M=1, dense psum) against the classic replicated DP step
    (_make_synced_train_step) — same loss and ulp-tight params (the two
    programs differ only in vjp composition and XLA contraction)."""
    from repro.core import PlanExecutor, SyncConfig, plan_from_config
    from repro.core import GradientSynchronizer
    from repro.launch.mesh import make_pipe_mesh
    from repro.launch.steps import (_make_synced_train_step,
                                    make_pipeline_train_step)
    from repro.optim import make_optimizer

    model = _TinyStackLM(blocks=2, n_stages=1)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adam", lr=0.05)
    batch = _tiny_batch(0, batch=16, seq=12)
    step_i = jnp.zeros((), jnp.int32)
    rng = jax.random.PRNGKey(1)

    mesh_c = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
    exec_c = PlanExecutor(plan_from_config(SyncConfig(), params), ("data",))
    cstep, _, init_cs = _make_synced_train_step(model, opt, exec_c, mesh_c,
                                                ("data",))
    pc, oc, sc = params, opt.init(params), init_cs(params)
    pc, oc, _, lc = jax.jit(cstep)(pc, oc, sc, batch, step_i, rng)

    mesh_p = make_pipe_mesh(1, 4)
    engine = GradientSynchronizer(SyncConfig(bucket_bytes=0), ("data",))
    pstep, init_po, init_ps = make_pipeline_train_step(model, opt, engine,
                                                       mesh_p, 1)
    shared, rows = model.split(params)
    pp = {"shared": shared, "rows": rows}
    op, sp = init_po(pp), init_ps(pp)
    pp, op, _, lp = jax.jit(pstep)(pp, op, sp, batch, step_i, rng)
    merged = model.merge(pp["shared"], pp["rows"])

    assert abs(float(lc) - float(lp)) < 1e-6, (float(lc), float(lp))
    for k in ("emb", "out", "b"):
        np.testing.assert_allclose(np.asarray(merged[k]),
                                   np.asarray(pc[k]),
                                   rtol=3e-5, atol=1e-7, err_msg=k)
    for k in ("w1", "b1", "w2"):
        np.testing.assert_allclose(np.asarray(merged["blocks"][k]),
                                   np.asarray(pc["blocks"][k]),
                                   rtol=3e-5, atol=1e-7, err_msg=k)
    print("pipeline S=1/M=1 matches the classic DP step ok (ulp-tight)")


def check_sharded_dp_bit_exact():
    """The tentpole acceptance criterion: sharded-DP (reduce-scatter grads,
    1/p-partitioned master params + Adam moments, params all-gather) must
    be BIT-EXACT vs replicated DP for dense fp32 over 3 steps on a real
    8-device mesh — for both the explicit ring wires and psum — and the
    per-device optimizer-state arrays must actually be 1/8 the replicated
    footprint.  Compressed (int8) wires must match bit-for-bit too (same
    payload gather, sliced), including the EF residual trajectory."""
    from repro.core import PlanExecutor, ShardLayout, SyncConfig
    from repro.core.grad_sync import sharded_plan_from_config
    from repro.launch.steps import (_make_synced_train_step,
                                    make_sharded_train_step)
    from repro.optim import make_optimizer, make_sharded_optimizer

    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    model = _TinyLM()
    params0 = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)

    for opt_name, algo, comp, exact in (
            ("adam", "ring", "none", True),
            ("adam", "psum", "none", True),
            ("adam", "ring", "int8", True),
            ("sgd", "ring", "none", True),
            ("lamb", "ring", "none", False)):   # layerwise norms: psum order
        cfg = SyncConfig(compressor=comp, algo=algo,
                         bucket_bytes=2048 if comp != "none" else 32 * 2**20)
        shared_plan = sharded_plan_from_config(cfg, params0)
        opt = make_optimizer(opt_name, lr=0.05)

        # replicated reference runs the SAME plan (same bucket boundaries:
        # ring chunk sums depend on them — DESIGN.md §8)
        step_fn, _, init_ss = _make_synced_train_step(
            model, opt, PlanExecutor(shared_plan, ("data",)), mesh,
            ("data",))
        p_r, os_r, ss_r = params0, opt.init(params0), init_ss(params0)
        jit_r = jax.jit(step_fn)
        for s in range(3):
            p_r, os_r, ss_r, _ = jit_r(p_r, os_r, ss_r, _tiny_batch(s),
                                       jnp.asarray(s, jnp.int32),
                                       jax.random.fold_in(rng, s))

        ex = PlanExecutor(shared_plan, ("data",))
        layout = ShardLayout.from_plan(shared_plan, params0, (8,))
        shopt = make_sharded_optimizer(opt_name, layout, ("data",), lr=0.05)
        sfn, init_rows, init_ss2 = make_sharded_train_step(
            model, ex, layout, shopt, mesh, ("data",))
        p_s, rows, ss_s = params0, init_rows(params0), init_ss2(params0)
        jit_s = jax.jit(sfn)
        for s in range(3):
            p_s, rows, ss_s, _ = jit_s(p_s, rows, ss_s, _tiny_batch(s),
                                       jnp.asarray(s, jnp.int32),
                                       jax.random.fold_in(rng, s))

        def cmp(a, b, what):
            a, b = np.asarray(a), np.asarray(b)
            if exact:
                assert np.array_equal(a, b), \
                    (opt_name, algo, comp, what, np.abs(a - b).max())
            else:
                np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-7)

        for k in p_r:
            cmp(p_r[k], p_s[k], f"params/{k}")
        if opt_name in ("adam", "lamb"):
            for mom in ("m", "v"):
                full = layout.tree_from_rows(rows["opt"][mom], params0)
                for k in p_r:
                    cmp(os_r[mom][k], full[k], f"{mom}/{k}")
        master = layout.tree_from_rows(rows["master"], params0)
        for k in p_r:
            cmp(master[k], p_s[k], f"master/{k}")
        if comp != "none":
            for a, b in zip(ss_r["error"], ss_s["error"]):
                if a is not None:
                    cmp(a, b, "EF residual")

        # the memory identity: per-device partitioned state is 1/8 (+pad)
        n_total = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params0))
        per_dev = sum(b.m for b in layout.buckets)
        assert per_dev <= -(-n_total // 8) + len(layout.buckets) * 8, \
            (per_dev, n_total)
        for r in rows["master"]:
            assert r.shape[0] == 8    # leading worker axis, sharded
    print("sharded-DP bit-exact vs replicated ok (ring/psum, int8, "
          "adam/sgd exact; lamb close)")


def check_sharded_checkpoint_reshard():
    """Partitioned optimizer state round-trips through a checkpoint onto a
    DIFFERENT mesh shape bit-equal: save 8-way shard rows, restore, re-chunk
    to a 4-way (and 2x2) layout — the reconstructed full state is identical
    because every layout chunks the same canonical flat buffer."""
    from repro.checkpoint import restore, save
    from repro.core import ShardLayout, SyncConfig
    from repro.core.grad_sync import sharded_plan_from_config
    import tempfile

    model = _TinyLM()
    params = model.init(jax.random.PRNGKey(3))
    plan = sharded_plan_from_config(SyncConfig(bucket_bytes=4096), params)
    lay8 = ShardLayout.from_plan(plan, params, (8,))
    rows8 = lay8.shard_rows(params)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save(path, {"master": rows8}, step=7)
        like = {"master": [np.zeros(r.shape, np.float32) for r in rows8]}
        restored = restore(path, like)

    lay4, rows4 = lay8.reshard(restored["master"], (4,))
    lay22, rows22 = lay8.reshard(restored["master"], (2, 2))
    want = jax.tree.leaves(params)
    for lay, rows in ((lay4, rows4), (lay22, rows22), (lay8, rows8)):
        got = lay.tree_from_rows(rows, params)
        for a, b in zip(jax.tree.leaves(got), want):
            assert np.array_equal(np.asarray(a),
                                  np.asarray(b).astype(np.float32)), \
                lay.axis_sizes
    print("sharded checkpoint reshard ok (8 -> 4, 8 -> 2x2, bit-equal)")


def check_reduce_scatter_all_gather_roundtrip():
    """The sharded wire primitives on a 2-axis (4x2) mesh: nested-canonical
    reduce_scatter chunks must agree with the host-side chunking twin, and
    all_gather_shards must invert them exactly."""
    from repro.core import chunk_rows
    from repro.core.collectives import all_gather_shards, reduce_scatter

    mesh = jax.make_mesh((4, 2), ("data", "pod"),
                         axis_types=(AxisType.Auto,) * 2)
    n = 37
    x = jax.random.normal(jax.random.PRNGKey(9), (8, n))
    ref = np.asarray(x).sum(0)
    for algo in ("psum", "ring", "hierarchical"):
        def body(v):
            v = v[0]
            sh = reduce_scatter(v, algo, ("data", "pod"))
            return sh[None], all_gather_shards(sh, n, algo, ("data", "pod"))
        f = jax.shard_map(body, mesh=mesh,
                          in_specs=P(("data", "pod"), None),
                          out_specs=(P(("data", "pod"), None), P(None)),
                          axis_names={"data", "pod"}, check_vma=False)
        shards, full = jax.jit(f)(x)
        want = chunk_rows(ref, (4, 2))
        np.testing.assert_allclose(np.asarray(shards).reshape(want.shape),
                                   want, atol=1e-4, err_msg=algo)
        np.testing.assert_allclose(np.asarray(full), ref, atol=1e-4,
                                   err_msg=algo)
    print("2-axis reduce_scatter/all_gather roundtrip ok")


def check_sharded_segment_ids_multi_axis():
    """The layerwise optimizers derive each rank's leaf-segment ids from
    static offsets + iota (no params-sized table on device); on a (4, 2)
    nested mesh every rank's derived ids must equal the host-side
    ``ShardLayout.seg_rows`` reference row."""
    from repro.core import ShardLayout, SyncConfig
    from repro.core.grad_sync import sharded_plan_from_config
    from repro.optim.sharded import _my_segments

    mesh = jax.make_mesh((4, 2), ("data", "pod"),
                         axis_types=(AxisType.Auto,) * 2)
    params = {"a": jnp.ones((5, 3)), "b": jnp.ones((7,)),
              "c": jnp.ones((11,))}
    plan = sharded_plan_from_config(SyncConfig(bucket_bytes=48), params)
    lay = ShardLayout.from_plan(plan, params, (4, 2))

    def body():
        return tuple(s[None] for s in _my_segments(lay, ("data", "pod")))

    f = jax.shard_map(body, mesh=mesh, in_specs=(),
                      out_specs=tuple(P(("data", "pod"), None)
                                      for _ in lay.buckets),
                      axis_names={"data", "pod"}, check_vma=False)
    got = jax.jit(f)()
    for j in range(len(lay.buckets)):
        np.testing.assert_array_equal(np.asarray(got[j]), lay.seg_rows(j),
                                      err_msg=f"bucket {j}")
    print("sharded segment-id derivation ok (4x2 mesh, vs host reference)")


def check_topology_dispatched_collectives():
    """ISSUE 5 satellite: collectives under the axis→tier dispatch.  An
    8-device host realises ``node:2@datacenter,device:4@fast_ici`` as a
    (2, 4) tiered mesh (``make_topology_mesh``); ``axes_for_topology``
    lists the shard_map axes innermost-first, so ``hierarchical_allreduce``
    runs its ring phases on the ``device`` (fast) axis and the shard ring
    on ``node`` — and must match ``psum`` within ulp tolerance (the
    reductions contract in different orders).  ring/mesh2d/tree are held
    to the same bound under the same dispatch."""
    from repro.core.collectives import allreduce, axes_for_topology
    from repro.core.schedule.topology import Topology
    from repro.launch.mesh import make_topology_mesh

    topo = Topology.from_spec("node:2@datacenter,device:4@fast_ici")
    mesh = make_topology_mesh(topo)
    assert mesh.axis_names == ("node", "device") and mesh.shape["node"] == 2
    axes = axes_for_topology(topo)
    assert axes == ("device", "node")   # inner ring on the fast tier
    x = jax.random.normal(jax.random.PRNGKey(21), (8, 1031))

    def run(algo):
        f = jax.shard_map(lambda v: allreduce(v, algo, axes),
                          mesh=mesh, in_specs=P(("node", "device"), None),
                          out_specs=P(None, None),
                          axis_names=set(axes), check_vma=False)
        return np.asarray(jax.jit(f)(x))[0]

    want = run("psum")
    for algo in ("hierarchical", "ring", "mesh2d", "tree"):
        got = run(algo)
        denom = np.abs(want).max() + 1e-9
        rel = np.abs(got - want).max() / denom
        assert rel < 1e-5, (algo, rel)
        # the manual algorithms must really dispatch over both tier axes
        f = jax.shard_map(lambda v: allreduce(v, algo, axes),
                          mesh=mesh, in_specs=P(("node", "device"), None),
                          out_specs=P(None, None),
                          axis_names=set(axes), check_vma=False)
        txt = jax.jit(f).lower(x).compile().as_text()
        assert "collective-permute" in txt, algo

    # 3-tier topology (2x2x2): hierarchical's shard must ring over EVERY
    # outer axis (dropping one would silently leave pod groups diverged —
    # the bug class this check exists for); mesh2d must REFUSE 3 axes.
    topo3 = Topology.from_spec("pod:2@datacenter,node:2@commodity,"
                               "device:2@fast_ici")
    mesh3 = make_topology_mesh(topo3)
    axes3 = axes_for_topology(topo3)
    assert axes3 == ("device", "node", "pod")
    spec3 = P(("pod", "node", "device"), None)

    def run3(algo):
        f = jax.shard_map(lambda v: allreduce(v, algo, axes3),
                          mesh=mesh3, in_specs=spec3,
                          out_specs=P(None, None),
                          axis_names=set(axes3), check_vma=False)
        return np.asarray(jax.jit(f)(x))[0]

    want3 = run3("psum")
    for algo in ("hierarchical", "ring", "tree"):
        got = run3(algo)
        rel = np.abs(got - want3).max() / (np.abs(want3).max() + 1e-9)
        assert rel < 1e-5, (algo, rel)
    try:
        run3("mesh2d")
    except ValueError as e:
        assert "two-axis" in str(e), e
    else:
        raise AssertionError("mesh2d over 3 axes must raise ValueError")
    print("topology-dispatched collectives ok (node:2 x device:4 and "
          "2x2x2: hierarchical/ring/tree vs psum within ulp; mesh2d "
          "refuses 3 axes)")


def check_tree_nonpow2_raises_value_error():
    """Satellite: the tree collective on a non-power-of-two axis raises
    ValueError at trace time (was a bare assert, stripped under -O)."""
    from repro.core.collectives import allreduce

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:6]), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(22), (6, 16))
    f = jax.shard_map(lambda v: allreduce(v, "tree", ("data",)),
                      mesh=mesh, in_specs=P("data", None),
                      out_specs=P(None, None),
                      axis_names={"data"}, check_vma=False)
    try:
        jax.jit(f).lower(x)
    except ValueError as e:
        assert "power-of-two" in str(e), e
    else:
        raise AssertionError("tree over 6 ranks must raise ValueError")
    print("tree non-power-of-two ValueError ok")


def check_hlo_collective_parse():
    from repro.launch.hlo_analysis import analyze
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    xs = jax.device_put(jnp.ones((8, 1024), jnp.float32),
                        NamedSharding(mesh, P("data", None)))
    g = jax.jit(lambda x: x.sum(0), out_shardings=NamedSharding(mesh, P(None)))
    txt = g.lower(xs).compile().as_text()
    s = analyze(txt, total_devices=8)
    assert s.collective_counts.get("all-reduce") == 1
    assert s.collective_operand_bytes == 4096.0
    assert abs(s.collective_wire_bytes - 2 * 4096 * 7 / 8) < 1
    print("hlo parse ok")


def check_all_to_all_bit_identity():
    """ISSUE 9: the expert-dispatch edge.  ``all_to_all`` on an 8-rank axis
    must match the gather-and-slice reference (all_gather the full (p, p,
    m, ...) exchange, slice column i) BIT-EXACTLY for both wire variants —
    chunks move verbatim, no arithmetic — be an involution (the exchange is
    a rank<->chunk transpose), transpose under autodiff to the REVERSE
    all-to-all (the combine edge), and the ring variant must really lower
    to collective-permute rotations, not a fused all-to-all."""
    from repro.core.collectives.api import A2A_VARIANTS, all_to_all

    mesh = jax.make_mesh((8,), ("ep",), axis_types=(AxisType.Auto,))
    x = jax.random.normal(jax.random.PRNGKey(17), (8, 8, 5, 7))
    w = jax.random.normal(jax.random.PRNGKey(18), (8, 8, 5, 7))

    for variant in A2A_VARIANTS:
        def body(xs, ws):
            c = xs[0]                                   # (p, m, ...) chunks
            out = all_to_all(c, "ep", variant)
            # gather-and-slice reference: full[j] = rank j's chunk row;
            # my row of the exchange is column i of the gathered matrix
            full = jax.lax.all_gather(c, "ep")          # (p, p, m, ...)
            ref = full[:, jax.lax.axis_index("ep")]
            back = all_to_all(out, "ep", variant)       # involution
            g = jax.grad(lambda t: jnp.sum(
                ws[0] * all_to_all(t, "ep", variant)))(c)
            return out[None], ref[None], back[None], g[None]

        f = jax.shard_map(body, mesh=mesh,
                          in_specs=(P("ep"), P("ep")),
                          out_specs=(P("ep"),) * 4,
                          axis_names={"ep"}, check_vma=False)
        out, ref, back, g = jax.jit(f)(x, w)
        assert np.array_equal(np.asarray(out), np.asarray(ref)), variant
        # global view: out[r, j] = x[j, r] — the rank<->chunk transpose
        assert np.array_equal(np.asarray(out),
                              np.asarray(x).transpose(1, 0, 2, 3)), variant
        assert np.array_equal(np.asarray(back), np.asarray(x)), variant
        # d/dx sum(w * a2a(x)) = reverse-a2a(w) = a2a(w) (involution)
        assert np.array_equal(np.asarray(g),
                              np.asarray(w).transpose(1, 0, 2, 3)), variant
        txt = jax.jit(f).lower(x, w).compile().as_text()
        if variant == "ring":
            assert "collective-permute" in txt, "ring a2a must ppermute"
    print("all_to_all bit-identity ok (direct/ring vs gather-and-slice, "
          "involution, autodiff reverse edge)")


def _adam_sgd_step(p, g, m, v, t, lr=0.05, b1=0.9, b2=0.999, eps=1e-8):
    """Inline elementwise adam (same arithmetic on full arrays and on
    shards — the property the TP/EP bit-exactness checks lean on)."""
    upd = jax.tree.map(lambda mi, gi: b1 * mi + (1 - b1) * gi, m, g)
    vel = jax.tree.map(lambda vi, gi: b2 * vi + (1 - b2) * gi * gi, v, g)
    def leaf(pi, mi, vi):
        mh = mi / (1 - b1 ** t)
        vh = vi / (1 - b2 ** t)
        return pi - lr * mh / (jnp.sqrt(vh) + eps)
    return jax.tree.map(leaf, p, upd, vel), upd, vel


def check_tp_dp_bit_exact():
    """ISSUE 9's tentpole acceptance criterion, TP leg: a TP=2 x DP=4
    train step (Megatron f/g wire — ``mlp_tp`` under shard_map with
    wi_gate/wi_up column-sharded and wo row-sharded over the tp axis) must
    match the unsharded DP=4 step (``mlp_blocked(blocks=2)`` — the same
    contraction order a tp pair performs, on one device) BIT-EXACTLY:
    params AND adam moments over 3 steps on the 8-device (data=4, tp=2)
    mesh.  What makes this exact: tp_out's forward psum of p=2 partials is
    one commutative float add (== the blocked reference's pairwise sum),
    and tp_in's backward psum makes every non-tp parameter's gradient
    bit-identical across tp ranks, so BOTH programs reduce grads over the
    data axis only, with the same 4-way tree."""
    from repro.models.layers import mlp_blocked, mlp_tp

    d, dff, vocab = 16, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    params0 = {"emb": jax.random.normal(ks[0], (vocab, d)) * 0.1,
               "wi_gate": jax.random.normal(ks[1], (d, dff)) * 0.3,
               "wi_up": jax.random.normal(ks[2], (d, dff)) * 0.3,
               "wo": jax.random.normal(ks[3], (dff, d)) * 0.3,
               "out": jax.random.normal(ks[4], (d, vocab)) * 0.1,
               "b": jnp.zeros((vocab,))}

    def loss_with(mlp_fn, p, toks):
        x = p["emb"][toks[:, :-1]]
        # barrier at the swap boundary (the DESIGN.md §9 trick): keeps XLA
        # fusion from crossing into the mlp, so the embed/softmax graph —
        # and its backward — compiles identically whether the block inside
        # is mlp_tp or mlp_blocked
        xb = jax.lax.optimization_barrier(x)
        h = x + jax.lax.optimization_barrier(mlp_fn(p, xb))
        logits = h @ p["out"] + p["b"]
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, toks[:, 1:][..., None], -1))

    def make_body(mlp_fn):
        def body(p, m, v, toks, t):
            l, g = jax.value_and_grad(
                lambda q: loss_with(mlp_fn, q, toks))(p)
            g = jax.tree.map(lambda gi: jax.lax.psum(gi, "data") / 4.0, g)
            p, m, v = _adam_sgd_step(p, g, m, v, t)
            return jax.lax.psum(l, "data") / 4.0, p, m, v
        return body

    def run(mesh, specs, body):
        zeros = jax.tree.map(jnp.zeros_like, params0)
        p, m, v = params0, zeros, zeros
        f = jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=(specs, specs, specs, P("data"), P()),
            out_specs=(P(), specs, specs, specs),
            axis_names=set(mesh.axis_names), check_vma=False))
        for s in range(3):
            toks = _tiny_batch(s, batch=16, seq=12)["tokens"]
            l, p, m, v = f(p, m, v, toks, jnp.asarray(s + 1, jnp.float32))
        return float(l), p, m, v

    mesh_tp = jax.make_mesh((4, 2), ("data", "tp"),
                            axis_types=(AxisType.Auto,) * 2)
    specs_tp = {"emb": P(), "wi_gate": P(None, "tp"), "wi_up": P(None, "tp"),
                "wo": P("tp", None), "out": P(), "b": P()}
    l_tp, p_tp, m_tp, v_tp = run(
        mesh_tp, specs_tp,
        make_body(lambda p, x: mlp_tp(p, x, axis="tp")))

    mesh_dp = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
    specs_dp = {k: P() for k in params0}
    l_dp, p_dp, m_dp, v_dp = run(
        mesh_dp, specs_dp,
        make_body(lambda p, x: mlp_blocked(p, x, blocks=2)))

    assert abs(l_tp - l_dp) < 1e-6, (l_tp, l_dp)
    for (path, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path((p_tp, m_tp, v_tp)),
            jax.tree_util.tree_leaves_with_path((p_dp, m_dp, v_dp))):
        a, b = np.asarray(a), np.asarray(b)
        assert np.array_equal(a, b), \
            (jax.tree_util.keystr(path), np.abs(a - b).max())
    print("TP=2 x DP=4 bit-exact vs unsharded DP=4 ok "
          "(params + adam moments, 3 steps)")


def check_ep_dp_bit_exact():
    """ISSUE 9's tentpole acceptance criterion, EP leg: an EP=2 x DP=4
    MoE train step (``moe_ffn(ep_axis='ep')`` — experts sharded E/ep per
    rank, capacity buffer exchanged with ``all_to_all`` dispatch/combine)
    must match the unsharded DP=4 step (``moe_ffn(groups=2)`` — the same
    per-group capacity math with both of an ep pair's token groups
    source-batched on one device) BIT-EXACTLY: expert params AND adam
    moments over 3 steps, both wire variants.  Chunks move verbatim and
    the expert einsums treat e/s as batch dims, so the only float sums are
    the SAME contractions in both programs; expert grads reduce over the
    data axis only (ep contributions arrive through the combine edge's
    autodiff, already summed inside the einsum).  The router stays frozen:
    routing is pure-DP compute (each rank routes its own tokens, no ep
    wire), and training it would hang grad equality on an 8-way-vs-4-way
    psum tree rather than on the EP wire this check pins.  Loss scalars
    differ in the last bits for exactly that reason — compared loosely."""
    from repro.configs.base import ModelConfig
    from repro.models import moe

    cfg = ModelConfig(name="t", family="qwen3", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      num_experts=4, top_k=2, moe_d_ff=24,
                      capacity_factor=1.5)
    d, E = 16, 4
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    router = jax.random.normal(ks[0], (d, E)) * 0.1
    ew0 = {"wi_gate": jax.random.normal(ks[1], (E, d, 24)) * 0.3,
           "wi_up": jax.random.normal(ks[2], (E, d, 24)) * 0.3,
           "wo": jax.random.normal(ks[3], (E, 24, d)) * 0.3}

    def batch(s):
        return jax.random.normal(jax.random.fold_in(ks[4], s), (8, 4, d))

    def make_body(moe_kwargs, loss_axes):
        def body(ew, m, v, xs, t):
            def loss_fn(w):
                out, _ = moe.moe_ffn(dict(w, router=router), cfg, xs,
                                     **moe_kwargs)
                return jnp.sum(out ** 2)
            l, g = jax.value_and_grad(loss_fn)(ew)
            g = jax.tree.map(lambda gi: jax.lax.psum(gi, "data") / 4.0, g)
            ew, m, v = _adam_sgd_step(ew, g, m, v, t)
            return jax.lax.psum(l, loss_axes), ew, m, v
        return body

    def run(mesh, espec, xspec, body):
        zeros = jax.tree.map(jnp.zeros_like, ew0)
        ew, m, v = ew0, zeros, zeros
        specs = {k: espec for k in ew0}
        f = jax.jit(jax.shard_map(
            body, mesh=mesh, in_specs=(specs, specs, specs, xspec, P()),
            out_specs=(P(), specs, specs, specs),
            axis_names=set(mesh.axis_names), check_vma=False))
        for s in range(3):
            l, ew, m, v = f(ew, m, v, batch(s),
                            jnp.asarray(s + 1, jnp.float32))
        return float(l), ew, m, v

    mesh_dp = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
    l_dp, ew_dp, m_dp, v_dp = run(
        mesh_dp, P(), P("data"),
        make_body({"groups": 2}, ("data",)))

    mesh_ep = jax.make_mesh((4, 2), ("data", "ep"),
                            axis_types=(AxisType.Auto,) * 2)
    for variant in ("direct", "ring"):
        l_ep, ew_ep, m_ep, v_ep = run(
            mesh_ep, P("ep"), P(("data", "ep")),
            make_body({"ep_axis": "ep", "a2a_variant": variant},
                      ("data", "ep")))
        assert abs(l_ep - l_dp) < 1e-4 * max(abs(l_dp), 1.0), \
            (variant, l_ep, l_dp)
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path((ew_ep, m_ep, v_ep)),
                jax.tree_util.tree_leaves_with_path((ew_dp, m_dp, v_dp))):
            a, b = np.asarray(a), np.asarray(b)
            assert np.array_equal(a, b), \
                (variant, jax.tree_util.keystr(path), np.abs(a - b).max())
    print("EP=2 x DP=4 bit-exact vs unsharded DP=4 ok "
          "(direct/ring a2a, expert params + adam moments, 3 steps)")


def check_drop_tap_shard_map():
    """The MoE drop tap (DESIGN.md §14) must survive the shard_map sync
    paths: a host callback baked into a PARTIAL-manual body — manual data
    axes with a size-1 auto model axis left over on the same mesh — made
    XLA abort outright (hlo_sharding.cc ``!IsManual()``), which is
    exactly the standard ``data(N) × model(1)`` session mesh every
    multi-device ``--sync comm`` / ``--parallelism`` run shard_maps over.
    compat's shard_map now promotes size-1 leftover axes into the manual
    set (semantically a no-op), so the body is full-manual and the tap
    FIRES.  (A >1 auto axis remaining is a genuinely-partial-manual body;
    jax 0.4.37 cannot partition the MoE scatter there at all, tap or no
    tap — ``moe_ffn`` additionally skips the callback in that case via
    ``host_callback_safe`` so the tap is never the crashing element.)"""
    from repro.configs.base import ModelConfig
    from repro.models import moe
    from repro.models.sharding_ctx import manual_region, mesh_ctx

    cfg = ModelConfig(name="t", family="qwen3", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      num_experts=4, top_k=2, moe_d_ff=24,
                      capacity_factor=0.5)          # forced overflow
    d, E = 16, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    params = {"router": jax.random.normal(ks[0], (d, E)) * 0.1,
              "wi_gate": jax.random.normal(ks[1], (E, d, 24)) * 0.3,
              "wi_up": jax.random.normal(ks[2], (E, d, 24)) * 0.3,
              "wo": jax.random.normal(ks[3], (E, 24, d)) * 0.3}
    x = jax.random.normal(ks[4], (8, 4, d))

    mesh = jax.make_mesh((8, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)

    def body(p, xs):
        with manual_region(("data",)):
            out, _ = moe.moe_ffn(p, cfg, xs)
        return jax.lax.psum(jnp.sum(out ** 2), "data")

    old = moe.enable_drop_tap(True)
    try:
        with mesh_ctx(mesh, ("data",)):
            f = jax.jit(jax.shard_map(
                body, mesh=mesh,
                in_specs=({k: P() for k in params}, P("data")),
                out_specs=P(), axis_names={"data"}, check_vma=False))
            moe.drain_drop_tap()
            float(f(params, x))            # blocks → callbacks have fired
        dropped, routed = moe.drain_drop_tap()
        assert routed > 0, (dropped, routed)
        assert dropped > 0, (dropped, routed)      # cap 0.5 must drop
    finally:
        moe.enable_drop_tap(old)
    print("moe drop tap under shard_map ok (size-1 model axis promoted "
          "to manual; callback fires on the data(8) x model(1) mesh)")


if __name__ == "__main__":
    check_collectives()
    check_ring_fused()
    check_fused_bit_trajectory()
    check_grad_sync()
    check_error_feedback_converges_distributed()
    check_plan_executor_heterogeneous()
    check_local_sgd()
    check_param_round_strategy()
    check_sharded_dp_bit_exact()
    check_pipeline_bit_exact()
    check_pipeline_matches_classic_dp_step()
    check_sharded_checkpoint_reshard()
    check_reduce_scatter_all_gather_roundtrip()
    check_sharded_segment_ids_multi_axis()
    check_topology_dispatched_collectives()
    check_tree_nonpow2_raises_value_error()
    check_hlo_collective_parse()
    check_all_to_all_bit_identity()
    check_tp_dp_bit_exact()
    check_ep_dp_bit_exact()
    check_drop_tap_shard_map()
    print("ALL MULTI-DEVICE CHECKS PASSED")
