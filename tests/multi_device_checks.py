"""Multi-device correctness checks, run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests must not pollute
the main process's device count — smoke tests see 1 device).

Exit code 0 = all checks passed.  Invoked by test_collectives.py.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import repro.compat  # noqa: E402,F401  (AxisType/shard_map shims on old JAX)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P  # noqa: E402


def check_collectives():
    from repro.core.collectives import allreduce, ALGOS
    mesh = jax.make_mesh((4, 2), ("data", "pod"), axis_types=(AxisType.Auto,) * 2)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 37))
    ref = np.asarray(x).reshape(4, 2, 37).sum(axis=(0, 1))
    for algo in ALGOS:
        f = jax.shard_map(lambda v: allreduce(v, algo, ("data", "pod")),
                          mesh=mesh, in_specs=P(("data", "pod"), None),
                          out_specs=P(None, None),
                          axis_names={"data", "pod"}, check_vma=False)
        out = np.asarray(jax.jit(f)(x))[0]
        if algo == "ring_fused":
            # the compressed ring is LOSSY by design (int8 wire with
            # per-hop requantization of partial sums, DESIGN.md §11):
            # bounded relative error, not exact.  Rank agreement is
            # checked with per-rank out_specs in check_ring_fused.
            rel = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
            assert rel < 0.05, ("ring_fused", rel)
        else:
            assert np.allclose(out, ref, atol=1e-4), algo
        # the manual algorithms must NOT lower to a plain all-reduce
        txt = jax.jit(f).lower(x).compile().as_text()
        if algo not in ("psum",):
            assert "collective-permute" in txt, algo
    print("collectives ok")


def check_grad_sync():
    from repro.core import GradientSynchronizer, SyncConfig
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 64, 32)),
             "b": jax.random.normal(jax.random.PRNGKey(2), (8, 33))}
    ref = jax.tree.map(lambda g: np.asarray(g).mean(0), grads)
    configs = [
        SyncConfig(compressor="none", algo="ring"),
        SyncConfig(compressor="int8", algo="hierarchical"),
        SyncConfig(compressor="qsgd", algo="ring"),
        SyncConfig(compressor="topk", algo="ring",
                   compressor_args=(("ratio", 0.5),)),
        SyncConfig(compressor="powersgd", algo="mesh2d",
                   compressor_args=(("rank", 16),)),
        # the fused Pallas wires (DESIGN.md §11), including the lossy
        # compressed-ring transport for the int8 payload
        SyncConfig(compressor="int8_fused", algo="ring"),
        SyncConfig(compressor="int8_fused", algo="ring_fused"),
        SyncConfig(compressor="topk_fused", algo="ring",
                   compressor_args=(("ratio", 0.25),)),
    ]
    for cfg in configs:
        sync = GradientSynchronizer(cfg, ("data",))

        def body(g, rng):
            g = jax.tree.map(lambda x: x[0], g)
            st = sync.init_state(g)
            out, _ = sync(g, st, rng)
            return out

        f = jax.shard_map(body, mesh=mesh,
                          in_specs=({"w": P("data", None, None),
                                     "b": P("data", None)}, P()),
                          out_specs={"w": P(None, None), "b": P(None)},
                          axis_names={"data"}, check_vma=False)
        out = jax.jit(f)(grads, jax.random.PRNGKey(0))
        for k in ref:
            denom = np.abs(ref[k]).max() + 1e-9
            rel = float(jnp.max(jnp.abs(out[k] - ref[k]))) / denom
            limit = 1e-5 if cfg.compressor == "none" else 1.2
            assert rel < limit, (cfg.compressor, rel)
    print("grad_sync ok")


def check_error_feedback_converges_distributed():
    """EF-compressed SGD on a shared quadratic reaches the optimum even with
    1-bit sign compression (the survey's §3.2.1 headline result)."""
    from repro.core import GradientSynchronizer, SyncConfig
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    w_star = jax.random.normal(jax.random.PRNGKey(5), (64,))
    sync = GradientSynchronizer(
        SyncConfig(compressor="sign", algo="ring"), ("data",))

    def run(noise):
        def body(noise):
            w = jnp.zeros((64,))
            st = sync.init_state({"w": w})

            def step(carry, i):
                w, st = carry
                # per-worker noisy gradient of ||w - w*||^2 / 2
                g = (w - w_star) + noise[0, i % 16]
                synced, st = sync({"w": g}, st, jax.random.fold_in(
                    jax.random.PRNGKey(0), i))
                w = w - 0.3 * synced["w"]
                return (w, st), None

            (w, _), _ = jax.lax.scan(step, (w, st), jnp.arange(300))
            return w

        f = jax.shard_map(body, mesh=mesh,
                          in_specs=P("data", None, None),
                          out_specs=P(None), axis_names={"data"},
                          check_vma=False)
        return jax.jit(f)(noise)

    noise = jax.random.normal(jax.random.PRNGKey(6), (8, 16, 64)) * 0.5
    # zero-mean noise across workers
    noise = noise - noise.mean(axis=0, keepdims=True)
    w = run(noise)
    rel = float(jnp.linalg.norm(w - w_star) / jnp.linalg.norm(w_star))
    assert rel < 0.05, rel
    print("EF sign-SGD convergence ok, rel err", rel)


def check_ring_fused():
    """The compressed-ring prototype on 8 REAL ranks (DESIGN.md §11):
    every rank reconstructs the SAME lossy sum (the all-gather phase
    circulates one quantized payload per chunk, owner included — any
    per-rank dequantization asymmetry would diverge replicas), the error
    is within the per-hop requantization bound, and the wire actually
    lowers to ppermute steps, not a hidden all-reduce."""
    from repro.core.collectives import allreduce
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    x = jax.random.normal(jax.random.PRNGKey(30), (8, 5000))
    ref = np.asarray(x).sum(0)

    f = jax.jit(jax.shard_map(
        lambda v: allreduce(v[0], "ring_fused", ("data",))[None],
        mesh=mesh, in_specs=P("data", None), out_specs=P("data", None),
        axis_names={"data"}, check_vma=False))
    per_rank = np.asarray(f(x))                 # (8, 5000), one row per rank
    assert np.all(per_rank == per_rank[0:1]), "ranks disagree"
    rel = np.abs(per_rank[0] - ref).max() / (np.abs(ref).max() + 1e-9)
    assert rel < 0.05, rel
    txt = f.lower(x).compile().as_text()
    assert "collective-permute" in txt and "all-reduce" not in txt
    print(f"ring_fused ok (8 ranks agree bitwise, rel err {rel:.4f})")


def check_fused_bit_trajectory():
    """THE fused-wire acceptance criterion: the one-pass kernels vs the
    SAME plan with ``fused=False`` (decomposed reference chain) on the
    REAL 8-device mesh, 3 sync rounds — EF residual trajectories must be
    bit-identical for both wires (int8 tiles + scales, bisection top-k).
    Payload equality per call is pinned at the compressor level in
    test_compression.py; residual equality across steps proves the
    executor's fused dispatch feeds the kernels identical buffers and
    carries identical state.  Synced sums: bit-equal for the aggregatable
    top-k; the int8 gather wire's fused decode is one reduction over the
    payload axis vs the loop's sequential adds — 2-ulp bound, the
    documented summation-order difference."""
    import dataclasses
    from repro.core import PlanExecutor, SyncConfig
    from repro.core.grad_sync import plan_from_config

    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    tmpl = {"w": jnp.zeros((64, 33)), "b": jnp.zeros((17,))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(31), (8, 3, 64, 33)),
             "b": jax.random.normal(jax.random.PRNGKey(32), (8, 3, 17))}

    for name, args in (("int8_fused", ()), ("topk_fused",
                                            (("ratio", 0.25),))):
        plan_f = plan_from_config(
            SyncConfig(compressor=name, algo="ring", bucket_bytes=2048,
                       compressor_args=args), tmpl)
        assert all(b.fused for b in plan_f.buckets)
        plan_u = dataclasses.replace(plan_f, buckets=tuple(
            dataclasses.replace(b, fused=False) for b in plan_f.buckets))
        outs = {}
        for tag, plan in (("fused", plan_f), ("unfused", plan_u)):
            ex = PlanExecutor(plan, ("data",))

            def body(g):
                g0 = jax.tree.map(lambda x: x[0], g)
                st = ex.init_state(jax.tree.map(lambda x: x[0], g0))
                res, errs = [], []
                for s in range(3):
                    out, st = ex(jax.tree.map(lambda x: x[s], g0), st,
                                 jax.random.PRNGKey(0))
                    res.append(out)
                    errs.append([e for e in st["error"] if e is not None])
                return res, errs

            f = jax.shard_map(body, mesh=mesh,
                              in_specs=({"w": P("data", None, None, None),
                                         "b": P("data", None, None)},),
                              out_specs=(P(None), P(None)),
                              axis_names={"data"}, check_vma=False)
            outs[tag] = jax.jit(f)(grads)
        (res_f, errs_f), (res_u, errs_u) = outs["fused"], outs["unfused"]
        for s in range(3):
            assert len(errs_f[s]) == len(errs_u[s]) > 0
            for j, (a, b) in enumerate(zip(errs_f[s], errs_u[s])):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{name} step {s} EF[{j}]")
            for k in ("w", "b"):
                a = np.asarray(res_f[s][k], np.float32)
                b = np.asarray(res_u[s][k], np.float32)
                if name == "topk_fused":
                    np.testing.assert_array_equal(
                        a, b, err_msg=f"{name} step {s} {k}")
                else:
                    tol = 2 * np.finfo(np.float32).eps * max(
                        1.0, np.abs(b).max())
                    assert np.abs(a - b).max() <= tol, (name, s, k)
    print("fused-vs-unfused bit trajectory ok (EF residuals bit-equal "
          "over 3 steps, int8 + topk, 8 ranks)")


def check_plan_executor_heterogeneous():
    """A CommPlan mixing dense/psum, packed int8/ring, and per-leaf topk
    must approximate the all-worker mean on a real 8-device mesh."""
    from repro.core import PlanExecutor
    from repro.core.schedule.planner import BucketPlan, CommPlan
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(11), (8, 64, 32)),
             "b": jax.random.normal(jax.random.PRNGKey(12), (8, 33))}
    ref = jax.tree.map(lambda g: np.asarray(g).mean(0), grads)
    # leaf order: b, w
    plan = CommPlan(buckets=(
        BucketPlan(leaves=(0,), compressor="none", algo="psum",
                   bucket_bytes=4 * 33),
        BucketPlan(leaves=(1,), compressor="int8", algo="ring",
                   bucket_bytes=4 * 64 * 32, pack=True),
    ))
    ex = PlanExecutor(plan, ("data",))

    def body(g, rng):
        g = jax.tree.map(lambda x: x[0], g)
        st = ex.init_state(g)
        out, st2 = ex(g, st, rng)
        return out

    f = jax.shard_map(body, mesh=mesh,
                      in_specs=({"w": P("data", None, None),
                                 "b": P("data", None)}, P()),
                      out_specs={"w": P(None, None), "b": P(None)},
                      axis_names={"data"}, check_vma=False)
    out = jax.jit(f)(grads, jax.random.PRNGKey(0))
    # dense psum bucket: exact; int8 bucket: close
    np.testing.assert_allclose(np.asarray(out["b"]), ref["b"], atol=1e-5)
    rel = float(jnp.max(jnp.abs(out["w"] - ref["w"]))) / \
        (np.abs(ref["w"]).max() + 1e-9)
    assert rel < 1.2, rel
    print("heterogeneous plan executor ok")


def check_local_sgd():
    from repro.core import average_params
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    params = {"w": jax.random.normal(jax.random.PRNGKey(7), (8, 16))}
    f = jax.shard_map(lambda p: average_params(p, ("data",)),
                      mesh=mesh, in_specs=({"w": P("data", None)},),
                      out_specs={"w": P(None)}, axis_names={"data"},
                      check_vma=False)
    out = jax.jit(f)(params)
    np.testing.assert_allclose(np.asarray(out["w"])[0],
                               np.asarray(params["w"]).mean(0), atol=1e-5)
    print("local sgd averaging ok")


def check_param_round_strategy():
    """SyncStrategy param round on 8 REAL workers (DESIGN.md §7): per-worker
    diverged params go in with a leading worker axis, one anchor-delta
    round brings every worker to (≈, for the compressed plan) the mean."""
    from repro.core import PlanExecutor, SyncConfig, plan_from_config
    from repro.launch.steps import make_param_round_step

    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    anchor = {"w": jax.random.normal(jax.random.PRNGKey(11), (16, 8))}
    noise = jax.random.normal(jax.random.PRNGKey(12), (8, 16, 8)) * 0.01
    params_w = {"w": anchor["w"][None] + noise}   # 8 diverged workers

    for comp, tol in (("none", 1e-6), ("int8", 2e-3)):
        reducer = PlanExecutor(
            plan_from_config(SyncConfig(compressor=comp, bucket_bytes=0),
                             anchor), ("data",))
        round_fn = jax.jit(make_param_round_step(reducer, mesh, ("data",)))
        red_state = jax.tree.map(
            lambda s: jnp.broadcast_to(s, (8,) + s.shape),
            reducer.init_state(anchor))
        out, new_anchor, _ = round_fn(params_w, anchor, red_state,
                                      jax.random.PRNGKey(0))
        got = np.asarray(out["w"])
        want = np.asarray(params_w["w"]).mean(0)
        assert np.all(got == got[0:1]), f"{comp}: workers disagree"
        np.testing.assert_allclose(got[0], want, atol=tol)
        np.testing.assert_allclose(np.asarray(new_anchor["w"]), got[0],
                                   atol=1e-6)
    print("strategy param round ok")


from tiny_lm import TinyLM as _TinyLM, tiny_batch as _tiny_batch  # noqa: E402
from tiny_lm import TinyStackLM as _TinyStackLM  # noqa: E402


def check_pipeline_bit_exact():
    """ISSUE 4's tentpole acceptance criterion: the pipeline(S=2, M=4)
    1F1B train step on the 8-device pipe(2) x data(4) mesh must match the
    single-stage DP step (pipe(1) x data(4), same global batch, same M
    micro-batches) BIT-EXACTLY — params and optimizer state over 3 steps,
    adam + sgd — including under int8/top-k DP-edge compression (the
    per-row sync granularity makes the compressed wire stage-count
    invariant; matching params+moments over 3 steps implies the EF
    residual trajectories agree, since residuals feed every later step).

    What makes this exact (DESIGN.md §9): row-boundary optimization
    barriers keep XLA fusion from crossing potential cut points (so a
    row's forward/backward compiles identically at every stage count), and
    the optimizer updates the per-row-unstacked tree (same leaf shapes at
    every S).  Should the XLA-owned psum wire ever reorder its reduction
    between the two programs, the documented fallback is the §8 ulp
    tolerance — flip ``exact`` for that row.
    """
    from repro.core import GradientSynchronizer, SyncConfig
    from repro.launch.mesh import make_pipe_mesh
    from repro.launch.steps import make_pipeline_train_step
    from repro.optim import make_optimizer

    M = 4

    def run(S, opt_name, comp, algo):
        model = _TinyStackLM(blocks=2, n_stages=S)
        params = model.init(jax.random.PRNGKey(0))
        mesh = make_pipe_mesh(S, 4)
        opt = make_optimizer(opt_name, lr=0.05)
        engine = GradientSynchronizer(
            SyncConfig(compressor=comp, algo=algo, bucket_bytes=0),
            ("data",))
        step_fn, init_opt, init_ss = make_pipeline_train_step(
            model, opt, engine, mesh, M)
        shared, rows = model.split(params)
        p = {"shared": shared, "rows": rows}
        o, ss = init_opt(p), init_ss(p)
        jit = jax.jit(step_fn)
        rng = jax.random.PRNGKey(1)
        for s in range(3):
            p, o, ss, loss = jit(p, o, ss, _tiny_batch(s, batch=16, seq=12),
                                 jnp.asarray(s, jnp.int32),
                                 jax.random.fold_in(rng, s))
        from repro.launch.steps import merge_opt_rows
        merged = model.merge(p["shared"], p["rows"])
        return merged, merge_opt_rows(o, model.layout.rows), float(loss)

    for opt_name, comp, algo, exact in (
            ("adam", "none", "psum", True),
            ("adam", "none", "ring", True),
            ("adam", "int8", "ring", True),
            ("adam", "topk", "ring", True),
            ("sgd", "none", "ring", True),
            ("sgd", "none", "psum", True)):
        p1, o1, l1 = run(1, opt_name, comp, algo)
        p2, o2, l2 = run(2, opt_name, comp, algo)
        for (path, a), (_, b) in list(zip(
                jax.tree_util.tree_leaves_with_path(p1),
                jax.tree_util.tree_leaves_with_path(p2))) + list(zip(
                jax.tree_util.tree_leaves_with_path(o1),
                jax.tree_util.tree_leaves_with_path(o2))):
            a, b = np.asarray(a), np.asarray(b)
            what = (opt_name, comp, algo, jax.tree_util.keystr(path))
            if exact:
                assert np.array_equal(a, b), \
                    (what, np.abs(a - b).max())
            else:
                np.testing.assert_allclose(a, b, rtol=3e-5, atol=1e-7,
                                           err_msg=str(what))
        assert abs(l1 - l2) < 1e-5, (opt_name, comp, algo, l1, l2)
    print("pipeline S=2 bit-exact vs single-stage DP ok (adam/sgd x "
          "psum/ring/int8/topk, params + opt state, 3 steps)")


def check_pipeline_matches_classic_dp_step():
    """Anchor for the S=1 reference itself: the degenerate pipeline step
    (S=1, M=1, dense psum) against the classic replicated DP step
    (_make_synced_train_step) — same loss and ulp-tight params (the two
    programs differ only in vjp composition and XLA contraction)."""
    from repro.core import PlanExecutor, SyncConfig, plan_from_config
    from repro.core import GradientSynchronizer
    from repro.launch.mesh import make_pipe_mesh
    from repro.launch.steps import (_make_synced_train_step,
                                    make_pipeline_train_step)
    from repro.optim import make_optimizer

    model = _TinyStackLM(blocks=2, n_stages=1)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("adam", lr=0.05)
    batch = _tiny_batch(0, batch=16, seq=12)
    step_i = jnp.zeros((), jnp.int32)
    rng = jax.random.PRNGKey(1)

    mesh_c = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
    exec_c = PlanExecutor(plan_from_config(SyncConfig(), params), ("data",))
    cstep, _, init_cs = _make_synced_train_step(model, opt, exec_c, mesh_c,
                                                ("data",))
    pc, oc, sc = params, opt.init(params), init_cs(params)
    pc, oc, _, lc = jax.jit(cstep)(pc, oc, sc, batch, step_i, rng)

    mesh_p = make_pipe_mesh(1, 4)
    engine = GradientSynchronizer(SyncConfig(bucket_bytes=0), ("data",))
    pstep, init_po, init_ps = make_pipeline_train_step(model, opt, engine,
                                                       mesh_p, 1)
    shared, rows = model.split(params)
    pp = {"shared": shared, "rows": rows}
    op, sp = init_po(pp), init_ps(pp)
    pp, op, _, lp = jax.jit(pstep)(pp, op, sp, batch, step_i, rng)
    merged = model.merge(pp["shared"], pp["rows"])

    assert abs(float(lc) - float(lp)) < 1e-6, (float(lc), float(lp))
    for k in ("emb", "out", "b"):
        np.testing.assert_allclose(np.asarray(merged[k]),
                                   np.asarray(pc[k]),
                                   rtol=3e-5, atol=1e-7, err_msg=k)
    for k in ("w1", "b1", "w2"):
        np.testing.assert_allclose(np.asarray(merged["blocks"][k]),
                                   np.asarray(pc["blocks"][k]),
                                   rtol=3e-5, atol=1e-7, err_msg=k)
    print("pipeline S=1/M=1 matches the classic DP step ok (ulp-tight)")


def check_sharded_dp_bit_exact():
    """The tentpole acceptance criterion: sharded-DP (reduce-scatter grads,
    1/p-partitioned master params + Adam moments, params all-gather) must
    be BIT-EXACT vs replicated DP for dense fp32 over 3 steps on a real
    8-device mesh — for both the explicit ring wires and psum — and the
    per-device optimizer-state arrays must actually be 1/8 the replicated
    footprint.  Compressed (int8) wires must match bit-for-bit too (same
    payload gather, sliced), including the EF residual trajectory."""
    from repro.core import PlanExecutor, ShardLayout, SyncConfig
    from repro.core.grad_sync import sharded_plan_from_config
    from repro.launch.steps import (_make_synced_train_step,
                                    make_sharded_train_step)
    from repro.optim import make_optimizer, make_sharded_optimizer

    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    model = _TinyLM()
    params0 = model.init(jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)

    for opt_name, algo, comp, exact in (
            ("adam", "ring", "none", True),
            ("adam", "psum", "none", True),
            ("adam", "ring", "int8", True),
            ("sgd", "ring", "none", True),
            ("lamb", "ring", "none", False)):   # layerwise norms: psum order
        cfg = SyncConfig(compressor=comp, algo=algo,
                         bucket_bytes=2048 if comp != "none" else 32 * 2**20)
        shared_plan = sharded_plan_from_config(cfg, params0)
        opt = make_optimizer(opt_name, lr=0.05)

        # replicated reference runs the SAME plan (same bucket boundaries:
        # ring chunk sums depend on them — DESIGN.md §8)
        step_fn, _, init_ss = _make_synced_train_step(
            model, opt, PlanExecutor(shared_plan, ("data",)), mesh,
            ("data",))
        p_r, os_r, ss_r = params0, opt.init(params0), init_ss(params0)
        jit_r = jax.jit(step_fn)
        for s in range(3):
            p_r, os_r, ss_r, _ = jit_r(p_r, os_r, ss_r, _tiny_batch(s),
                                       jnp.asarray(s, jnp.int32),
                                       jax.random.fold_in(rng, s))

        ex = PlanExecutor(shared_plan, ("data",))
        layout = ShardLayout.from_plan(shared_plan, params0, (8,))
        shopt = make_sharded_optimizer(opt_name, layout, ("data",), lr=0.05)
        sfn, init_rows, init_ss2 = make_sharded_train_step(
            model, ex, layout, shopt, mesh, ("data",))
        p_s, rows, ss_s = params0, init_rows(params0), init_ss2(params0)
        jit_s = jax.jit(sfn)
        for s in range(3):
            p_s, rows, ss_s, _ = jit_s(p_s, rows, ss_s, _tiny_batch(s),
                                       jnp.asarray(s, jnp.int32),
                                       jax.random.fold_in(rng, s))

        def cmp(a, b, what):
            a, b = np.asarray(a), np.asarray(b)
            if exact:
                assert np.array_equal(a, b), \
                    (opt_name, algo, comp, what, np.abs(a - b).max())
            else:
                np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-7)

        for k in p_r:
            cmp(p_r[k], p_s[k], f"params/{k}")
        if opt_name in ("adam", "lamb"):
            for mom in ("m", "v"):
                full = layout.tree_from_rows(rows["opt"][mom], params0)
                for k in p_r:
                    cmp(os_r[mom][k], full[k], f"{mom}/{k}")
        master = layout.tree_from_rows(rows["master"], params0)
        for k in p_r:
            cmp(master[k], p_s[k], f"master/{k}")
        if comp != "none":
            for a, b in zip(ss_r["error"], ss_s["error"]):
                if a is not None:
                    cmp(a, b, "EF residual")

        # the memory identity: per-device partitioned state is 1/8 (+pad)
        n_total = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params0))
        per_dev = sum(b.m for b in layout.buckets)
        assert per_dev <= -(-n_total // 8) + len(layout.buckets) * 8, \
            (per_dev, n_total)
        for r in rows["master"]:
            assert r.shape[0] == 8    # leading worker axis, sharded
    print("sharded-DP bit-exact vs replicated ok (ring/psum, int8, "
          "adam/sgd exact; lamb close)")


def check_sharded_checkpoint_reshard():
    """Partitioned optimizer state round-trips through a checkpoint onto a
    DIFFERENT mesh shape bit-equal: save 8-way shard rows, restore, re-chunk
    to a 4-way (and 2x2) layout — the reconstructed full state is identical
    because every layout chunks the same canonical flat buffer."""
    from repro.checkpoint import restore, save
    from repro.core import ShardLayout, SyncConfig
    from repro.core.grad_sync import sharded_plan_from_config
    import tempfile

    model = _TinyLM()
    params = model.init(jax.random.PRNGKey(3))
    plan = sharded_plan_from_config(SyncConfig(bucket_bytes=4096), params)
    lay8 = ShardLayout.from_plan(plan, params, (8,))
    rows8 = lay8.shard_rows(params)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save(path, {"master": rows8}, step=7)
        like = {"master": [np.zeros(r.shape, np.float32) for r in rows8]}
        restored = restore(path, like)

    lay4, rows4 = lay8.reshard(restored["master"], (4,))
    lay22, rows22 = lay8.reshard(restored["master"], (2, 2))
    want = jax.tree.leaves(params)
    for lay, rows in ((lay4, rows4), (lay22, rows22), (lay8, rows8)):
        got = lay.tree_from_rows(rows, params)
        for a, b in zip(jax.tree.leaves(got), want):
            assert np.array_equal(np.asarray(a),
                                  np.asarray(b).astype(np.float32)), \
                lay.axis_sizes
    print("sharded checkpoint reshard ok (8 -> 4, 8 -> 2x2, bit-equal)")


def check_reduce_scatter_all_gather_roundtrip():
    """The sharded wire primitives on a 2-axis (4x2) mesh: nested-canonical
    reduce_scatter chunks must agree with the host-side chunking twin, and
    all_gather_shards must invert them exactly."""
    from repro.core import chunk_rows
    from repro.core.collectives import all_gather_shards, reduce_scatter

    mesh = jax.make_mesh((4, 2), ("data", "pod"),
                         axis_types=(AxisType.Auto,) * 2)
    n = 37
    x = jax.random.normal(jax.random.PRNGKey(9), (8, n))
    ref = np.asarray(x).sum(0)
    for algo in ("psum", "ring", "hierarchical"):
        def body(v):
            v = v[0]
            sh = reduce_scatter(v, algo, ("data", "pod"))
            return sh[None], all_gather_shards(sh, n, algo, ("data", "pod"))
        f = jax.shard_map(body, mesh=mesh,
                          in_specs=P(("data", "pod"), None),
                          out_specs=(P(("data", "pod"), None), P(None)),
                          axis_names={"data", "pod"}, check_vma=False)
        shards, full = jax.jit(f)(x)
        want = chunk_rows(ref, (4, 2))
        np.testing.assert_allclose(np.asarray(shards).reshape(want.shape),
                                   want, atol=1e-4, err_msg=algo)
        np.testing.assert_allclose(np.asarray(full), ref, atol=1e-4,
                                   err_msg=algo)
    print("2-axis reduce_scatter/all_gather roundtrip ok")


def check_sharded_segment_ids_multi_axis():
    """The layerwise optimizers derive each rank's leaf-segment ids from
    static offsets + iota (no params-sized table on device); on a (4, 2)
    nested mesh every rank's derived ids must equal the host-side
    ``ShardLayout.seg_rows`` reference row."""
    from repro.core import ShardLayout, SyncConfig
    from repro.core.grad_sync import sharded_plan_from_config
    from repro.optim.sharded import _my_segments

    mesh = jax.make_mesh((4, 2), ("data", "pod"),
                         axis_types=(AxisType.Auto,) * 2)
    params = {"a": jnp.ones((5, 3)), "b": jnp.ones((7,)),
              "c": jnp.ones((11,))}
    plan = sharded_plan_from_config(SyncConfig(bucket_bytes=48), params)
    lay = ShardLayout.from_plan(plan, params, (4, 2))

    def body():
        return tuple(s[None] for s in _my_segments(lay, ("data", "pod")))

    f = jax.shard_map(body, mesh=mesh, in_specs=(),
                      out_specs=tuple(P(("data", "pod"), None)
                                      for _ in lay.buckets),
                      axis_names={"data", "pod"}, check_vma=False)
    got = jax.jit(f)()
    for j in range(len(lay.buckets)):
        np.testing.assert_array_equal(np.asarray(got[j]), lay.seg_rows(j),
                                      err_msg=f"bucket {j}")
    print("sharded segment-id derivation ok (4x2 mesh, vs host reference)")


def check_topology_dispatched_collectives():
    """ISSUE 5 satellite: collectives under the axis→tier dispatch.  An
    8-device host realises ``node:2@datacenter,device:4@fast_ici`` as a
    (2, 4) tiered mesh (``make_topology_mesh``); ``axes_for_topology``
    lists the shard_map axes innermost-first, so ``hierarchical_allreduce``
    runs its ring phases on the ``device`` (fast) axis and the shard ring
    on ``node`` — and must match ``psum`` within ulp tolerance (the
    reductions contract in different orders).  ring/mesh2d/tree are held
    to the same bound under the same dispatch."""
    from repro.core.collectives import allreduce, axes_for_topology
    from repro.core.schedule.topology import Topology
    from repro.launch.mesh import make_topology_mesh

    topo = Topology.from_spec("node:2@datacenter,device:4@fast_ici")
    mesh = make_topology_mesh(topo)
    assert mesh.axis_names == ("node", "device") and mesh.shape["node"] == 2
    axes = axes_for_topology(topo)
    assert axes == ("device", "node")   # inner ring on the fast tier
    x = jax.random.normal(jax.random.PRNGKey(21), (8, 1031))

    def run(algo):
        f = jax.shard_map(lambda v: allreduce(v, algo, axes),
                          mesh=mesh, in_specs=P(("node", "device"), None),
                          out_specs=P(None, None),
                          axis_names=set(axes), check_vma=False)
        return np.asarray(jax.jit(f)(x))[0]

    want = run("psum")
    for algo in ("hierarchical", "ring", "mesh2d", "tree"):
        got = run(algo)
        denom = np.abs(want).max() + 1e-9
        rel = np.abs(got - want).max() / denom
        assert rel < 1e-5, (algo, rel)
        # the manual algorithms must really dispatch over both tier axes
        f = jax.shard_map(lambda v: allreduce(v, algo, axes),
                          mesh=mesh, in_specs=P(("node", "device"), None),
                          out_specs=P(None, None),
                          axis_names=set(axes), check_vma=False)
        txt = jax.jit(f).lower(x).compile().as_text()
        assert "collective-permute" in txt, algo

    # 3-tier topology (2x2x2): hierarchical's shard must ring over EVERY
    # outer axis (dropping one would silently leave pod groups diverged —
    # the bug class this check exists for); mesh2d must REFUSE 3 axes.
    topo3 = Topology.from_spec("pod:2@datacenter,node:2@commodity,"
                               "device:2@fast_ici")
    mesh3 = make_topology_mesh(topo3)
    axes3 = axes_for_topology(topo3)
    assert axes3 == ("device", "node", "pod")
    spec3 = P(("pod", "node", "device"), None)

    def run3(algo):
        f = jax.shard_map(lambda v: allreduce(v, algo, axes3),
                          mesh=mesh3, in_specs=spec3,
                          out_specs=P(None, None),
                          axis_names=set(axes3), check_vma=False)
        return np.asarray(jax.jit(f)(x))[0]

    want3 = run3("psum")
    for algo in ("hierarchical", "ring", "tree"):
        got = run3(algo)
        rel = np.abs(got - want3).max() / (np.abs(want3).max() + 1e-9)
        assert rel < 1e-5, (algo, rel)
    try:
        run3("mesh2d")
    except ValueError as e:
        assert "two-axis" in str(e), e
    else:
        raise AssertionError("mesh2d over 3 axes must raise ValueError")
    print("topology-dispatched collectives ok (node:2 x device:4 and "
          "2x2x2: hierarchical/ring/tree vs psum within ulp; mesh2d "
          "refuses 3 axes)")


def check_tree_nonpow2_raises_value_error():
    """Satellite: the tree collective on a non-power-of-two axis raises
    ValueError at trace time (was a bare assert, stripped under -O)."""
    from repro.core.collectives import allreduce

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:6]), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(22), (6, 16))
    f = jax.shard_map(lambda v: allreduce(v, "tree", ("data",)),
                      mesh=mesh, in_specs=P("data", None),
                      out_specs=P(None, None),
                      axis_names={"data"}, check_vma=False)
    try:
        jax.jit(f).lower(x)
    except ValueError as e:
        assert "power-of-two" in str(e), e
    else:
        raise AssertionError("tree over 6 ranks must raise ValueError")
    print("tree non-power-of-two ValueError ok")


def check_hlo_collective_parse():
    from repro.launch.hlo_analysis import analyze
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    xs = jax.device_put(jnp.ones((8, 1024), jnp.float32),
                        NamedSharding(mesh, P("data", None)))
    g = jax.jit(lambda x: x.sum(0), out_shardings=NamedSharding(mesh, P(None)))
    txt = g.lower(xs).compile().as_text()
    s = analyze(txt, total_devices=8)
    assert s.collective_counts.get("all-reduce") == 1
    assert s.collective_operand_bytes == 4096.0
    assert abs(s.collective_wire_bytes - 2 * 4096 * 7 / 8) < 1
    print("hlo parse ok")


if __name__ == "__main__":
    check_collectives()
    check_ring_fused()
    check_fused_bit_trajectory()
    check_grad_sync()
    check_error_feedback_converges_distributed()
    check_plan_executor_heterogeneous()
    check_local_sgd()
    check_param_round_strategy()
    check_sharded_dp_bit_exact()
    check_pipeline_bit_exact()
    check_pipeline_matches_classic_dp_step()
    check_sharded_checkpoint_reshard()
    check_reduce_scatter_all_gather_roundtrip()
    check_sharded_segment_ids_multi_axis()
    check_topology_dispatched_collectives()
    check_tree_nonpow2_raises_value_error()
    check_hlo_collective_parse()
    print("ALL MULTI-DEVICE CHECKS PASSED")
