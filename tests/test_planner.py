"""Communication planner (schedule/planner.py) + PlanExecutor (grad_sync).

Covers: plan/execute equivalence (the degenerate one-strategy plan must
reproduce the legacy GradientSynchronizer output bit-for-bit), planner
monotonicity in the link parameters (higher β -> more compression, higher
α -> fewer/larger buckets), the auto-plan-beats-fixed-configs guarantee,
and a heterogeneous-plan round-trip under shard_map.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (GradientSynchronizer, PlanExecutor, SyncConfig,
                        plan_from_config)
from repro.core.schedule import (LINK_PRESETS, LayerProfile, LinkParams,
                                 fixed_config_plan, plan, plan_cost_s,
                                 profiles_from_grads)
from repro.core.schedule.planner import BucketPlan, CommPlan

RNG = jax.random.PRNGKey(0)


def _grads():
    return {"w1": jax.random.normal(RNG, (64, 32)),
            "b1": jax.random.normal(jax.random.PRNGKey(1), (33,)),
            "w2": jax.random.normal(jax.random.PRNGKey(2), (128, 16))}


# ---------------------------------------------------------------------------
# Plan/execute equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(compressor="none", algo="psum"),
    dict(compressor="int8", algo="ring"),
    dict(compressor="int8", algo="ring", bucket_bytes=0),
    dict(compressor="topk", algo="ring", compressor_args=(("ratio", 0.1),),
         bucket_bytes=2048),
    dict(compressor="qsgd", algo="ring"),
    dict(compressor="sign", algo="ring", bucket_bytes=512),
    dict(compressor="powersgd", algo="ring", compressor_args=(("rank", 2),)),
])
def test_one_entry_plan_equals_legacy_synchronizer(kw):
    """PlanExecutor on the degenerate plan == GradientSynchronizer,
    bit-for-bit, including EF state over multiple steps."""
    grads = _grads()
    cfg = SyncConfig(**kw)
    sync = GradientSynchronizer(cfg, ())
    ex = PlanExecutor(plan_from_config(cfg, grads), ())

    st_s, st_e = sync.init_state(grads), ex.init_state(grads)
    assert sorted(st_s.keys()) == sorted(st_e.keys())
    for step in range(3):
        r = jax.random.fold_in(jax.random.PRNGKey(7), step)
        out_s, st_s = sync(grads, st_s, r)
        out_e, st_e = ex(grads, st_e, r)
        for k in grads:
            a, b = np.asarray(out_s[k]), np.asarray(out_e[k])
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(a, b, err_msg=f"{kw} leaf {k}")
    assert sync.payload_bits(grads) == ex.payload_bits(grads)


def test_one_entry_plan_equivalence_under_shard_map():
    """Same equivalence inside a (1-device) shard_map — the production
    calling convention."""
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import PartitionSpec as P
    grads = _grads()
    cfg = SyncConfig(compressor="int8", algo="ring", bucket_bytes=4096)
    sync = GradientSynchronizer(cfg, ("data",))
    ex = PlanExecutor(plan_from_config(cfg, grads), ("data",))

    def run(engine):
        def body(g, rng):
            st = engine.init_state(g)
            out, st2 = engine(g, st, rng)
            return out
        f = jax.shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                          axis_names={"data"}, check_vma=False)
        return jax.jit(f)(grads, jax.random.PRNGKey(3))

    out_s, out_e = run(sync), run(ex)
    for k in grads:
        np.testing.assert_array_equal(np.asarray(out_s[k]),
                                      np.asarray(out_e[k]))


def test_heterogeneous_plan_round_trip_shard_map():
    """A plan mixing dense psum, packed int8/ring, and per-leaf topk executes
    under shard_map; with world=1 the synced grads must equal the local
    compressor round-trip (and the dense bucket must be exact)."""
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import PartitionSpec as P
    grads = _grads()
    leaves = jax.tree.leaves(grads)  # order: b1, w1, w2 (dict sorts keys)
    comm_plan = CommPlan(buckets=(
        BucketPlan(leaves=(0,), compressor="none", algo="psum",
                   bucket_bytes=4 * leaves[0].size),
        BucketPlan(leaves=(1,), compressor="int8", algo="ring",
                   bucket_bytes=4 * leaves[1].size, pack=True),
        BucketPlan(leaves=(2,), compressor="topk",
                   compressor_args=(("ratio", 0.25),), algo="ring",
                   bucket_bytes=4 * leaves[2].size, pack=False),
    ))
    ex = PlanExecutor(comm_plan, ("data",))

    def body(g, rng):
        st = ex.init_state(g)
        out, st2 = ex(g, st, rng)
        return out, st2["step"]

    f = jax.shard_map(body, mesh=mesh, in_specs=(P(), P()),
                      out_specs=(P(), P()),
                      axis_names={"data"}, check_vma=False)
    out, step = jax.jit(f)(grads, jax.random.PRNGKey(5))
    assert int(step) == 1
    out_leaves = jax.tree.leaves(out)
    # dense bucket: exact
    np.testing.assert_allclose(np.asarray(out_leaves[0]),
                               np.asarray(leaves[0]), rtol=1e-6)
    # compressed buckets: equal to the local round-trip (world=1), finite,
    # and correlated with the input
    for i in (1, 2):
        o = np.asarray(out_leaves[i]).ravel()
        g = np.asarray(leaves[i]).ravel()
        assert np.all(np.isfinite(o))
        corr = np.corrcoef(o, g)[0, 1]
        assert corr > 0.5, corr


# ---------------------------------------------------------------------------
# Planner search properties
# ---------------------------------------------------------------------------

def _profiles(n_layers=12, grad_mb=4.0, t_layer=2e-4):
    return [LayerProfile(t_backward_s=t_layer, grad_bytes=grad_mb * 2**20)
            for _ in range(n_layers)]


def test_higher_beta_picks_more_compression():
    """As bandwidth shrinks (β grows), the planned wire bytes must not grow —
    and on a slow link the planner must actually compress something."""
    profs = _profiles()
    world = 64

    def wire_bytes(p):
        from repro.core.schedule.cost import compressed_wire_bytes
        return sum(compressed_wire_bytes(b.compressor, b.compressor_args,
                                         b.bucket_bytes // 4)
                   for b in p.buckets)

    betas = [1 / 400e9, 1 / 50e9, 1 / 10e9, 1 / 1.25e9]
    prev = None
    for beta in betas:
        link = LinkParams(alpha_s=1e-6, beta_s_per_byte=beta)
        p = plan(profs, link, world)
        wb = wire_bytes(p)
        if prev is not None:
            assert wb <= prev + 1e-6, (beta, wb, prev)
        prev = wb
    slow = plan(profs, LINK_PRESETS["commodity"], world)
    assert any(b.compressor != "none" for b in slow.buckets)


def test_higher_alpha_merges_buckets():
    """As per-message latency grows, the planner must not choose MORE
    (smaller) buckets — merging is how MG-WFBP pays fewer αs."""
    profs = _profiles(n_layers=24, grad_mb=1.0)
    world = 64
    prev = None
    for alpha in (1e-7, 1e-6, 1e-5, 1e-4, 1e-3):
        link = LinkParams(alpha_s=alpha, beta_s_per_byte=1 / 50e9)
        p = plan(profs, link, world)
        if prev is not None:
            assert p.n_buckets <= prev + 0, (alpha, p.n_buckets, prev)
        prev = p.n_buckets
    assert prev == 1 or prev < 24  # strong latency must have merged


def test_auto_plan_never_modeled_slower_than_fixed_configs():
    """The acceptance guarantee: the planner's modeled iteration time is <=
    every fixed single-strategy config it knows about, at any granularity in
    its grid, across link regimes and world sizes."""
    profs = _profiles(n_layers=16, grad_mb=2.0)
    fixed = [("none", "psum", ()), ("topk", "ring", (("ratio", 0.01),)),
             ("int8", "ring", ())]
    for preset in ("fast_ici", "datacenter", "commodity"):
        link = LINK_PRESETS[preset]
        for world in (8, 64, 256):
            auto = plan(profs, link, world)
            for comp, algo, cargs in fixed:
                fp = fixed_config_plan(profs, link, world, comp, algo,
                                       compressor_args=cargs)
                assert auto.modeled_step_s <= fp.modeled_step_s + 1e-12, (
                    preset, world, comp, algo,
                    auto.modeled_step_s, fp.modeled_step_s)


def test_small_buckets_fall_back_to_dense():
    """The per-bucket selection is dense-restricted below the size threshold
    (compression cannot beat α there and only adds bias).  On a fast link a
    mixed model keeps the SMALL bucket dense on the latency-optimal tree;
    the big buckets take the fused compressed ring since PR 6 (ring_fused
    moves ~4x fewer bytes with a near-free modeled one-pass compute term,
    undercutting dense even on fast ICI) -- and with the candidate set
    restricted to dense wires, the historical all-dense pick with
    per-bucket algorithm differentiation still reproduces."""
    from repro.core.schedule.planner import (DEFAULT_CANDIDATES,
                                             _pick_candidate)
    for world in (8, 64, 256):
        for regime in ("fast_ici", "datacenter", "commodity"):
            cand, _ = _pick_candidate(2048, world, LINK_PRESETS[regime],
                                      DEFAULT_CANDIDATES,
                                      dense_small_bytes=65536)
            assert cand.compressor == "none", (world, regime)

    profs = ([LayerProfile(2e-4, 4 * 2**20) for _ in range(12)]
             + [LayerProfile(1e-5, 1024) for _ in range(4)])
    p = plan(profs, LINK_PRESETS["fast_ici"], world=64)
    # the sub-threshold bucket stays dense no matter what wins elsewhere
    for b in p.buckets:
        if b.bucket_bytes < 65536:
            assert b.compressor == "none", b
    # per-bucket differentiation: at least two distinct strategies
    assert len({(b.compressor, b.algo) for b in p.buckets}) >= 2, p.buckets

    dense_only = tuple(c for c in DEFAULT_CANDIDATES
                       if c.compressor == "none")
    pd = plan(profs, LINK_PRESETS["fast_ici"], world=64,
              candidates=dense_only)
    assert all(b.compressor == "none" for b in pd.buckets)
    assert len({b.algo for b in pd.buckets}) >= 2, pd.buckets


def test_plan_cost_matches_simulator_for_uniform_dense():
    """A uniform dense plan's simulated time equals the generalized
    MG-WFBP simulation with the same bucket boundaries."""
    from repro.core.schedule.cost import allreduce_cost_s
    profs = _profiles(n_layers=8, grad_mb=8.0)
    link = LINK_PRESETS["datacenter"]
    world = 32
    fp = fixed_config_plan(profs, link, world, "none", "ring",
                           bucket_bytes=16 * 2**20)
    # hand-simulate
    t, link_free = 0.0, 0.0
    ready = []
    produce = {}
    for i in reversed(range(len(profs))):
        t += profs[i].t_backward_s
        produce[i] = t
    for b in fp.buckets:
        ready.append(max(produce[i] for i in b.leaves))
    for r, b in sorted(zip(ready, fp.buckets), key=lambda x: x[0]):
        start = max(r, link_free)
        link_free = start + allreduce_cost_s("ring", b.bucket_bytes, world,
                                             link)
    expect = max(t, link_free)
    assert abs(fp.modeled_step_s - expect) < 1e-12


def test_profiles_from_grads_order_and_mass():
    grads = _grads()
    profs = profiles_from_grads(grads, t_backward_s=1.0)
    leaves = jax.tree.leaves(grads)
    assert len(profs) == len(leaves)
    for p, g in zip(profs, leaves):
        assert p.grad_bytes == 4 * g.size
    assert abs(sum(p.t_backward_s for p in profs) - 1.0) < 1e-9


def test_world_one_plan_is_single_dense_bucket():
    profs = _profiles(4)
    p = plan(profs, LINK_PRESETS["fast_ici"], world=1)
    assert p.n_buckets == 1
    assert p.buckets[0].compressor == "none"
