"""Flash attention (jnp streaming + custom VJP) vs the naive oracle, across
GQA/MQA ratios, windows, softcaps, chunk sizes, and both train and decode
paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (attention_reference, flash_attention)

RNG = jax.random.PRNGKey(0)


def qkv(B, T, H, KV, hd, S=None, dtype=jnp.float32):
    S = S or T
    q = jax.random.normal(RNG, (B, T, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (B, S, KV, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=True, window=48),
    dict(causal=True, softcap=30.0),
    dict(causal=False),
    dict(causal=True, window=32, softcap=20.0),
])
def test_forward_matches_reference(H, KV, kwargs):
    q, k, v = qkv(2, 128, H, KV, 32)
    out = flash_attention(q, k, v, q_chunk=32, kv_chunk=64, **kwargs)
    ref = attention_reference(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=True, window=48),
    dict(causal=True, softcap=25.0), dict(causal=False),
])
def test_custom_vjp_matches_reference(kwargs):
    q, k, v = qkv(1, 128, 4, 2, 32)

    def loss_f(fn):
        return lambda q, k, v: jnp.sum(jnp.tanh(fn(q, k, v)))

    f = loss_f(lambda q, k, v: flash_attention(q, k, v, q_chunk=32,
                                               kv_chunk=32, **kwargs))
    g = loss_f(lambda q, k, v: attention_reference(q, k, v, **kwargs))
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gg = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gg):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance():
    q, k, v = qkv(2, 128, 4, 2, 32)
    outs = [flash_attention(q, k, v, q_chunk=c, kv_chunk=kc)
            for c, kc in [(16, 16), (32, 128), (128, 32), (128, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_bf16_path():
    q, k, v = qkv(1, 64, 2, 2, 32, dtype=jnp.bfloat16)
    out = flash_attention(q, k, v, q_chunk=32, kv_chunk=32)
    ref = attention_reference(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32))
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)


def test_cross_attention_lengths():
    """q and kv lengths differ (encoder-decoder cross attention)."""
    q, k, v = qkv(2, 64, 4, 4, 32, S=128)
    out = flash_attention(q, k, v, causal=False, q_chunk=32, kv_chunk=64)
    ref = attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
