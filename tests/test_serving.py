"""Serving subsystem (DESIGN.md §12): paged KV cache, continuous
batching, sharded-decode planning.

The load-bearing contract is BIT-IDENTITY: at temperature 0 the
continuous engine — paged pool, vector-position decode, active-slot
masking, mid-stream admissions — must emit exactly the tokens of the
static ``launch/serve.generate`` reference at the same ``max_len``.
Plus: page alloc/free invariants (no leaks, no aliasing, trash page
never handed out), compile-once discipline, the decode cost model and
``plan_serving``, the deterministic bench_ci serving gate, and the
``--reduced`` flag fix.
"""
from __future__ import annotations

import os
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serve import (Engine, LeastLoadedRouter, MultiReplicaServer,
                         PageAllocator, Request, ServeConfig, SimCosts,
                         TRASH_PAGE, run_static)
from repro.serve.engine import latency_summary

SCRIPTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "scripts")


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced(get_config("gemma-2b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, P, seed=1):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n, P),
                                         0, cfg.vocab_size), np.int32)


# ---------------------------------------------------------------------------
# satellite: the --reduced flag is actually disableable now
# ---------------------------------------------------------------------------

def test_reduced_flag_parsing():
    from repro.launch.serve import build_parser
    ap = build_parser()
    assert ap.parse_args([]).reduced is True
    assert ap.parse_args(["--reduced"]).reduced is True
    assert ap.parse_args(["--no-reduced"]).reduced is False


# ---------------------------------------------------------------------------
# page allocator invariants
# ---------------------------------------------------------------------------

def test_page_allocator_invariants():
    a = PageAllocator(n_pages=9, page_size=4, length=16, max_batch=3)
    assert a.pages_needed(1) == 1 and a.pages_needed(5) == 2
    assert a.pages_needed(999) == 4          # capped at pages_per_slot
    a.alloc(0, 8)
    a.alloc(1, 5)
    a.check()
    assert TRASH_PAGE not in a.live_pages()
    with pytest.raises(RuntimeError):
        a.alloc(0, 4)                        # double alloc
    assert a.free(1) == 2
    assert (a.table()[1] == TRASH_PAGE).all()
    a.alloc(2, 16)
    a.check()
    with pytest.raises(RuntimeError):
        a.alloc(1, 16)                       # 2 free pages < 4 needed
    a.check()


def test_no_page_leaks_or_aliasing(gemma):
    # sim mode runs the identical alloc/free state machine with no device
    # pool; check() asserts disjoint live pages + full accounting each step
    cfg, model, _ = gemma
    eng = Engine(model, None,
                 ServeConfig(max_batch=3, max_len=16, page_size=4),
                 sim=SimCosts())
    reqs = [Request(rid=i, prompt=_prompts(cfg, 1, 8)[0],
                    max_new=[8, 3, 5, 8, 2][i], arrival_s=0.002 * i)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    seen = []
    while eng.busy():
        eng.step()
        for alloc in eng.cache.allocators.values():
            alloc.check()
        seen.append(sum(len(a.live_pages())
                        for a in eng.cache.allocators.values()))
    assert max(seen) > 0
    for alloc in eng.cache.allocators.values():   # drained: no leaks
        assert not alloc.live_pages()
        alloc.check()


def test_oversubscribed_pool_defers_admission(gemma):
    # pool holds ~2 concurrent sequences for a 4-slot engine: admission
    # must wait for pages, and every request still completes
    cfg, model, _ = gemma
    eng = Engine(model, None,
                 ServeConfig(max_batch=4, max_len=16, page_size=4,
                             n_pages=9), sim=SimCosts())
    out = eng.run([Request(rid=i, prompt=_prompts(cfg, 1, 8)[0], max_new=8)
                   for i in range(6)])
    assert sorted(c.rid for c in out) == list(range(6))
    assert all(len(c.tokens) == 8 for c in out)


# ---------------------------------------------------------------------------
# tentpole: bit-identical continuous vs static decode at temperature 0
# ---------------------------------------------------------------------------

def test_engine_bit_identical(gemma):
    from repro.launch.serve import generate
    cfg, model, params = gemma
    P, G, ML = 8, 8, 16
    prompts = _prompts(cfg, 3, P)
    ref = np.asarray(generate(model, params, prompts, gen=G, max_len=ML,
                              rng=jax.random.PRNGKey(2)))
    eng = Engine(model, params,
                 ServeConfig(max_batch=3, max_len=ML, page_size=4))
    out = eng.run([Request(rid=i, prompt=prompts[i], max_new=G)
                   for i in range(3)])
    for c in out:
        np.testing.assert_array_equal(c.tokens, ref[c.rid])


def test_engine_bit_identical_midstream_admission(gemma):
    # 5 requests through 2 slots: retirements free slots mid-stream and
    # later admissions join a half-full batch — rows must still match the
    # per-request static reference exactly
    from repro.launch.serve import generate
    cfg, model, params = gemma
    P, ML = 8, 16
    gens = [8, 3, 5, 8, 2]
    prompts = _prompts(cfg, 5, P)
    refs = [np.asarray(generate(model, params, prompts[i:i + 1],
                                gen=gens[i], max_len=ML,
                                rng=jax.random.PRNGKey(2)))[0]
            for i in range(5)]
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, max_len=ML, page_size=4))
    out = eng.run([Request(rid=i, prompt=prompts[i], max_new=gens[i])
                   for i in range(5)])
    assert len(out) == 5
    for c in out:
        np.testing.assert_array_equal(c.tokens, refs[c.rid])
    assert eng.compile_counts() == {"prefill": 1, "admit": 1, "decode": 1}


def test_zero_token_completion(gemma):
    # regression: max_new=0 requests used to crash Completion.finish_s
    # (emit_s[-1] on an empty list) — they must complete with no tokens,
    # finish at admit time, and keep latency_summary finite, mixed into a
    # batch with normal requests
    cfg, model, _ = gemma
    eng = Engine(model, None,
                 ServeConfig(max_batch=2, max_len=16, page_size=4),
                 sim=SimCosts())
    reqs = [Request(rid=0, prompt=_prompts(cfg, 1, 8)[0], max_new=0),
            Request(rid=1, prompt=_prompts(cfg, 1, 8)[0], max_new=4)]
    out = {c.rid: c for c in eng.run(reqs)}
    assert len(out) == 2
    empty = out[0]
    assert len(empty.tokens) == 0 and empty.emit_s == []
    assert empty.finish_s == empty.admit_s
    assert empty.first_token_s == empty.admit_s
    assert np.isfinite(empty.per_token_latency_s)
    assert len(out[1].tokens) == 4
    summ = latency_summary(list(out.values()))
    assert summ["tokens"] == 4
    assert all(np.isfinite(v) for v in summ.values())

    # the static baseline takes the same degenerate request
    stat = run_static(model, None, reqs, max_batch=2, max_len=16,
                      sim=SimCosts())
    stat = {c.rid: c for c in stat}
    assert len(stat[0].tokens) == 0 and stat[0].emit_s == []
    assert stat[0].finish_s == stat[0].admit_s
    assert len(stat[1].tokens) == 4


def test_run_static_matches_generate(gemma):
    from repro.launch.serve import generate
    cfg, model, params = gemma
    P, G, ML = 8, 4, 12
    prompts = _prompts(cfg, 2, P)
    ref = np.asarray(generate(model, params, prompts, gen=G, max_len=ML,
                              rng=jax.random.PRNGKey(2)))
    out = run_static(model, params,
                     [Request(rid=i, prompt=prompts[i], max_new=G)
                      for i in range(2)], max_batch=2, max_len=ML)
    for c in out:
        np.testing.assert_array_equal(c.tokens, ref[c.rid])


def test_quantized_kv_runs_lossy(gemma):
    # int8 paged KV: documented lossy — assert it runs, pools are int8,
    # and greedy decode still emits valid finite tokens
    cfg, model, params = gemma
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, max_len=16, page_size=4,
                             quantize="int8"))
    leaves = jax.tree.leaves(eng.pool)
    assert any(l.dtype == np.int8 for l in leaves)
    out = eng.run([Request(rid=i, prompt=_prompts(cfg, 2, 8)[i], max_new=4)
                   for i in range(2)])
    for c in out:
        assert ((c.tokens >= 0) & (c.tokens < cfg.vocab_size)).all()


# ---------------------------------------------------------------------------
# satellite: jits hoisted — sessions never recompile per request
# ---------------------------------------------------------------------------

def test_generate_session_compiles_once(gemma):
    from repro.launch.serve import session_for
    cfg, model, params = gemma
    s = session_for(model)
    assert session_for(model) is s          # cached per model
    prompts = _prompts(cfg, 2, 8)
    before = s.compile_counts()
    a = s.generate(params, prompts, gen=3, max_len=12,
                   rng=jax.random.PRNGKey(0))
    b = s.generate(params, prompts, gen=3, max_len=12,
                   rng=jax.random.PRNGKey(0))
    after = s.compile_counts()
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # second call added no traces
    assert after["prefill"] <= before["prefill"] + 1
    assert after["decode"] <= before["decode"] + 1


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def test_least_loaded_router_ties_round_robin():
    r = LeastLoadedRouter()
    assert r.pick([0, 0, 0]) == 0           # 3-way tie: cursor at 0
    assert r.pick([1, 0, 0]) == 2           # tie {1,2}: cursor advanced
    assert r.pick([1, 0, 1]) == 1           # unique minimum always wins
    assert r.pick([1, 1, 1]) == 0           # cursor wraps deterministically


def test_multi_replica_server_drains(gemma):
    cfg, model, _ = gemma
    sim = SimCosts()
    srv = MultiReplicaServer(
        [Engine(model, None, ServeConfig(max_batch=2, max_len=16,
                                         page_size=4), sim=sim)
         for _ in range(2)])
    out = srv.run([Request(rid=i, prompt=_prompts(cfg, 1, 8)[0], max_new=4)
                   for i in range(6)])
    assert sorted(c.rid for c in out) == list(range(6))
    assert sorted(set(srv.routes)) == [0, 1]     # both replicas used


def test_sim_continuous_beats_static(gemma):
    cfg, model, _ = gemma
    sim = SimCosts()
    reqs = [Request(rid=i, prompt=_prompts(cfg, 1, 8)[0],
                    max_new=24 if i % 4 == 0 else 4) for i in range(12)]
    eng = Engine(model, None, ServeConfig(max_batch=4, max_len=32,
                                          page_size=8), sim=sim)
    cont = latency_summary(eng.run(reqs))
    stat = latency_summary(run_static(model, None, reqs, 4, 32, sim=sim))
    assert cont["tokens"] == stat["tokens"]
    assert cont["makespan_s"] < stat["makespan_s"]
    assert cont["p99_s"] <= stat["p99_s"]


# ---------------------------------------------------------------------------
# decode cost model + serving planner
# ---------------------------------------------------------------------------

def test_decode_step_cost():
    from repro.core.schedule import (LINK_PRESETS, decode_step_cost_s)
    link = LINK_PRESETS["fast_ici"]
    pb, L, D = 4e9, 18, 2048
    t1 = decode_step_cost_s(pb, L, D, batch=8, tp=1, net=link)
    t4 = decode_step_cost_s(pb, L, D, batch=8, tp=4, net=link)
    assert t4 < t1                 # fast link: sharding the weights wins
    slow = decode_step_cost_s(pb, L, D, batch=8, tp=4,
                              net=LINK_PRESETS["commodity"])
    assert slow > t4               # same shard, slower collectives
    with pytest.raises(ValueError):
        decode_step_cost_s(pb, L, D, batch=8, tp=0, net=link)


def test_plan_serving_places_tp_on_fast_tier():
    from repro.core.schedule import (TOPOLOGY_PRESETS, Topology,
                                     plan_serving)
    net = Topology.from_spec(TOPOLOGY_PRESETS["two_tier_pod"])
    best, arms = plan_serving(net, net.world, 5e9, 18, 2048, batch=8)
    assert best.tokens_per_s == max(a.tokens_per_s for a in arms)
    assert best.replicas * best.tp <= net.world
    # a tight latency budget forces TP, and its collectives land on the
    # fast (device) tier, never across nodes
    budget = min(a.step_s for a in arms) * 1.01
    tight, _ = plan_serving(net, net.world, 5e9, 18, 2048, batch=8,
                            latency_budget_s=budget)
    assert tight.tp > 1
    assert tight.tp_tier == "device"
    with pytest.raises(ValueError):
        plan_serving(net, net.world, 5e9, 18, 2048, batch=8, tp_grid=(3,))


def test_render_serving_plan():
    from repro.core.schedule import (TOPOLOGY_PRESETS, Topology,
                                     plan_serving)
    from repro.launch.report import render_serving_plan
    net = Topology.from_spec(TOPOLOGY_PRESETS["two_tier_pod"])
    best, arms = plan_serving(net, net.world, 5e9, 18, 2048, batch=8)
    md = render_serving_plan(best, arms, arch="gemma-2b", batch=8)
    assert best.key() in md and "tok/s" in md and "| arm |" in md


# ---------------------------------------------------------------------------
# bench_ci serving suite: deterministic, gated, and the gate trips
# ---------------------------------------------------------------------------

def test_bench_ci_serving_gate(tmp_path):
    sys.path.insert(0, SCRIPTS)
    try:
        import bench_ci
    finally:
        sys.path.remove(SCRIPTS)
    recs = bench_ci.collect_serving()
    assert recs == bench_ci.collect_serving()       # bit-deterministic
    ratio = recs["gemma-2b/sim/speedup"]["continuous_over_static_makespan"]
    assert ratio < 1.0
    # against the COMMITTED baseline
    basedir = os.path.join(os.path.dirname(SCRIPTS), "benchmarks",
                           "baselines")
    assert not bench_ci.gate({"serving": recs}, basedir, 0.10)
    # negative test: a 20% regression must trip the 10% gate
    import copy
    bad = copy.deepcopy(recs)
    for r in bad.values():
        r[r["metric"]] *= 1.2
    assert bench_ci.gate({"serving": bad}, basedir, 0.10)
