"""hypothesis-or-stub (satellite of the planner PR; see requirements-dev.txt).

``from hyp_compat import given, settings, st`` gives test modules the real
hypothesis API when it is installed.  When it is not (the seed container),
``given`` becomes a skip-marking decorator and ``st`` a chainable stub, so
module-level strategy expressions still evaluate and the module's
NON-property tests keep running instead of the whole file being skipped.
"""
from __future__ import annotations

import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _StubStrategy:
        """Absorbs any attribute access / call chain at module import."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _StubStrategy()

    def given(*a, **k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (requirements-dev.txt)")(fn)
        return deco

    def settings(*a, **k):
        return lambda fn: fn
