"""Sharded-DP planner + shard-layout properties (ISSUE 3 satellites).

Property-based (hypothesis, via the hyp_compat shim) coverage of:

  * the canonical chunking layout: chunk_rows/rows_to_flat round-trip for
    arbitrary sizes and (nested) axis shapes, and agreement with the
    device-side ``pad_to_chunks`` twin;
  * the cost model: reduce-scatter is the reduce half of the allreduce,
    the params-gather tail is never free, so a sharded plan is never
    MODELED FASTER than the replicated plan — sharding is a memory trade;
  * the memory model: per-worker sharded state is (moments+1)/world of a
    full f32 param set, monotone in world size;
  * the planner decision: with a fixed per-worker budget the
    replicated->sharded crossover is MONOTONE in param count and in world
    size (once memory forces sharding, more params / the same params on
    any world keep it forced);
  * auto never modeled slower than either fixed mode (the bench_sharded
    acceptance inequality, asserted across link regimes here).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core.schedule import (LINK_PRESETS, LayerProfile, LinkParams,
                                 allreduce_cost_s, fixed_config_plan,
                                 opt_state_bytes_per_worker, plan,
                                 plan_rounds, reduce_scatter_cost_s,
                                 shard_gather_tail_s)
from repro.core.shard_state import (ShardLayout, chunk_rows, nested_ms,
                                    rows_to_flat)
from repro.core.grad_sync import sharded_plan_from_config
from repro.core import SyncConfig


def _profs(n=12, mb=4.0, t_layer=2e-4):
    return [LayerProfile(t_backward_s=t_layer, grad_bytes=mb * 2**20)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Layout properties
# ---------------------------------------------------------------------------

@given(st.integers(1, 4000),
       st.lists(st.sampled_from([1, 2, 3, 4, 8]), min_size=1, max_size=3))
@settings(max_examples=50, deadline=None)
def test_chunk_rows_roundtrip(n, axis_sizes):
    flat = np.arange(n, dtype=np.float32) + 1.0
    rows = chunk_rows(flat, axis_sizes)
    world = int(np.prod(axis_sizes))
    assert rows.shape == (world, nested_ms(n, axis_sizes)[-1])
    back = rows_to_flat(rows, n, axis_sizes)
    np.testing.assert_array_equal(back, flat)
    # padding is zeros and every original element appears exactly once
    assert rows.sum() == flat.sum()


@given(st.integers(1, 500),
       st.lists(st.sampled_from([1, 2, 4]), min_size=1, max_size=2))
@settings(max_examples=25, deadline=None)
def test_chunk_rows_matches_device_pad_to_chunks(n, axis_sizes):
    """Host-side chunking (state init / checkpoints) and the device-side
    twin the collectives use must agree slot-for-slot — this equality is
    what makes reduce-scattered gradient chunks land on the state shards
    their owner holds."""
    from repro.core.collectives import pad_to_chunks
    flat = np.arange(n, dtype=np.float32) + 1.0
    rows = chunk_rows(flat, axis_sizes)
    dev = np.asarray(pad_to_chunks(jnp.asarray(flat), axis_sizes))
    np.testing.assert_array_equal(rows.reshape(-1), dev)


def test_layout_seg_ids_and_reshard():
    params = {"a": jnp.ones((5, 3)), "b": jnp.ones((7,)),
              "c": jnp.ones((2, 2))}
    plan_ = sharded_plan_from_config(SyncConfig(bucket_bytes=48), params)
    lay = ShardLayout.from_plan(plan_, params, (4,))
    # segment ids: every real slot carries its leaf id, padding the sentinel
    sizes = {i: int(np.prod(l.shape))
             for i, l in enumerate(jax.tree.leaves(params))}
    for j, b in enumerate(lay.buckets):
        seg = lay.seg_rows(j)
        assert seg.shape == (4, b.m)
        counts = {i: int((seg == i).sum()) for i in b.leaves}
        assert counts == {i: sizes[i] for i in b.leaves}
        assert int((seg == lay.n_leaves).sum()) == 4 * b.m - b.n
    # reshard to a different mesh shape preserves the full state bit-exactly
    rows = lay.shard_rows(params)
    for new_sizes in ((2,), (1,), (2, 2)):
        new_lay, new_rows = lay.reshard(rows, new_sizes)
        got = new_lay.tree_from_rows(new_rows, params)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_non_divisor_worlds_bit_exact():
    """The elastic path (DESIGN.md §15): state saved on world 8 must land
    bit-exactly on worlds that do NOT divide it — 6 (preemption), 5, 3,
    and a nested (2, 3) mesh — and back to 8 again.  Nested ceil-chunking
    only pads the tail, so no divisibility is required; a silent
    misalignment here would corrupt every post-reshard optimizer step."""
    rng = np.random.default_rng(7)
    params = {"w": jnp.asarray(rng.normal(size=(13, 5)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(11,)).astype(np.float32))}
    plan_ = sharded_plan_from_config(SyncConfig(bucket_bytes=128), params)
    lay8 = ShardLayout.from_plan(plan_, params, (8,))
    rows8 = lay8.shard_rows(params)
    for sizes in ((6,), (5,), (3,), (2, 3)):
        new_lay, new_rows = lay8.reshard(rows8, sizes)
        assert new_lay.world == int(np.prod(sizes))
        got = new_lay.tree_from_rows(new_rows, params)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and back to 8: the full 8 -> 6 -> 8 elastic round trip
        back_lay, back_rows = new_lay.reshard(new_rows, (8,))
        for r8, rb in zip(rows8, back_rows):
            np.testing.assert_array_equal(np.asarray(r8), np.asarray(rb))
    # invalid target shapes fail loudly, not with misaligned rows
    for bad in ((), (0,), (-2,), (2.5,)):
        with pytest.raises(ValueError, match="positive integer"):
            lay8.reshard(rows8, bad)


# ---------------------------------------------------------------------------
# Cost-model properties
# ---------------------------------------------------------------------------

@given(st.integers(2, 512), st.floats(1e3, 1e9),
       st.sampled_from(["ring", "psum", "tree", "hierarchical"]))
@settings(max_examples=50, deadline=None)
def test_reduce_scatter_is_half_the_ring_allreduce(p, n_bytes, algo):
    """The scatter edge is priced as the ring reduce half REGARDLESS of
    the bucket's algo, because that is what the executor runs (explicit
    algos ring; psum delegates to XLA's ring-equivalent) — pricing the
    named algo would open a modeled/executed gap."""
    link = LINK_PRESETS["datacenter"]
    rs = reduce_scatter_cost_s(algo, n_bytes, p, link)
    ar_ring = allreduce_cost_s("ring", n_bytes, p, link)
    assert rs == pytest.approx(ar_ring / 2)
    assert rs > 0


def test_sharded_plan_never_modeled_faster():
    """The memory trade has a price: moving the gather half of the
    allreduce out of the overlappable window (it must wait for the
    optimizer) can only cost wall clock, never save it."""
    for preset in ("fast_ici", "datacenter", "commodity"):
        link = LINK_PRESETS[preset]
        for world in (8, 64, 256):
            for t_layer in (2e-5, 2e-4, 2e-3):
                profs = _profs(t_layer=t_layer)
                rep = plan(profs, link, world, shard_state=False)
                sh = plan(profs, link, world, shard_state=True)
                assert sh.shard_state and not rep.shard_state
                assert sh.modeled_step_s >= rep.modeled_step_s - 1e-15, \
                    (preset, world, t_layer)
                assert shard_gather_tail_s(sh, link, world) > 0


def test_measured_moments_override_name_default():
    """sgd with momentum=0.0 carries NO moment buffers: the session
    measures the actual count and the memory model must honour it (the
    per-name default would charge 1x params of phantom state and could
    flip budget decisions needlessly)."""
    from repro.api import SessionConfig, TrainSession
    pb = 64 * 2**20
    assert opt_state_bytes_per_worker("sgd", pb, 8, False, moments=0.0) == 0
    assert opt_state_bytes_per_worker("sgd", pb, 8, True, moments=0.0) == \
        pytest.approx(pb / 8)   # master shard only
    sess = TrainSession(SessionConfig(arch="xlstm-125m", reduced=True,
                                      batch=2, seq=16, steps=2,
                                      optimizer="sgd"))
    assert sess.opt_moments == 0.0
    sess2 = TrainSession(SessionConfig(arch="xlstm-125m", reduced=True,
                                       batch=2, seq=16, steps=2,
                                       optimizer="adam"))
    assert sess2.opt_moments == pytest.approx(2.0)


@given(st.integers(2, 512), st.integers(1, 100))
@settings(max_examples=50, deadline=None)
def test_sharded_memory_identity(world, n_mb):
    pb = n_mb * 2**20
    rep = opt_state_bytes_per_worker("adam", pb, world, False)
    sh = opt_state_bytes_per_worker("adam", pb, world, True)
    assert rep == 2 * pb                    # two Adam moments
    assert sh == pytest.approx(3 * pb / world)   # + master, over 1/p
    sh2 = opt_state_bytes_per_worker("adam", pb, world * 2, True)
    assert sh2 < sh                          # monotone in world size


# ---------------------------------------------------------------------------
# Planner-decision properties
# ---------------------------------------------------------------------------

def _decision(n_layers, world, budget_bytes, mb=4.0):
    best, _ = plan_rounds(_profs(n=n_layers, mb=mb),
                          LINK_PRESETS["datacenter"], world,
                          opt_name="adam", memory_budget_bytes=budget_bytes)
    return best.shard_state


def test_crossover_monotone_in_param_count():
    """With a fixed per-worker budget, growing the model flips the
    decision replicated -> sharded exactly once (replicated moments grow
    past the budget and never come back)."""
    budget = 100 * 2**20
    decisions = [_decision(n_layers, world=64, budget_bytes=budget)
                 for n_layers in (1, 2, 4, 8, 12, 16, 24, 32)]
    assert decisions == sorted(decisions), decisions   # False... then True...
    assert decisions[0] is False and decisions[-1] is True


def test_crossover_monotone_in_world_size():
    """A model whose replicated moments bust the budget needs sharding at
    EVERY world size (replicated memory does not depend on p), and the
    sharded footprint only shrinks with p — the decision cannot flip
    back."""
    budget = 40 * 2**20          # < 2 moments x 96 MiB params
    for world in (2, 4, 8, 64, 256):
        assert _decision(12, world, budget) is True, world
    # generous budget: never shard (the tail is pure cost)
    for world in (2, 8, 256):
        assert _decision(12, world, 10 * 2**30) is False, world


def test_budget_with_no_feasible_arm_picks_min_memory():
    best, arms = plan_rounds(_profs(), LINK_PRESETS["datacenter"], 64,
                             opt_name="adam", memory_budget_bytes=1)
    assert best.shard_state
    assert best.opt_mem_bytes == min(a.opt_mem_bytes for a in arms.values())


def test_auto_never_modeled_slower_than_either_fixed_mode():
    """The bench_sharded acceptance inequality: the unconstrained search
    (which contains both execution modes as arms) is never modeled slower
    than the fixed replicated dense mode, the fixed sharded dense mode, or
    the compressed fixed baselines in either mode."""
    for preset in ("fast_ici", "datacenter", "commodity"):
        link = LINK_PRESETS[preset]
        for world in (8, 64, 256):
            profs = _profs()
            best, arms = plan_rounds(profs, link, world, opt_name="adam")
            assert "every_step_sharded" in arms
            for shard in (False, True):
                for comp, algo, cargs in (("none", "psum", ()),
                                          ("none", "ring", ()),
                                          ("int8", "ring", ())):
                    fp = fixed_config_plan(profs, link, world, comp, algo,
                                           compressor_args=cargs,
                                           shard_state=shard)
                    assert best.modeled_step_s <= fp.modeled_step_s + 1e-12, \
                        (preset, world, shard, comp, algo)


def test_sharded_arm_reports_memory_in_record():
    best, arms = plan_rounds(_profs(), LINK_PRESETS["commodity"], 64,
                             opt_name="adam",
                             memory_budget_bytes=10 * 2**20)
    assert best.shard_state
    rec_arm = arms["every_step_sharded"]
    assert rec_arm.opt_mem_bytes == pytest.approx(
        opt_state_bytes_per_worker(
            "adam", sum(p.grad_bytes for p in _profs()), 64, True))
