"""Config-system invariants: stack plans, layer patterns, shape applicability."""
import pytest

from repro.configs import (ALL_ARCHS, SHAPES, applicable_shapes, get_config,
                           reduced)


def test_all_archs_resolve():
    assert len(ALL_ARCHS) == 10
    for name in ALL_ARCHS:
        cfg = get_config(name)
        assert cfg.name == name


def test_stack_plan_covers_all_layers():
    for name in ALL_ARCHS:
        cfg = get_config(name)
        plan = cfg.stack_plan()
        total = cfg.first_dense + sum(s.num_layers for s in plan) - cfg.first_dense
        assert sum(s.num_layers for s in plan) == cfg.num_layers, name


def test_jamba_pattern():
    cfg = get_config("jamba-v0.1-52b")
    specs = [cfg.layer_spec(i) for i in range(cfg.num_layers)]
    attn_layers = [i for i, s in enumerate(specs) if s.mixer == "attn"]
    assert attn_layers == [3, 11, 19, 27]          # 1:7 interleave
    moe_layers = [i for i, s in enumerate(specs) if s.ffn == "moe"]
    assert moe_layers == list(range(1, 32, 2))     # every other layer
    assert all(s.mixer == "mamba" for i, s in enumerate(specs)
               if i not in attn_layers)


def test_gemma3_pattern():
    cfg = get_config("gemma3-4b")
    specs = [cfg.layer_spec(i) for i in range(cfg.num_layers)]
    # 5 local : 1 global
    for i, s in enumerate(specs):
        if i % 6 == 5:
            assert s.window is None, i
        else:
            assert s.window == cfg.window_size, i


def test_deepseek_v2_first_dense():
    cfg = get_config("deepseek-v2-lite-16b")
    specs = [cfg.layer_spec(i) for i in range(cfg.num_layers)]
    assert specs[0].ffn == "dense"
    assert all(s.ffn == "moe" for s in specs[1:])
    assert all(s.mixer == "mla" for s in specs)


def test_xlstm_pattern():
    cfg = get_config("xlstm-125m")
    specs = [cfg.layer_spec(i) for i in range(cfg.num_layers)]
    assert [s.mixer for s in specs[:4]] == ["mlstm"] * 3 + ["slstm"]


def test_applicable_shapes():
    long_ok = {n for n in ALL_ARCHS
               if "long_500k" in applicable_shapes(get_config(n))}
    assert long_ok == {"gemma2-9b", "gemma3-4b", "xlstm-125m", "jamba-v0.1-52b"}
    for n in ALL_ARCHS:
        shapes = applicable_shapes(get_config(n))
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)


def test_reduced_bounds():
    for name in ALL_ARCHS:
        r = reduced(get_config(name))
        assert r.d_model <= 512
        assert r.num_layers <= 8
        if r.num_experts:
            assert r.num_experts <= 4
        assert r.family == get_config(name).family


def test_padded_vocab_divisible():
    for name in ALL_ARCHS:
        cfg = get_config(name)
        assert cfg.padded_vocab % 16 == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_param_counts_plausible():
    import re
    from repro.models import count_params
    expected = {"deepseek-67b": 67e9, "gemma2-9b": 10e9, "gemma-2b": 2.5e9,
                "qwen3-moe-30b-a3b": 30e9, "jamba-v0.1-52b": 52e9,
                "chameleon-34b": 34e9, "deepseek-v2-lite-16b": 16e9,
                "gemma3-4b": 4.5e9}
    for name, target in expected.items():
        n = count_params(get_config(name))
        assert 0.8 * target < n < 1.25 * target, (name, n)
