"""Optimizers (survey §3.1.1) and LR schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (apply_updates, clip_by_global_norm, make_optimizer,
                         legw_warmup_steps, scale_lr_for_batch, warmup_cosine)

RNG = jax.random.PRNGKey(3)


def quad_problem(opt, steps=200):
    """Minimize ||w - w*||^2; returns final distance."""
    w_star = jax.random.normal(RNG, (8, 4))
    params = {"w": jnp.zeros((8, 4))}
    state = opt.init(params)
    for t in range(steps):
        grads = {"w": 2 * (params["w"] - w_star)}
        updates, state = opt.update(grads, state, params, jnp.asarray(t))
        params = apply_updates(params, updates)
    return float(jnp.linalg.norm(params["w"] - w_star))


@pytest.mark.parametrize("name,kwargs", [
    ("sgd", dict(lr=0.1)),
    ("sgd", dict(lr=0.05, momentum=0.9)),
    ("adam", dict(lr=0.05)),
    ("lamb", dict(lr=0.05, weight_decay=0.0)),
    ("lars", dict(lr=0.5, trust_coef=0.02, weight_decay=0.0)),
])
def test_optimizers_converge_quadratic(name, kwargs):
    # LAMB's trust ratio ties the step size to ||w||, which slows the last
    # stretch on a quadratic from zero-init — hence the looser bound.
    assert quad_problem(make_optimizer(name, **kwargs)) < 0.3, name


def test_lars_trust_ratio_formula():
    opt = make_optimizer("lars", lr=1.0, momentum=0.0, weight_decay=0.0,
                         trust_coef=0.01)
    params = {"w": jnp.full((4,), 2.0)}          # ||w|| = 4
    grads = {"w": jnp.full((4,), 1.0)}           # ||g|| = 2
    state = opt.init(params)
    updates, _ = opt.update(grads, state, params, jnp.asarray(0))
    # trust = 0.01 * 4 / 2 = 0.02; update = -lr * trust * g = -0.02
    np.testing.assert_allclose(np.asarray(updates["w"]), -0.02, rtol=1e-5)


def test_lamb_trust_scales_update_to_weight_norm():
    opt = make_optimizer("lamb", lr=1.0, weight_decay=0.0)
    params = {"w": jax.random.normal(RNG, (16,)) * 3}
    grads = {"w": jax.random.normal(jax.random.fold_in(RNG, 1), (16,)) * 100}
    state = opt.init(params)
    updates, _ = opt.update(grads, state, params, jnp.asarray(0))
    # ||update|| == lr * ||w|| regardless of gradient scale
    np.testing.assert_allclose(float(jnp.linalg.norm(updates["w"])),
                               float(jnp.linalg.norm(params["w"])), rtol=1e-4)


def test_adam_matches_reference_step():
    opt = make_optimizer("adam", lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.25])}
    state = opt.init(params)
    updates, _ = opt.update(grads, state, params, jnp.asarray(0))
    # bias-corrected first step: update = -lr * g/|g| elementwise (m/c1 = g,
    # sqrt(v/c2) = |g|)
    np.testing.assert_allclose(np.asarray(updates["w"]),
                               [-0.1, -0.1], rtol=1e-4)


def test_scaling_rules():
    assert scale_lr_for_batch(0.1, 256, 1024, "linear") == pytest.approx(0.4)
    assert scale_lr_for_batch(0.1, 256, 1024, "sqrt") == pytest.approx(0.2)
    assert legw_warmup_steps(100, 256, 2048) == 800  # LEGW: warmup x k


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(s(0)) == 0.0
    assert float(s(5)) == pytest.approx(0.5)
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-6)
    # monotone decay after warmup
    vals = [float(s(t)) for t in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.optim import global_norm
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    clipped2, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 3.0)
