"""Chunkwise-parallel mLSTM (the §Perf MXU formulation) must match the
sequential per-step recurrence exactly — states, outputs, and end-to-end
through the model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.models.xlstm import mlstm_chunkwise

RNG = jax.random.PRNGKey(11)


def sequential_reference(q, k, v, log_i, log_f):
    """Direct transcription of the per-step recurrence (f32)."""
    B, T, H, dh = q.shape
    C = jnp.zeros((B, H, dh, dh), jnp.float32)
    n = jnp.zeros((B, H, dh), jnp.float32)
    m = jnp.full((B, H), -1e30, jnp.float32)
    hs = []
    for t in range(T):
        q_t, k_t, v_t = (x[:, t].astype(jnp.float32) for x in (q, k, v))
        li, lf = log_i[:, t], log_f[:, t]
        m_new = jnp.maximum(lf + m, li)
        i_p = jnp.exp(li - m_new)
        f_p = jnp.exp(lf + m - m_new)
        C = C * f_p[..., None, None] + i_p[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :])
        n = n * f_p[..., None] + i_p[..., None] * k_t
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)), 1.0)
        hs.append(num / den[..., None])
        m = m_new
    return jnp.stack(hs, axis=1), (C, n, m)


@pytest.mark.parametrize("chunk", [1, 4, 8, 32])
def test_chunkwise_equals_sequential(chunk):
    B, T, H, dh = 2, 32, 3, 8
    q = jax.random.normal(RNG, (B, T, H, dh))
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (B, T, H, dh))
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (B, T, H, dh))
    log_i = jax.random.normal(jax.random.fold_in(RNG, 3), (B, T, H))
    log_f = -jax.nn.softplus(
        -jax.random.normal(jax.random.fold_in(RNG, 4), (B, T, H)))
    init = (jnp.zeros((B, H, dh, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32))
    hs, (C, n, m) = mlstm_chunkwise(q, k, v, log_i, log_f, init, chunk=chunk)
    hs_ref, (C_r, n_r, m_r) = sequential_reference(q, k, v, log_i, log_f)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(n), np.asarray(n_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_model_parallel_form_matches_sequential():
    """Full xlstm model: mlstm_parallel=True == sequential scan form."""
    cfg = reduced(get_config("xlstm-125m"))
    cfg_seq = dataclasses.replace(cfg, mlstm_parallel=False)
    cfg_par = dataclasses.replace(cfg, mlstm_parallel=True, mlstm_chunk=16)
    m_seq, m_par = Model(cfg_seq), Model(cfg_par)
    params = m_seq.init(RNG)
    batch = {"tokens": jax.random.randint(RNG, (2, 32), 0, cfg.vocab_size)}
    l_seq = m_seq.loss(params, batch)
    l_par = m_par.loss(params, batch)
    np.testing.assert_allclose(float(l_seq), float(l_par), rtol=1e-4)
    # gradients agree too
    g_seq = jax.grad(m_seq.loss)(params, batch)
    g_par = jax.grad(m_par.loss)(params, batch)
    for a, b in zip(jax.tree.leaves(g_seq), jax.tree.leaves(g_par)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)


def test_chunkwise_grad_finite():
    B, T, H, dh = 1, 16, 2, 4
    args = [jax.random.normal(jax.random.fold_in(RNG, i), (B, T, H, dh))
            for i in range(3)]
    gates = [jax.random.normal(jax.random.fold_in(RNG, 9), (B, T, H)),
             -jax.nn.softplus(-jax.random.normal(jax.random.fold_in(RNG, 5),
                                                 (B, T, H)))]
    init = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
            jnp.full((B, H), -1e30))

    def f(q, k, v):
        hs, _ = mlstm_chunkwise(q, k, v, *gates, init, chunk=4)
        return jnp.sum(hs ** 2)

    grads = jax.grad(f, argnums=(0, 1, 2))(*args)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))
