"""The unified parallelism surface (ISSUE 9): ParallelismSpec round-trips,
the planner's TP/EP arms + divisibility/budget guards, the MoE capacity
drop tap, and the single ``--parallelism`` CLI flag with its warned shims.

The wire-level checks (all_to_all bit-identity, TP=2×DP=4 and EP=2×DP=4
step bit-exactness) need 8 host devices configured before jax initializes,
so they live in the multi_device_checks.py subprocess; the a2a identity
check is driven from here so this file is the satellite's entry point.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParallelismSpec
from repro.core.schedule import (ExpertAxis, LayerProfile, LinkParams,
                                 TensorAxis, expert_parallel_arm, plan_rounds,
                                 tensor_parallel_arm)
from repro.core.schedule.topology import Topology


# ---------------------------------------------------------------------------
# ParallelismSpec: parse / validate round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "dp=4,tp=2@fast_ici,pp=2@node,micro=8",
    "ep=2@device,shard",
    "tp=8",
    "dp=32",
    "dp=2,tp=2@device,ep=2",
    "micro=4",
    "shard",
    "",
])
def test_spec_string_roundtrip(spec):
    ps = ParallelismSpec.from_spec(spec)
    assert ParallelismSpec.from_spec(ps.spec()) == ps
    # the record block round-trips too (the DESIGN.md §14 schema)
    assert ParallelismSpec.from_record(ps.to_record()) == ps


def test_spec_parse_and_construction_errors():
    for bad in ("tp=0", "pp=-1", "tp=two", "nope=2", "tp=2,tp=4",
                "dp=2@node",          # dp takes no tier placement
                "micro=4@node",       # micro takes no tier placement
                "pp=2,shard"):        # competing optimizer-memory answers
        with pytest.raises(ValueError):
            ParallelismSpec.from_spec(bad)
    with pytest.raises(ValueError, match="meaningless"):
        ParallelismSpec(tp=1, tp_tier="device")


def test_spec_resolve_fills_dp_and_guards_divisibility():
    ps = ParallelismSpec.from_spec("tp=2,ep=2").resolve(32)
    assert (ps.dp, ps.world, ps.model_world) == (8, 32, 4)
    assert ps.spec() == "dp=8,tp=2,ep=2"
    with pytest.raises(ValueError, match="do not divide world"):
        ParallelismSpec.from_spec("tp=3").resolve(32)
    with pytest.raises(ValueError, match="!= world"):
        ParallelismSpec.from_spec("dp=4,tp=2").resolve(32)
    with pytest.raises(ValueError, match="unresolved dp=0"):
        ParallelismSpec.from_spec("tp=2").world


def test_spec_resolve_against_topology_tiers():
    topo = Topology.from_spec("node:4@datacenter,device:8@fast_ici")
    ps = ParallelismSpec.from_spec("tp=2@device").resolve(topo)
    assert ps.dp == 16
    with pytest.raises(ValueError, match="no tier named"):
        ParallelismSpec.from_spec("tp=2@pod").resolve(topo)
    with pytest.raises(ValueError, match="does not divide tier"):
        ParallelismSpec.from_spec("tp=16@device").resolve(topo)


def test_spec_legacy_bridge_and_trivial():
    assert ParallelismSpec.legacy(pipeline_stages=2, micro_batches=4,
                                  pipe_tier="node").spec() == \
        "pp=2@node,micro=4"
    assert ParallelismSpec.legacy(shard_state=True).shard_state
    assert ParallelismSpec().is_trivial
    assert not ParallelismSpec(micro_batches=4).is_trivial
    assert ParallelismSpec(micro_batches=1).is_trivial


# ---------------------------------------------------------------------------
# all_to_all bit-identity (8 fake devices -> subprocess, like every
# multi-device check; see multi_device_checks.check_all_to_all_bit_identity)
# ---------------------------------------------------------------------------

def test_all_to_all_bit_identity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    res = subprocess.run(
        [sys.executable, "-c",
         "import multi_device_checks as m; m.check_all_to_all_bit_identity()"],
        cwd=os.path.dirname(__file__), env=env,
        capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "all_to_all bit-identity ok" in res.stdout


# ---------------------------------------------------------------------------
# TP/EP arm pricing
# ---------------------------------------------------------------------------

def _profiles(n=8, mb=4.0, t=2e-4):
    return [LayerProfile(t_backward_s=t, grad_bytes=mb * 2**20)
            for _ in range(n)]


def _tensor_axis(tokens=4096 * 512):
    return TensorAxis(global_tokens=float(tokens),
                      bytes_per_token=1024 * 4.0, n_layers=8)


def test_tp_arm_monotone_in_beta():
    """The activation edge is bandwidth traffic: model_comm_s (and with it
    the arm's modeled step) must be nondecreasing in β."""
    profs = _profiles()
    axis = _tensor_axis()
    prev = None
    for beta_gbps in (400, 100, 25, 6.25, 1.5):
        link = LinkParams(alpha_s=1e-6, beta_s_per_byte=1 / (beta_gbps * 1e9))
        arm = tensor_parallel_arm(profs, link, world=8, tp=2, axis=axis)
        assert arm.model_comm_s > 0
        if prev is not None:
            assert arm.model_comm_s > prev.model_comm_s
            assert arm.modeled_step_s >= prev.modeled_step_s
        prev = arm


def test_tp_arm_never_faster_than_dp_at_world_eq_tp():
    """At world == tp there is no DP edge left to shrink: the tp arm keeps
    the full backward and ADDS 4 serial activation allreduces per layer,
    so it must never be modeled faster than the every-step DP arm at the
    same world (at these configs — token-heavy activations, the regime
    the grid actually prices)."""
    profs = _profiles()
    axis = _tensor_axis()
    for beta_gbps in (100, 25, 1.5):
        for world in (2, 4, 8):
            link = LinkParams(alpha_s=1e-6,
                              beta_s_per_byte=1 / (beta_gbps * 1e9))
            best, arms = plan_rounds(profs, link, world, tensor=TensorAxis(
                global_tokens=axis.global_tokens,
                bytes_per_token=axis.bytes_per_token, n_layers=8,
                tp_grid=(world,)))
            key = f"tp({world})"
            assert key in arms, sorted(arms)
            assert arms[key].modeled_step_s >= \
                arms["every_step"].modeled_step_s


def test_tp_ep_arms_are_memory_levers():
    """tp shards ALL weights 1/tp; ep shards the expert fraction 1/ep —
    both must show up in opt_mem_bytes (how they win under a budget)."""
    profs = _profiles()
    link = LinkParams()
    tp_arm = tensor_parallel_arm(profs, link, world=8, tp=4,
                                 axis=_tensor_axis())
    ep_arm = expert_parallel_arm(
        profs, link, world=8, ep=4,
        axis=ExpertAxis(global_tokens=4096.0, bytes_per_token=128.0,
                        n_moe_layers=4, expert_fraction=0.8))
    _, arms = plan_rounds(profs, link, 8)
    repl = arms["every_step"].opt_mem_bytes
    assert tp_arm.opt_mem_bytes == pytest.approx(repl / 4)
    assert ep_arm.opt_mem_bytes == pytest.approx(repl * (0.8 / 4 + 0.2))


def test_tp_placement_prefers_fast_tier():
    """On a tiered topology the same tp size is priced once per hosting
    tier; the serial activation edge makes the fast inner tier strictly
    cheaper (why TP belongs on ICI)."""
    topo = Topology.from_spec("node:4@datacenter,device:8@fast_ici")
    _, arms = plan_rounds(_profiles(), topo, 32, tensor=_tensor_axis())
    assert arms["tp(4)@device"].model_comm_s < \
        arms["tp(4)@node"].model_comm_s


def test_plan_rounds_pinned_spec_guards():
    profs = _profiles()
    link = LinkParams()
    taxis = _tensor_axis()
    eaxis = ExpertAxis(global_tokens=4096.0, bytes_per_token=128.0,
                       n_moe_layers=4)
    # pinned model axis without its pricing descriptor
    with pytest.raises(ValueError, match="no TensorAxis"):
        plan_rounds(profs, link, 8, parallelism="tp=2")
    with pytest.raises(ValueError, match="no ExpertAxis"):
        plan_rounds(profs, link, 8, parallelism="ep=2")
    with pytest.raises(ValueError, match="no PipelineAxis"):
        plan_rounds(profs, link, 8, parallelism="pp=2")
    # divisibility guard fires before any pricing
    with pytest.raises(ValueError, match="do not divide world"):
        plan_rounds(profs, link, 8, parallelism="tp=3", tensor=taxis)
    # tier guard on a topology
    topo = Topology.from_spec("node:4@datacenter,device:8@fast_ici")
    with pytest.raises(ValueError, match="no tier named"):
        plan_rounds(profs, topo, 32, parallelism="tp=2@pod", tensor=taxis)
    # tp/ep arms never carry shard_state: the combination is outside the
    # search space and must fail loudly, not silently plan something else
    with pytest.raises(ValueError, match="matches no priced arm"):
        plan_rounds(profs, link, 8, parallelism="ep=2,shard", expert=eaxis)
    # a pinned, reachable spec filters the pool to matching arms only
    best, _ = plan_rounds(profs, link, 8, parallelism="tp=2", tensor=taxis)
    assert (best.tp, best.parallelism.spec()) == (2, "dp=4,tp=2")


def test_memory_budget_can_select_model_axis():
    """A budget below the replicated footprint must move the winner onto a
    memory-shrinking arm (shard/tp/ep), never silently keep replicated."""
    profs = _profiles()
    link = LinkParams()
    _, arms = plan_rounds(profs, link, 8)
    repl = arms["every_step"].opt_mem_bytes
    best, _ = plan_rounds(profs, link, 8, tensor=_tensor_axis(),
                          memory_budget_bytes=repl * 0.6)
    assert best.opt_mem_bytes <= repl * 0.6
    assert best.tp > 1 or best.shard_state or best.ep > 1


# ---------------------------------------------------------------------------
# MoE capacity overflow: the drop tap (satellite c)
# ---------------------------------------------------------------------------

def _moe_cfg(capacity_factor):
    from repro.configs.base import ModelConfig
    return ModelConfig(name="t", family="qwen3", num_layers=1, d_model=16,
                       num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                       num_experts=4, top_k=2, moe_d_ff=24,
                       capacity_factor=capacity_factor)


def _moe_params(cfg):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    return {"router": jax.random.normal(ks[0], (d, E)) * 0.1,
            "wi_gate": jax.random.normal(ks[1], (E, d, ff)),
            "wi_up": jax.random.normal(ks[2], (E, d, ff)),
            "wo": jax.random.normal(ks[3], (E, ff, d))}


def test_moe_forced_overflow_surfaces_dropped_tokens():
    """capacity_factor far below the routing skew MUST report drops — the
    silent-token-drop regression this PR fixes.  The tap crosses jit and
    grad (jax.debug.callback), counts drain-and-reset, and an ample
    capacity reports zero."""
    from repro.models import moe

    x = jax.random.normal(jax.random.PRNGKey(4), (8, 4, 16))
    was = moe.enable_drop_tap(True)
    try:
        cfg = _moe_cfg(0.25)                      # forced overflow
        out, _ = jax.jit(lambda v: moe.moe_ffn(_moe_params(cfg), cfg, v))(x)
        out.block_until_ready()
        dropped, routed = moe.drain_drop_tap()
        assert routed == 8 * 4 * cfg.top_k
        assert dropped > 0
        # drained -> reset
        assert moe.drain_drop_tap() == (0.0, 0.0)

        # the tap must survive the grad program too (training is where the
        # drops actually bite)
        cfg2 = _moe_cfg(0.25)
        g = jax.jit(jax.grad(lambda v: jnp.sum(
            moe.moe_ffn(_moe_params(cfg2), cfg2, v)[0] ** 2)))(x)
        jax.block_until_ready(g)
        dropped, routed = moe.drain_drop_tap()
        assert dropped > 0 and routed > 0

        cfg3 = _moe_cfg(8.0)                      # ample capacity
        out, _ = jax.jit(lambda v: moe.moe_ffn(_moe_params(cfg3), cfg3, v))(x)
        out.block_until_ready()
        dropped, routed = moe.drain_drop_tap()
        assert (dropped, routed) == (0.0, 8 * 4 * cfg3.top_k)
    finally:
        moe.enable_drop_tap(was)


def test_moe_drop_tap_disabled_counts_nothing():
    from repro.models import moe

    was = moe.enable_drop_tap(False)
    try:
        cfg = _moe_cfg(0.25)
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 4, 16))
        out, _ = jax.jit(lambda v: moe.moe_ffn(_moe_params(cfg), cfg, v))(x)
        out.block_until_ready()
        assert moe.drain_drop_tap() == (0.0, 0.0)
    finally:
        moe.enable_drop_tap(was)


def test_render_moe_drops_report():
    from repro.launch.report import render_moe_drops

    over = render_moe_drops(26.0, 768.0, 1.25)
    assert "26" in over and "768" in over and "3.4%" in over
    assert "capacity_factor" in over
    clean = render_moe_drops(0.0, 768.0, 1.25)
    assert "no overflow" in clean


# ---------------------------------------------------------------------------
# CLI: the unified --parallelism flag + warned shims (satellite b)
# ---------------------------------------------------------------------------

def _resolve(argv, capsys=None):
    from repro.launch.train import parse_args, resolve_cli_parallelism
    return resolve_cli_parallelism(parse_args(argv))


def test_cli_plan_world_is_gone():
    from repro.launch.train import parse_args
    with pytest.raises(SystemExit):
        parse_args(["--plan-world", "256"])


def test_cli_parallelism_spec_parses():
    spec, shard, pipe, micro = _resolve(
        ["--parallelism", "dp=4,tp=2@device,micro=2"])
    assert (spec.dp, spec.tp, spec.tp_tier) == (4, 2, "device")
    assert (shard, pipe, micro) == (False, 1, 2)
    # a real pipeline with no micro=M gets the executor's default M=8
    spec, _, pipe, micro = _resolve(["--parallelism", "pp=2"])
    assert (spec.micro_batches, pipe, micro) == (8, 2, 8)
    with pytest.raises(SystemExit, match="--parallelism:"):
        _resolve(["--parallelism", "tp=0"])
    with pytest.raises(SystemExit, match="--parallelism:"):
        _resolve(["--parallelism", "pp=2,shard"])


def test_cli_shim_shard_state(capsys):
    spec, shard, pipe, micro = _resolve(["--shard-state"])
    assert shard and spec.shard_state and spec.spec() == "shard"
    assert "--shard-state" in capsys.readouterr().out


def test_cli_shim_pipeline_stages(capsys):
    spec, shard, pipe, micro = _resolve(["--pipeline-stages", "2"])
    assert (pipe, micro) == (2, 8)
    assert (spec.pp, spec.micro_batches) == (2, 8)
    assert "--pipeline-stages" in capsys.readouterr().out


def test_cli_shim_micro_batches(capsys):
    spec, shard, pipe, micro = _resolve(["--micro-batches", "4"])
    assert (pipe, micro) == (1, 4)
    assert spec.spec() == "micro=4"
    assert "--micro-batches" in capsys.readouterr().out


def test_cli_no_flags_no_warning(capsys):
    spec, shard, pipe, micro = _resolve([])
    assert spec.is_trivial and (shard, pipe, micro) == (False, 1, 1)
    assert "deprecated" not in capsys.readouterr().out


@pytest.mark.parametrize("shim", [["--shard-state"],
                                  ["--pipeline-stages", "2"],
                                  ["--micro-batches", "4"]])
def test_cli_spec_refuses_each_shim(shim):
    with pytest.raises(SystemExit, match="subsumes"):
        _resolve(["--parallelism", "dp=2"] + shim)


def test_cli_legacy_pipe_shard_conflict():
    with pytest.raises(SystemExit, match="pick one"):
        _resolve(["--pipeline-stages", "2", "--shard-state"])
