"""End-to-end behaviour tests: the trainer drives loss down under every
sync mode (vanilla / compressed / local SGD), serving generates finite
tokens, checkpoints round-trip, and the data pipeline is deterministic.

Marked ``slow`` (40-step CPU training runs, ~5 min total): excluded from
the default tier-1 selection, run by the dedicated CI matrix job."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def run_train(extra, steps=40):
    argv = ["--arch", "xlstm-125m", "--reduced", "--steps", str(steps),
            "--batch", "4", "--seq", "32", "--lr", "3e-3",
            "--log-every", "1000"] + extra
    return train_mod.main(argv)


def test_vanilla_training_learns():
    losses = run_train([])
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


@pytest.mark.parametrize("compressor,algo", [
    ("int8", "ring"), ("sign", "ring"), ("topk", "psum"),
])
def test_comm_optimized_training_learns(compressor, algo):
    losses = run_train(["--sync", "comm", "--compressor", compressor,
                        "--algo", algo])
    assert losses[-1] < losses[0] - 0.15, (compressor, losses[0], losses[-1])


def test_local_sgd_training():
    losses = run_train(["--local-sgd", "4"])
    assert losses[-1] < losses[0] - 0.2


def test_serve_generates():
    toks = serve_mod.main(["--arch", "gemma-2b", "--batch", "2",
                           "--prompt-len", "8", "--gen", "4"])
    assert toks.shape == (2, 4)
    assert np.isfinite(np.asarray(toks)).all()


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore, save
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    path = str(tmp_path / "ckpt")
    save(path, tree, step=17)
    restored = restore(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert latest_step(path) == 17


def test_data_pipeline_deterministic_and_sharded():
    from repro.data import DataConfig, SyntheticPipeline
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    p = SyntheticPipeline(cfg)
    b1, b2 = p.batch(3), p.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p.batch(4)["tokens"], b1["tokens"])
    h0 = p.batch(3, host_id=0, num_hosts=2)
    h1 = p.batch(3, host_id=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_data_pipeline_learnable_structure():
    from repro.data import DataConfig, SyntheticPipeline
    cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=16)
    p = SyntheticPipeline(cfg)
    toks = p.batch(0)["tokens"]
    cur, nxt = toks[:, :-1].reshape(-1), toks[:, 1:].reshape(-1)
    pred = (p._a * cur + p._b) % cfg.vocab_size
    agree = float(np.mean(pred == nxt))
    assert agree > 0.8, agree


def test_lag_trigger_behaviour():
    from repro.core import init_lag_state, lag_trigger, lag_update_state
    g = {"w": jnp.ones((8,))}
    st = init_lag_state(g)
    assert bool(lag_trigger(g, st["g_last"], 0.1))      # first step: sync
    st = lag_update_state(st, g, True)
    assert int(st["rounds"]) == 1
    assert not bool(lag_trigger(g, st["g_last"], 0.1))  # unchanged: reuse
    g2 = {"w": jnp.ones((8,)) * 2.0}
    assert bool(lag_trigger(g2, st["g_last"], 0.1))     # changed: sync


def test_local_sgd_schedule():
    from repro.core import LocalSGDConfig, communication_rounds, should_sync
    cfg = LocalSGDConfig(period=4, post_local_after=3)
    synced = [t for t in range(12) if should_sync(t, cfg)]
    assert synced == [0, 1, 2, 3, 7, 11]
    assert communication_rounds(12, cfg) == 6
