"""SyncStrategy sessions (core/strategy.py + repro/api.py + planner rounds
axis).  Covers: the scheduler registry, host-side rounds-accounting
properties per scheduler, the degenerate every-step strategy's bit-for-bit
equivalence with the legacy GradientSynchronizer path (params, optimizer
state, EF residuals over ≥3 steps), the LAG regression (a high threshold
must actually SKIP rounds — the flag used to be dead), honest comm-rounds
accounting end-to-end, the parameter-round program's anchor-delta
semantics, and the planner's rounds axis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SessionConfig, TrainSession, strategy_from_plan
from repro.core import (AsymmetricPushPullConfig, GradientSynchronizer,
                        LocalSGDConfig, PlanExecutor, SCHEDULERS, SyncConfig,
                        SyncStrategy, communication_rounds, get_scheduler,
                        make_strategy, plan_from_config)
from repro.core.schedule import (LINK_PRESETS, LayerProfile, plan_rounds,
                                 serial_round_plan)

ARCH_KW = dict(arch="xlstm-125m", reduced=True, batch=2, seq=16, steps=8)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_scheduler_registry():
    assert {"every_step", "local_sgd", "lag", "push_pull"} <= set(SCHEDULERS)
    with pytest.raises(KeyError):
        get_scheduler("nope")
    s = get_scheduler("local_sgd", period=7)
    assert s.cfg.period == 7 and s.diverges_params and s.has_param_rounds


# ---------------------------------------------------------------------------
# Rounds-accounting properties (host-side dispatch, no compilation)
# ---------------------------------------------------------------------------

def _simulate(sched, steps, probes=None):
    state = sched.init_state({"w": jnp.zeros((3,))})
    actions = []
    for t in range(steps):
        a, state = sched.round(t, state, None if probes is None
                               else probes[t])
        actions.append(a)
        state = sched.commit(state, a, {"w": jnp.ones((3,))})
    return actions


def test_every_step_rounds():
    acts = _simulate(get_scheduler("every_step"), 17)
    assert all(a.compute == "sync" and not a.param_round for a in acts)


def test_local_sgd_rounds_match_table2():
    cfg = LocalSGDConfig(period=4, post_local_after=3)
    sched = get_scheduler("local_sgd", cfg=cfg)
    acts = _simulate(sched, 12)
    assert all(a.compute == "local" for a in acts)
    assert sum(a.param_round for a in acts) == communication_rounds(12, cfg)
    assert [t for t, a in enumerate(acts) if a.param_round] == \
        [0, 1, 2, 3, 7, 11]


def test_push_pull_rounds_match_config():
    cfg = AsymmetricPushPullConfig(n_push=2, n_fetch=3)
    acts = _simulate(get_scheduler("push_pull", cfg=cfg), 12)
    rounds = cfg.rounds(12)
    assert sum(a.compute == "sync" for a in acts) == rounds["push"] == 6
    assert sum(a.param_round for a in acts) == rounds["fetch"] == 4
    assert acts[0].compute == "local"   # step 0 pushes nothing (n_push=2)


def test_lag_rounds_follow_trigger():
    sched = get_scheduler("lag", threshold=0.5)
    probes = [{"delta": 1.0, "scale": 1.0},   # first: ||g-0||² = ||g||² > θ
              {"delta": 0.1, "scale": 1.0},   # small change: reuse
              {"delta": 0.9, "scale": 1.0}]   # large change: sync
    acts = _simulate(sched, 3, probes)
    assert [a.compute for a in acts] == ["sync", "reuse", "sync"]
    with pytest.raises(ValueError):
        sched.round(0, sched.init_state({"w": jnp.zeros(2)}), None)


def test_lag_first_round_always_syncs():
    """θ >= 1 must not freeze training: g_last starts at zero (delta ==
    scale), so the first round syncs unconditionally; only later rounds
    consult the threshold."""
    sched = get_scheduler("lag", threshold=5.0)
    acts = _simulate(sched, 3, [{"delta": 1.0, "scale": 1.0}] * 3)
    assert [a.compute for a in acts] == ["sync", "reuse", "reuse"]


def test_lag_rejects_check_every():
    from repro.core import LAGConfig
    with pytest.raises(ValueError):
        get_scheduler("lag", cfg=LAGConfig(threshold=0.1, check_every=10))


def test_lag_commit_updates_g_last_and_rounds():
    sched = get_scheduler("lag", threshold=0.5)
    state = sched.init_state({"w": jnp.zeros((2,))})
    a, state = sched.round(0, state, {"delta": 1.0, "scale": 1.0})
    state = sched.commit(state, a, {"w": jnp.full((2,), 3.0)})
    assert int(state["rounds"]) == 1
    np.testing.assert_array_equal(np.asarray(state["g_last"]["w"]),
                                  np.full((2,), 3.0, np.float32))


# ---------------------------------------------------------------------------
# Equivalence: every-step strategy == legacy GradientSynchronizer path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sync_kw", [
    dict(compressor="int8", algo="ring"),                    # allreduce wire
    dict(compressor="topk", algo="ring",
         compressor_args=(("ratio", 0.25),), bucket_bytes=8192),  # gather+EF
])
@pytest.mark.slow
def test_every_step_session_equals_legacy_path(sync_kw):
    """TrainSession with the degenerate every-step strategy must reproduce
    the legacy make_comm_optimized_train_step loop bit-for-bit: params,
    optimizer state and EF residuals over 3 steps."""
    from repro.configs import get_config, reduced
    from repro.data import DataConfig, SyntheticPipeline
    from repro.launch.mesh import data_axes, make_host_mesh
    from repro.launch.steps import make_comm_optimized_train_step
    from repro.models import Model
    from repro.models.sharding_ctx import set_mesh_ctx
    from repro.optim import make_optimizer, warmup_cosine

    steps = 3
    scfg = SyncConfig(**sync_kw)
    cfg = SessionConfig(**dict(ARCH_KW, steps=steps))
    sess = TrainSession(cfg, strategy=make_strategy(
        "every_step", axes=("data",), sync=scfg))
    sess.run(steps)

    # the legacy wiring, exactly as train.py's main() used to hand-build it
    model_cfg = reduced(get_config(cfg.arch))
    model = Model(model_cfg)
    mesh = make_host_mesh(data=1, model=len(jax.devices()))
    set_mesh_ctx(mesh, ("data",))
    axes = data_axes(mesh)
    opt = make_optimizer(cfg.optimizer,
                         lr=warmup_cosine(cfg.lr, cfg.warmup, cfg.steps))
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    opt_state = opt.init(params)
    data = SyntheticPipeline(DataConfig(vocab_size=model_cfg.vocab_size,
                                        seq_len=cfg.seq,
                                        global_batch=cfg.batch))
    step_fn, _, init_sync_state = make_comm_optimized_train_step(
        model, opt, scfg, mesh, axes)
    sync_state = init_sync_state(params)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
    for step in range(steps):
        batch = jax.tree.map(jnp.asarray, data.batch(step))
        params, opt_state, sync_state, loss = jit_step(
            params, opt_state, sync_state, batch,
            jnp.asarray(step, jnp.int32), jax.random.fold_in(rng, step))

    for name, a, b in [("params", params, sess.params),
                       ("opt", opt_state, sess.opt_state),
                       ("sync_state",
                        jax.tree.map(lambda s: s[0], sync_state),
                        sess.sync_state)]:
        la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
        assert len(la) == len(lb), name
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=f"{sync_kw} {name}")
    assert sess.comm_rounds == sess.grad_rounds == steps


# ---------------------------------------------------------------------------
# The dead --lag regression + honest rounds accounting, end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_lag_session_skips_rounds_at_high_threshold():
    """--lag used to build state and never consult it (every step synced).
    Under the scheduler, a high threshold in LAG's regime (deterministic
    full-batch gradients) must reuse the last synced gradient on most
    steps: rounds < steps, while every step still pays the scalar probe."""
    steps = 8
    sess = TrainSession(SessionConfig(**ARCH_KW), strategy=SyncStrategy(
        scheduler=get_scheduler("lag", threshold=0.5)))
    orig = sess.data.batch
    sess.data.batch = lambda step, **kw: orig(0)   # LAG's full-batch regime
    p0 = jax.tree.leaves(sess.params)[0].copy()
    sess.run(steps)
    assert 1 <= sess.grad_rounds < steps, sess.grad_rounds
    assert sess.control_rounds == steps
    assert sess.comm_rounds == sess.grad_rounds
    assert int(sess._sched_state["rounds"]) == sess.grad_rounds
    # reused gradients still move the parameters
    assert not np.array_equal(np.asarray(p0),
                              np.asarray(jax.tree.leaves(sess.params)[0]))


@pytest.mark.slow
def test_local_sgd_session_rounds_accounting():
    """comm_rounds is the survey's Table 2 quantity: T/τ averaging rounds,
    not one per step (the legacy loop counted every step as a round)."""
    sess = TrainSession(SessionConfig(**dict(ARCH_KW, steps=7)),
                        strategy=make_strategy("local_sgd", period=3,
                                               axes=("data",)))
    losses = sess.run(7)
    assert sess.grad_rounds == 0
    assert sess.param_rounds == communication_rounds(
        7, LocalSGDConfig(period=3)) == 2
    assert sess.comm_rounds == 2
    assert all(np.isfinite(losses))


@pytest.mark.slow
def test_push_pull_session_with_compressed_push():
    """Asymmetric push/pull composed with a compressing (EF) grad reducer:
    params/opt state diverge per worker between rounds, the EF residual is
    per-worker, and the two cadences are counted separately."""
    sess = TrainSession(
        SessionConfig(**dict(ARCH_KW, steps=5)),
        strategy=make_strategy("push_pull", n_push=2, n_fetch=2,
                               axes=("data",),
                               sync=SyncConfig(compressor="topk",
                                               compressor_args=(("ratio",
                                                                 0.25),))))
    losses = sess.run(5)
    expect = AsymmetricPushPullConfig(n_push=2, n_fetch=2).rounds(5)
    assert sess.grad_rounds == expect["push"] == 2
    assert sess.param_rounds == expect["fetch"] == 2
    assert all(np.isfinite(losses))
    assert sess.sync_state is not None and "error" in sess.sync_state
    # EF residual must be parameter-shaped, not worker-axis-mangled
    errs = [e for e in sess.sync_state["error"] if e is not None]
    assert errs and all(e.ndim >= 1 for e in errs)


# ---------------------------------------------------------------------------
# Parameter-round program (anchor-delta compressed averaging)
# ---------------------------------------------------------------------------

def _toy_params():
    k = jax.random.PRNGKey(3)
    return {"w": jax.random.normal(k, (16, 8)),
            "b": jax.random.normal(jax.random.PRNGKey(4), (5,))}


def _run_param_round(sync_cfg):
    from repro.launch.steps import (broadcast_worker_state,
                                    make_param_round_step)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    params = _toy_params()
    reducer = PlanExecutor(plan_from_config(sync_cfg, params), ("data",))
    round_fn = jax.jit(make_param_round_step(reducer, mesh, ("data",)))
    anchor = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    # pretend one local phase moved the params
    moved = jax.tree.map(lambda p: p + 0.01 * jnp.sign(p), params)
    out, new_anchor, _ = round_fn(
        broadcast_worker_state(moved, 1), anchor,
        broadcast_worker_state(reducer.init_state(params), 1),
        jax.random.PRNGKey(0))
    return moved, jax.tree.map(lambda s: s[0], out), new_anchor


def test_param_round_dense_is_exact_average():
    """anchor + mean(p - anchor) with a dense plan is exactly mean(p) —
    on one worker, the moved params themselves."""
    moved, out, new_anchor = _run_param_round(SyncConfig(compressor="none"))
    for k in moved:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(moved[k]),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new_anchor[k]),
                                   np.asarray(out[k]), rtol=1e-6)


def test_param_round_preserves_param_dtype():
    """A compressed round must hand back params in their ORIGINAL dtype
    (bf16 stays bf16 — the f32 anchor is round state, not the params),
    otherwise the first averaging round silently doubles parameter memory
    and retraces the local step."""
    from repro.launch.steps import (broadcast_worker_state,
                                    make_param_round_step)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), _toy_params())
    reducer = PlanExecutor(
        plan_from_config(SyncConfig(compressor="int8", bucket_bytes=0),
                         params), ("data",))
    round_fn = jax.jit(make_param_round_step(reducer, mesh, ("data",)))
    anchor = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    out, new_anchor, _ = round_fn(
        broadcast_worker_state(params, 1), anchor,
        broadcast_worker_state(reducer.init_state(params), 1),
        jax.random.PRNGKey(0))
    for k in params:
        assert out[k].dtype == jnp.bfloat16, (k, out[k].dtype)
        assert new_anchor[k].dtype == jnp.float32


def test_param_round_compressed_tracks_params():
    """Compressing the anchor DELTA (not raw params) keeps the round sound:
    int8 quantization of a 0.01-scale delta lands within quantization error
    of the true average; compressing raw params would be off by O(|p|)."""
    moved, out, _ = _run_param_round(SyncConfig(compressor="int8",
                                                bucket_bytes=0))
    for k in moved:
        err = np.abs(np.asarray(out[k]) - np.asarray(moved[k])).max()
        assert err < 2e-3, (k, err)   # delta scale 0.01, int8 grid ≈ 1e-4


# ---------------------------------------------------------------------------
# Planner rounds axis
# ---------------------------------------------------------------------------

def _profs(n=12, mb=4.0, t_layer=2e-4):
    return [LayerProfile(t_backward_s=t_layer, grad_bytes=mb * 2**20)
            for _ in range(n)]


def test_rounds_axis_commodity_picks_periodic_compressed():
    """When communication dominates compute (slow link, light backward) the
    composite winner must reduce rounds AND bits: τ>1 with a compressed
    per-bucket plan — the regime both surveys highlight."""
    best, arms = plan_rounds(_profs(t_layer=2e-5), LINK_PRESETS["commodity"],
                             world=64)
    assert best.schedule.kind == "local_sgd" and best.schedule.period > 1
    assert any(b.compressor != "none" for b in best.comm.buckets)
    assert best.modeled_step_s <= arms["every_step"].modeled_step_s


def test_rounds_axis_fast_link_heavy_backward_stays_every_step():
    """When overlap already hides communication, reducing rounds buys
    nothing but the statistical surcharge: every-step must win.  (Since
    PR 6 the fused compressed ring may shave the last exposed sliver even
    here, so the historical all-dense pick is asserted under a
    dense-restricted candidate set.)"""
    from repro.core.schedule.planner import DEFAULT_CANDIDATES
    best, _ = plan_rounds(_profs(t_layer=1e-3), LINK_PRESETS["fast_ici"],
                          world=64)
    assert best.schedule.kind == "every_step"
    dense_only = tuple(c for c in DEFAULT_CANDIDATES
                       if c.compressor == "none")
    best_d, _ = plan_rounds(_profs(t_layer=1e-3), LINK_PRESETS["fast_ici"],
                            world=64, candidates=dense_only)
    assert best_d.schedule.kind == "every_step"
    assert all(b.compressor == "none" for b in best_d.comm.buckets)


def test_rounds_axis_never_slower_than_fixed_baselines():
    """The acceptance invariant extends to composites: the winner is never
    modeled slower than any fixed every-step config."""
    from repro.core.schedule import fixed_config_plan
    from repro.core.schedule.planner import FIXED_BASELINES
    for preset in ("fast_ici", "datacenter", "commodity"):
        link = LINK_PRESETS[preset]
        for world in (8, 64, 256):
            profs = _profs()
            best, _ = plan_rounds(profs, link, world)
            for name, (comp, algo, cargs) in FIXED_BASELINES.items():
                fp = fixed_config_plan(profs, link, world, comp, algo,
                                       compressor_args=cargs)
                assert best.modeled_step_s <= fp.modeled_step_s + 1e-12, (
                    preset, world, name)


def test_serial_round_plan_cost_is_sum_of_buckets():
    from repro.core.schedule.cost import bucket_sync_cost_s
    link = LINK_PRESETS["datacenter"]
    rp = serial_round_plan(_profs(), link, world=32)
    total = sum(bucket_sync_cost_s(b.compressor, b.compressor_args, b.algo,
                                   b.bucket_bytes, 32, link)
                for b in rp.buckets)
    assert abs(rp.modeled_step_s - total) < 1e-12


def test_strategy_from_plan_round_trip():
    best, arms = plan_rounds(_profs(t_layer=2e-5), LINK_PRESETS["commodity"],
                             world=64)
    st = strategy_from_plan(best, ("data",))
    assert st.scheduler.name == "local_sgd"
    assert isinstance(st.param_reducer, PlanExecutor)
    st2 = strategy_from_plan(arms["every_step"], ("data",))
    assert st2.scheduler.name == "every_step"
    assert isinstance(st2.grad_reducer, PlanExecutor)


def test_make_strategy_routes_reducers():
    scfg = SyncConfig(compressor="int8", algo="ring")
    st = make_strategy("every_step", axes=("data",), sync=scfg)
    assert isinstance(st.grad_reducer, GradientSynchronizer)
    assert st.param_reducer is None
    st = make_strategy("local_sgd", period=4, axes=("data",), sync=scfg)
    assert st.grad_reducer is None        # pure param-round scheduler:
    assert isinstance(st.param_reducer, GradientSynchronizer)  # cfg -> round
    st = make_strategy("push_pull", n_push=2, n_fetch=3, axes=("data",),
                       sync=scfg)
    assert isinstance(st.grad_reducer, GradientSynchronizer)
    assert st.param_reducer is None       # fetch rounds: plain averaging
