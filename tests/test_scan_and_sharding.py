"""chunked_scan equivalence (hypothesis over lengths/chunks), sharding-ctx
constraint semantics, TIC/TAC schedules, and asymmetric push/pull."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyp_compat import given, settings, st

from repro.models.scan_utils import chunked_scan

RNG = jax.random.PRNGKey(21)


@given(st.integers(1, 64), st.integers(1, 32), st.booleans())
@settings(max_examples=25, deadline=None)
def test_chunked_scan_equals_scan(T, chunk, ckpt):
    xs = jnp.sin(jnp.arange(T * 3, dtype=jnp.float32)).reshape(T, 3)

    def step(c, x):
        c = jnp.tanh(c + x.sum())
        return c, c * x

    c_ref, ys_ref = jax.lax.scan(step, jnp.zeros(()), xs)
    c, ys = chunked_scan(step, jnp.zeros(()), xs, chunk=chunk,
                         checkpoint_step=ckpt)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ys_ref), rtol=1e-6)


def test_chunked_scan_gradient_matches():
    T = 32
    xs = jax.random.normal(RNG, (T, 4))

    def run(fn):
        def loss(xs):
            _, ys = fn(lambda c, x: (0.9 * c + x, jnp.tanh(c)),
                       jnp.zeros((4,)), xs)
            return jnp.sum(ys ** 2)
        return jax.grad(loss)(xs)

    g_ref = run(jax.lax.scan)
    g = run(lambda s, i, x: chunked_scan(s, i, x, chunk=8))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-5,
                               atol=1e-6)


def test_constrain_noop_without_mesh():
    from repro.models.sharding_ctx import constrain, constrain_hard
    x = jnp.ones((4, 8))
    assert constrain(x, ("b", "m")) is x
    assert constrain_hard(x, ("b", None)) is x


def test_constrain_divisibility_guard():
    """On a real mesh, non-divisible dims must never be pinned to an axis."""
    import subprocess, sys, os
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import repro.compat  # installs AxisType/shard_map shims on old JAX
from jax.sharding import AxisType
from repro.models.sharding_ctx import constrain, constrain_hard, mesh_ctx
mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
with mesh_ctx(mesh, ("data",)):
    @jax.jit
    def f(x):
        # dim0=6 not divisible by data=4 -> must not shard; dim1=8 by model=2 ok
        return constrain(x, ("b", "m")) * 2
    out = f(jnp.ones((6, 8)))
    assert out.shape == (6, 8)
    @jax.jit
    def g(x):
        return constrain_hard(x, ("b", "m")) + 1
    assert g(jnp.ones((8, 6))).shape == (8, 6)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0 and "OK" in res.stdout, res.stderr


def test_tic_tac_schedules():
    from repro.core.schedule import (LayerProfile, iteration_time_fifo,
                                     iteration_time_tic, iteration_time_tac)
    layers = [LayerProfile(1e-3, 5e6)] * 12
    a, b = 5e-6, 1 / 10e9
    fifo = iteration_time_fifo(layers, a, b)
    tic = iteration_time_tic(layers, a, b)
    tac = iteration_time_tac(layers, a, b)
    tb = sum(l.t_backward_s for l in layers)
    for t in (tic, tac):
        assert tb - 1e-12 <= t <= fifo + 1e-9


def test_asymmetric_push_pull():
    from repro.core.local_sgd import AsymmetricPushPullConfig
    cfg = AsymmetricPushPullConfig(n_push=2, n_fetch=3)
    r = cfg.rounds(12)
    assert r == {"push": 6, "fetch": 4}
    assert cfg.should_push(1) and not cfg.should_push(0)
    assert cfg.should_fetch(2) and not cfg.should_fetch(0)


def test_per_leaf_ef_equals_bucketed_for_single_leaf():
    """With one leaf, per-leaf (bucket_bytes=0) and bucketed sync agree up to
    the flatten (same compressor semantics on the same values)."""
    from repro.core import GradientSynchronizer, SyncConfig
    g = {"w": jax.random.normal(RNG, (64,))}
    outs = []
    for bb in (0, 1 << 30):
        sync = GradientSynchronizer(
            SyncConfig(compressor="int8", algo="ring", bucket_bytes=bb), ())
        st = sync.init_state(g)
        out, st2 = sync(g, st, jax.random.PRNGKey(0))
        outs.append(np.asarray(jax.tree.leaves(out)[0]).reshape(-1))
        assert int(st2["step"]) == 1
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)
