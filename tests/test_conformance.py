"""Cross-strategy conformance suite (ISSUE 3 headline): every wire ×
{replicated, sharded} execution mode on a real training loop, asserting
bit-exactness where the runtime PROMISES it (dense fp32, elementwise
optimizers — DESIGN.md §8) and bounded divergence + EF-residual
bookkeeping everywhere else.

The strategy (rounds) axis of the matrix is covered per-scheduler at the
session level by test_strategy.py; this file owns the execution-mode axis:

  * sharded == replicated for dense fp32 with adam/sgd, on both the
    explicit ring wires and psum — params, master shards, moments, over
    multiple steps.  The STRICT bit-for-bit form of this check runs on
    the 8-device mesh in multi_device_checks.py (the acceptance
    criterion); here at world=1 the two degenerate graphs may differ by
    XLA's per-graph FMA contraction of the final update add, so the
    promise is "within a few ulp per step" (asserted tightly);
  * compressed wires (gather-pattern int8/topk, aggregatable qsgd,
    factorized powersgd): same guarantee (the payload exchange is
    identical; sharding only slices the decompressed sum) and the EF
    residual trajectory is preserved;
  * layerwise optimizers (lamb): bounded divergence only (trust-ratio
    norms are partial-sum + psum, a different summation order);
  * sharded mode REFUSES schedulers with local phases or gradient reuse
    (partitioned state cannot follow per-worker divergence);
  * both modes are deterministic end to end (same seed -> same run),
    which the whole matrix implicitly depends on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tiny_lm import TinyLM, tiny_batch

from repro.core import (PlanExecutor, ShardLayout, SyncConfig, SyncStrategy,
                        get_scheduler, make_strategy)
from repro.core.grad_sync import sharded_plan_from_config
from repro.launch.steps import (_make_synced_train_step,
                                make_sharded_train_step)
from repro.optim import make_optimizer, make_sharded_optimizer

STEPS = 3

# wire matrix: (name, SyncConfig kwargs, exact-for-elementwise-opts)
WIRES = [
    ("dense/psum", dict(compressor="none", algo="psum"), True),
    ("dense/ring", dict(compressor="none", algo="ring"), True),
    ("dense/hierarchical", dict(compressor="none", algo="hierarchical"),
     True),
    ("int8/ring", dict(compressor="int8", algo="ring", bucket_bytes=2048),
     True),
    ("topk/ring", dict(compressor="topk", algo="ring",
                       compressor_args=(("ratio", 0.25),),
                       bucket_bytes=2048), True),
    ("qsgd/ring", dict(compressor="qsgd", algo="ring", bucket_bytes=2048),
     True),
    ("powersgd/ring", dict(compressor="powersgd", algo="ring",
                           compressor_args=(("rank", 2),)), True),
    # the fused Pallas wires (DESIGN.md §11): gather-pattern int8 tiles +
    # scales, and the aggregatable bisection top-k — one-pass kernels in
    # the hot path, decomposed chain as the pinned reference
    ("int8_fused/ring", dict(compressor="int8_fused", algo="ring",
                             compressor_args=(("tile", 128),),
                             bucket_bytes=2048), True),
    ("topk_fused/ring", dict(compressor="topk_fused", algo="ring",
                             compressor_args=(("ratio", 0.25),
                                              ("tile", 128)),
                             bucket_bytes=2048), True),
]


def _mesh1():
    return jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))


def _run_replicated(model, params0, plan, opt_name, steps=STEPS):
    mesh = _mesh1()
    opt = make_optimizer(opt_name, lr=0.05)
    step_fn, _, init_ss = _make_synced_train_step(
        model, opt, PlanExecutor(plan, ("data",)), mesh, ("data",))
    p, os_, ss = params0, opt.init(params0), init_ss(params0)
    jit = jax.jit(step_fn)
    losses = []
    for s in range(steps):
        p, os_, ss, loss = jit(p, os_, ss, tiny_batch(s),
                               jnp.asarray(s, jnp.int32),
                               jax.random.fold_in(jax.random.PRNGKey(1), s))
        losses.append(float(loss))
    # strip the leading per-worker axis from the sync state (world=1)
    return p, os_, jax.tree.map(lambda x: x[0], ss), losses


def _run_sharded(model, params0, plan, opt_name, steps=STEPS):
    mesh = _mesh1()
    ex = PlanExecutor(plan, ("data",))
    layout = ShardLayout.from_plan(plan, params0, (1,))
    shopt = make_sharded_optimizer(opt_name, layout, ("data",), lr=0.05)
    step_fn, init_rows, init_ss = make_sharded_train_step(
        model, ex, layout, shopt, mesh, ("data",))
    p, rows, ss = params0, init_rows(params0), init_ss(params0)
    jit = jax.jit(step_fn)
    losses = []
    for s in range(steps):
        p, rows, ss, loss = jit(p, rows, ss, tiny_batch(s),
                                jnp.asarray(s, jnp.int32),
                                jax.random.fold_in(jax.random.PRNGKey(1), s))
        losses.append(float(loss))
    return p, rows, jax.tree.map(lambda x: x[0], ss), losses, layout


# ---------------------------------------------------------------------------
# The execution-mode conformance matrix
# ---------------------------------------------------------------------------

def _assert_tight(a, b, what):
    """'Bit-exact modulo XLA's FMA contraction of the update add': the
    absolute deviation is bounded by a few ulp of the ADDENDS of
    ``params + update`` per step (~1e-8 at parameter scale), far inside
    this tolerance; strict equality is asserted on the 8-device mesh in
    multi_device_checks.py where both graphs contract identically."""
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype, what
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6, err_msg=what)


@pytest.mark.parametrize("opt_name", ["adam", "sgd"])
@pytest.mark.parametrize("name,kw,exact", WIRES,
                         ids=[w[0] for w in WIRES])
def test_sharded_matches_replicated(name, kw, exact, opt_name):
    """Per wire: sharded-DP params + reconstructed optimizer state vs the
    replicated path running the SAME plan.  Elementwise optimizers promise
    ulp-level agreement (the scatter chunks equal the allreduce slices and
    the update commutes with slicing; strict bit-exactness is asserted on
    the 8-device mesh in multi_device_checks.py)."""
    # powersgd needs a leaf above its dense-small fallback (4096 elems)
    # for the factorized path + its EF residual to actually engage
    model = TinyLM(d=80) if kw["compressor"] == "powersgd" else TinyLM()
    params0 = model.init(jax.random.PRNGKey(0))
    plan = sharded_plan_from_config(SyncConfig(**kw), params0)

    p_r, os_r, ss_r, losses_r = _run_replicated(model, params0, plan,
                                                opt_name)
    p_s, rows, ss_s, losses_s, layout = _run_sharded(model, params0, plan,
                                                     opt_name)

    def cmp(a, b, what):
        if exact:
            _assert_tight(a, b, f"{name} {what}")
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-7,
                                       err_msg=f"{name} {what}")

    for k in p_r:
        cmp(p_r[k], p_s[k], f"params/{k}")
    # master shards reconstruct to exactly the (f32) params — this leg IS
    # strict: the gather moves exact values
    master = layout.tree_from_rows(rows["master"], params0)
    for k in p_r:
        np.testing.assert_array_equal(np.asarray(master[k]),
                                      np.asarray(p_s[k], np.float32),
                                      err_msg=f"{name} master/{k}")
    if opt_name == "adam":
        for mom in ("m", "v"):
            full = layout.tree_from_rows(rows["opt"][mom], params0)
            for k in p_r:
                cmp(os_r[mom][k], full[k], f"{mom}/{k}")
    np.testing.assert_allclose(losses_r, losses_s, rtol=1e-6, err_msg=name)


@pytest.mark.parametrize("name,kw", [(w[0], w[1]) for w in WIRES
                                     if w[1]["compressor"] != "none"],
                         ids=[w[0] for w in WIRES
                              if w[1]["compressor"] != "none"])
def test_ef_residual_bookkeeping_preserved_under_sharding(name, kw):
    """Compressed wires must carry EF state in BOTH modes with the same
    schema and the same trajectory: present, leaf/bucket-shaped, updated
    every step, and matching between modes (the residual corrects what
    this worker SENT — sharding does not change the send; the tolerance
    absorbs only the update-add ulp drift feeding back through params)."""
    model = TinyLM(d=80) if kw["compressor"] == "powersgd" else TinyLM()
    params0 = model.init(jax.random.PRNGKey(0))
    plan = sharded_plan_from_config(SyncConfig(**kw), params0)

    _, _, ss_r, _ = _run_replicated(model, params0, plan, "adam")
    _, _, ss_s, _, _ = _run_sharded(model, params0, plan, "adam")
    assert int(ss_r["step"]) == int(ss_s["step"]) == STEPS
    key = "error"
    assert key in ss_r and key in ss_s, name
    nonzero = 0
    for a, b in zip(ss_r[key], ss_s[key]):
        assert (a is None) == (b is None)
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7, err_msg=name)
        nonzero += int(np.any(np.asarray(a) != 0))
    # a biased/quantizing compressor must actually be accumulating error
    assert nonzero > 0, f"{name}: EF residuals all zero after {STEPS} steps"


@pytest.mark.parametrize("name,kw", [
    ("int8_fused", dict(compressor="int8_fused", algo="ring",
                        bucket_bytes=2048)),
    ("topk_fused", dict(compressor="topk_fused", algo="ring",
                        compressor_args=(("ratio", 0.25),),
                        bucket_bytes=2048)),
], ids=["int8_fused", "topk_fused"])
def test_fused_vs_unfused_bit_trajectory(name, kw):
    """The fused one-pass wire vs the SAME plan with ``fused=False`` (the
    decomposed reference chain), 3 sync rounds of fresh gradients: EF
    residual trajectories and synced sums must track each other at the
    few-ulp level.  These are two DIFFERENT world=1 XLA programs, so the
    promise here carries the same FMA-contraction caveat as
    ``_assert_tight`` (observed: 1-ulp flips on ~10% of elements); the
    BIT-STRICT 3-step run for both wires lives on the 8-device mesh in
    multi_device_checks.py (the acceptance criterion), where payload
    equality is pinned at the compressor level by test_compression.py."""
    import dataclasses

    from repro.core.grad_sync import plan_from_config

    mesh = _mesh1()
    tmpl = {"w": jnp.zeros((64, 33)), "b": jnp.zeros((17,))}
    plan_f = plan_from_config(SyncConfig(**kw), tmpl)
    assert all(b.fused for b in plan_f.buckets)
    plan_u = dataclasses.replace(plan_f, buckets=tuple(
        dataclasses.replace(b, fused=False) for b in plan_f.buckets))
    grads = [{"w": jax.random.normal(jax.random.fold_in(
                  jax.random.PRNGKey(3), s), (64, 33)),
              "b": jax.random.normal(jax.random.fold_in(
                  jax.random.PRNGKey(4), s), (17,))} for s in range(3)]

    def run(plan):
        ex = PlanExecutor(plan, ("data",))

        def body():
            st = ex.init_state(grads[0])
            outs, errs = [], []
            for g in grads:
                out, st = ex(g, st, jax.random.PRNGKey(0))
                outs.append(out)
                errs.append([e for e in st["error"] if e is not None])
            return outs, errs

        f = jax.shard_map(
            body, mesh=mesh, in_specs=(),
            out_specs=(jax.sharding.PartitionSpec(),) * 2,
            axis_names={"data"}, check_vma=False)
        return jax.jit(f)()

    outs_f, errs_f = run(plan_f)
    outs_u, errs_u = run(plan_u)
    def cmp(a, b, what):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        tol = 4 * np.finfo(np.float32).eps * max(1.0, np.abs(b).max())
        assert np.abs(a - b).max() <= tol, (what, np.abs(a - b).max())

    for s in range(3):
        assert len(errs_f[s]) == len(errs_u[s]) > 0
        for j, (a, b) in enumerate(zip(errs_f[s], errs_u[s])):
            cmp(a, b, f"{name} step {s} EF[{j}]")
        for k in ("w", "b"):
            cmp(outs_f[s][k], outs_u[s][k], f"{name} step {s} {k}")


def test_modes_are_deterministic():
    """Same seed -> bit-identical run, in both modes (the conformance
    comparisons above are meaningless without this)."""
    model = TinyLM()
    params0 = model.init(jax.random.PRNGKey(0))
    plan = sharded_plan_from_config(
        SyncConfig(compressor="int8", algo="ring", bucket_bytes=2048),
        params0)
    for runner in (_run_replicated, _run_sharded):
        a = runner(model, params0, plan, "adam")
        b = runner(model, params0, plan, "adam")
        for x, y in zip(jax.tree.leaves(a[0]), jax.tree.leaves(b[0])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert a[3] == b[3]


def test_layerwise_optimizer_bounded_divergence():
    """LAMB's sharded trust ratios use segment-sum + psum partial norms —
    a different summation order than the replicated per-leaf norm, so the
    promise is bounded divergence, not bit-exactness."""
    model = TinyLM()
    params0 = model.init(jax.random.PRNGKey(0))
    plan = sharded_plan_from_config(SyncConfig(compressor="none",
                                               algo="ring"), params0)
    p_r, _, _, _ = _run_replicated(model, params0, plan, "lamb")
    p_s, _, _, _, _ = _run_sharded(model, params0, plan, "lamb")
    for k in p_r:
        np.testing.assert_allclose(np.asarray(p_r[k]), np.asarray(p_s[k]),
                                   rtol=2e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# Matrix edges: what sharded mode must refuse
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched_kw", [
    dict(scheduler="local_sgd", period=2),
    dict(scheduler="push_pull", n_push=2, n_fetch=2),
    dict(scheduler="lag", threshold=0.5),
], ids=["local_sgd", "push_pull", "lag"])
def test_shard_state_refuses_diverging_schedulers(sched_kw):
    """Partitioned optimizer state cannot follow schedulers with local
    phases or gradient reuse; the session must fail LOUDLY at build, not
    silently train nonsense."""
    from repro.api import SessionConfig, TrainSession
    sess = TrainSession(
        SessionConfig(arch="xlstm-125m", reduced=True, batch=2, seq=16,
                      steps=2),
        strategy=make_strategy(axes=("data",), shard_state=True,
                               **sched_kw))
    with pytest.raises(ValueError, match="shard_state"):
        sess.step_once()


def test_plan_auto_refuses_pinned_scheduler_with_shard():
    from repro.api import SessionConfig, TrainSession
    sess = TrainSession(SessionConfig(arch="xlstm-125m", reduced=True,
                                      batch=2, seq=16, steps=2))
    with pytest.raises(ValueError, match="shard_state"):
        sess.plan_auto(scheduler=get_scheduler("local_sgd", period=4),
                       shard_state=True, t_backward_s=0.02, plan_world=64)


# ---------------------------------------------------------------------------
# Session-level sharded run (the full TrainSession surface, world=1)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_session_sharded_equals_replicated_end_to_end():
    """TrainSession --shard-state vs the replicated session on the real
    reduced-xlstm model: matching losses and ulp-close params (dense fp32
    psum, the default wire), honest rounds accounting, and the 1/p memory
    identity in the layout.  Two steps only: xlstm's exponential sLSTM
    gates amplify the world=1 FMA-contraction seed (~7e-9 after one
    update) chaotically from the third step on — the multi-step strict
    equivalence lives in multi_device_checks.py where both graphs
    contract identically."""
    from repro.api import SessionConfig, TrainSession
    kw = dict(arch="xlstm-125m", reduced=True, batch=2, seq=16, steps=2)

    sh = TrainSession(SessionConfig(**kw),
                      strategy=make_strategy("every_step", axes=("data",),
                                             shard_state=True))
    losses_s = sh.run(2)
    assert sh.grad_rounds == 2 and sh.comm_rounds == 2
    assert sh.layout is not None
    # world=1: shard rows must still carry the leading worker axis
    for r in sh._opt_state["master"]:
        assert r.shape[0] == 1

    # replicated reference: the SAME packed dense plan (DESIGN.md §8 —
    # exactness is promised per bucket boundary)
    ref = TrainSession(SessionConfig(**kw))
    plan = sharded_plan_from_config(SyncConfig(), ref._params)
    ref.strategy = SyncStrategy(scheduler=get_scheduler("every_step"),
                                grad_reducer=PlanExecutor(plan, ("data",)))
    losses_r = ref.run(2)

    np.testing.assert_allclose(losses_r, losses_s, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(sh.params)):
        _assert_tight(a, b, "session params")
    # reconstructed moments match the replicated optimizer state
    full = sh.full_opt_state()
    for mom in ("m", "v"):
        for a, b in zip(jax.tree.leaves(ref.opt_state[mom]),
                        jax.tree.leaves(full[mom])):
            _assert_tight(a, b, f"session {mom}")
