"""Per-architecture smoke tests (deliverable f): every assigned arch's
reduced variant runs one forward/train step and one decode step on CPU with
finite outputs and correct shapes, and prefill+decode is consistent with the
full forward pass (cache correctness, including sliding-window ring buffers
and recurrent states)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduced
from repro.models import Model
from repro.models.transformer import materialize_cache

RNG = jax.random.PRNGKey(0)


def make(name):
    cfg = reduced(get_config(name))
    model = Model(cfg)
    params = model.init(RNG)
    return cfg, model, params


def batch_for(cfg, B=2, T=32):
    b = {"tokens": jax.random.randint(RNG, (B, T), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        b["src"] = jax.random.normal(RNG, (B, T, cfg.d_model)) * 0.1
    return b


# the heavyweight reduced archs (~20-30 s each on CPU) ride in the slow
# CI tier; the rest stay in the default tier-1 selection
_HEAVY = {"xlstm-125m", "deepseek-v2-lite-16b", "jamba-v0.1-52b",
          "gemma2-9b"}


@pytest.mark.parametrize(
    "name", [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY
             else a for a in ALL_ARCHS])
def test_train_step_smoke(name):
    cfg, model, params = make(name)
    batch = batch_for(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), name
    # loss at init ~ ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5, float(loss)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and float(gnorm) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_step_smoke(name):
    cfg, model, params = make(name)
    B, L = 2, 16
    cache = materialize_cache(model.init_cache(B, L, src_len=L))
    if cfg.is_encoder_decoder:
        b = batch_for(cfg, B, 8)
        _, cache = model.prefill(params, b, max_len=L)
        pos = 8
    else:
        pos = 0
    tok = jax.random.randint(RNG, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = model.decode_step(params, tok, cache,
                                          jnp.asarray(pos, jnp.int32))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize(
    "name", [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY
             else a for a in ("gemma-2b", "deepseek-v2-lite-16b",
                              "xlstm-125m", "jamba-v0.1-52b",
                              "seamless-m4t-large-v2", "gemma3-4b")])
def test_prefill_decode_matches_full_forward(name):
    """logits(prefill P tokens, then decode one) == logits(prefill P+1).
    MoE capacity is raised so no tokens drop (drops differ between the two
    tokenizations and are not a cache bug)."""
    import dataclasses
    cfg, model, params = make(name)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        model = Model(cfg)
    B, P = 2, 12
    b = batch_for(cfg, B, P + 1)
    full_logits, _ = model.prefill(params, b, max_len=P + 4)

    b_pre = {k: (v[:, :P] if k == "tokens" else v) for k, v in b.items()}
    _, cache = model.prefill(params, b_pre, max_len=P + 4)
    step_logits, _ = model.decode_step(params, b["tokens"][:, P:P + 1], cache,
                                       jnp.asarray(P, jnp.int32))
    np.testing.assert_allclose(np.asarray(step_logits), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_sliding_window_ring_cache():
    """Decode far past the window: ring buffer must evict correctly."""
    cfg = reduced(get_config("gemma3-4b"))   # window 32 after reduction
    model = Model(cfg)
    params = model.init(RNG)
    T = cfg.window_size + 16                  # exceed the window
    tokens = jax.random.randint(RNG, (1, T + 1), 0, cfg.vocab_size)
    full_logits, _ = model.prefill(params, {"tokens": tokens}, max_len=T + 4)
    _, cache = model.prefill(params, {"tokens": tokens[:, :T]}, max_len=T + 4)
    step_logits, _ = model.decode_step(params, tokens[:, T:T + 1], cache,
                                       jnp.asarray(T, jnp.int32))
    np.testing.assert_allclose(np.asarray(step_logits), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_mla_absorb_equivalence():
    """Absorbed MLA decode (the §Perf optimization) == naive MLA decode."""
    cfg, model, params = make("deepseek-v2-lite-16b")
    B, P = 2, 8
    b = batch_for(cfg, B, P)
    _, cache = model.prefill(params, b, max_len=P + 4)
    tok = b["tokens"][:, -1:]
    l1, _ = model.decode_step(params, tok, cache, jnp.asarray(P, jnp.int32),
                              mla_absorb=False)
    l2, _ = model.decode_step(params, tok, cache, jnp.asarray(P, jnp.int32),
                              mla_absorb=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-3)


def test_moe_routing_mass_conservation():
    """Every kept token's expert outputs are weighted by normalized router
    weights; with identical expert weights MoE == dense MLP of same size."""
    import dataclasses
    from repro.models import moe as moe_mod
    # capacity_factor high enough that nothing is dropped (drop-free check)
    cfg = dataclasses.replace(reduced(get_config("qwen3-moe-30b-a3b")),
                              capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(RNG)
    # find a MoE ffn params leaf and make all experts identical
    seg = params["stack"][0][0]["ffn"]
    for k in ("wi_gate", "wi_up", "wo"):
        w0 = seg[k][(0,) * 1]  # stacked (repeats, E, ...)
        seg[k] = jnp.broadcast_to(seg[k][:, :1], seg[k].shape)
    x = jax.random.normal(RNG, (2, 16, cfg.d_model)) * 0.3
    out, aux = moe_mod.moe_ffn(jax.tree.map(lambda p: p[0], seg), cfg, x)
    # identical experts + normalized weights -> same as single expert MLP
    from repro.models.layers import mlp
    dense = mlp({"wi_gate": seg["wi_gate"][0, 0], "wi_up": seg["wi_up"][0, 0],
                 "wo": seg["wo"][0, 0]}, x, cfg.activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=2e-2, atol=2e-2)
    assert jnp.isfinite(aux)
