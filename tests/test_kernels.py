"""Pallas kernels vs ref.py oracles: shape x dtype sweeps in interpret mode
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,T,H,KV,hd", [
    (1, 128, 2, 2, 32), (2, 256, 4, 2, 64), (1, 128, 8, 1, 32),
    (2, 128, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_shapes(B, T, H, KV, hd, dtype):
    q = jax.random.normal(RNG, (B, T, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (B, T, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (B, T, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, q_blk=64, kv_blk=64)
    r = ref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(r),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("kwargs", [
    dict(window=64), dict(softcap=30.0), dict(window=64, softcap=20.0),
    dict(causal=False),
])
def test_flash_kernel_variants(kwargs):
    q = jax.random.normal(RNG, (1, 256, 4, 32))
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (1, 256, 2, 32))
    out = ops.flash_attention(q, k, v, q_blk=64, kv_blk=64, **kwargs)
    r = ref.flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_tiles", [1, 3, 8])
@pytest.mark.parametrize("decay", [1.0, 0.9])
def test_quantize_ef_kernel(n_tiles, decay):
    n = n_tiles * 1024
    g = jax.random.normal(RNG, (n,)) * 2.5
    e = jax.random.normal(jax.random.fold_in(RNG, 1), (n,)) * 0.3
    q, e_new, sc = ops.quantize_ef(g, e, decay=decay, tile=1024)
    qr, er, scr = ref.quantize_ef_ref(g, e, decay=decay, tile=1024)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    # atol covers fused-vs-ref rounding differences across jaxlib versions
    # (observed up to ~1.3e-6 on the CPU interpreter backend)
    np.testing.assert_allclose(np.asarray(e_new), np.asarray(er), atol=3e-6)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(scr), atol=0)


def test_quantize_ef_reconstruction_bound():
    """|corrected - dequant(q)| <= scale/254 per element (round-to-nearest)."""
    from repro.kernels.ops import dequantize
    n = 4096
    g = jax.random.normal(RNG, (n,)) * 5
    e = jnp.zeros((n,))
    q, e_new, sc = ops.quantize_ef(g, e, tile=1024)
    recon = dequantize(q, sc, tile=1024)
    bound = jnp.repeat(sc, 1024) / 127.0 * 0.5 + 1e-6
    assert bool(jnp.all(jnp.abs(g - recon) <= bound))
    np.testing.assert_allclose(np.asarray(g - recon), np.asarray(e_new),
                               rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("ratio", [0.01, 0.05, 0.25])
def test_topk_mask_kernel(ratio):
    n = 8 * 1024
    x = jax.random.normal(RNG, (n,))
    got = ops.topk_mask(x, ratio=ratio, tile=1024)
    want = ref.topk_mask_ref(x, ratio=ratio, tile=1024)
    k = max(1, int(1024 * ratio))
    nnz = int((got != 0).sum())
    # per-tile counts within bisection tolerance of the exact oracle
    assert abs(nnz - int((want != 0).sum())) <= 8 * 2
    # kept values are a subset relationship: every kept kernel value matches x
    kept = np.asarray(got != 0)
    np.testing.assert_array_equal(np.asarray(got)[kept], np.asarray(x)[kept])
    # magnitudes: min kept >= max dropped within each tile (up to bisection eps)
    xb = np.asarray(x).reshape(-1, 1024)
    gb = np.asarray(got).reshape(-1, 1024)
    for xt, gt in zip(xb, gb):
        kept_t = gt != 0
        if kept_t.any() and (~kept_t).any():
            assert np.abs(xt[kept_t]).min() >= np.abs(xt[~kept_t]).max() - 1e-4
