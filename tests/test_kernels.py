"""Pallas kernels vs ref.py oracles: shape x dtype sweeps in interpret mode
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,T,H,KV,hd", [
    (1, 128, 2, 2, 32), (2, 256, 4, 2, 64), (1, 128, 8, 1, 32),
    (2, 128, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_shapes(B, T, H, KV, hd, dtype):
    q = jax.random.normal(RNG, (B, T, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (B, T, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (B, T, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, q_blk=64, kv_blk=64)
    r = ref.flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(r),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("kwargs", [
    dict(window=64), dict(softcap=30.0), dict(window=64, softcap=20.0),
    dict(causal=False),
])
def test_flash_kernel_variants(kwargs):
    q = jax.random.normal(RNG, (1, 256, 4, 32))
    k = jax.random.normal(jax.random.fold_in(RNG, 1), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.fold_in(RNG, 2), (1, 256, 2, 32))
    out = ops.flash_attention(q, k, v, q_blk=64, kv_blk=64, **kwargs)
    r = ref.flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_tiles", [1, 3, 8])
@pytest.mark.parametrize("decay", [1.0, 0.9])
def test_quantize_ef_kernel(n_tiles, decay):
    n = n_tiles * 1024
    g = jax.random.normal(RNG, (n,)) * 2.5
    e = jax.random.normal(jax.random.fold_in(RNG, 1), (n,)) * 0.3
    q, e_new, sc = ops.quantize_ef(g, e, decay=decay, tile=1024)
    qr, er, scr = ref.quantize_ef_ref(g, e, decay=decay, tile=1024)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    # atol covers fused-vs-ref rounding differences across jaxlib versions
    # (observed up to ~1.3e-6 on the CPU interpreter backend)
    np.testing.assert_allclose(np.asarray(e_new), np.asarray(er), atol=3e-6)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(scr), atol=0)


def test_quantize_ef_reconstruction_bound():
    """|corrected - dequant(q)| <= scale/254 per element (round-to-nearest)."""
    from repro.kernels.ops import dequantize
    n = 4096
    g = jax.random.normal(RNG, (n,)) * 5
    e = jnp.zeros((n,))
    q, e_new, sc = ops.quantize_ef(g, e, tile=1024)
    recon = dequantize(q, sc, tile=1024)
    bound = jnp.repeat(sc, 1024) / 127.0 * 0.5 + 1e-6
    assert bool(jnp.all(jnp.abs(g - recon) <= bound))
    np.testing.assert_allclose(np.asarray(g - recon), np.asarray(e_new),
                               rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# Backend dispatch (DESIGN.md §11) — the regression tests for the historical
# unconditional-interpret default
# ---------------------------------------------------------------------------

def test_dispatch_defaults_follow_backend(monkeypatch):
    """No caller may hardcode interpret mode: ``None`` resolves to the
    compiled kernel on TPU and the xla/interpreter lowering elsewhere, and
    ``REPRO_KERNELS_IMPL`` overrides the default (explicit args win)."""
    from repro.kernels import dispatch

    monkeypatch.delenv(dispatch.IMPL_ENV, raising=False)
    expect = "pallas" if dispatch.on_tpu() else "xla"
    assert dispatch.resolve_impl(None) == expect
    assert dispatch.resolve_interpret(None) == (not dispatch.on_tpu())
    assert dispatch.resolve_interpret(True) is True
    assert dispatch.resolve_interpret(False) is False

    monkeypatch.setenv(dispatch.IMPL_ENV, "interpret")
    assert dispatch.resolve_impl(None) == "interpret"
    monkeypatch.setenv(dispatch.IMPL_ENV, "xla")
    assert dispatch.resolve_impl(None) == "xla"
    # an explicit impl beats the env override
    assert dispatch.resolve_impl("interpret") == "interpret"
    monkeypatch.setenv(dispatch.IMPL_ENV, "bogus")
    with pytest.raises(ValueError, match="bogus"):
        dispatch.resolve_impl(None)


def test_hot_path_is_not_interpreter_off_tpu(monkeypatch):
    """ops.py's default dispatch off-TPU must be the vectorized xla
    lowering, never the Pallas interpreter (the perf bug this PR fixes):
    the jitted wrapper receives impl='xla'."""
    from repro.kernels import dispatch
    monkeypatch.delenv(dispatch.IMPL_ENV, raising=False)
    if dispatch.on_tpu():
        pytest.skip("off-TPU dispatch check")
    seen = {}
    orig = ops._quantize_ef

    def spy(g, e, decay, tile, impl):
        seen["impl"] = impl
        return orig(g, e, decay, tile, impl)

    monkeypatch.setattr(ops, "_quantize_ef", spy)
    g = jax.random.normal(RNG, (1024,))
    ops.quantize_ef(g, jnp.zeros_like(g), tile=1024)
    assert seen["impl"] == "xla"


# interpret (the Pallas kernel body under the interpreter) and xla (the
# ref.py lowering) must agree BITWISE under jit — that equivalence is what
# lets the off-TPU hot path skip the interpreter without changing any
# payload or residual.  Ragged lengths exercise the pad-and-mask contract.
@pytest.mark.parametrize("n", [1024, 1000, 2065, 4096])
def test_interpret_matches_xla_bitwise(n):
    g = jax.random.normal(RNG, (n,)) * 2.0
    e = jax.random.normal(jax.random.fold_in(RNG, 1), (n,)) * 0.3

    for a, b in zip(ops.quantize_ef(g, e, tile=1024, impl="interpret"),
                    ops.quantize_ef(g, e, tile=1024, impl="xla")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(ops.topk_ef(g, e, ratio=0.25, tile=1024,
                                impl="interpret"),
                    ops.topk_ef(g, e, ratio=0.25, tile=1024, impl="xla")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    q, sc = ops.quantize_tiles(g, tile=1024, impl="xla")
    for a, b in zip(ops.quantize_tiles(g, tile=1024, impl="interpret"),
                    (q, sc)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    qg, sg = jnp.stack([q] * 4), jnp.stack([sc] * 4)
    np.testing.assert_array_equal(
        np.asarray(ops.dequant_accum(qg, sg, tile=1024, impl="interpret")),
        np.asarray(ops.dequant_accum(qg, sg, tile=1024, impl="xla")))


@pytest.mark.parametrize("n", [1000, 2065])
def test_ragged_pad_and_mask_contract(n):
    """Non-tile-aligned lengths: zero-pad to the boundary, compute, slice
    back — the partial tile's scale and residual must match computing on
    the padded array directly (pads cannot change max|·| or be kept by a
    positive threshold), and the EF identity y + e_new == g + e holds on
    the ragged buffer."""
    tile = 1024
    m = -(-n // tile) * tile
    g = jax.random.normal(RNG, (n,)) * 2.0
    e = jax.random.normal(jax.random.fold_in(RNG, 1), (n,)) * 0.3
    gp = jnp.pad(g, (0, m - n))
    ep = jnp.pad(e, (0, m - n))

    q, e_new, sc = ops.quantize_ef(g, e, tile=tile)
    qp, ep_new, scp = ops.quantize_ef(gp, ep, tile=tile)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qp)[:n])
    np.testing.assert_array_equal(np.asarray(e_new), np.asarray(ep_new)[:n])
    np.testing.assert_array_equal(np.asarray(sc), np.asarray(scp))
    # the pad region of the padded run stays exactly zero
    assert not np.asarray(qp)[n:].any() and not np.asarray(ep_new)[n:].any()

    y, e2 = ops.topk_ef(g, e, ratio=0.25, tile=tile)
    yp, ep2 = ops.topk_ef(gp, ep, ratio=0.25, tile=tile)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yp)[:n])
    np.testing.assert_array_equal(np.asarray(e2), np.asarray(ep2)[:n])
    np.testing.assert_allclose(np.asarray(y) + np.asarray(e2),
                               np.asarray(g + e), atol=1e-6)

    q2, sc2 = ops.quantize_tiles(g, tile=tile)
    d = ops.dequant_accum(jnp.stack([q2] * 3), jnp.stack([sc2] * 3),
                          tile=tile)
    assert d.shape == (n,)
    np.testing.assert_allclose(
        np.asarray(d), 3 * np.asarray(ref.dequantize_ref(q2, sc2, tile=tile)),
        rtol=1e-6, atol=1e-6)


def test_dequant_accum_matches_per_payload_loop():
    """The fused decode (one read per payload, one dense write) equals the
    decomposed per-rank dequantize+add loop up to summation order."""
    n, w, tile = 4096, 8, 1024
    qs, scs = [], []
    for i in range(w):
        x = jax.random.normal(jax.random.fold_in(RNG, i), (n,)) * (1 + i)
        q, sc = ops.quantize_tiles(x, tile=tile)
        qs.append(q)
        scs.append(sc)
    got = ops.dequant_accum(jnp.stack(qs), jnp.stack(scs), tile=tile)
    want = sum(ref.dequantize_ref(q, sc, tile=tile)
               for q, sc in zip(qs, scs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("ratio", [0.01, 0.05, 0.25])
def test_topk_mask_kernel(ratio):
    n = 8 * 1024
    x = jax.random.normal(RNG, (n,))
    got = ops.topk_mask(x, ratio=ratio, tile=1024)
    want = ref.topk_mask_ref(x, ratio=ratio, tile=1024)
    k = max(1, int(1024 * ratio))
    nnz = int((got != 0).sum())
    # per-tile counts within bisection tolerance of the exact oracle
    assert abs(nnz - int((want != 0).sum())) <= 8 * 2
    # kept values are a subset relationship: every kept kernel value matches x
    kept = np.asarray(got != 0)
    np.testing.assert_array_equal(np.asarray(got)[kept], np.asarray(x)[kept])
    # magnitudes: min kept >= max dropped within each tile (up to bisection eps)
    xb = np.asarray(x).reshape(-1, 1024)
    gb = np.asarray(got).reshape(-1, 1024)
    for xt, gt in zip(xb, gb):
        kept_t = gt != 0
        if kept_t.any() and (~kept_t).any():
            assert np.abs(xt[kept_t]).min() >= np.abs(xt[~kept_t]).max() - 1e-4
