"""JAX version compatibility shims.

The codebase is written against the modern JAX API surface (``jax.shard_map``
with ``axis_names``/``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``, ``jax.lax.axis_size``).  The pinned container
toolchain ships an older JAX where those spell differently:

  * ``jax.shard_map``          -> ``jax.experimental.shard_map.shard_map``
                                  (``axis_names`` becomes the complement
                                  ``auto=`` frozenset; ``check_vma`` was
                                  ``check_rep``)
  * ``jax.make_mesh``          -> same, minus ``axis_types``
  * ``jax.sharding.AxisType``  -> absent (all axes behave as Auto)
  * ``jax.lax.axis_size(ax)``  -> ``jax.lax.psum(1, ax)`` (statically folded)

Importing this module (``repro/__init__.py`` does it) installs forwarding
wrappers ONLY for the spellings the installed JAX lacks; on a modern JAX it
is a no-op.  Call sites keep the modern spelling everywhere.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    orig = jax.make_mesh
    if "axis_types" in inspect.signature(orig).parameters:
        return

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        # old JAX has no axis-type concept; every axis is effectively Auto,
        # which is what this repo requests everywhere
        return orig(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as legacy

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        if axis_names is None:
            auto = frozenset()
        else:
            # Size-1 leftover axes are promoted into the manual set: a
            # one-shard axis is manual/auto-indistinguishable semantically,
            # but leaving it auto makes the shard_map PARTIAL-manual, and
            # XLA aborts (hlo_sharding.cc IsManual check) on any host
            # callback baked into a partial-manual body — e.g. the MoE
            # drop tap on the standard data(N) x model(1) session mesh.
            auto = frozenset(a for a in mesh.axis_names
                             if a not in axis_names and mesh.shape[a] > 1)
        # check_vma=False maps to the old check_rep=False (skip the
        # replication-invariance check)
        return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=bool(check_vma), auto=auto)

    jax.shard_map = shard_map


def _install_axis_size() -> None:
    if hasattr(jax.lax, "axis_size"):
        return

    def axis_size(axis_name):
        # psum of a Python literal is folded statically to the axis size
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = axis_size


def _install_optimization_barrier_grad() -> None:
    try:
        jax.make_jaxpr(jax.grad(lambda x: jax.lax.optimization_barrier(x)))(1.0)
        return   # differentiation rule exists
    except NotImplementedError:
        pass
    orig = jax.lax.optimization_barrier

    @jax.custom_vjp
    def barrier(xs):
        return orig(xs)

    barrier.defvjp(lambda xs: (barrier(xs), None), lambda _, g: (g,))
    jax.lax.optimization_barrier = barrier


_install_axis_type()
_install_make_mesh()
_install_shard_map()
_install_axis_size()
_install_optimization_barrier_grad()
