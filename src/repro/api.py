"""TrainSession — the programmatic synchronization surface (DESIGN.md §7).

The survey's levers used to be hand-wired in ``launch/train.py``'s main():
rounds (§3.1 local SGD / LAG), bits (§3.2-3.3 compression / fusion / the
planner) and overlap each had a one-off code path, ``--lag`` was silently
dead, and there was no entry point for benchmarks, serving or tests.  A
session owns the pieces once:

    from repro.api import SessionConfig, TrainSession
    from repro.core import SyncConfig, make_strategy

    sess = TrainSession(SessionConfig(arch="xlstm-125m", reduced=True),
                        strategy=make_strategy("local_sgd", period=8,
                                               sync=SyncConfig(
                                                   compressor="int8",
                                                   algo="ring")))
    losses = sess.run(steps=50, log_every=10)
    print(sess.comm_rounds, "communication rounds over", sess.step, "steps")

or let the planner choose the whole composite (rounds × bits × overlap):

    sess = TrainSession(SessionConfig(arch="xlstm-125m", reduced=True))
    sp = sess.plan_auto(link="commodity", plan_world=256)
    print(sp.describe()); sess.run(steps=50)

The session compiles one program per strategy *phase* — the synced step, the
purely-local step, the parameter-round, LAG's probe/sync/reuse — and the
strategy's :class:`~repro.core.strategy.RoundScheduler` dispatches between
them host-side (exactly how LAG deploys on a real pod: data-dependent wire
traffic cannot live inside one SPMD program).  Communication rounds are
counted HONESTLY: a round is a collective that actually ran (gradient syncs
+ parameter rounds; LAG's 8-byte trigger probes are tallied separately as
``control_rounds``), which is the survey's Table 2 quantity.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import load_arrays as load_ckpt_arrays
from repro.checkpoint import save as save_ckpt
from repro.checkpoint.checkpoint import _flatten_with_paths
from repro.configs import get_config, reduced
from repro.core import (GradientSynchronizer, ParallelismSpec, PlanExecutor,
                        ShardLayout, SyncConfig, SyncStrategy, get_scheduler)
from repro.core.grad_sync import sharded_plan_from_config
from repro.core.pipeline import StagedModel
from repro.core.collectives import axes_for_topology
from repro.core.schedule import (LINK_PRESETS, CalibratedTopology,
                                 ExpertAxis, LinkParams, PipelineAxis,
                                 RoundSchedule, StrategyPlan, TensorAxis,
                                 Topology, calibrate_topology,
                                 drift_fraction, fixed_config_plan,
                                 modeled_wall_step_s, pipeline_arm,
                                 pipeline_placements, plan, plan_comm_error_s,
                                 plan_rounds, profiles_from_grads,
                                 resolve_calibration, resolve_cost_table,
                                 serial_round_plan)
from repro.core.schedule.planner import FIXED_BASELINES, local_sgd_arm
from repro.core.strategy import LocalSGDScheduler
from repro.data import DataConfig, SyntheticPipeline
from repro.launch.mesh import (data_axes, make_host_mesh, make_pipe_mesh,
                               make_topology_mesh)
from repro.launch.steps import (_make_synced_train_step, _world_of,
                                broadcast_worker_state, make_lag_programs,
                                make_local_train_step, make_param_round_step,
                                make_pipeline_train_step,
                                make_sharded_train_step, make_train_step,
                                merge_opt_rows, worker_view)
from repro.models import Model
from repro.models.sharding_ctx import set_mesh_ctx
from repro.optim import make_optimizer, make_sharded_optimizer, warmup_cosine


@dataclasses.dataclass
class SessionConfig:
    """What to train (model/optimizer/data); HOW to synchronize is the
    strategy, passed separately."""
    arch: str = "xlstm-125m"
    reduced: bool = False
    steps: int = 100            # LR-schedule horizon and default run length
    batch: int = 8
    seq: int = 128
    lr: float = 3e-3
    warmup: int = 20
    optimizer: str = "adam"
    data_parallel: int = 0      # 0 -> all devices
    seed: int = 0


def strategy_from_plan(sp: StrategyPlan,
                       axes: Sequence[str] = ("data",)) -> SyncStrategy:
    """Instantiate the executable strategy a planner composite describes."""
    if sp.schedule.kind == "local_sgd":
        return SyncStrategy(
            scheduler=get_scheduler("local_sgd", period=sp.schedule.period),
            param_reducer=PlanExecutor(sp.comm, tuple(axes)))
    if sp.pipeline_stages > 1:
        # the arm's comm plan describes the DP edge of the modeled heavy
        # stage; execution re-derives a per-row plan on the live stage
        # pytree from the arm's dominant (compressor, algo) choice — the
        # reference executor's granularity contract (DESIGN.md §9)
        dom = max(sp.comm.buckets, key=lambda b: b.bucket_bytes)
        return SyncStrategy(
            scheduler=get_scheduler("every_step"),
            grad_reducer=GradientSynchronizer(
                SyncConfig(compressor=dom.compressor,
                           compressor_args=dom.compressor_args,
                           algo=dom.algo, bucket_bytes=0), tuple(axes)),
            parallelism=sp.parallelism)
    # tp/ep winners execute their DP edge here (the model axes need a
    # tp×data / ep×data mesh; on this host they are planning + record
    # axes, validated bit-exactly by the multi-device checks) — the
    # strategy still CARRIES the spec so records and describe() are honest
    return SyncStrategy(scheduler=get_scheduler("every_step"),
                        grad_reducer=PlanExecutor(sp.comm, tuple(axes)),
                        parallelism=sp.parallelism)


def _collapse_mean(tree):
    """Collapse per-worker state (leading world axis, the diverging-
    scheduler carry) to its consensus view: the mean for inexact leaves —
    exactly the parameter-averaging round a local scheduler would run
    next — and worker 0 for integer/bool leaves (step counters etc.,
    identical across workers by construction)."""
    def one(x):
        if jnp.issubdtype(x.dtype, jnp.inexact):
            return jnp.mean(x, axis=0).astype(x.dtype)
        return x[0]
    return jax.tree.map(one, tree)


class TrainSession:
    """One training run driven by a :class:`SyncStrategy`.

    ``strategy=None`` is the vanilla BSP baseline (pjit, XLA-inserted
    collectives).  Everything else goes through the scheduler-dispatched
    phase programs.  Rounds accounting: ``grad_rounds`` (gradient syncs),
    ``param_rounds`` (parameter averaging), ``control_rounds`` (LAG scalar
    probes); ``comm_rounds = grad_rounds + param_rounds``.
    """

    def __init__(self, cfg: Optional[SessionConfig] = None,
                 strategy: Optional[SyncStrategy] = None):
        self.cfg = cfg or SessionConfig()
        self.strategy = strategy
        c = self.cfg
        model_cfg = get_config(c.arch)
        if c.reduced:
            model_cfg = reduced(model_cfg)
        self.model_cfg = model_cfg
        self.model = Model(model_cfg)
        n_dev = len(jax.devices())
        dp = c.data_parallel or n_dev
        self.mesh = make_host_mesh(data=dp, model=n_dev // dp)
        set_mesh_ctx(self.mesh, ("data",))
        self.axes = data_axes(self.mesh)
        self.world = _world_of(self.mesh, self.axes)
        lr = warmup_cosine(c.lr, c.warmup, c.steps)
        self._lr = lr          # schedule, reused by the sharded optimizer
        self.optimizer = make_optimizer(c.optimizer, lr=lr)
        self.data = SyntheticPipeline(DataConfig(
            vocab_size=model_cfg.vocab_size, seq_len=c.seq,
            global_batch=c.batch,
            embedding_dim=model_cfg.d_model if model_cfg.embedding_inputs
            else 0))
        self.rng = jax.random.PRNGKey(c.seed)
        self._params = self.model.init(self.rng)
        self._opt_state = self.optimizer.init(self._params)
        # measured f32 moment buffers per parameter (sgd with momentum=0.0
        # carries none; the planner's per-name default would over-count) —
        # feeds the memory model and the per-worker report
        n_elems = sum(l.size for l in jax.tree.leaves(self._params))
        self.opt_moments = (sum(l.size for l in
                                jax.tree.leaves(self._opt_state))
                            / max(n_elems, 1))

        self.step = 0
        self.losses: List[float] = []
        self.grad_rounds = 0
        self.param_rounds = 0
        self.control_rounds = 0
        # MoE capacity overflow must not vanish silently (DESIGN.md §14):
        # arm the host-side tap BEFORE the first trace bakes the callback
        # into the step program; step_once drains it per step.
        self.dropped_tokens = 0.0
        self.routed_tokens = 0.0
        if model_cfg.num_experts:
            from repro.models.moe import enable_drop_tap
            enable_drop_tap(True)
        self.planned: Optional[Dict[str, Any]] = None
        self.layout: Optional[ShardLayout] = None   # set by sharded builds
        self.staged: Optional[StagedModel] = None   # set by pipeline builds
        self.topology: Optional[Topology] = None    # set by apply_topology
        self.tiered_mesh = False     # True when the mesh IS one-axis-per-tier
        self.calibration: Optional[CalibratedTopology] = None
        self.step_times: List[float] = []      # per-step wall time (run())
        self.replans = 0
        self.replan_events: List[Dict[str, Any]] = []
        self._t_backward_spread_s = 0.0        # profile_backward repeat spread
        self._replan_drift_pct = 0.0           # 0 = replanning off
        self._replan_every = 25
        self._max_replans = 1
        self._window: List[float] = []         # step times since last check
        self._plan_kwargs: Optional[Dict[str, Any]] = None
        self._restore_opt: Optional[Dict[str, Any]] = None  # load_checkpoint
        self._built = False

    # -- state views ---------------------------------------------------------

    @property
    def comm_rounds(self) -> int:
        """Collective rounds that actually ran (survey Table 2)."""
        return self.grad_rounds + self.param_rounds

    @property
    def _diverging(self) -> bool:
        return (self.strategy is not None
                and self.strategy.scheduler.diverges_params)

    @property
    def params(self):
        if self.staged is not None:
            return self.staged.merge(self._params["shared"],
                                     self._params["rows"])
        return worker_view(self._params) if (self._built and self._diverging) \
            else self._params

    @property
    def opt_state(self):
        return worker_view(self._opt_state) \
            if (self._built and self._diverging) else self._opt_state

    @property
    def sync_state(self):
        """Grad-reducer state (EF residuals etc.), worker-0 view."""
        if getattr(self, "_sync_state", None) is None:
            return None
        return worker_view(self._sync_state)

    # -- auto planning (rounds × bits × overlap) -----------------------------

    def resolve_link(self, link="fast_ici", alpha=None,
                     beta_gbps=None) -> LinkParams:
        lp = LINK_PRESETS[link] if isinstance(link, str) else link
        a = lp.alpha_s if alpha is None else alpha
        b = lp.beta_s_per_byte if beta_gbps is None \
            else 1.0 / (beta_gbps * 1e9)
        return LinkParams(alpha_s=a, beta_s_per_byte=b)

    def apply_topology(self, topology) -> Topology:
        """Install a tiered network model (``--topology``, DESIGN.md §10).

        ``topology`` is a :class:`Topology`, a spec string
        (``"node:4@datacenter,device:8@fast_ici"``), or a
        ``TOPOLOGY_PRESETS`` name.  The planner then prices every arm on
        it (its world REPLACES ``plan_world``).  When the topology's
        world matches this host's devices (pure DP — no model axis), the
        session mesh is rebuilt with one axis per tier so collectives
        actually dispatch axis→tier — hierarchical's inner ring runs on
        the fast-tier axis (``collectives.axes_for_topology``); otherwise
        the topology stays a planning model (a pod modeled from a
        laptop) and execution keeps the flat host mesh."""
        if self._built:
            raise RuntimeError("apply_topology must run before the first "
                               "step")
        topo = Topology.from_spec(topology) if isinstance(topology, str) \
            else topology
        self.topology = topo
        n_dev = len(jax.devices())
        self.tiered_mesh = (topo.n_tiers > 1 and topo.world == n_dev
                            and self.cfg.data_parallel in (0, n_dev))
        if self.tiered_mesh:
            self.mesh = make_topology_mesh(topo)
            set_mesh_ctx(self.mesh, tuple(t.name for t in topo.tiers))
            self.axes = axes_for_topology(topo)
            self.world = topo.world
        return topo

    def profile_backward(self, repeats: int = 3) -> float:
        """Wall time of the PER-DEVICE backward (compile excluded): the
        planned shard_map step computes global_batch / world per device, so
        time that slice — timing the full global batch would inflate
        t_backward by the data-parallel factor and make the planner
        over-hide communication.  bwd ≈ 2/3 of a grad step.  Min-of-N
        (the calibration timing policy, DESIGN.md §13); the repeat spread
        is kept as ``_t_backward_spread_s``, the measurement-error term
        of the drift report's fit bound."""
        grad_fn = jax.jit(lambda p, b: jax.grad(self.model.loss)(p, b))
        batch = jax.tree.map(jnp.asarray, self.data.batch(0))
        n_global = jax.tree.leaves(batch)[0].shape[0]
        per_dev = max(1, n_global // self.world)
        batch = jax.tree.map(lambda x: x[:per_dev], batch)
        jax.block_until_ready(grad_fn(self._params, batch))   # compile
        times = []
        for _ in range(max(repeats, 1)):
            t0 = time.time()
            jax.block_until_ready(grad_fn(self._params, batch))
            times.append(time.time() - t0)
        self._t_backward_spread_s = (max(times) - min(times)) * (2.0 / 3.0)
        return min(times) * (2.0 / 3.0)

    def calibrate(self, sizes=None, repeats=None,
                  timer=None) -> CalibratedTopology:
        """Measure THIS host's collective fabric and fit per-tier α/β
        with confidence bounds (``--calibrate``, DESIGN.md §13).  On a
        tiered mesh (``apply_topology`` matched the device count) each
        tier's axis is timed separately; otherwise the flat fabric over
        all local devices is fitted — and if a planning-only topology was
        requested, the calibration measures the host, not the model, so
        say so.  The result is stored as ``self.calibration`` and feeds
        :meth:`plan_auto` via ``calibration=``."""
        from repro.core.schedule.calibration import (CAL_LINK_REPEATS,
                                                     CAL_LINK_SIZES)
        if self.topology is not None and not self.tiered_mesh:
            print(f"note: --calibrate times the HOST fabric "
                  f"({len(jax.devices())} device(s)), not the planning "
                  f"topology {self.topology.spec()}", flush=True)
        topo = self.topology if self.tiered_mesh else None
        kw: Dict[str, Any] = {
            "sizes": sizes if sizes is not None else CAL_LINK_SIZES,
            "repeats": repeats if repeats is not None else CAL_LINK_REPEATS,
        }
        if timer is not None:
            kw["timer"] = timer
            if topo is None and self.topology is not None:
                topo = self.topology    # injected timer: no mesh needed
        elif topo is not None:
            kw["mesh"] = self.mesh      # the tiered session mesh
        self.calibration = calibrate_topology(topo, **kw)
        return self.calibration

    def _pipeline_executable(self, S: int, M: int) -> bool:
        """Can pipeline(S, M) actually run on THIS host's devices/batch?
        (The modeled plan may target a pod via ``plan_world``.)"""
        n_dev = len(jax.devices())
        if S < 2 or n_dev % S:
            return False
        dp = self.cfg.data_parallel or n_dev // S
        if dp * S != n_dev or self.cfg.batch % dp:
            return False
        if (self.cfg.batch // dp) % M:
            return False
        try:
            StagedModel(self.model, S)
        except ValueError:
            return False
        return True

    def _model_axes(self, pipe_axis: PipelineAxis
                    ) -> Tuple[TensorAxis, Optional[ExpertAxis]]:
        """The tp/ep pricing axes for THIS model (DESIGN.md §14): tp pays
        4 activation allreduces per layer (Megatron wire); ep exists only
        for MoE stacks, dispatching top-k activation rows per token with
        ``expert_fraction`` measured from the analytic param count."""
        mc = self.model_cfg
        tensor_axis = TensorAxis(
            global_tokens=pipe_axis.global_tokens,
            bytes_per_token=pipe_axis.bytes_per_token,
            n_layers=mc.num_layers)
        expert_axis = None
        if mc.num_experts:
            n_moe = sum(1 for i in range(mc.num_layers)
                        if mc.layer_spec(i).ffn == "moe")
            if n_moe:
                ffm = mc.moe_d_ff or mc.d_ff
                expert_params = n_moe * 3 * mc.num_experts * mc.d_model * ffm
                frac = min(0.99, expert_params / max(mc.num_params(), 1))
                expert_axis = ExpertAxis(
                    global_tokens=pipe_axis.global_tokens,
                    bytes_per_token=float(mc.top_k * mc.d_model * 4),
                    n_moe_layers=n_moe, expert_fraction=frac)
        return tensor_axis, expert_axis

    def plan_auto(self, link="fast_ici", *, alpha=None, beta_gbps=None,
                  plan_world: int = 0, tau_grid=None, candidates=None,
                  scheduler=None, t_backward_s: Optional[float] = None,
                  shard_state: Optional[bool] = None,
                  memory_budget_gb: Optional[float] = None,
                  pipeline_stages: Optional[int] = None,
                  micro_batches: Optional[int] = None,
                  parallelism=None,
                  topology=None,
                  compression_costs=None,
                  calibration=None,
                  straggler_s: float = 0.0) -> StrategyPlan:
        """``--sync auto``: profile one step, search (rounds schedule ×
        per-bucket strategy × shard axis × parallelism axis), install the
        winning composite as this session's strategy.  ``scheduler`` pins
        the rounds axis (an explicit ``--local-sgd``/``--lag``/
        ``--push-pull`` choice) and only the per-bucket plan is searched.
        ``shard_state`` pins the shard axis (None = searched: sharded wins
        only when ``memory_budget_gb`` rules replicated optimizer state out
        — the gather tail never wins on wall clock alone).
        ``pipeline_stages``/``micro_batches`` pin the parallelism axis to
        pipeline(S, M); left None the free search prices pipeline arms too
        (DESIGN.md §9).  ``topology`` (or a prior :meth:`apply_topology`)
        replaces the flat link model with a tiered network — every arm is
        then priced per tier, the pipeline arms search axis placements,
        and the topology's world supersedes the deprecated ``plan_world``
        (a disagreement warns and prefers the topology).
        ``compression_costs`` — a
        :class:`~repro.core.schedule.cost.CompressionCostTable` or a path
        to one recorded by ``benchmarks/bench_collectives.py
        --write-compression-costs`` — replaces the analytic
        compression-compute term with MEASURED per-compressor fits in
        every arm (and in the fixed baselines, so the comparison stays
        apples-to-apples).  ``calibration`` — a
        :class:`~repro.core.schedule.CalibratedTopology` (from
        :meth:`calibrate` / ``--calibrate``) or a path to a saved one —
        replaces the preset link model with the FITTED fabric: a tiered
        calibration becomes the pricing topology outright; a flat one
        supplies the measured link (so an explicit ``plan_world`` still
        prices a hypothetical pod, on real α/β).  Stashes the full
        ``parallelism`` — a :class:`~repro.core.ParallelismSpec` or spec
        string (``"dp=4,tp=2@device"``) pinning the whole parallelism
        axis at once: the free search prices every arm but only arms
        matching the spec may win (impossible specs fail loudly inside
        ``plan_rounds``).  It subsumes the single-axis pins, so combining
        it with ``shard_state``/``pipeline_stages``/``micro_batches`` or
        a pinned ``scheduler`` is an error.  ``straggler_s`` (measured
        worst-vs-median step-time skew, the elastic runtime's signal)
        prices ``cost.straggler_penalty_s`` into every arm so a
        persistent straggler demotes the winning cadence (DESIGN.md §15).
        Stashes the full decision record in ``self.planned`` for
        reporting."""
        if self._built:
            raise RuntimeError("plan_auto must run before the first step")
        if parallelism is not None:
            if (shard_state is not None or pipeline_stages is not None
                    or micro_batches is not None):
                raise ValueError(
                    "parallelism= subsumes shard_state/pipeline_stages/"
                    "micro_batches — fold them into the spec "
                    "(e.g. 'dp=4,pp=2,micro=8,shard')")
            if scheduler is not None:
                raise ValueError(
                    "parallelism= pins arms of the planner's FREE search; "
                    "a pinned rounds scheduler bypasses that search — "
                    "drop one")
            parallelism = ParallelismSpec.coerce(parallelism)
        if topology is not None:
            self.apply_topology(topology)
        cal = resolve_calibration(calibration)
        cal_link = None
        if cal is not None:
            self.calibration = cal
            shape = [(t.name, t.size) for t in cal.topology.tiers]
            if self.topology is not None and \
                    [(t.name, t.size) for t in self.topology.tiers] != shape:
                print(f"warning: calibration measured "
                      f"{cal.topology.spec()} but the planning topology is "
                      f"{self.topology.spec()}; fitted links apply only to "
                      f"the fabric they were measured on — planning keeps "
                      f"the preset links", flush=True)
            elif cal.topology.is_flat and self.topology is None \
                    and plan_world and plan_world != cal.world:
                # hypothetical world, measured link: the fitted flat α/β
                # price the requested plan_world
                cal_link = cal.topology.innermost.link
            else:
                self.apply_topology(cal.topology)
        if scheduler is not None and shard_state:
            raise ValueError("shard_state composes only with the planner's "
                             "every-step arm, not a pinned rounds scheduler")
        if scheduler is not None and memory_budget_gb is not None:
            raise ValueError(
                "memory_budget_gb constrains the planner's FREE search "
                "over arms; a pinned rounds scheduler fixes the memory "
                "footprint, so the budget cannot be enforced — drop one")
        if pipeline_stages is not None and pipeline_stages > 1:
            if scheduler is not None or shard_state:
                raise ValueError("pipeline_stages composes with every-step "
                                 "replicated DP only (DESIGN.md §9)")
        if self.topology is not None:
            lp = self.topology
            world = lp.world
            if plan_world and plan_world != world:
                print(f"warning: plan_world={plan_world} disagrees with "
                      f"the topology ({lp.spec()} = world {world}); "
                      f"planning for the topology — plan_world is "
                      f"deprecated, the tier-size product wins", flush=True)
        else:
            lp = cal_link if cal_link is not None \
                else self.resolve_link(link, alpha, beta_gbps)
            world = plan_world or self.world
        if t_backward_s is None:
            t_backward_s = self.profile_backward()
        profiles = profiles_from_grads(self._params, t_backward_s)
        cost_table = resolve_cost_table(compression_costs)
        kw: Dict[str, Any] = {}
        if candidates is not None:
            kw["candidates"] = candidates
        if cost_table is not None:
            kw["cost_table"] = cost_table
        t_bwd = sum(p.t_backward_s for p in profiles)
        pipe_axis = PipelineAxis(
            global_tokens=float(self.cfg.batch * self.cfg.seq),
            bytes_per_token=float(self.model_cfg.d_model * 4))
        tensor_axis, expert_axis = self._model_axes(pipe_axis)
        mem_budget = (memory_budget_gb * 2**30
                      if memory_budget_gb is not None else None)

        def _stash(sg) -> Dict[str, Any]:
            # what _replan / replan_now re-runs with a fresh profile.
            # Pinned-scheduler sessions stash the FREE search (their pin
            # is a user preference, not an execution constraint), so a
            # straggler-priced re-plan can demote a pinned-LAG cadence
            # to local SGD mid-run (DESIGN.md §15).
            return {"lp": lp, "world": world,
                    "opt_name": self.cfg.optimizer, "shard_grid": sg,
                    "opt_moments": self.opt_moments,
                    "memory_budget_bytes": mem_budget,
                    "pipe_axis": pipe_axis, "tensor_axis": tensor_axis,
                    "expert_axis": expert_axis, "parallelism": parallelism,
                    "kw": dict(kw), "tau_grid": tau_grid,
                    "straggler_s": straggler_s}

        arms: Dict[str, StrategyPlan]
        if pipeline_stages is not None and pipeline_stages > 1:
            # pinned pipeline(S, M): price that arm, plan only its DP edge
            S = pipeline_stages
            M = micro_batches or 8
            # price at the requested world when it factors into pipe(S) x
            # data(>=2); otherwise at the smallest such world (a 1-device
            # demo still gets an honest modeled record)
            plan_w = world if (world % S == 0 and world // S >= 2) else 2 * S
            act = (pipe_axis.global_tokens / (plan_w // S) / M
                   * pipe_axis.bytes_per_token)
            net_p = lp
            if isinstance(lp, Topology) and (
                    plan_w != lp.world
                    or not pipeline_placements(lp, plan_w, S)):
                # the pinned S fits no tier (or the fallback world left
                # the topology behind): price flat on the outermost link
                print(f"note: pinned pipeline(S={S}) fits no tier of "
                      f"{lp.spec()}; pricing it flat on the outermost "
                      f"link", flush=True)
                net_p = lp.outermost.link
            best = pipeline_arm(
                profiles, net_p, plan_w, S, M, act,
                opt_name=self.cfg.optimizer,
                opt_moments=self.opt_moments, **kw)
            arms = {best.key: best}
            self.strategy = strategy_from_plan(best, self.axes)
        elif scheduler is None:
            shard_grid = ((False, True) if shard_state is None
                          else (bool(shard_state),))
            # replan hook re-runs exactly this search with a fresh profile
            self._plan_kwargs = _stash(shard_grid)
            best, arms = plan_rounds(
                profiles, lp, world,
                opt_name=self.cfg.optimizer, shard_grid=shard_grid,
                opt_moments=self.opt_moments,
                memory_budget_bytes=mem_budget,
                pipeline=pipe_axis, tensor=tensor_axis, expert=expert_axis,
                parallelism=parallelism, straggler_s=straggler_s,
                **dict(kw, **({"tau_grid": tau_grid}
                              if tau_grid is not None else {})))
            exec_best = best
            if best.pipeline_stages > 1 and not self._pipeline_executable(
                    best.pipeline_stages, best.micro_batches):
                # the modeled winner targets a pod this host cannot stage;
                # run the best arm that CAN execute here, keep the record
                fits = [a for a in arms.values()
                        if a.pipeline_stages <= 1
                        or self._pipeline_executable(a.pipeline_stages,
                                                     a.micro_batches)]
                exec_best = min(fits, key=lambda a: a.modeled_step_s)
                print(f"note: modeled winner {best.key} needs a "
                      f"pipe({best.pipeline_stages}) mesh this host cannot "
                      f"build; executing {exec_best.key} instead", flush=True)
            self.strategy = strategy_from_plan(exec_best, self.axes)
        elif isinstance(scheduler, LocalSGDScheduler):
            self._plan_kwargs = _stash((False,))
            rp = serial_round_plan(profiles, lp, world, **kw)
            best = local_sgd_arm(rp, t_bwd, scheduler.cfg.period)
            arms = {best.schedule.key: best}
            self.strategy = SyncStrategy(
                scheduler=scheduler,
                param_reducer=PlanExecutor(rp, tuple(self.axes)))
        else:
            # LAG / push-pull / every-step instance: the grad-sync rounds
            # get the overlap-planned per-bucket plan; the round COUNT is
            # the scheduler's (data-dependent for LAG), so the every-step
            # modeled time is an upper bound.  The schedule records the
            # scheduler actually executed, not every_step.
            self._plan_kwargs = _stash((False,))
            cp = plan(profiles, lp, world, **kw)
            best = StrategyPlan(
                schedule=RoundSchedule(kind=scheduler.name), comm=cp,
                modeled_step_s=cp.modeled_step_s,
                round_cost_s=cp.modeled_step_s, t_backward_s=t_bwd)
            arms = {best.schedule.key: best}
            self.strategy = SyncStrategy(
                scheduler=scheduler,
                grad_reducer=PlanExecutor(cp, tuple(self.axes)))

        baselines = {
            name: fixed_config_plan(profiles, lp, world, comp, algo,
                                    compressor_args=cargs,
                                    cost_table=cost_table)
            for name, (comp, algo, cargs) in FIXED_BASELINES.items()}
        self.planned = {"strategy_plan": best, "arms": arms,
                        "baselines": baselines,
                        "t_backward_s": t_backward_s,
                        "cost_table": cost_table}
        return best

    def apply_micro_batching(self, micro_batches: int) -> bool:
        """Attach S=1 micro-batched accumulation (the degenerate pipe) to
        the installed strategy — the ``--sync auto --micro-batches M``
        composition.  Composes with every-step replicated arms only; for
        other winners (local SGD, sharded, an already-pipelined arm) the
        request is declined with a printed reason rather than silently
        dropped.  Returns True when micro-batching will run."""
        if self._built:
            raise RuntimeError("apply_micro_batching must run before the "
                               "first step")
        M = int(micro_batches)
        st = self.strategy
        if M <= 1 or st is None:
            return M <= 1 and st is None
        if st.pipeline_stages > 1 or st.micro_batches > 1:
            return True                      # already micro-batched
        sched = st.scheduler
        if (sched.computes != frozenset({"sync"}) or sched.has_param_rounds
                or sched.needs_grad_probe or st.shard_state):
            print(f"note: micro-batching composes with every-step "
                  f"replicated sync only; chosen arm "
                  f"({st.describe()}) runs without it", flush=True)
            return False
        reducer = st.grad_reducer
        if isinstance(reducer, PlanExecutor):
            # re-derive a per-row config reducer (plans are tied to the
            # full-model pytree) from the plan's dominant bucket
            dom = max(reducer.plan.buckets, key=lambda b: b.bucket_bytes)
            reducer = GradientSynchronizer(
                SyncConfig(compressor=dom.compressor,
                           compressor_args=dom.compressor_args,
                           algo=dom.algo, bucket_bytes=0),
                tuple(self.axes))
        self.strategy = SyncStrategy(
            scheduler=sched, grad_reducer=reducer,
            parallelism=ParallelismSpec(micro_batches=M))
        return True

    # -- program construction ------------------------------------------------

    def _build(self) -> None:
        if self._built:
            return
        self._sync_state = None
        self._anchor = None
        self._red_state = None
        if self.strategy is None:
            self._base = jax.jit(
                make_train_step(self.model, self.optimizer),
                donate_argnums=(0, 1))
            self._built = True
            return

        if self.strategy.pipeline_stages > 1 or \
                self.strategy.micro_batches > 1:
            # S=1 with micro-batches is the degenerate pipe: same 1F1B
            # executor, no boundary sends — plain gradient accumulation
            self._build_pipeline(self.strategy)
            self._built = True
            return

        if self.strategy.shard_state:
            self._build_sharded(self.strategy)
            self._built = True
            return

        st = self.strategy
        sched = st.scheduler
        self._sched_state = sched.init_state(self._params)
        engine = st.grad_reducer
        if engine is None and "sync" in sched.computes:
            engine = GradientSynchronizer(SyncConfig(), tuple(self.axes))

        if sched.needs_grad_probe:
            probe, sync_apply, reuse_apply = make_lag_programs(
                self.model, self.optimizer, engine, self.mesh, self.axes)
            # probe must NOT donate: params/batch are reused by the apply
            # program the scheduler dispatches afterwards
            self._probe = jax.jit(probe)
            self._sync = jax.jit(sync_apply, donate_argnums=(0, 1, 2, 3))
            self._reuse = jax.jit(reuse_apply, donate_argnums=(0, 1))
            self._sync_state = broadcast_worker_state(
                engine.init_state(self._params), self.world)
        elif "sync" in sched.computes:
            step_fn, _, init_sync_state = _make_synced_train_step(
                self.model, self.optimizer, engine, self.mesh, self.axes,
                per_worker_params=sched.diverges_params)
            self._sync = jax.jit(step_fn, donate_argnums=(0, 1, 2))
            self._sync_state = init_sync_state(self._params)
        if "local" in sched.computes:
            self._local = jax.jit(
                make_local_train_step(self.model, self.optimizer, self.mesh,
                                      self.axes),
                donate_argnums=(0, 1))
        if sched.has_param_rounds:
            self._param_round = jax.jit(
                make_param_round_step(st.param_reducer, self.mesh, self.axes,
                                      algo=st.param_algo),
                donate_argnums=(0, 1, 2))
            if st.param_reducer is not None:
                self._anchor = jax.tree.map(
                    lambda p: p.astype(jnp.float32), self._params)
                self._red_state = broadcast_worker_state(
                    st.param_reducer.init_state(self._params), self.world)
        if sched.diverges_params:
            self._params = broadcast_worker_state(self._params, self.world)
            self._opt_state = broadcast_worker_state(self._opt_state,
                                                     self.world)
        self._built = True

    def _build_pipeline(self, st: SyncStrategy) -> None:
        """Pipeline-parallel programs (DESIGN.md §9): rebuild the mesh as
        ``pipe(S) × data``, split params into shared + per-stage layer rows,
        and compile the 1F1B step.  ``self._params`` becomes
        ``{"shared": ..., "rows": (S, R/S, ...)}`` (the ``params`` property
        merges it back); the DP gradient edge runs per LAYER ROW so
        compression granularity is stage-count invariant."""
        sched = st.scheduler
        if (sched.computes != frozenset({"sync"}) or sched.has_param_rounds
                or sched.needs_grad_probe or sched.diverges_params):
            raise ValueError(
                f"pipeline_stages requires an every-step gradient-sync "
                f"scheduler, got {sched.name!r}: local phases and gradient "
                f"reuse assume each worker holds the WHOLE model")
        S, M = st.pipeline_stages, st.micro_batches
        n_dev = len(jax.devices())
        if n_dev % S != 0:
            raise ValueError(f"{n_dev} devices do not factor into "
                             f"pipe({S}) x data")
        dp = self.cfg.data_parallel or n_dev // S
        if dp * S != n_dev:
            raise ValueError(f"data_parallel={dp} x pipeline_stages={S} "
                             f"!= {n_dev} devices")
        if self.cfg.batch % dp or (self.cfg.batch // dp) % M:
            raise ValueError(
                f"global batch {self.cfg.batch} must split into "
                f"{dp} DP shards x {M} micro-batches")
        self.mesh = make_pipe_mesh(S, dp)
        set_mesh_ctx(self.mesh, ("data",))
        self.axes = data_axes(self.mesh)
        self.world = dp
        self._sched_state = sched.init_state(self._params)
        self.staged = StagedModel(self.model, S)
        shared, rows = self.staged.split(self._params)
        self._params = {"shared": shared, "rows": rows}

        engine = st.grad_reducer
        if engine is None:
            engine = GradientSynchronizer(SyncConfig(), tuple(self.axes))
        elif isinstance(engine, GradientSynchronizer):
            # per-leaf buckets: the DP edge syncs per layer row, keeping
            # compression granularity identical for every stage count
            engine = GradientSynchronizer(
                dataclasses.replace(engine.cfg, bucket_bytes=0),
                tuple(self.axes))
        else:
            raise ValueError(
                "pipeline mode takes a SyncConfig-backed reducer (a "
                "CommPlan is tied to the full-model pytree; the stage "
                "pytree is per-row)")
        step_fn, init_opt_state, init_sync_state = make_pipeline_train_step(
            self.staged, self.optimizer, engine, self.mesh, M, self.axes)
        self._sync = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        self._opt_state = init_opt_state(self._params)
        self._sync_state = init_sync_state(self._params)
        self._anchor = None
        self._red_state = None

    def _build_sharded(self, st: SyncStrategy) -> None:
        """Sharded-DP programs (DESIGN.md §8): the every-step sync program
        is replaced by ``make_sharded_train_step`` and ``self._opt_state``
        becomes the partitioned {master, moments} shard rows."""
        sched = st.scheduler
        if (sched.computes != frozenset({"sync"}) or sched.has_param_rounds
                or sched.needs_grad_probe or sched.diverges_params):
            raise ValueError(
                f"shard_state requires an every-step gradient-sync "
                f"scheduler, got {sched.name!r}: local phases (local_sgd/"
                f"push_pull) and gradient reuse (lag) need full per-worker "
                f"optimizer state by construction")
        self._sched_state = sched.init_state(self._params)
        engine = st.grad_reducer
        if engine is None:
            engine = PlanExecutor(
                sharded_plan_from_config(SyncConfig(), self._params),
                tuple(self.axes))
        elif isinstance(engine, GradientSynchronizer):
            engine = PlanExecutor(
                sharded_plan_from_config(engine.cfg, self._params),
                tuple(self.axes))
        axis_sizes = tuple(self.mesh.shape[a] for a in self.axes)
        self.layout = ShardLayout.from_plan(engine.plan, self._params,
                                            axis_sizes)
        shopt = make_sharded_optimizer(self.cfg.optimizer, self.layout,
                                       self.axes, lr=self._lr)
        step_fn, init_opt_rows, init_sync_state = make_sharded_train_step(
            self.model, engine, self.layout, shopt, self.mesh, self.axes)
        self._sync = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        if self._restore_opt is not None:
            # elastic-resharding restore (DESIGN.md §15): re-partition the
            # checkpoint's LEAF-SHAPED optimizer state onto THIS layout —
            # the f32 master (synthesized from the restored params when
            # the checkpoint came from a replicated run) and each moment
            # tree become canonical shard rows via ``shard_rows``, which
            # is what makes an 8-world checkpoint land bit-equal on a
            # 6-rank fabric
            full = dict(self._restore_opt)
            master = full.pop("master", None)
            if master is None:
                master = jax.tree.map(lambda p: p.astype(jnp.float32),
                                      self._params)
            masters = self.layout.shard_rows(master)
            fresh = shopt.init(masters)
            if sorted(fresh) != sorted(full):
                raise ValueError(
                    f"checkpoint optimizer buffers {sorted(full)} do not "
                    f"match {self.cfg.optimizer!r}'s {sorted(fresh)}")
            self._opt_state = {
                "master": masters,
                "opt": {k: self.layout.shard_rows(full[k]) for k in fresh}}
            self._restore_opt = None
        else:
            self._opt_state = init_opt_rows(self._params)  # replaces replicated
        self._sync_state = init_sync_state(self._params)
        self._anchor = None
        self._red_state = None

    def full_opt_state(self):
        """Leaf-shaped view of the optimizer state: the replicated state
        as-is, or — in sharded mode — moments and the f32 master params
        reconstructed from the canonical shard rows (checkpoint
        portability / conformance testing).  In pipeline mode the per-stage
        (S, R/S, ...) moment rows are merged back to the stack's (R, ...)
        leaves, so the checkpoint does not pin the stage count."""
        if self._built and self.staged is not None:
            return merge_opt_rows(self._opt_state, self.staged.layout.rows)
        if not (self._built and self.strategy is not None
                and self.strategy.shard_state):
            return self.opt_state
        rows = self._opt_state
        full = {k: self.layout.tree_from_rows(v, self._params)
                for k, v in rows["opt"].items()}
        full["master"] = self.layout.tree_from_rows(rows["master"],
                                                    self._params)
        return full

    # -- stepping ------------------------------------------------------------

    def step_once(self) -> float:
        """Run one training step under the strategy; returns the loss."""
        self._build()
        step = self.step
        batch = jax.tree.map(jnp.asarray, self.data.batch(step))
        step_i = jnp.asarray(step, jnp.int32)
        rng_s = jax.random.fold_in(self.rng, step)

        if self.strategy is None:
            self._params, self._opt_state, loss = self._base(
                self._params, self._opt_state, batch, step_i)
            self.grad_rounds += 1   # BSP syncs gradients every step
            loss = float(loss)
            self.losses.append(loss)
            self.step += 1
            self._drain_drops()
            return loss

        sched = self.strategy.scheduler
        probe = None
        if sched.needs_grad_probe:
            loss_p, grads_w, delta, scale = self._probe(
                self._params, batch, self._sched_state["g_last"])
            probe = {"delta": float(delta), "scale": float(scale)}
            self.control_rounds += 1
        action, self._sched_state = sched.round(step, self._sched_state,
                                                probe)
        synced = None
        if action.compute == "sync":
            if sched.needs_grad_probe:
                self._params, self._opt_state, self._sync_state, synced = \
                    self._sync(self._params, self._opt_state,
                               self._sync_state, grads_w, step_i, rng_s)
                loss = loss_p
            else:
                self._params, self._opt_state, self._sync_state, loss = \
                    self._sync(self._params, self._opt_state,
                               self._sync_state, batch, step_i, rng_s)
            self.grad_rounds += 1
        elif action.compute == "reuse":
            self._params, self._opt_state = self._reuse(
                self._params, self._opt_state, self._sched_state["g_last"],
                step_i)
            loss = loss_p
        elif action.compute == "local":
            self._params, self._opt_state, loss = self._local(
                self._params, self._opt_state, batch, step_i)
        else:
            raise ValueError(f"unknown action {action.compute!r}")
        if action.param_round:
            self._params, self._anchor, self._red_state = self._param_round(
                self._params, self._anchor, self._red_state, rng_s)
            self.param_rounds += 1
        self._sched_state = sched.commit(self._sched_state, action, synced)

        loss = float(loss)
        self.losses.append(loss)
        self.step += 1
        self._drain_drops()
        return loss

    def _drain_drops(self) -> None:
        """Collect the MoE capacity-overflow counts the step's debug
        callbacks reported (``float(loss)`` already blocked on the step,
        so they have fired)."""
        if not self.model_cfg.num_experts:
            return
        from repro.models.moe import drain_drop_tap
        d, r = drain_drop_tap()
        self.dropped_tokens += d
        self.routed_tokens += r

    @property
    def drop_fraction(self) -> float:
        """Fraction of routed token-choices dropped to capacity overflow
        so far (0.0 for dense models or before any step)."""
        return self.dropped_tokens / self.routed_tokens \
            if self.routed_tokens else 0.0

    def run(self, steps: Optional[int] = None, log_every: int = 0,
            log=print) -> List[float]:
        """Train ``steps`` steps (default: ``cfg.steps``); returns the
        losses of THIS run.  The step log reports honest round counts."""
        steps = steps or self.cfg.steps
        t0 = time.time()
        start = self.step
        out: List[float] = []
        for i in range(steps):
            pre_built = self._built      # a build step pays compile time
            ts = time.time()
            loss = self.step_once()
            dt = time.time() - ts
            self.step_times.append(dt)
            if pre_built:
                self._window.append(dt)
            self._maybe_replan()
            out.append(loss)
            if log_every and i % log_every == 0:
                dt = (time.time() - t0) / max(i, 1)
                drops = (f", dropped {self.drop_fraction * 100:.1f}%"
                         if self.routed_tokens else "")
                log(f"step {self.step - 1:5d} loss {loss:.4f} "
                    f"({dt * 1e3:.0f} ms/step, comm rounds "
                    f"{self.comm_rounds}{drops})", flush=True)
        self.wall_s = time.time() - t0
        self.steps_run = self.step - start
        return out

    # -- modeled vs measured -------------------------------------------------

    def measured_step_s(self) -> float:
        """Median wall time of the steps :meth:`run` executed, dropping
        the first (it pays compilation).  NaN before any steps ran."""
        times = self.step_times[1:] or self.step_times
        return statistics.median(times) if times else float("nan")

    def enable_replan(self, drift_pct: float, check_every: int = 25,
                      max_replans: int = 1) -> None:
        """Arm the drift-gated re-planning hook (``--replan-drift-pct``):
        every ``check_every`` post-compile steps, compare the window's
        median step time against the plan's modeled wall step; when the
        drift exceeds ``drift_pct`` percent, re-profile the backward pass
        and re-run the planner search.  Off by default (0 disarms)."""
        self._replan_drift_pct = float(drift_pct)
        self._replan_every = max(int(check_every), 2)
        self._max_replans = int(max_replans)

    def _modeled_wall_s(self) -> float:
        sp = self.planned.get("strategy_plan") if self.planned else None
        if sp is None:
            return float("nan")
        return modeled_wall_step_s(sp.modeled_step_s, sp.t_backward_s)

    def _maybe_replan(self) -> None:
        if (self._replan_drift_pct <= 0 or self.planned is None
                or len(self._window) < self._replan_every
                or self.replans >= self._max_replans):
            if len(self._window) >= self._replan_every:
                self._window.clear()
            return
        measured = statistics.median(self._window)
        self._window.clear()
        modeled = self._modeled_wall_s()
        if not modeled or modeled != modeled:
            return
        drift = drift_fraction(modeled, measured)
        if abs(drift) * 100.0 <= self._replan_drift_pct:
            return
        self._replan(drift, measured)

    def replan_now(self, straggler_s: float = 0.0,
                   t_backward_s: Optional[float] = None) -> Dict[str, Any]:
        """Force one re-plan outside the drift gate — the elastic
        runtime's straggler escalation (DESIGN.md §15): re-run the stashed
        planner search pricing every arm with
        ``cost.straggler_penalty_s(straggler_s, rounds/step)``, so a
        persistent straggler demotes the winning cadence (every-step pays
        the full skew per step; a local-SGD τ arm pays skew/τ) instead of
        stalling the bus.  ``t_backward_s`` skips the wall-clock backward
        re-profile (deterministic replans).  Returns the recorded event;
        requires a prior :meth:`plan_auto` (the stashed search)."""
        if self.planned is None:
            raise RuntimeError("replan_now needs a prior plan_auto")
        self._replan(0.0, self.measured_step_s(),
                     straggler_s=straggler_s, t_backward_s=t_backward_s)
        return self.replan_events[-1]

    def _replan(self, drift: float, measured_s: float,
                straggler_s: float = 0.0,
                t_backward_s: Optional[float] = None) -> None:
        """Re-run the stashed planner search with a FRESH backward profile
        (the measured fabric disagreed with the modeled one, or a
        straggler skew was reported).  The new winner is installed when
        neither the outgoing nor the incoming arm pins an execution shape
        that would strand state: no pipeline/micro-batch mesh and no shard
        rows on either side, and an incoming arm the session can rebuild
        from the live leaf-shaped params — plain every-step or local SGD.
        Rounds-schedule swaps (every_step↔local_sgd, LAG→either) ARE
        installed: an outgoing diverging scheduler's per-worker state is
        collapsed to its mean view first (counted as one parameter round —
        it IS the averaging round the scheduler owed), scheduler/EF state
        re-initializes on the rebuild.  Pipeline and sharded shapes still
        only record the recommendation."""
        event: Dict[str, Any] = {
            "step": self.step, "drift_frac": drift,
            "measured_step_s": measured_s,
            "old_key": self.planned["strategy_plan"].key,
            "applied": False, "note": ""}
        if straggler_s > 0.0:
            event["straggler_s"] = straggler_s
        pk = self._plan_kwargs
        if pk is None:
            event["note"] = ("no free-search plan to rerun (pinned "
                             "pipeline)")
            event["new_key"] = event["old_key"]
            self.replans += 1
            self.replan_events.append(event)
            return
        t_bwd = t_backward_s if t_backward_s is not None \
            else self.profile_backward()
        params = worker_view(self._params) if (self._built
                                               and self._diverging) \
            else self._params
        if self.staged is not None:
            params = self.params
        profiles = profiles_from_grads(params, t_bwd)
        extra = dict(pk["kw"])
        if pk["tau_grid"] is not None:
            extra["tau_grid"] = pk["tau_grid"]
        ss = straggler_s if straggler_s > 0.0 \
            else pk.get("straggler_s", 0.0)
        best, arms = plan_rounds(
            profiles, pk["lp"], pk["world"], opt_name=pk["opt_name"],
            shard_grid=pk["shard_grid"], opt_moments=pk["opt_moments"],
            memory_budget_bytes=pk["memory_budget_bytes"],
            pipeline=pk["pipe_axis"], tensor=pk["tensor_axis"],
            expert=pk["expert_axis"], parallelism=pk["parallelism"],
            straggler_s=ss, **extra)
        event["new_key"] = best.key
        old = self.strategy
        old_ok = (old is not None
                  and old.pipeline_stages <= 1 and old.micro_batches <= 1
                  and not old.shard_state)
        new_ok = (best.schedule.kind in ("every_step", "local_sgd")
                  and not best.shard_state
                  and best.pipeline_stages <= 1
                  and best.micro_batches <= 1)
        if old_ok and new_ok:
            if best.key != event["old_key"] \
                    or type(old.scheduler).name != best.schedule.kind:
                if self._built and old.scheduler.diverges_params:
                    # the collapse IS the parameter-averaging round the
                    # outgoing local scheduler owed — count it honestly
                    self._params = _collapse_mean(self._params)
                    self._opt_state = _collapse_mean(self._opt_state)
                    self.param_rounds += 1
                self.strategy = strategy_from_plan(best, self.axes)
                self._built = False    # rebuild lazily; EF residual resets
                event["applied"] = True
            else:
                event["note"] = "re-plan kept the incumbent arm"
        else:
            event["note"] = ("winner needs a different execution shape "
                             "(shard/pipeline); not swapped mid-run")
        self.planned = dict(self.planned, strategy_plan=best, arms=arms,
                            t_backward_s=t_bwd)
        self.replans += 1
        self.replan_events.append(event)
        print(f"replan @step {self.step}: drift {drift * 100:+.1f}%"
              + (f", straggler {ss * 1e3:.1f} ms" if ss > 0 else "")
              + f" -> {best.key}"
              + (" (installed)" if event["applied"]
                 else f" ({event['note']})"), flush=True)

    def drift_report(self) -> Optional[Dict[str, Any]]:
        """The modeled-vs-measured closing of the loop: per-arm predicted
        step time against this run's measured median, with the fit's
        error budget (comm α/β confidence + backward-profile spread +
        measurement spread).  None until both a plan and steps exist."""
        if self.planned is None or not self.step_times:
            return None
        sp = self.planned["strategy_plan"]
        measured = self.measured_step_s()
        modeled_wall = self._modeled_wall_s()
        times = self.step_times[1:] or self.step_times
        spread = (max(times) - min(times)) / 2.0 if len(times) > 1 else 0.0
        comm_err = plan_comm_error_s(sp.comm, self.calibration)
        fit_err = comm_err + self._t_backward_spread_s + spread
        arms = {}
        for key, arm in self.planned.get("arms", {}).items():
            wall = modeled_wall_step_s(arm.modeled_step_s, arm.t_backward_s)
            arms[key] = {
                "modeled_step_s": arm.modeled_step_s,
                "modeled_wall_step_s": wall,
                "drift_pct": drift_fraction(wall, measured) * 100.0}
        return {
            "plan_key": sp.key,
            "modeled_step_s": sp.modeled_step_s,
            "modeled_wall_step_s": modeled_wall,
            "measured_step_s": measured,
            "steps_measured": len(times),
            "drift_frac": drift_fraction(modeled_wall, measured),
            "drift_pct": drift_fraction(modeled_wall, measured) * 100.0,
            "comm_fit_err_s": comm_err,
            "t_backward_err_s": self._t_backward_spread_s,
            "measured_spread_s": spread,
            "fit_error_s": fit_err,
            "within_fit_error": abs(measured - modeled_wall) <= fit_err,
            "replans": self.replans,
            "replan_events": list(self.replan_events),
            "arms": arms,
        }

    def save_checkpoint(self, path: str) -> None:
        """In sharded mode the optimizer state is saved LEAF-SHAPED (via
        :meth:`full_opt_state` — master params + moments reconstructed
        from the canonical shard rows), so a checkpoint restores onto any
        mesh shape or bucket plan; raw (world, m) rows would pin the
        checkpoint to this run's layout.  ``ShardLayout.shard_rows``
        re-partitions on restore."""
        save_ckpt(path, {"params": self.params, "opt": self.full_opt_state()},
                  step=self.step)

    def load_checkpoint(self, path: str) -> int:
        """Restore a checkpoint written by :meth:`save_checkpoint` into
        this session, BEFORE the first step compiles the programs.  The
        payload checksum is verified first (a truncated file raises
        ``ValueError``, DESIGN.md §15).  Because checkpoints are
        leaf-shaped, restore is execution-mode agnostic: params load
        directly; optimizer state fills the replicated template when this
        session runs replicated (a sharded checkpoint's f32 master is
        simply dropped — the params carry the same values), and the full
        leaf-shaped dict is stashed for :meth:`_build_sharded` to
        re-partition onto THIS session's ``ShardLayout`` — the elastic
        resharding path: a checkpoint saved on world 8 restores onto a
        6-rank fabric without restart.  Sets and returns the restored
        step; the synthetic data pipeline is a pure function of the step
        index, so resumption replays the exact batch sequence."""
        if self._built:
            raise RuntimeError("load_checkpoint must run before the first "
                               "step")
        if self.strategy is not None and (
                self.strategy.pipeline_stages > 1
                or self.strategy.micro_batches > 1):
            raise NotImplementedError(
                "load_checkpoint composes with replicated and sharded DP "
                "builds; restoring into a pipeline/micro-batched build is "
                "not supported")
        data, manifest = load_ckpt_arrays(path)

        def tree_at(prefix, like):
            flat = _flatten_with_paths(like)
            missing = [k for k in flat if f"{prefix}/{k}" not in data]
            if missing:
                raise ValueError(
                    f"checkpoint {path!r} lacks {prefix!r} leaves "
                    f"{missing[:3]}{'…' if len(missing) > 3 else ''} — "
                    f"was it saved from a different model config?")
            leaves = [jnp.asarray(data[f"{prefix}/{k}"]) for k in flat]
            return jax.tree.unflatten(jax.tree.structure(like), leaves)

        self._params = tree_at("params", self._params)
        # every top-level optimizer entry is params-shaped by the
        # checkpoint contract (full_opt_state): moments, momentum, and —
        # for sharded-run checkpoints — the f32 "master" copy
        tops = sorted({k.split("/", 2)[1]
                       for k in data if k.startswith("opt/")})
        full = {t: tree_at(f"opt/{t}", self._params) for t in tops}
        self._restore_opt = dict(full)
        moments = {k: v for k, v in full.items() if k != "master"}
        if isinstance(self._opt_state, dict):
            missing = sorted(set(self._opt_state) - set(moments))
            if missing:
                raise ValueError(
                    f"checkpoint {path!r} lacks optimizer buffers "
                    f"{missing} required by {self.cfg.optimizer!r}")
            self._opt_state = {k: moments[k] for k in self._opt_state}
        else:                      # non-dict optimizer state: structural
            self._opt_state = tree_at("opt", self._opt_state)
        self.step = int(manifest.get("step") or 0)
        return self.step

    def summary(self) -> str:
        parts = [f"steps {self.step}", f"comm rounds {self.comm_rounds} "
                 f"(grad {self.grad_rounds}, param {self.param_rounds}"
                 + (f", control probes {self.control_rounds}"
                    if self.control_rounds else "") + ")"]
        if self.routed_tokens:
            parts.append(
                f"moe dropped {self.dropped_tokens:.0f}/"
                f"{self.routed_tokens:.0f} token-choices "
                f"({self.drop_fraction * 100:.1f}%)")
        if self.strategy is not None:
            parts.append(self.strategy.describe())
        else:
            parts.append("vanilla BSP")
        return "; ".join(parts)
