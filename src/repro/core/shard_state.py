"""Shard layout for sharded data parallelism (ZeRO-style, DESIGN.md §8).

Sharded-DP partitions the per-bucket flat state — f32 master parameters and
optimizer moments — over the data axes: the canonical owner of chunk w of a
bucket is the device at row-major mesh position w.  This module is the
single source of truth for that layout:

  * the NESTED chunking rule (pad to p1 chunks of m1 = ceil(n/p1), each of
    those to p2 chunks of m2 = ceil(m1/p2), ...) — the host-side twin of
    ``repro.core.collectives.pad_to_chunks``, so state initialised here
    lands exactly where the reduce-scatter edge delivers gradient chunks;
  * host-side pack / shard / unshard conversions (checkpoint resharding:
    a state saved under one mesh shape restores bit-equal under another);
  * per-element leaf segment ids (layerwise optimizers — LAMB/LARS trust
    ratios need per-LAYER norms, which a shard only partially sees);
  * the optimizer-memory accounting the planner and report use.

Everything here is static host-side metadata + numpy; the only jax arrays
are the shard rows themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule.planner import OPT_MOMENTS, CommPlan  # noqa: F401


def nested_ms(n: int, axis_sizes: Sequence[int]) -> List[int]:
    """Per-level chunk lengths [m1, m2, ...]; the last entry is the
    per-rank shard length."""
    ms, cur = [], int(n)
    for p in axis_sizes:
        cur = -(-cur // int(p))
        ms.append(cur)
    return ms


def chunk_rows(flat: np.ndarray, axis_sizes: Sequence[int]) -> np.ndarray:
    """Host twin of ``collectives.pad_to_chunks``: (n,) -> (world, m) with
    row w = the canonical chunk owned by rank w."""
    arr = np.asarray(flat).reshape(1, -1)
    for p in axis_sizes:
        p = int(p)
        n = arr.shape[-1]
        m = -(-n // p)
        arr = np.pad(arr, [(0, 0)] * (arr.ndim - 1) + [(0, p * m - n)])
        arr = arr.reshape(arr.shape[:-1] + (p, m))
    return arr.reshape(-1, arr.shape[-1])


def rows_to_flat(rows: np.ndarray, n: int,
                 axis_sizes: Sequence[int]) -> np.ndarray:
    """Inverse of :func:`chunk_rows`: (world, m) canonical rows -> (n,)."""
    sizes = [int(p) for p in axis_sizes]
    ms = nested_ms(n, sizes)
    lens = [int(n)] + ms[:-1]
    arr = np.asarray(rows).reshape(tuple(sizes) + (ms[-1],))
    for ln in reversed(lens):
        arr = arr.reshape(arr.shape[:-2] + (arr.shape[-2] * arr.shape[-1],))
        arr = arr[..., :ln]
    return arr.reshape(-1)


@dataclasses.dataclass(frozen=True)
class BucketShard:
    """Static shard geometry of one fused bucket."""
    leaves: Tuple[int, ...]        # leaf ids, in packed order
    sizes: Tuple[int, ...]         # element count per packed leaf
    n: int                         # unpadded bucket elements
    m: int                         # per-rank shard elements (nested ceil)


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Canonical sharded layout of a ``CommPlan``'s buckets over the data
    axes (``axis_sizes`` in mesh-axis order; world = their product)."""
    axis_sizes: Tuple[int, ...]
    buckets: Tuple[BucketShard, ...]
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_dtypes: Tuple[Any, ...]

    @property
    def world(self) -> int:
        w = 1
        for p in self.axis_sizes:
            w *= int(p)
        return w

    @property
    def n_leaves(self) -> int:
        return len(self.leaf_shapes)

    @classmethod
    def from_plan(cls, plan: CommPlan, params,
                  axis_sizes: Sequence[int]) -> "ShardLayout":
        leaves = jax.tree.leaves(params)
        sizes = tuple(int(np.prod(l.shape)) for l in leaves)
        buckets = []
        for b in plan.buckets:
            bs = tuple(sizes[i] for i in b.leaves)
            n = int(sum(bs))
            buckets.append(BucketShard(
                leaves=tuple(b.leaves), sizes=bs, n=n,
                m=nested_ms(n, axis_sizes)[-1] if n else 0))
        claimed = sorted(i for b in buckets for i in b.leaves)
        if claimed != list(range(len(leaves))):
            raise ValueError(f"plan does not cover the pytree: {claimed} "
                             f"vs {len(leaves)} leaves")
        return cls(axis_sizes=tuple(int(p) for p in axis_sizes),
                   buckets=tuple(buckets),
                   leaf_shapes=tuple(tuple(l.shape) for l in leaves),
                   leaf_dtypes=tuple(l.dtype for l in leaves))

    # -- host-side conversions (init / checkpoint resharding) ----------------

    def _pack_np(self, leaves, b: BucketShard) -> np.ndarray:
        return np.concatenate([
            np.asarray(jax.device_get(leaves[i])).reshape(-1)
            .astype(np.float32) for i in b.leaves])

    def shard_rows(self, tree) -> List[jnp.ndarray]:
        """Pack a leaf-shaped pytree into per-bucket canonical shard rows
        [(world, m_b) f32] — how partitioned state is initialised AND how
        it is carried (leading device axis, sharded over the data axes)."""
        leaves = jax.tree.leaves(tree)
        return [jnp.asarray(chunk_rows(self._pack_np(leaves, b),
                                       self.axis_sizes))
                for b in self.buckets]

    def tree_from_rows(self, rows, like) -> Any:
        """Inverse of :func:`shard_rows`: reassemble the full leaf-shaped
        pytree (f32) from per-bucket shard rows.  ``like`` supplies the
        tree structure; values come entirely from ``rows``."""
        leaves = jax.tree.leaves(like)
        out = [None] * len(leaves)
        for b, r in zip(self.buckets, rows):
            flat = rows_to_flat(np.asarray(jax.device_get(r)), b.n,
                                self.axis_sizes)
            off = 0
            for i, sz in zip(b.leaves, b.sizes):
                out[i] = jnp.asarray(
                    flat[off:off + sz].reshape(self.leaf_shapes[i]))
                off += sz
        return jax.tree.unflatten(jax.tree.structure(like), out)

    def reshard(self, rows, new_axis_sizes: Sequence[int]
                ) -> Tuple["ShardLayout", List[Any]]:
        """Move saved shard rows to a different mesh shape (checkpoint
        restore on a new world size): returns (new_layout, new_rows).
        Full state round-trips bit-equal because both layouts chunk the
        same canonical flat buffer — including NON-DIVISOR world changes
        (8 → 6 → 8, the elastic-resharding path, DESIGN.md §15): nested
        ceil-chunking only pads the tail, it never requires the old and
        new worlds to divide each other.  Invalid target shapes (empty,
        zero or negative axes, non-integers) fail loudly here instead of
        producing silently misaligned rows."""
        sizes = tuple(new_axis_sizes)
        if not sizes or any(int(p) != p or int(p) < 1 for p in sizes):
            raise ValueError(
                f"cannot reshard to axis sizes {sizes!r}: every axis must "
                f"be a positive integer (world = their product)")
        new = dataclasses.replace(
            self, axis_sizes=tuple(int(p) for p in new_axis_sizes),
            buckets=tuple(dataclasses.replace(
                b, m=nested_ms(b.n, new_axis_sizes)[-1])
                for b in self.buckets))
        out = []
        for b, r in zip(self.buckets, rows):
            flat = rows_to_flat(np.asarray(jax.device_get(r)), b.n,
                                self.axis_sizes)
            out.append(jnp.asarray(chunk_rows(flat, new.axis_sizes)))
        return new, out

    # -- layerwise-optimizer support -----------------------------------------

    def seg_rows(self, b_idx: int) -> np.ndarray:
        """(world, m) int32 leaf-segment id per padded slot of bucket
        ``b_idx`` (padding slots get the sentinel id ``n_leaves``): rank w
        indexes row w to segment-sum its partial per-layer norms."""
        b = self.buckets[b_idx]
        ids = np.concatenate([np.full(sz, i, np.int32)
                              for i, sz in zip(b.leaves, b.sizes)])
        rows = chunk_rows(ids.astype(np.float64) + 1.0, self.axis_sizes)
        # padding became 0.0 under chunk_rows; shift back so real ids are
        # exact and padding maps to the sentinel
        rows = rows.astype(np.int64) - 1
        rows[rows < 0] = self.n_leaves
        return rows.astype(np.int32)

    # -- memory accounting (the report's headline number) --------------------

    def param_bytes(self) -> int:
        """Dense f32 bytes of the full parameter set."""
        return 4 * sum(b.n for b in self.buckets)

    def opt_bytes_per_worker(self, opt_name: str, sharded: bool,
                             moments: float = None) -> float:
        """f32 optimizer-state bytes per worker: ``moments`` buffers
        replicated, or (moments + the f32 master copy) over the 1/p shard
        (padded) when partitioned.  ``moments`` overrides the per-name
        worst-case default with the measured buffer count (sgd with
        momentum=0.0 carries none)."""
        mom = OPT_MOMENTS.get(opt_name, 2) if moments is None else moments
        if not sharded:
            return mom * self.param_bytes()
        return (mom + 1) * 4 * sum(b.m for b in self.buckets)
