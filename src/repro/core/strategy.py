"""SyncStrategy — the survey's two algorithm-level levers as ONE composable
surface (§3.1 rounds × §3.2-3.3 bits).

A strategy is a **round scheduler** (how often a communication round runs:
every step, local-SGD τ, LAG's lazy trigger, Dean-style asymmetric
push/pull) composed with a **per-round reducer** (what a round moves: a
``CommPlan`` executed by ``PlanExecutor`` — possibly compressed, per-bucket
heterogeneous — or plain parameter averaging).  The two levers multiply:
periodic averaging *of compressed per-bucket syncs* is the regime both the
comprehensive (2003.06307) and quantitative (2005.13247) surveys highlight,
and this module is what lets ``--sync auto`` choose it.

Schedulers carry their own state through a uniform ``init_state`` /
``round`` interface and live in a registry mirroring
``core/compression.REGISTRY``:

    sched = get_scheduler("local_sgd", period=8)
    action, state = sched.round(step, state)        # host-side dispatch
    state = sched.commit(state, action, synced)     # after the round ran

``round`` returns a :class:`RoundAction` naming which compiled program the
trainer dispatches this step (``sync`` — gradient-reducing step, ``local``
— purely local step with NO gradient collective, ``reuse`` — LAG's apply of
the last synchronized gradient) plus whether a parameter-averaging round
follows.  ``repro.api.TrainSession`` holds the compiled programs and the
honest communication-rounds accounting; ``launch/train.py`` is a thin CLI
over it.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, FrozenSet, Optional

from repro.core.grad_sync import GradientSynchronizer, PlanExecutor, SyncConfig
from repro.core.lag import LAGConfig, init_lag_state, lag_update_state
from repro.core.local_sgd import (AsymmetricPushPullConfig, LocalSGDConfig,
                                  should_sync)
from repro.core.parallelism import ParallelismSpec
from repro.core.schedule.planner import CommPlan


@dataclasses.dataclass(frozen=True)
class RoundAction:
    """What the trainer runs at one step (decided host-side, like LAG's
    host dispatch — the decision picks between compiled programs)."""
    compute: str = "sync"        # 'sync' | 'local' | 'reuse'
    param_round: bool = False    # run the parameter-reduce program after


class RoundScheduler:
    """Base round scheduler: WHEN communication happens (survey §3.1).

    Class attributes describe what the trainer must compile:

      * ``computes`` — the set of compute actions ``round`` may return
      * ``has_param_rounds`` — ever requests a parameter-averaging round
      * ``needs_grad_probe`` — ``round`` needs this step's gradient norms
        (LAG: the trainer runs a probe program first and passes
        ``probe={'delta': .., 'scale': ..}``)
      * ``diverges_params`` — local phases let per-worker parameters drift,
        so the trainer must carry params/optimizer state PER WORKER
        (leading device axis) instead of replicated
      * ``supports_backpressure`` — the scheduler has a cadence lever a
        straggler signal can demote (:meth:`backpressure`)
    """
    name: str = "base"
    computes: FrozenSet[str] = frozenset({"sync"})
    has_param_rounds: bool = False
    needs_grad_probe: bool = False
    diverges_params: bool = False
    supports_backpressure: bool = False

    def init_state(self, params) -> Dict[str, Any]:
        return {}

    def backpressure(self, factor: float = 2.0) -> bool:
        """Demote this scheduler's global round cadence in response to a
        straggler signal (survey §3.1.2: trade synchronization frequency
        for stall time instead of blocking the bus on the slowest
        worker).  Returns True when the cadence actually changed; the
        base scheduler has no cadence lever and returns False — the
        elastic runtime then escalates to a straggler-priced re-plan
        (``plan_rounds(straggler_s=...)``, DESIGN.md §15)."""
        return False

    def round(self, step: int, state: Dict[str, Any],
              probe: Optional[Dict[str, float]] = None
              ) -> tuple[RoundAction, Dict[str, Any]]:
        raise NotImplementedError

    def commit(self, state: Dict[str, Any], action: RoundAction,
               synced_grads=None) -> Dict[str, Any]:
        """Called after the dispatched program ran (LAG records the newly
        synchronized gradient here)."""
        return state

    def describe(self) -> str:
        return self.name


# ---------------------------------------------------------------------------
# Registry (mirrors core/compression.REGISTRY)
# ---------------------------------------------------------------------------

SCHEDULERS: Dict[str, Callable[..., RoundScheduler]] = {}


def register_scheduler(name: str):
    def deco(cls):
        SCHEDULERS[name] = cls
        return cls
    return deco


def get_scheduler(name: str, **kwargs) -> RoundScheduler:
    if name not in SCHEDULERS:
        raise KeyError(
            f"unknown scheduler {name!r}; known: {sorted(SCHEDULERS)}")
    return SCHEDULERS[name](**kwargs)


# ---------------------------------------------------------------------------
# The schedulers
# ---------------------------------------------------------------------------

@register_scheduler("every_step")
class EveryStepScheduler(RoundScheduler):
    """Vanilla BSP cadence: one gradient-sync round per step.  The
    degenerate strategy — bit-for-bit the legacy GradientSynchronizer /
    make_comm_optimized_train_step path when composed with the same
    reducer."""
    name = "every_step"
    computes = frozenset({"sync"})

    def round(self, step, state, probe=None):
        return RoundAction("sync"), state


@register_scheduler("local_sgd")
class LocalSGDScheduler(RoundScheduler):
    """Periodic averaging (survey §3.1.2): τ purely-local optimizer steps,
    then one parameter-averaging round; ``post_local_after`` runs a
    parameter round after EVERY step during warmup (post-local SGD in the
    param-averaging formulation; per-worker optimizer moments stay local
    throughout, as in local Adam).  Rounds = T/τ, the survey's Table 2
    quantity."""
    name = "local_sgd"
    computes = frozenset({"local"})
    has_param_rounds = True
    diverges_params = True
    supports_backpressure = True

    def __init__(self, period: int = 4, post_local_after: int = 0,
                 cfg: Optional[LocalSGDConfig] = None):
        self.cfg = cfg or LocalSGDConfig(period=period,
                                         post_local_after=post_local_after)
        if self.cfg.period < 1:
            raise ValueError(f"local SGD period must be >= 1, "
                             f"got {self.cfg.period}")

    def round(self, step, state, probe=None):
        return RoundAction("local",
                           param_round=should_sync(step, self.cfg)), state

    def backpressure(self, factor: float = 2.0) -> bool:
        # stretching τ is pure host-side dispatch: the compiled local /
        # param-round programs don't depend on the period, so the demotion
        # is safe mid-run (rounds just get rarer from the next step on)
        new = max(int(round(self.cfg.period * factor)), self.cfg.period + 1)
        self.cfg = dataclasses.replace(self.cfg, period=new)
        return True

    def describe(self):
        return (f"local_sgd τ={self.cfg.period}"
                + (f" post_local={self.cfg.post_local_after}"
                   if self.cfg.post_local_after else ""))


@register_scheduler("lag")
class LAGScheduler(RoundScheduler):
    """Lazily aggregated gradients (survey §3.1.2, Chen et al. 2018):
    communicate only when the gradient changed enough,

        sync  iff  ||g_t - g_last||² > threshold · ||g_t||²,

    otherwise reuse the last synchronized gradient.  The trainer's probe
    program computes the two (globally psum-ed) scalars — the only wire
    traffic of a skipped round, which is LAG's entire point.  State schema:
    ``{'g_last': pytree, 'rounds': int32}`` (``core.lag.init_lag_state``)."""
    name = "lag"
    computes = frozenset({"sync", "reuse"})
    needs_grad_probe = True
    supports_backpressure = True

    def __init__(self, threshold: float = 0.1,
                 cfg: Optional[LAGConfig] = None):
        self.cfg = cfg or LAGConfig(threshold=threshold)
        if self.cfg.check_every != 1:
            # the probe IS the backward here (grads are needed every step
            # regardless); a trigger cadence would only skip two scalar
            # psums while silently changing the sync pattern, so reject it
            # rather than ignore it
            raise ValueError("check_every != 1 is not supported by this "
                             "executor: the trigger rides the per-step "
                             "backward probe")

    def init_state(self, params):
        return init_lag_state(params)

    def round(self, step, state, probe=None):
        if probe is None:
            raise ValueError("LAG needs a gradient probe "
                             "({'delta': .., 'scale': ..})")
        # the first round must sync unconditionally: g_last is still zero,
        # so delta == scale and a threshold >= 1 would otherwise reuse the
        # all-zero gradient forever (training silently frozen)
        trigger = (int(state["rounds"]) == 0
                   or probe["delta"] > self.cfg.threshold * probe["scale"])
        return RoundAction("sync" if trigger else "reuse"), state

    def commit(self, state, action, synced_grads=None):
        if action.compute == "sync":
            return lag_update_state(state, synced_grads, True)
        return state

    def backpressure(self, factor: float = 2.0) -> bool:
        # a larger threshold makes the lazy trigger lazier: more reuse
        # rounds, fewer bus-stalling syncs — LAG's native demotion lever
        self.cfg = dataclasses.replace(
            self.cfg, threshold=self.cfg.threshold * max(factor, 1.0))
        return True

    def describe(self):
        return f"lag θ={self.cfg.threshold}"


@register_scheduler("push_pull")
class PushPullScheduler(RoundScheduler):
    """Dean et al. 2012 asymmetric push/pull (survey §3.1.2): gradients are
    pushed (synced) every ``n_push`` steps, parameters fetched (re-averaged)
    every ``n_fetch`` steps — the two directions of worker↔server traffic on
    decoupled cadences.  Steps that push neither run purely locally."""
    name = "push_pull"
    computes = frozenset({"sync", "local"})
    has_param_rounds = True
    diverges_params = True
    supports_backpressure = True

    def __init__(self, n_push: int = 1, n_fetch: int = 1,
                 cfg: Optional[AsymmetricPushPullConfig] = None):
        self.cfg = cfg or AsymmetricPushPullConfig(n_push=n_push,
                                                   n_fetch=n_fetch)

    def backpressure(self, factor: float = 2.0) -> bool:
        c = self.cfg
        self.cfg = AsymmetricPushPullConfig(
            n_push=max(int(round(c.n_push * factor)), c.n_push + 1),
            n_fetch=max(int(round(c.n_fetch * factor)), c.n_fetch + 1))
        return True

    def round(self, step, state, probe=None):
        compute = "sync" if self.cfg.should_push(step) else "local"
        return RoundAction(compute,
                           param_round=self.cfg.should_fetch(step)), state

    def describe(self):
        return f"push_pull push={self.cfg.n_push} fetch={self.cfg.n_fetch}"


# ---------------------------------------------------------------------------
# The composed strategy
# ---------------------------------------------------------------------------

_LEGACY_KNOB_MSG = (
    "SyncStrategy({names}) is deprecated; pass "
    "parallelism=ParallelismSpec(...) (or a spec string like "
    "'pp=2,micro=8,shard') instead — the per-knob fields will be removed "
    "next release (DESIGN.md §14)")


class SyncStrategy:
    """scheduler × reducers × parallelism.  Reducers are any engine with
    the ``init_state(tree)`` / ``__call__(tree, state, rng)`` interface
    (``PlanExecutor``, ``GradientSynchronizer``):

      * ``grad_reducer`` — runs inside 'sync' rounds on the gradients
        (None -> dense psum, the vanilla exchange)
      * ``param_reducer`` — runs inside parameter rounds on the params-minus-
        anchor delta (None -> plain dense ``average_params``); compressing
        the delta instead of the raw parameters is what keeps error feedback
        and sparsification sound for periodic averaging

    ``parallelism`` (a :class:`~repro.core.parallelism.ParallelismSpec`,
    spec string, or None = pure replicated DP) names how the world is
    factored — ZeRO shard_state, pipeline (pp, micro), tensor (tp), and
    expert (ep) axes with their tier placements — ONE object shared with
    ``plan_rounds`` and the plan records (DESIGN.md §14).  Only every-step
    gradient sync composes with a non-trivial spec: schedulers with local
    phases or gradient reuse need full per-worker replicated state by
    construction.

    The pre-spec per-knob surface (``shard_state`` / ``pipeline_stages`` /
    ``micro_batches`` constructor args and attributes) still works as a
    deprecated pass-through: constructing with the knobs warns once and
    builds the equivalent spec; READING ``.shard_state`` etc. stays silent
    (the executor does it on every build)."""

    def __init__(self, scheduler: RoundScheduler, grad_reducer: Any = None,
                 param_reducer: Any = None, param_algo: str = "psum",
                 parallelism=None,
                 shard_state: Optional[bool] = None,
                 pipeline_stages: Optional[int] = None,
                 micro_batches: Optional[int] = None):
        self.scheduler = scheduler
        self.grad_reducer = grad_reducer
        self.param_reducer = param_reducer
        self.param_algo = param_algo
        legacy = {k: v for k, v in (("shard_state", shard_state),
                                    ("pipeline_stages", pipeline_stages),
                                    ("micro_batches", micro_batches))
                  if v is not None}
        if legacy:
            if parallelism is not None:
                raise ValueError(
                    f"pass either parallelism= or the deprecated "
                    f"{sorted(legacy)} knobs, not both")
            warnings.warn(
                _LEGACY_KNOB_MSG.format(names=", ".join(sorted(legacy))),
                DeprecationWarning, stacklevel=2)
            pp = 1 if pipeline_stages is None else int(pipeline_stages)
            mb = 1 if micro_batches is None else int(micro_batches)
            if pp < 1 or mb < 1:
                raise ValueError(f"pipeline_stages/micro_batches must be "
                                 f">= 1, got {pp}/{mb}")
            parallelism = ParallelismSpec.legacy(
                shard_state=bool(shard_state), pipeline_stages=pp,
                micro_batches=mb)
        self.parallelism = ParallelismSpec.coerce(parallelism)

    # -- deprecated per-knob views (silent reads; the executor uses them) --

    @property
    def shard_state(self) -> bool:
        return self.parallelism.shard_state

    @property
    def pipeline_stages(self) -> int:
        return int(self.parallelism.pp)

    @property
    def micro_batches(self) -> int:
        return max(int(self.parallelism.micro_batches), 1)

    def describe(self) -> str:
        p = self.parallelism
        if self.pipeline_stages > 1:
            mode = (f" [pipeline S={self.pipeline_stages} "
                    f"M={self.micro_batches}]")
        elif self.micro_batches > 1:
            mode = f" [micro-batches M={self.micro_batches}]"
        else:
            mode = ""
        if p.tp > 1:
            mode += f" [tp={p.tp}" + (f"@{p.tp_tier}" if p.tp_tier else "") \
                + "]"
        if p.ep > 1:
            mode += f" [ep={p.ep}" + (f"@{p.ep_tier}" if p.ep_tier else "") \
                + "]"
        parts = [self.scheduler.describe()
                 + (" [shard_state 1/p]" if self.shard_state else "")
                 + mode]
        if "sync" in self.scheduler.computes:
            parts.append("grads via "
                         + _describe_reducer(self.grad_reducer, "dense psum"))
        if self.scheduler.has_param_rounds:
            parts.append("param rounds via "
                         + _describe_reducer(self.param_reducer,
                                             f"dense {self.param_algo} avg"))
        return "; ".join(parts)


def _describe_reducer(reducer, default: str) -> str:
    if reducer is None:
        return default
    if isinstance(reducer, GradientSynchronizer):
        c = reducer.cfg
        return f"{c.algo}/{c.compressor}"
    if isinstance(reducer, PlanExecutor):
        n = reducer.plan.n_buckets
        kinds = sorted({f"{b.algo}/{b.compressor}"
                        for b in reducer.plan.buckets})
        return f"CommPlan[{n} buckets: {', '.join(kinds)}]"
    return type(reducer).__name__


def make_strategy(scheduler: str | RoundScheduler = "every_step", *,
                  axes=("data",), sync: Optional[SyncConfig] = None,
                  plan: Optional[CommPlan] = None,
                  param_plan: Optional[CommPlan] = None,
                  param_algo: str = "psum",
                  parallelism=None,
                  shard_state: Optional[bool] = None,
                  pipeline_stages: Optional[int] = None,
                  micro_batches: Optional[int] = None,
                  **scheduler_kwargs) -> SyncStrategy:
    """Convenience constructor: resolve the scheduler by registry name and
    build reducers from either a global ``SyncConfig`` or a planned
    ``CommPlan``.  For schedulers with parameter rounds the sync config /
    ``param_plan`` feeds the param-round reducer instead.  ``parallelism``
    takes a :class:`~repro.core.parallelism.ParallelismSpec` or spec string;
    the per-knob trio is the deprecated pass-through."""
    if isinstance(scheduler, str):
        scheduler = get_scheduler(scheduler, **scheduler_kwargs)
    if sync is not None and plan is not None:
        raise ValueError("pass either sync= or plan=, not both")

    grad_reducer = param_reducer = None
    if plan is not None:
        grad_reducer = PlanExecutor(plan, tuple(axes))
    elif sync is not None:
        grad_reducer = GradientSynchronizer(sync, tuple(axes))
    if scheduler.has_param_rounds:
        if param_plan is not None:
            param_reducer = PlanExecutor(param_plan, tuple(axes))
        elif "sync" not in scheduler.computes:
            # pure param-round schedulers (local_sgd): a given sync/plan
            # describes the ROUND's exchange, not a per-step grad sync
            param_reducer, grad_reducer = grad_reducer, None
    return SyncStrategy(scheduler=scheduler, grad_reducer=grad_reducer,
                        param_reducer=param_reducer, param_algo=param_algo,
                        parallelism=parallelism,
                        shard_state=shard_state,
                        pipeline_stages=pipeline_stages,
                        micro_batches=micro_batches)
