# The paper's primary contribution — the communication-optimization taxonomy
# (survey §3 + §4) as a composable library.  See DESIGN.md §1 for the map.
from repro.core.grad_sync import (  # noqa: F401
    GradientSynchronizer, PlanExecutor, SyncConfig, bucketize,
    plan_from_config)
from repro.core.schedule.planner import BucketPlan, CommPlan  # noqa: F401
from repro.core.shard_state import (  # noqa: F401
    BucketShard, ShardLayout, chunk_rows, rows_to_flat)
from repro.core.local_sgd import (  # noqa: F401
    AsymmetricPushPullConfig, LocalSGDConfig, average_params,
    communication_rounds, should_sync)
from repro.core.lag import LAGConfig, init_lag_state, lag_trigger, lag_update_state  # noqa: F401
from repro.core.parallelism import ParallelismSpec  # noqa: F401
from repro.core.strategy import (  # noqa: F401
    EveryStepScheduler, LAGScheduler, LocalSGDScheduler, PushPullScheduler,
    RoundAction, RoundScheduler, SCHEDULERS, SyncStrategy, get_scheduler,
    make_strategy, register_scheduler)
