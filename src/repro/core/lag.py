"""LAG — Lazily Aggregated Gradients (survey §3.1.2; Chen et al. 2018).

Workers reuse the last synchronized gradient when their local gradient has
not changed enough to justify a communication round:

    skip if ||g_t - g_last||^2 <= threshold * ||g_t||^2

Adaptation (DESIGN.md §5): LAG's per-worker skip decision makes wire traffic
data-dependent, which a static SPMD program cannot express.  We therefore
hoist the decision to the host: a cheap jitted probe computes the global
trigger, and the trainer dispatches either the compiled ``sync`` step or the
compiled ``reuse`` step — two programs, which is also how one would deploy
LAG on a real TPU pod.  Communication complexity (rounds actually used) is
reported exactly as in the paper's linear-regression experiment
(5283 -> 1756 rounds), reproduced in ``benchmarks/bench_periodic.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LAGConfig:
    threshold: float = 0.1     # relative change that forces a sync
    check_every: int = 1


def init_lag_state(grads):
    return {"g_last": jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads),
            "rounds": jnp.zeros((), jnp.int32)}


@jax.jit
def lag_trigger(grads, g_last, threshold: float):
    """True -> the change is large, communicate this round."""
    def sq(t):
        return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(t))
    delta = sq(jax.tree.map(lambda a, b: a.astype(jnp.float32) - b, grads, g_last))
    scale = sq(grads)
    return delta > threshold * scale


def lag_update_state(state, grads, synced: bool):
    if synced:
        return {"g_last": jax.tree.map(lambda g: g.astype(jnp.float32), grads),
                "rounds": state["rounds"] + 1}
    return state
