"""Measured calibration: compression compute (DESIGN.md §11) and the
collective fabric itself (DESIGN.md §13).

The α-β cost model prices the wire from link parameters, but hand-written
``LINK_PRESETS`` are exactly the unvalidated constants Zhang et al. ("Is
Network the Bottleneck?") show diverging from measured collective behavior
at real message sizes.  This module closes the modeled↔measured loop twice:

  * :func:`measure_compression_costs` times each compressor's encode and
    decode on the backend actually running, fits ``seconds = n_bytes / bw
    + c0`` per stage, and hands the planner a
    :class:`~repro.core.schedule.cost.CompressionCostTable` — the measured
    COMPUTE term (PR 6).
  * :func:`calibrate_topology` times the actual collectives (per algorithm
    × payload size × tier axis, under ``shard_map`` on the real mesh, via
    the same ``collectives/api.py`` edges training executes) and fits
    per-tier ``LinkParams`` (α, β) WITH confidence bounds — the measured
    WIRE term.  The result, a :class:`CalibratedTopology`, drops into
    every ``net`` argument of ``cost.py`` (``as_topology`` unwraps it), so
    ``plan_auto(calibration=...)`` prices every arm on the fabric it will
    run on.

Timing policy (shared rationale with ``benchmarks/common.py``, see
DESIGN.md §13): calibration uses MIN-of-N per point — the minimum is the
best estimate of the uncontended cost that the α-β model defines, while
the median (used by throughput benches) tracks what a loaded machine
delivers.  Fits are least squares over ≥3 sizes; every fit records its
residual and confidence bounds so a noisy calibration is visible instead
of silently wrong (the old two-point ``_fit`` clamped noise to a
through-origin model with no signal).

Drift accounting: :func:`drift_fraction` (measured/modeled − 1) and
:func:`modeled_wall_step_s` define the modeled-vs-measured comparison the
plan records carry and ``--replan-drift-pct`` gates on.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule.cost import (CompressionCostTable, LinkParams)
from repro.core.schedule.topology import Tier, Topology

# (compressor, args) pairs calibrated by default — the compressed members
# of planner.DEFAULT_CANDIDATES (keys in the table are compressor NAMES:
# the cost model does not distinguish arg variants of one compressor).
CALIBRATION_SET: Tuple[Tuple[str, Tuple[Tuple[str, Any], ...]], ...] = (
    ("int8", ()),
    ("qsgd", (("levels", 127),)),
    ("topk", (("ratio", 0.01),)),
    ("sign", ()),
    ("int8_fused", ()),
    ("topk_fused", (("ratio", 0.01),)),
)

# Buffer sizes (f32 elements) the compression fit is anchored on: 1, 2 and
# 8 MiB dense — ≥3 sizes so the least-squares fit has a residual to report
# (the old two-point secant could not distinguish noise from signal).
CAL_SIZES: Tuple[int, ...] = (1 << 18, 1 << 19, 1 << 21)

CAL_WORLD = 8

# Payload sizes (f32 elements) the LINK fit is anchored on — spanning the
# α-dominated (16 KiB) through β-dominated (8 MiB) regimes so both
# coefficients are identified.
CAL_LINK_SIZES: Tuple[int, ...] = (1 << 12, 1 << 15, 1 << 18, 1 << 21)

# Algorithms timed per tier: psum (the XLA edge training actually runs)
# and the explicit ring share one phase formula, giving the joint fit
# algorithm diversity at no formula risk; tree is opt-in (power-of-two
# tiers only).
CAL_LINK_ALGOS: Tuple[str, ...] = ("psum", "ring")

CAL_LINK_REPEATS = 5


def _time_best_s(fn, *args, repeats: int = 3) -> float:
    """min-of-N wall time of an already-jitted ``fn`` (first call compiles
    and is discarded)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Least-squares fitting with confidence bounds
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AffineFit:
    """Least-squares ``t = intercept + slope·x`` with standard errors.

    ``slope_err``/``intercept_err`` are the 1-σ standard errors from the
    residual variance (``inf`` with <3 points: two points leave zero
    degrees of freedom, which is exactly the blindness the old two-point
    fit hid).  ``degenerate`` flags a non-increasing fit — timing noise
    swamping the size signal."""
    slope: float
    intercept: float
    slope_err: float
    intercept_err: float
    r2: float
    rms_s: float
    n: int
    degenerate: bool = False


def fit_affine(points: Sequence[Tuple[float, float]]) -> AffineFit:
    """Fit ``t = intercept + slope·x`` to ``(x, t)`` samples by least
    squares; see :class:`AffineFit` for what is reported."""
    pts = sorted((float(x), float(t)) for x, t in points)
    if len(pts) < 2:
        raise ValueError(f"need >= 2 points to fit a line, got {len(pts)}")
    x = np.asarray([p[0] for p in pts])
    t = np.asarray([p[1] for p in pts])
    X = np.stack([x, np.ones_like(x)], axis=1)
    coef, _, _, _ = np.linalg.lstsq(X, t, rcond=None)
    slope, intercept = float(coef[0]), float(coef[1])
    resid = t - X @ coef
    rss = float(resid @ resid)
    m = len(pts)
    tss = float(((t - t.mean()) ** 2).sum())
    r2 = 1.0 - rss / tss if tss > 0 else 1.0
    if m > 2:
        sigma2 = rss / (m - 2)
        try:
            cov = sigma2 * np.linalg.inv(X.T @ X)
            slope_err = math.sqrt(max(float(cov[0, 0]), 0.0))
            intercept_err = math.sqrt(max(float(cov[1, 1]), 0.0))
        except np.linalg.LinAlgError:
            slope_err = intercept_err = float("inf")
    else:
        slope_err = intercept_err = float("inf")
    return AffineFit(slope=slope, intercept=intercept, slope_err=slope_err,
                     intercept_err=intercept_err, r2=r2,
                     rms_s=math.sqrt(rss / m), n=m,
                     degenerate=slope <= 0.0)


def _fit(points: Sequence[Tuple[float, float]]
         ) -> Tuple[float, float, AffineFit]:
    """(bw_bytes_per_s, overhead_s, fit) from (n_bytes, seconds) samples:
    a least-squares affine fit over all sizes.  A non-increasing fit still
    degenerates to the through-origin secant (the planner needs SOME
    positive bandwidth), but now WARNS and flags the fit so the recorded
    table carries the degradation instead of silently reporting
    ``overhead_s = 0`` as measured."""
    fit = fit_affine(points)
    if fit.degenerate:
        b_max, t_max = max(points)
        warnings.warn(
            f"calibration fit degenerated: seconds non-increasing over "
            f"{fit.n} sizes (slope {fit.slope:.3e} s/B) — timing noise "
            f"swamps the size signal; clamping to a through-origin model",
            stacklevel=2)
        slope = max(t_max / b_max, 1e-15)
        return 1.0 / slope, 0.0, fit
    return 1.0 / fit.slope, max(fit.intercept, 0.0), fit


def measure_compression_costs(
        compressors: Sequence[Tuple[str, Tuple[Tuple[str, Any], ...]]]
        = CALIBRATION_SET,
        sizes: Sequence[int] = CAL_SIZES,
        cal_world: int = CAL_WORLD,
        repeats: int = 3,
        seed: int = 0) -> CompressionCostTable:
    """Time encode/decode per compressor at each size and fit the linear
    per-stage model.  Returns the table ``bucket_sync_phases`` consumes;
    each entry carries its fit quality (rms residual, R², degeneracy)."""
    from repro.core.compression import get_compressor

    entries = []
    quality = []
    for name, args in compressors:
        comp = get_compressor(name, **dict(args))
        enc_pts, dec_pts = [], []
        for i, n in enumerate(sizes):
            key = jax.random.PRNGKey(seed + i)
            g = jax.random.normal(key, (int(n),), dtype=jnp.float32)
            e = jnp.zeros_like(g)
            n_bytes = float(n) * 4.0

            if comp.fused_ef_compress is not None:
                enc = jax.jit(lambda g, e, c=comp:
                              c.fused_ef_compress(g, e, 1.0))
                payload, meta, _ = comp.fused_ef_compress(g, e, 1.0)
                enc_pts.append((n_bytes, _time_best_s(enc, g, e,
                                                      repeats=repeats)))
            else:
                enc = jax.jit(lambda g, c=comp: c.compress(g, None))
                payload, meta = comp.compress(g, None)
                enc_pts.append((n_bytes, _time_best_s(enc, g,
                                                      repeats=repeats)))

            if comp.fused_decode_sum is not None:
                gathered = jax.tree.map(
                    lambda a: jnp.stack([a] * int(cal_world)), payload)
                dec = jax.jit(lambda p, c=comp, m=meta:
                              c.fused_decode_sum(p, m))
                dec_pts.append((n_bytes, _time_best_s(dec, gathered,
                                                      repeats=repeats)))
            else:
                dec = jax.jit(lambda p, c=comp, m=meta: c.decompress(p, m))
                dec_pts.append((n_bytes, _time_best_s(dec, payload,
                                                      repeats=repeats)))
        for stage, pts in (("encode", enc_pts), ("decode", dec_pts)):
            bw, c0, fit = _fit(pts)
            entries.append((f"{name}/{stage}", bw, c0))
            quality.append((f"{name}/{stage}", fit.rms_s, fit.r2,
                            fit.degenerate))
    return CompressionCostTable(entries=tuple(entries),
                                cal_world=int(cal_world),
                                quality=tuple(quality))


def resolve_cost_table(spec) -> Optional[CompressionCostTable]:
    """Coerce a ``compression_costs`` argument — ``None``, an existing
    table, or a path to a recorded JSON — into a table."""
    if spec is None or isinstance(spec, CompressionCostTable):
        return spec
    return CompressionCostTable.load(spec)


# ---------------------------------------------------------------------------
# Collective calibration: fitted per-tier LinkParams (DESIGN.md §13)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinkFit:
    """Fitted (α, β) of ONE tier's fabric, with 1-σ confidence bounds and
    the fit residual.  ``degenerate`` marks fits with no wire signal: a
    1-rank tier (collectives are no-ops; the fit is raw dispatch
    overhead) or a negative coefficient clamped to zero."""
    alpha_s: float
    beta_s_per_byte: float
    alpha_err_s: float
    beta_err_s_per_byte: float
    r2: float
    rms_s: float
    n_samples: int
    degenerate: bool = False

    @property
    def link(self) -> LinkParams:
        return LinkParams(alpha_s=self.alpha_s,
                          beta_s_per_byte=self.beta_s_per_byte)

    def describe(self) -> str:
        bw = (1.0 / self.beta_s_per_byte / 1e9
              if self.beta_s_per_byte > 0 else float("inf"))
        return (f"α={self.alpha_s:.3e}±{self.alpha_err_s:.1e} s, "
                f"β⁻¹={bw:.2f} GB/s, rms={self.rms_s:.2e} s, "
                f"R²={self.r2:.3f}, n={self.n_samples}"
                + (" [degenerate]" if self.degenerate else ""))


def _phase_coeffs(algo: str, p: int, n_bytes: float
                  ) -> Optional[Tuple[float, float]]:
    """(∂t/∂α, ∂t/∂β) of one single-axis collective of ``n_bytes`` over
    ``p`` ranks — the design-matrix row linking a timed sample to the
    tier's (α, β).  Must mirror ``cost.allreduce_phases`` exactly: the
    fit is only as honest as the formula it inverts."""
    if p <= 1:
        return None
    if algo in ("ring", "psum"):
        return 2.0 * (p - 1), 2.0 * (p - 1) * n_bytes / p
    if algo == "tree":
        if p & (p - 1):
            return None          # tree needs a power-of-two axis
        return 2.0 * math.log2(p), 2.0 * math.log2(p) * n_bytes
    return None


def _fit_link(rows: Sequence[Tuple[float, float, float]]) -> LinkFit:
    """Joint least squares ``t = a·α + b·β`` over ``(a, b, t)`` rows from
    :func:`_phase_coeffs` — one fit per tier, pooling every (algo × size)
    sample.  Negative coefficients (noise) are clamped to 0 and flagged."""
    A = np.asarray([[r[0], r[1]] for r in rows])
    t = np.asarray([r[2] for r in rows])
    coef, _, _, _ = np.linalg.lstsq(A, t, rcond=None)
    alpha, beta = float(coef[0]), float(coef[1])
    resid = t - A @ coef
    rss = float(resid @ resid)
    m = len(rows)
    tss = float(((t - t.mean()) ** 2).sum())
    r2 = 1.0 - rss / tss if tss > 0 else 1.0
    if m > 2:
        sigma2 = rss / (m - 2)
        try:
            cov = sigma2 * np.linalg.inv(A.T @ A)
            a_err = math.sqrt(max(float(cov[0, 0]), 0.0))
            b_err = math.sqrt(max(float(cov[1, 1]), 0.0))
        except np.linalg.LinAlgError:
            a_err = b_err = float("inf")
    else:
        a_err = b_err = float("inf")
    degenerate = alpha < 0.0 or beta < 0.0
    if degenerate:
        warnings.warn(
            f"link fit degenerated (α={alpha:.3e}, β={beta:.3e}); "
            f"clamping negative coefficients to 0 — the measured fabric "
            f"is faster than the timing floor resolves", stacklevel=2)
    return LinkFit(alpha_s=max(alpha, 0.0),
                   beta_s_per_byte=max(beta, 0.0),
                   alpha_err_s=a_err, beta_err_s_per_byte=b_err,
                   r2=r2, rms_s=math.sqrt(rss / m), n_samples=m,
                   degenerate=degenerate)


def _fit_degenerate_tier(samples: Sequence[Tuple[float, float]]) -> LinkFit:
    """A 1-rank tier: the collective is a no-op, so the timings are pure
    dispatch overhead.  Fit ``t = α + n·β`` directly and flag it — the
    resulting near-zero link is the honest price of communication on a
    fabric with one member."""
    fit = fit_affine(samples)
    return LinkFit(alpha_s=max(fit.intercept, 0.0),
                   beta_s_per_byte=max(fit.slope, 0.0),
                   alpha_err_s=fit.intercept_err,
                   beta_err_s_per_byte=fit.slope_err,
                   r2=fit.r2, rms_s=fit.rms_s, n_samples=fit.n,
                   degenerate=True)


@dataclasses.dataclass(frozen=True)
class CalibratedTopology:
    """A :class:`Topology` whose links are FITTED from measured
    collectives, with per-tier fit residuals and confidence bounds.

    ``topology`` carries the fitted :class:`LinkParams` (each tier's
    ``link_name`` is ``"calibrated"`` and its ``fit`` field holds the
    :class:`LinkFit`), so it drops into every ``net`` argument of the
    cost model — ``as_topology`` unwraps this wrapper too, making a
    ``CalibratedTopology`` itself a valid ``net``.  ``samples`` keeps the
    raw ``(tier, algo, p, n_bytes, seconds)`` timings for offline refits
    (the deterministic CI calibration suite replays exactly such records).
    """
    topology: Topology
    fits: Tuple[Tuple[str, LinkFit], ...]      # (tier_name, fit), outer first
    samples: Tuple[Tuple[str, str, int, float, float], ...] = ()

    @property
    def world(self) -> int:
        return self.topology.world

    def fit_for(self, tier_name: str) -> Optional[LinkFit]:
        for name, fit in self.fits:
            if name == tier_name:
                return fit
        return None

    def describe(self) -> str:
        lines = [f"calibrated topology: {self.topology.spec()} "
                 f"({len(self.samples)} timed collectives)"]
        for name, fit in self.fits:
            lines.append(f"  {name}: {fit.describe()}")
        return "\n".join(lines)

    def allreduce_error_s(self, n_bytes: float, p: int) -> float:
        """1-σ propagated fit error of one ring allreduce of ``n_bytes``
        over ``p`` ranks, priced like the cost model prices it: the ring
        formula on the bottleneck tier, with that tier's coefficient
        errors in place of its coefficients."""
        if p <= 1:
            return 0.0
        t = self.topology.bottleneck(n_bytes / p)
        fit = self.fit_for(t.name)
        if fit is None or not math.isfinite(fit.alpha_err_s):
            return 0.0
        return 2.0 * (p - 1) * (fit.alpha_err_s
                                + (n_bytes / p) * fit.beta_err_s_per_byte)

    # -- persistence ---------------------------------------------------------

    def to_json(self) -> Dict[str, Any]:
        return {
            "version": 1,
            "world": self.world,
            "tiers": [{
                "name": t.name, "size": t.size,
                "alpha_s": f.alpha_s,
                "beta_s_per_byte": f.beta_s_per_byte,
                "alpha_err_s": f.alpha_err_s,
                "beta_err_s_per_byte": f.beta_err_s_per_byte,
                "r2": f.r2, "rms_s": f.rms_s,
                "n_samples": f.n_samples, "degenerate": f.degenerate,
            } for t, (_, f) in zip(self.topology.tiers, self.fits)],
            "samples": [{"tier": tn, "algo": al, "p": p,
                         "n_bytes": nb, "seconds": s}
                        for tn, al, p, nb, s in self.samples],
        }

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "CalibratedTopology":
        tiers, fits = [], []
        for e in obj["tiers"]:
            fit = LinkFit(
                alpha_s=float(e["alpha_s"]),
                beta_s_per_byte=float(e["beta_s_per_byte"]),
                alpha_err_s=float(e["alpha_err_s"]),
                beta_err_s_per_byte=float(e["beta_err_s_per_byte"]),
                r2=float(e["r2"]), rms_s=float(e["rms_s"]),
                n_samples=int(e["n_samples"]),
                degenerate=bool(e["degenerate"]))
            tiers.append(Tier(e["name"], int(e["size"]), fit.link,
                              link_name="calibrated", fit=fit))
            fits.append((e["name"], fit))
        samples = tuple((s["tier"], s["algo"], int(s["p"]),
                         float(s["n_bytes"]), float(s["seconds"]))
                        for s in obj.get("samples", []))
        return cls(topology=Topology(tuple(tiers)), fits=tuple(fits),
                   samples=samples)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "CalibratedTopology":
        with open(path) as f:
            return cls.from_json(json.load(f))


def _collective_timer(mesh, repeats: int) -> Callable[..., float]:
    """The default ``timer``: min-of-N wall time of one jitted
    ``shard_map`` allreduce over ONE mesh axis — the exact edge
    ``collectives.api.allreduce`` dispatches during training, replicated
    input so every rank holds the full payload."""
    from jax.sharding import PartitionSpec as P

    from repro.core.collectives.api import allreduce

    def timer(algo: str, axis: str, p: int, n_bytes: float) -> float:
        n_elems = max(int(n_bytes // 4), 1)
        x = jnp.arange(n_elems, dtype=jnp.float32)
        fn = jax.jit(jax.shard_map(
            lambda v: allreduce(v, algo, (axis,)), mesh=mesh,
            in_specs=P(), out_specs=P(), axis_names={axis},
            check_vma=False))
        return _time_best_s(fn, x, repeats=repeats)

    return timer


def calibrate_topology(topology: Optional[Topology] = None, *,
                       mesh=None,
                       sizes: Sequence[int] = CAL_LINK_SIZES,
                       algos: Sequence[str] = CAL_LINK_ALGOS,
                       repeats: int = CAL_LINK_REPEATS,
                       timer: Optional[Callable[..., float]] = None
                       ) -> CalibratedTopology:
    """Time real collectives per (tier axis × algorithm × payload size)
    and fit per-tier (α, β) by joint least squares over the phase
    formulas of ``cost.allreduce_phases`` (DESIGN.md §13).

    ``topology`` names the tiers to calibrate (default: the flat
    single-tier fabric over every local device, axis ``"data"``).  With
    the default timer the topology's world must equal the local device
    count — calibration measures the fabric it runs on, not a model of
    another one.  ``timer(algo, axis, p, n_bytes) -> seconds`` injects a
    fake fabric for tests and for replaying recorded samples (the
    deterministic CI suite); injected timers skip mesh construction, so
    any topology can be refitted offline.
    """
    if topology is None:
        topology = Topology.flat(len(jax.devices()), LinkParams(),
                                 name="data")
    if timer is None:
        n_dev = len(jax.devices())
        if topology.world != n_dev:
            raise ValueError(
                f"cannot calibrate {topology.spec()} (world "
                f"{topology.world}) on {n_dev} local device(s): "
                f"calibration times the fabric it runs on — pass a "
                f"topology matching the host, or inject a timer")
        if mesh is None:
            from repro.launch.mesh import make_topology_mesh
            mesh = make_topology_mesh(topology)
        timer = _collective_timer(mesh, repeats)

    fits: List[Tuple[str, LinkFit]] = []
    tiers: List[Tier] = []
    samples: List[Tuple[str, str, int, float, float]] = []
    for tier in topology.tiers:
        p = int(tier.size)
        rows: List[Tuple[float, float, float]] = []
        raw: List[Tuple[float, float]] = []
        for algo in algos:
            for n in sizes:
                n_bytes = float(int(n) * 4)
                coeffs = _phase_coeffs(algo, p, n_bytes)
                if p > 1 and coeffs is None:
                    continue          # algo unusable on this axis (tree)
                t = float(timer(algo, tier.name, p, n_bytes))
                samples.append((tier.name, algo, p, n_bytes, t))
                raw.append((n_bytes, t))
                if coeffs is not None:
                    rows.append((coeffs[0], coeffs[1], t))
        fit = _fit_link(rows) if rows else _fit_degenerate_tier(raw)
        fits.append((tier.name, fit))
        tiers.append(Tier(tier.name, p, fit.link, link_name="calibrated",
                          fit=fit))
    return CalibratedTopology(topology=Topology(tuple(tiers)),
                              fits=tuple(fits), samples=tuple(samples))


def resolve_calibration(spec) -> Optional[CalibratedTopology]:
    """Coerce a ``calibration`` argument — ``None``, an existing
    :class:`CalibratedTopology`, or a path to a saved one — into the
    object ``plan_auto`` consumes."""
    if spec is None or isinstance(spec, CalibratedTopology):
        return spec
    return CalibratedTopology.load(spec)


# ---------------------------------------------------------------------------
# Modeled-vs-measured drift (plan records, --replan-drift-pct)
# ---------------------------------------------------------------------------

def drift_fraction(modeled_s: float, measured_s: float) -> float:
    """measured/modeled − 1: +0.25 means the measured step ran 25% slower
    than the model predicted.  The drift-report quantity and the
    re-planning trigger."""
    if not modeled_s > 0.0:
        raise ValueError(f"modeled time must be > 0, got {modeled_s}")
    return measured_s / modeled_s - 1.0


def modeled_wall_step_s(modeled_step_s: float, t_backward_s: float) -> float:
    """The plan's prediction of one WALL-CLOCK step.  ``modeled_step_s``
    prices the backward+sync window only (the overlap objective); the
    forward pass runs outside it and costs half the backward under the
    standard bwd = 2·fwd ratio ``profile_backward`` assumes — so the
    wall-step prediction adds ``t_backward_s / 2``.  Optimizer update and
    host dispatch stay unmodeled; they land in the drift number, which is
    the point of reporting it."""
    return float(modeled_step_s) + 0.5 * float(t_backward_s)


def plan_comm_error_s(plan, calibration: Optional[CalibratedTopology]
                      ) -> float:
    """1-σ propagated link-fit error of a ``CommPlan``'s wire time: the
    per-bucket ring-formula error (``allreduce_error_s``) summed over
    buckets.  0 without a calibration (preset links carry no error
    model)."""
    if calibration is None:
        return 0.0
    return sum(calibration.allreduce_error_s(b.bucket_bytes, plan.world)
               for b in plan.buckets)
