"""Measured compression-compute calibration (DESIGN.md §11).

The α-β cost model prices the wire from link parameters, but until now the
compress/decompress COMPUTE term was a fixed analytic constant
(``cost.COMPRESS_PROC_BW`` × a pass count).  This module measures it: time
each compressor's encode and decode on the backend actually running, fit
``seconds = n_bytes / bw + c0`` per stage, and hand the planner a
:class:`~repro.core.schedule.cost.CompressionCostTable` — the first
MEASURED input into ``plan_auto``.  ``benchmarks/bench_collectives.py
--write-compression-costs PATH`` records the table;
``launch/train.py --compression-costs PATH`` (or
``plan_auto(compression_costs=...)``) feeds it back.

Encode times the fused one-pass hook when the compressor has one (that is
the op the executor actually runs), else the decomposed ``compress``.
Decode times ``fused_decode_sum`` over ``cal_world`` stacked payloads for
gather-pattern wires (matching how ``cost._compute_cost_s`` rescales the
fit to the plan's world), else a single-payload ``decompress``.
"""
from __future__ import annotations

import time
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.schedule.cost import CompressionCostTable

# (compressor, args) pairs calibrated by default — the compressed members
# of planner.DEFAULT_CANDIDATES (keys in the table are compressor NAMES:
# the cost model does not distinguish arg variants of one compressor).
CALIBRATION_SET: Tuple[Tuple[str, Tuple[Tuple[str, Any], ...]], ...] = (
    ("int8", ()),
    ("qsgd", (("levels", 127),)),
    ("topk", (("ratio", 0.01),)),
    ("sign", ()),
    ("int8_fused", ()),
    ("topk_fused", (("ratio", 0.01),)),
)

# Buffer sizes (f32 elements) the linear fit is anchored on: 1 MiB and
# 8 MiB dense — inside the bucket range the planner actually prices.
CAL_SIZES: Tuple[int, ...] = (1 << 18, 1 << 21)

CAL_WORLD = 8


def _time_best_s(fn, *args, repeats: int = 3) -> float:
    """min-of-N wall time of an already-jitted ``fn`` (first call compiles
    and is discarded)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _fit(points: Sequence[Tuple[float, float]]) -> Tuple[float, float]:
    """(bw_bytes_per_s, overhead_s) from (n_bytes, seconds) samples: the
    two-point secant, clamped to a through-origin model when timing noise
    makes the secant non-increasing."""
    pts = sorted(points)
    (b1, t1), (b2, t2) = pts[0], pts[-1]
    slope = (t2 - t1) / (b2 - b1) if b2 > b1 else 0.0
    if slope <= 0.0:
        slope = t2 / b2
        return 1.0 / max(slope, 1e-15), 0.0
    return 1.0 / slope, max(t1 - b1 * slope, 0.0)


def measure_compression_costs(
        compressors: Sequence[Tuple[str, Tuple[Tuple[str, Any], ...]]]
        = CALIBRATION_SET,
        sizes: Sequence[int] = CAL_SIZES,
        cal_world: int = CAL_WORLD,
        repeats: int = 3,
        seed: int = 0) -> CompressionCostTable:
    """Time encode/decode per compressor at each size and fit the linear
    per-stage model.  Returns the table ``bucket_sync_phases`` consumes."""
    from repro.core.compression import get_compressor

    entries = []
    for name, args in compressors:
        comp = get_compressor(name, **dict(args))
        enc_pts, dec_pts = [], []
        for i, n in enumerate(sizes):
            key = jax.random.PRNGKey(seed + i)
            g = jax.random.normal(key, (int(n),), dtype=jnp.float32)
            e = jnp.zeros_like(g)
            n_bytes = float(n) * 4.0

            if comp.fused_ef_compress is not None:
                enc = jax.jit(lambda g, e, c=comp:
                              c.fused_ef_compress(g, e, 1.0))
                payload, meta, _ = comp.fused_ef_compress(g, e, 1.0)
                enc_pts.append((n_bytes, _time_best_s(enc, g, e,
                                                      repeats=repeats)))
            else:
                enc = jax.jit(lambda g, c=comp: c.compress(g, None))
                payload, meta = comp.compress(g, None)
                enc_pts.append((n_bytes, _time_best_s(enc, g,
                                                      repeats=repeats)))

            if comp.fused_decode_sum is not None:
                gathered = jax.tree.map(
                    lambda a: jnp.stack([a] * int(cal_world)), payload)
                dec = jax.jit(lambda p, c=comp, m=meta:
                              c.fused_decode_sum(p, m))
                dec_pts.append((n_bytes, _time_best_s(dec, gathered,
                                                      repeats=repeats)))
            else:
                dec = jax.jit(lambda p, c=comp, m=meta: c.decompress(p, m))
                dec_pts.append((n_bytes, _time_best_s(dec, payload,
                                                      repeats=repeats)))
        bw, c0 = _fit(enc_pts)
        entries.append((f"{name}/encode", bw, c0))
        bw, c0 = _fit(dec_pts)
        entries.append((f"{name}/decode", bw, c0))
    return CompressionCostTable(entries=tuple(entries),
                                cal_world=int(cal_world))


def resolve_cost_table(spec) -> Optional[CompressionCostTable]:
    """Coerce a ``compression_costs`` argument — ``None``, an existing
    table, or a path to a recorded JSON — into a table."""
    if spec is None or isinstance(spec, CompressionCostTable):
        return spec
    return CompressionCostTable.load(spec)
