"""α-β (latency-bandwidth) communication cost model (survey §4.1/§4.3).

This module is the single home of the analytic cost model: the survey's
Fig. 10/12 comparisons and the §4.3 protocol study are parameter sweeps over
it, and the communication planner (``schedule/planner.py``) uses it as the
objective when choosing a per-bucket sync strategy.  It used to live inside
``collectives/api.py``; the dispatch module re-exports it for compatibility.

Every cost function takes a *network* argument ``net`` that is either a
bare :class:`LinkParams` (one flat link — the historical model) or a
:class:`~repro.core.schedule.topology.Topology` (ordered tiers, outermost
first).  Each algorithm phase is priced on the tier it actually traverses
(DESIGN.md §10):

  * ring / psum / gather — lockstep flat traversals: every synchronous
    step is gated by the slowest link the embedded ring crosses, i.e. the
    topology's bottleneck tier (Zhang et al. 2020);
  * tree — log2(size) doubling rounds per tier, full payload each;
  * hierarchical — inner ring on the innermost (fast) tier, the shard
    ring on the outermost (slow) tier (Jia et al. 2018);
  * mesh2d — one ring phase per perpendicular axis: the first on the
    inner tier, the second on the outer (Ying et al. 2018);
  * p2p — the tier the pipe axis lands on (outermost by default).

On ``Topology.flat`` (or a bare ``LinkParams``) every formula reduces to
the pre-topology expression BIT-FOR-BIT — ``tests/test_topology.py`` pins
this, and it is what keeps the committed ``benchmarks/baselines/*.json``
green.

Message libraries and protocols (§4.2/§4.3) appear only through their α
(per-message latency) and β (inverse bandwidth) parameters — on TPU the
"protocol" layer is ICI and lives below XLA (DESIGN.md §5).

Costs for *compressed* exchanges are priced at the survey's wire metric —
``Compressor.payload_bits`` — i.e. the bytes an ideal message library would
move.  See DESIGN.md §5 for how the reference executor realises each wire
pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.schedule.topology import Tier, Topology, as_topology


@dataclasses.dataclass(frozen=True)
class LinkParams:
    alpha_s: float = 1e-6       # per-message latency (s)
    beta_s_per_byte: float = 1.0 / 50e9   # inverse link bandwidth (s/B)


# Canonical network regimes (survey Fig. 8/10/12 sweeps).  Benchmarks and the
# planner share these so the "fast_ici" of one table is the "fast_ici" of
# another — previously each bench re-typed its own (α, β) literals.
LINK_PRESETS: Dict[str, LinkParams] = {
    "fast_ici": LinkParams(alpha_s=1e-6, beta_s_per_byte=1 / 50e9),
    "datacenter": LinkParams(alpha_s=5e-6, beta_s_per_byte=1 / 10e9),
    "commodity": LinkParams(alpha_s=50e-6, beta_s_per_byte=1 / 1.25e9),
}

Net = Union[LinkParams, Topology]


def allreduce_phases(algo: str, n_bytes: float, p: int, net: Net,
                     k: Optional[int] = None) -> List[Tuple[str, float]]:
    """Per-phase costs of one allreduce: ``[(tier_name, seconds), ...]``,
    each phase on the tier it traverses.  The totals below are the
    left-fold sum of these phases, so breakdown and total always agree."""
    if p <= 1:
        return []
    topo = as_topology(net, p)
    if algo == "ring" or algo == "psum":
        t = topo.bottleneck(n_bytes / p)
        a, b = t.link.alpha_s, t.link.beta_s_per_byte
        return [(t.name, 2 * (p - 1) * (a + (n_bytes / p) * b))]
    if algo == "tree":
        return [(t.name, 2 * np.log2(t.size)
                 * (t.link.alpha_s + n_bytes * t.link.beta_s_per_byte))
                for t in topo.tiers if t.size > 1]
    if algo == "hierarchical":
        inner_t = topo.innermost
        # k defaults to the innermost tier (the executed inner ring runs
        # on exactly that axis); an explicit k is a flat-network knob
        k = k or (int(np.sqrt(p)) if topo.is_flat else inner_t.size)
        ai, bi = inner_t.link.alpha_s, inner_t.link.beta_s_per_byte
        phases = [(inner_t.name, 2 * (k - 1) * (ai + (n_bytes / k) * bi))]
        if topo.is_flat:
            ao, bo = ai, bi
            phases.append((inner_t.name, 2 * (p // k - 1)
                           * (ao + (n_bytes / k / (p // k)) * bo)))
        else:
            # the n/k shard rings over EVERY outer tier in turn (matching
            # hierarchical_allreduce's outer loop), innermost outer first
            # — pricing only the outermost would hide middle tiers
            for t in reversed(topo.tiers[:-1]):
                at, bt = t.link.alpha_s, t.link.beta_s_per_byte
                phases.append((t.name, 2 * (t.size - 1)
                               * (at + (n_bytes / k / t.size) * bt)))
        phases.append((inner_t.name, 2 * (k - 1) * ai))  # broadcast latency
        return phases
    if algo in ("mesh2d", "mesh2d_split"):
        if topo.n_tiers > 2:
            # mesh2d is 2-D by construction (execution raises too); the
            # planner filters these candidates out (_algo_usable)
            raise ValueError(f"mesh2d is a two-axis collective; topology "
                             f"{topo.spec()} has {topo.n_tiers} tiers")
        inner_t, outer_t = topo.innermost, topo.outermost
        px = int(np.sqrt(p)) if topo.is_flat else topo.inner_size
        py = p // px
        ai, bi = inner_t.link.alpha_s, inner_t.link.beta_s_per_byte
        ao, bo = outer_t.link.alpha_s, outer_t.link.beta_s_per_byte
        div = 2 if algo == "mesh2d_split" else 1
        return [(inner_t.name,
                 2 * (px - 1) * (ai + (n_bytes / px) * bi) / div),
                (outer_t.name,
                 2 * (py - 1) * (ao + (n_bytes / px / py) * bo) / div)]
    raise ValueError(algo)


def allreduce_cost_s(algo: str, n_bytes: float, p: int, net: Net,
                     k: Optional[int] = None) -> float:
    """Predicted wall time of one allreduce of n_bytes over p ranks.

    ring:          2(p-1) steps of n/p bytes (on the bottleneck tier)
    tree (PS):     2 log2(size) steps of n bytes per tier
    hierarchical:  intra ring over k on the inner tier + inter ring over
                   p/k on n/k shards on the outer tier
                   (Jia et al.: 4(k-1) + 2(p/k - 1) steps)
    mesh2d:        two perpendicular ring phases (inner axis on the inner
                   tier, outer axis on the outer tier)
    """
    return sum((c for _, c in allreduce_phases(algo, n_bytes, p, net, k)),
               0.0)


def reduce_scatter_cost_s(algo: str, n_bytes: float, p: int,
                          net: Net) -> float:
    """One reduce-scatter of ``n_bytes`` (each rank keeps 1/p): (p-1)
    steps of n/p — the bandwidth-optimal (p-1)/p·n edge that ZeRO-style
    sharded DP pays instead of the allreduce's 2(p-1)/p·n.

    Priced as the RING reduce half for EVERY algo, because that is what
    ``collectives.reduce_scatter`` executes: explicit algos run the ring
    (nested per axis), and the psum algo delegates to XLA, whose
    reduce-scatter is ring-equivalent.  Pricing the named algo's allreduce
    half instead would let the planner pick e.g. a latency-optimal tree
    bucket whose sharded execution is actually a (p-1)-hop ring — the
    modeled/executed gap the conformance work exists to prevent."""
    del algo
    return allreduce_cost_s("ring", n_bytes, p, net) / 2.0


def shard_gather_cost_s(algo: str, n_bytes: float, p: int,
                        net: Net) -> float:
    """All-gather of partitioned state totalling ``n_bytes`` (each rank
    contributes n/p) — the forward-edge params gather of sharded DP.
    Ring-priced for every algo, mirroring :func:`reduce_scatter_cost_s`
    (the executed gather is a ring / XLA's ring-equivalent)."""
    del algo
    return allreduce_cost_s("ring", n_bytes, p, net) / 2.0


def p2p_cost_s(n_bytes: float, net: Net,
               tier: Optional[Union[int, str]] = None) -> float:
    """One point-to-point transfer of ``n_bytes`` (α + nβ) — the pipeline
    boundary edge: one micro-batch of activations (forward) or
    grad-activations (backward) crossing one stage cut (DESIGN.md §9).
    On a tiered network the edge is priced on the tier the ``pipe`` axis
    lands on — ``tier`` by index or name, defaulting to the OUTERMOST
    (pipeline across nodes, the placement that keeps the dense gradient
    ring on the fast tier)."""
    if isinstance(net, Topology):
        t = net.outermost
        if tier is not None:
            if isinstance(tier, str):
                match = [x for x in net.tiers if x.name == tier]
                if not match:
                    raise ValueError(f"no tier named {tier!r} in "
                                     f"{net.spec()}")
                t = match[0]
            else:
                t = net.tiers[tier]
        link = t.link
    else:
        link = net
    return link.alpha_s + n_bytes * link.beta_s_per_byte


def allgather_cost_s(n_bytes: float, p: int, net: Net) -> float:
    """Ring all-gather where every rank contributes ``n_bytes``: (p-1) steps
    each moving one rank's payload (the gather-based compressor wire
    pattern of 1-bit SGD / DGC, DESIGN.md §5) — a lockstep flat traversal,
    gated by the bottleneck tier like the ring."""
    if p <= 1:
        return 0.0
    link = as_topology(net, p).bottleneck(n_bytes).link
    return (p - 1) * (link.alpha_s + n_bytes * link.beta_s_per_byte)


def straggler_penalty_s(skew_s: float, rounds_per_step: float = 1.0) -> float:
    """Per-step cost of a straggling worker under a given round cadence
    (survey §3.1.2 — the stale-synchronous motivation): a lockstep
    collective waits for its slowest member, so every ROUND that actually
    runs pays the measured worst-vs-median step-time skew ``skew_s``.  A
    schedule running ``rounds_per_step`` global rounds per step therefore
    pays ``skew_s · rounds_per_step``: every-step BSP eats the full skew
    each step, a local-SGD τ arm amortizes it τ× — which is exactly the
    cadence-demotion lever the elastic runtime's backpressure exercises
    (``plan_rounds(..., straggler_s=)`` adds this term to every arm, so a
    persistent straggler can flip the planner's winner; DESIGN.md §15).
    Zero skew prices to exactly 0.0, keeping straggler-free plans
    bit-identical to the committed baselines."""
    if skew_s <= 0.0:
        return 0.0
    return float(skew_s) * max(float(rounds_per_step), 0.0)


def _resolve_tier(topo: Topology, tier: Optional[Union[int, str]],
                  m_bytes: float) -> Tier:
    """Tier selection shared by the placed-axis cost functions: by index
    or name when the caller placed the axis, else the bottleneck tier of
    a flat traversal moving ``m_bytes`` per step."""
    if tier is None:
        return topo.bottleneck(m_bytes)
    if isinstance(tier, str):
        match = [t for t in topo.tiers if t.name == tier]
        if not match:
            raise ValueError(f"no tier named {tier!r} in {topo.spec()}")
        return match[0]
    return topo.tiers[tier]


def all_to_all_cost_s(n_bytes: float, p: int, net: Net,
                      variant: str = "direct",
                      tier: Optional[Union[int, str]] = None) -> float:
    """One all-to-all where every rank holds ``n_bytes`` total and sends
    an equal ``n_bytes/p`` chunk to each peer — the expert dispatch /
    combine edge of MoE expert parallelism (survey §4; DESIGN.md §14).

      * ``direct`` — all pairs exchange concurrently (XLA's fused
        all-to-all): one launch latency, but a rank's NIC still serialises
        its (p-1) outgoing chunks: α + (p-1)·(n/p)·β.
      * ``ring`` — (p-1) ppermute rotations of one chunk each (what
        ``collectives.api.all_to_all(variant="ring")`` executes):
        (p-1)·(α + (n/p)·β) — the same bytes, (p-2) extra message
        latencies, so direct ≤ ring always and the gap is α-dominated
        (the planner's variant choice is a latency/topology call, not a
        bandwidth one).

    On a tiered network the edge is priced on the tier the ``ep`` axis
    was placed on (``tier`` by index or name — ``Topology.place``
    semantics), defaulting to the bottleneck tier of a flat traversal."""
    if p <= 1:
        return 0.0
    if variant not in ("direct", "ring"):
        raise ValueError(f"unknown all_to_all variant {variant!r}; "
                         f"known: ('direct', 'ring')")
    # like p2p_cost_s, the net here may be a FULL topology whose world
    # exceeds p (the ep axis is a placed sub-group of it) — resolve the
    # tier directly instead of as_topology's world check
    inner = getattr(net, "topology", None)
    if isinstance(inner, Topology):
        net = inner
    topo = net if isinstance(net, Topology) else Topology.flat(p, net)
    t = _resolve_tier(topo, tier, n_bytes / p)
    a, b = t.link.alpha_s, t.link.beta_s_per_byte
    chunk = n_bytes / p
    if variant == "ring":
        return (p - 1) * (a + chunk * b)
    return a + (p - 1) * chunk * b


# Effective HBM bandwidth for weight-streaming decode (B/s).  Incremental
# decode is memory-bound: every step reads the full (TP-sharded) parameter
# set once, so compute time is param_bytes / bandwidth, not a FLOP count.
DECODE_HBM_BW = 800e9


def decode_step_cost_s(param_bytes: float, n_layers: int, d_model: int,
                       batch: int, tp: int, net: Net, *,
                       act_bytes: int = 2,
                       hbm_bw: float = DECODE_HBM_BW) -> float:
    """Predicted wall time of ONE batched decode step under ``tp``-way
    tensor parallelism (DESIGN.md §12).

    Compute is weight streaming — each rank reads its ``param_bytes/tp``
    shard once per token — and communication is the Megatron pattern: two
    allreduces per layer of the ``(batch, d_model)`` activations, priced
    by :func:`allreduce_cost_s` on whatever tier ``net`` places the TP
    group on.  Tiny payloads make this α-dominated, which is why the
    serving planner pins TP groups to the fastest tier."""
    if tp < 1:
        raise ValueError("tp must be >= 1")
    step = param_bytes / (tp * hbm_bw)
    if tp > 1:
        act = float(batch) * d_model * act_bytes
        step += 2 * n_layers * allreduce_cost_s("ring", act, tp, net)
    return step


def compressed_wire_bytes(compressor: str, compressor_args: Tuple[Tuple[str, Any], ...],
                          n_elems: int) -> float:
    """Per-rank wire bytes for one fused bucket of ``n_elems`` f32 values
    under ``compressor`` — ``payload_bits`` / 8, the survey's metric."""
    from repro.core.compression import get_compressor
    comp = get_compressor(compressor, **dict(compressor_args))
    return comp.payload_bits((int(n_elems),)) / 8.0


# Effective processing bandwidth of the compress/decompress kernels (B/s of
# dense input).  Compression is NOT free: quantize/top-k are memory-bound
# passes over the bucket, and pricing them is what makes the planner prefer
# dense exchanges on fast links (where the α-β savings cannot pay for the
# extra passes) and compression on slow ones — the survey's Fig. 7/8 story.
COMPRESS_PROC_BW = 30e9

# Phase label for compress/decompress time in per-tier breakdowns: it is
# device compute, not wire time on any tier.
COMPUTE_PHASE = "compute"

# Per-tile f32 scale overhead of the fused int8 wire (DESIGN.md §11) —
# shared with the ring_fused hop pricing below.
FUSED_TILE = 8 * 128


@dataclasses.dataclass(frozen=True)
class CompressionCostTable:
    """MEASURED compression-compute costs — the first measured input into
    the planner (Zhang et al. 2020: modeled α-β costs diverge from
    measurement exactly where per-step compute overheads dominate).

    ``entries`` holds linear fits ``seconds = n_bytes / bw + c0`` keyed
    ``"{compressor}/{encode|decode}"`` against the DENSE bucket bytes:

      * ``encode`` — the full send-side pass (EF add + compress + residual
        update for EF wires);
      * ``decode`` — the full receive-side pass at the calibration world
        size (``calibration.CAL_WORLD``): one decompress for aggregatable
        wires, the decompress+accumulate over all gathered payloads for
        gather-pattern wires (scaled linearly in p when priced at other
        world sizes).

    Produced by ``schedule.calibration.measure_compression_costs`` (and
    recorded by ``benchmarks/bench_collectives.py``); consumed by
    :func:`bucket_sync_phases` via the ``cost_table`` argument, replacing
    the hand-waved ``COMPRESS_PROC_BW`` term for compressors it covers.

    ``quality`` carries per-key fit diagnostics — ``(key, rms_s, r2,
    degenerate)`` — so a table whose fits degenerated under timing noise
    says so (DESIGN.md §13); it rides separately from ``entries`` to keep
    every existing 3-tuple consumer intact.  Recorded files are VERSIONED
    (``SCHEMA_VERSION``): v2+ files require ``cal_world`` — the
    gather-decode rescale in :func:`_compute_cost_s` is wrong at any
    other world, so a stale hand-edited file must fail loudly; legacy
    unversioned files warn and keep the historical default.
    """
    SCHEMA_VERSION = 2

    entries: Tuple[Tuple[str, float, float], ...] = ()
    cal_world: int = 8
    quality: Tuple[Tuple[str, float, float, bool], ...] = ()

    def stage_s(self, compressor: str, stage: str,
                n_bytes: float) -> Optional[float]:
        key = f"{compressor}/{stage}"
        for k, bw, c0 in self.entries:
            if k == key:
                return float(n_bytes) / bw + c0
        return None

    def fit_quality(self, key: str) -> Optional[Tuple[float, float, bool]]:
        """(rms_s, r2, degenerate) of the fit behind ``key``, if the
        table recorded it."""
        for k, rms, r2, deg in self.quality:
            if k == key:
                return rms, r2, deg
        return None

    def to_json(self) -> Dict[str, Any]:
        q = {k: (rms, r2, deg) for k, rms, r2, deg in self.quality}
        entries = []
        for k, bw, c0 in self.entries:
            e: Dict[str, Any] = {"key": k, "bw_bytes_per_s": bw,
                                 "overhead_s": c0}
            if k in q:
                rms, r2, deg = q[k]
                e.update(fit_rms_s=rms, fit_r2=r2, fit_degenerate=deg)
            entries.append(e)
        return {"version": self.SCHEMA_VERSION,
                "cal_world": self.cal_world, "entries": entries}

    @classmethod
    def from_json(cls, obj: Dict[str, Any]) -> "CompressionCostTable":
        import warnings
        version = int(obj.get("version", 1))
        if version >= 2:
            if "cal_world" not in obj:
                raise ValueError(
                    "compression cost table (schema v2+) is missing the "
                    "required 'cal_world' field; the gather-decode rescale "
                    "is wrong without the calibration world — re-record "
                    "with bench_collectives --write-compression-costs")
            cal_world = int(obj["cal_world"])
        elif "cal_world" in obj:
            cal_world = int(obj["cal_world"])
        else:
            warnings.warn(
                "legacy compression-cost table has no 'cal_world'; "
                "assuming the historical default 8 — gather-decode costs "
                "may be rescaled from the wrong world, re-record the "
                "table", stacklevel=2)
            cal_world = 8
        entries, quality = [], []
        for e in obj.get("entries", []):
            entries.append((e["key"], float(e["bw_bytes_per_s"]),
                            float(e["overhead_s"])))
            if "fit_rms_s" in e:
                quality.append((e["key"], float(e["fit_rms_s"]),
                                float(e.get("fit_r2", float("nan"))),
                                bool(e.get("fit_degenerate", False))))
        return cls(entries=tuple(entries), cal_world=cal_world,
                   quality=tuple(quality))

    def save(self, path: str) -> None:
        import json
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "CompressionCostTable":
        import json
        with open(path) as f:
            return cls.from_json(json.load(f))


def bucket_sync_cost_s(compressor: str, compressor_args: Tuple[Tuple[str, Any], ...],
                       algo: str, n_bytes: float, p: int, net: Net,
                       proc_bw: float = COMPRESS_PROC_BW,
                       shard_state: bool = False,
                       cost_table: Optional[CompressionCostTable] = None
                       ) -> float:
    """Predicted wall time to synchronise ONE fused gradient bucket of
    ``n_bytes`` (dense f32) across ``p`` ranks with the given strategy.

      * dense ("none"):         one allreduce of n_bytes on ``algo``
      * aggregatable payloads:  one allreduce of the compressed bytes, plus
                                one compress + one decompress pass
      * gather-based payloads:  ring all-gather of the compressed bytes,
                                plus one compress pass and p per-rank
                                decompress/accumulate passes over the
                                compact payloads (the DGC pattern)

    ``shard_state=True`` prices the sharded-DP SCATTER edge instead: dense
    exchanges become reduce-scatters (half the allreduce — each rank only
    needs its owned chunk of the sum); compressed exchanges are unchanged
    (gather-based payloads already all-gather the compressed bytes, and
    aggregatable factorizations must be fully visible on every rank to
    rebuild the approximation — sharding only changes which slice a rank
    keeps).  The params all-gather on the forward edge is priced separately
    (``shard_gather_cost_s``) because it cannot overlap the backward.

    Defined as the sum of :func:`bucket_sync_phases` — ONE copy of the
    wire-pattern branching, so the per-tier breakdown rows in the plan
    report always reconcile with the modeled totals exactly."""
    return sum((s for _, s in bucket_sync_phases(
        compressor, compressor_args, algo, n_bytes, p, net,
        proc_bw=proc_bw, shard_state=shard_state,
        cost_table=cost_table)), 0.0)


def _compute_cost_s(compressor: str, n_bytes: float, p: int,
                    aggregatable: bool, c_bytes: float, proc_bw: float,
                    cost_table: Optional[CompressionCostTable]) -> float:
    """The compress/decompress compute term of one bucket sync: the
    MEASURED fit when ``cost_table`` covers the compressor (encode +
    decode, the latter scaled linearly from the calibration world to p
    for gather-pattern wires whose decode walks all p payloads), else the
    analytic ``COMPRESS_PROC_BW`` pass-count model."""
    if cost_table is not None:
        enc = cost_table.stage_s(compressor, "encode", n_bytes)
        dec = cost_table.stage_s(compressor, "decode", n_bytes)
        if enc is not None and dec is not None:
            if not aggregatable:
                dec = dec * (p / float(max(cost_table.cal_world, 1)))
            return enc + dec
    if aggregatable:
        return 2 * n_bytes / proc_bw
    return (n_bytes + p * c_bytes) / proc_bw


def bucket_sync_phases(compressor: str,
                       compressor_args: Tuple[Tuple[str, Any], ...],
                       algo: str, n_bytes: float, p: int, net: Net,
                       proc_bw: float = COMPRESS_PROC_BW,
                       shard_state: bool = False,
                       cost_table: Optional[CompressionCostTable] = None
                       ) -> List[Tuple[str, float]]:
    """Per-tier breakdown of :func:`bucket_sync_cost_s` — one
    ``(tier_name, seconds)`` entry per wire phase plus a ``"compute"``
    entry for compress/decompress time.  Feeds the per-tier rows of the
    plan report and the plan record (DESIGN.md §10).

    ``cost_table`` (a :class:`CompressionCostTable`) replaces the analytic
    ``proc_bw`` compute term with measured per-compressor fits — the
    planner's first measured input (``plan_auto(compression_costs=...)``).
    """
    if p <= 1:
        return []
    topo = as_topology(net, p)
    if algo == "ring_fused":
        # Compressed ring (collectives/ring_fused.py): the ring's wire
        # phases at the int8 payload size (~n/4 + per-tile scales, per-hop
        # requantization included in the wire bytes), with the per-hop
        # compress/decompress OVERLAPPED against the permutes by the
        # double-buffered schedule — the compute term charges only the
        # pipeline fill (1/p of the bucket's encode+decode), measured
        # from the int8_fused fits when a cost table is supplied.
        n_elems = max(int(n_bytes // 4), 1)
        ring_bytes = n_elems * 1.0 + 4.0 * float(-(-n_elems // FUSED_TILE))
        phases = allreduce_phases("ring", ring_bytes, p, net)
        fill = _compute_cost_s("int8_fused", n_bytes, p, True, ring_bytes,
                               proc_bw, cost_table) / p
        return phases + [(COMPUTE_PHASE, fill)]
    if compressor == "none":
        if shard_state:
            # reduce-scatter = the ring reduce half, on the ring's tier
            return [(name, c / 2.0) for name, c
                    in allreduce_phases("ring", n_bytes, p, net)]
        return allreduce_phases(algo, n_bytes, p, net)
    from repro.core.compression import get_compressor
    comp = get_compressor(compressor, **dict(compressor_args))
    n_elems = int(n_bytes // 4)
    c_bytes = comp.payload_bits((max(n_elems, 1),)) / 8.0
    compute = _compute_cost_s(compressor, n_bytes, p, comp.aggregatable,
                              c_bytes, proc_bw, cost_table)
    if comp.aggregatable:
        return (allreduce_phases(algo, c_bytes, p, net)
                + [(COMPUTE_PHASE, compute)])
    return [(topo.bottleneck(c_bytes).name, allgather_cost_s(c_bytes, p, net)),
            (COMPUTE_PHASE, compute)]
