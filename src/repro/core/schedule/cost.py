"""α-β (latency-bandwidth) communication cost model (survey §4.1/§4.3).

This module is the single home of the analytic cost model: the survey's
Fig. 10/12 comparisons and the §4.3 protocol study are parameter sweeps over
it, and the communication planner (``schedule/planner.py``) uses it as the
objective when choosing a per-bucket sync strategy.  It used to live inside
``collectives/api.py``; the dispatch module re-exports it for compatibility.

Message libraries and protocols (§4.2/§4.3) appear only through their α
(per-message latency) and β (inverse bandwidth) parameters — on TPU the
"protocol" layer is ICI and lives below XLA (DESIGN.md §5).

Costs for *compressed* exchanges are priced at the survey's wire metric —
``Compressor.payload_bits`` — i.e. the bytes an ideal message library would
move.  See DESIGN.md §5 for how the reference executor realises each wire
pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkParams:
    alpha_s: float = 1e-6       # per-message latency (s)
    beta_s_per_byte: float = 1.0 / 50e9   # inverse link bandwidth (s/B)


# Canonical network regimes (survey Fig. 8/10/12 sweeps).  Benchmarks and the
# planner share these so the "fast_ici" of one table is the "fast_ici" of
# another — previously each bench re-typed its own (α, β) literals.
LINK_PRESETS: Dict[str, LinkParams] = {
    "fast_ici": LinkParams(alpha_s=1e-6, beta_s_per_byte=1 / 50e9),
    "datacenter": LinkParams(alpha_s=5e-6, beta_s_per_byte=1 / 10e9),
    "commodity": LinkParams(alpha_s=50e-6, beta_s_per_byte=1 / 1.25e9),
}


def allreduce_cost_s(algo: str, n_bytes: float, p: int, link: LinkParams,
                     k: Optional[int] = None) -> float:
    """Predicted wall time of one allreduce of n_bytes over p ranks.

    ring:          2(p-1) steps of n/p bytes
    tree (PS):     2 log2(p) steps of n bytes
    hierarchical:  intra ring over k + inter ring over p/k on n/k shards
                   (Jia et al.: 4(k-1) + 2(p/k - 1) steps)
    mesh2d:        two perpendicular ring phases on sqrt(p) ranks
    """
    a, b = link.alpha_s, link.beta_s_per_byte
    if p <= 1:
        return 0.0
    if algo == "ring" or algo == "psum":
        return 2 * (p - 1) * (a + (n_bytes / p) * b)
    if algo == "tree":
        return 2 * np.log2(p) * (a + n_bytes * b)
    if algo == "hierarchical":
        k = k or int(np.sqrt(p))
        inner = 2 * (k - 1) * (a + (n_bytes / k) * b)
        outer = 2 * (p // k - 1) * (a + (n_bytes / k / (p // k)) * b)
        return inner + outer + 2 * (k - 1) * a  # broadcast-phase latency
    if algo in ("mesh2d", "mesh2d_split"):
        px = int(np.sqrt(p))
        py = p // px
        t = (2 * (px - 1) * (a + (n_bytes / px) * b)
             + 2 * (py - 1) * (a + (n_bytes / px / py) * b))
        return t / (2 if algo == "mesh2d_split" else 1)
    raise ValueError(algo)


def reduce_scatter_cost_s(algo: str, n_bytes: float, p: int,
                          link: LinkParams) -> float:
    """One reduce-scatter of ``n_bytes`` (each rank keeps 1/p): (p-1)
    steps of n/p — the bandwidth-optimal (p-1)/p·n edge that ZeRO-style
    sharded DP pays instead of the allreduce's 2(p-1)/p·n.

    Priced as the RING reduce half for EVERY algo, because that is what
    ``collectives.reduce_scatter`` executes: explicit algos run the ring
    (nested per axis), and the psum algo delegates to XLA, whose
    reduce-scatter is ring-equivalent.  Pricing the named algo's allreduce
    half instead would let the planner pick e.g. a latency-optimal tree
    bucket whose sharded execution is actually a (p-1)-hop ring — the
    modeled/executed gap the conformance work exists to prevent."""
    del algo
    return allreduce_cost_s("ring", n_bytes, p, link) / 2.0


def shard_gather_cost_s(algo: str, n_bytes: float, p: int,
                        link: LinkParams) -> float:
    """All-gather of partitioned state totalling ``n_bytes`` (each rank
    contributes n/p) — the forward-edge params gather of sharded DP.
    Ring-priced for every algo, mirroring :func:`reduce_scatter_cost_s`
    (the executed gather is a ring / XLA's ring-equivalent)."""
    del algo
    return allreduce_cost_s("ring", n_bytes, p, link) / 2.0


def p2p_cost_s(n_bytes: float, link: LinkParams) -> float:
    """One point-to-point transfer of ``n_bytes`` (α + nβ) — the pipeline
    boundary edge: one micro-batch of activations (forward) or
    grad-activations (backward) crossing one stage cut (DESIGN.md §9)."""
    return link.alpha_s + n_bytes * link.beta_s_per_byte


def allgather_cost_s(n_bytes: float, p: int, link: LinkParams) -> float:
    """Ring all-gather where every rank contributes ``n_bytes``: (p-1) steps
    each moving one rank's payload (the gather-based compressor wire
    pattern of 1-bit SGD / DGC, DESIGN.md §5)."""
    if p <= 1:
        return 0.0
    return (p - 1) * (link.alpha_s + n_bytes * link.beta_s_per_byte)


def compressed_wire_bytes(compressor: str, compressor_args: Tuple[Tuple[str, Any], ...],
                          n_elems: int) -> float:
    """Per-rank wire bytes for one fused bucket of ``n_elems`` f32 values
    under ``compressor`` — ``payload_bits`` / 8, the survey's metric."""
    from repro.core.compression import get_compressor
    comp = get_compressor(compressor, **dict(compressor_args))
    return comp.payload_bits((int(n_elems),)) / 8.0


# Effective processing bandwidth of the compress/decompress kernels (B/s of
# dense input).  Compression is NOT free: quantize/top-k are memory-bound
# passes over the bucket, and pricing them is what makes the planner prefer
# dense exchanges on fast links (where the α-β savings cannot pay for the
# extra passes) and compression on slow ones — the survey's Fig. 7/8 story.
COMPRESS_PROC_BW = 30e9


def bucket_sync_cost_s(compressor: str, compressor_args: Tuple[Tuple[str, Any], ...],
                       algo: str, n_bytes: float, p: int, link: LinkParams,
                       proc_bw: float = COMPRESS_PROC_BW,
                       shard_state: bool = False) -> float:
    """Predicted wall time to synchronise ONE fused gradient bucket of
    ``n_bytes`` (dense f32) across ``p`` ranks with the given strategy.

      * dense ("none"):         one allreduce of n_bytes on ``algo``
      * aggregatable payloads:  one allreduce of the compressed bytes, plus
                                one compress + one decompress pass
      * gather-based payloads:  ring all-gather of the compressed bytes,
                                plus one compress pass and p per-rank
                                decompress/accumulate passes over the
                                compact payloads (the DGC pattern)

    ``shard_state=True`` prices the sharded-DP SCATTER edge instead: dense
    exchanges become reduce-scatters (half the allreduce — each rank only
    needs its owned chunk of the sum); compressed exchanges are unchanged
    (gather-based payloads already all-gather the compressed bytes, and
    aggregatable factorizations must be fully visible on every rank to
    rebuild the approximation — sharding only changes which slice a rank
    keeps).  The params all-gather on the forward edge is priced separately
    (``shard_gather_cost_s``) because it cannot overlap the backward."""
    if p <= 1:
        return 0.0
    if compressor == "none":
        if shard_state:
            return reduce_scatter_cost_s(algo, n_bytes, p, link)
        return allreduce_cost_s(algo, n_bytes, p, link)
    from repro.core.compression import get_compressor
    comp = get_compressor(compressor, **dict(compressor_args))
    n_elems = int(n_bytes // 4)
    c_bytes = comp.payload_bits((max(n_elems, 1),)) / 8.0
    if comp.aggregatable:
        return (allreduce_cost_s(algo, c_bytes, p, link)
                + 2 * n_bytes / proc_bw)
    return (allgather_cost_s(c_bytes, p, link)
            + (n_bytes + p * c_bytes) / proc_bw)
