"""Analytic computation-communication overlap model (survey §3.3, Fig. 8).

Back-propagation produces per-layer gradients last-layer-first; each layer's
communication can start once its gradient exists (WFBP, Poseidon).  Given
per-layer backward times ``t_b[l]`` and communication times ``t_c[l]`` (from
the α-β model), this module computes the iteration time of:

  * ``fifo``     — serial: all backward, then all communication
  * ``wfbp``     — wait-free BP: comm of layer l starts at max(ready, link free)
  * ``mg_wfbp``  — WFBP with merged (fused) gradients [Shi et al. 2019]:
                   merging removes per-message latency α when a merge lets a
                   transfer be hidden (the survey's Fig. 8 Case 3 fix)
  * ``p3``       — priority-based propagation [Jayarajan et al. 2019]:
                   tensors are sliced and the *first* layers get priority, so
                   the forward pass of the next iteration can start earliest.

These are simulators (the scheduling insight), not XLA passes — on TPU the
XLA latency-hiding scheduler performs the overlap; the knob our runtime
actually owns is the fusion granularity (``grad_sync.bucketize``), whose
effect this model predicts (see benchmarks/bench_overlap.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """Backward compute time and gradient size of one layer (index 0 = input
    layer; backward runs from the last layer to the first)."""
    t_backward_s: float
    grad_bytes: float


def comm_time(nbytes: float, alpha: float, beta: float) -> float:
    return alpha + nbytes * beta


def iteration_time_fifo(layers: Sequence[LayerProfile], alpha: float,
                        beta: float) -> float:
    tb = sum(l.t_backward_s for l in layers)
    tc = sum(comm_time(l.grad_bytes, alpha, beta) for l in layers)
    return tb + tc


def iteration_time_wfbp(layers: Sequence[LayerProfile], alpha: float,
                        beta: float) -> float:
    """Comm of layer l (produced in order L-1 .. 0) starts when its gradient
    is ready and the link is free; iteration ends when all comms finish."""
    order = list(range(len(layers)))[::-1]
    t = 0.0
    link_free = 0.0
    for l in order:
        t += layers[l].t_backward_s            # gradient ready
        start = max(t, link_free)
        link_free = start + comm_time(layers[l].grad_bytes, alpha, beta)
    return max(t, link_free)


def iteration_time_mg_wfbp(layers: Sequence[LayerProfile], alpha: float,
                           beta: float, bucket_bytes: float) -> float:
    """Merge consecutive gradients into buckets of ``bucket_bytes`` before
    sending — one α per bucket instead of one per layer."""
    order = list(range(len(layers)))[::-1]
    t = 0.0
    link_free = 0.0
    pending = 0.0
    for j, l in enumerate(order):
        t += layers[l].t_backward_s
        pending += layers[l].grad_bytes
        last = j == len(order) - 1
        if pending >= bucket_bytes or last:
            start = max(t, link_free)
            link_free = start + comm_time(pending, alpha, beta)
            pending = 0.0
    return max(t, link_free)


def iteration_time_p3(layers: Sequence[LayerProfile], alpha: float,
                      beta: float, slice_bytes: float) -> float:
    """P3: slice every gradient into ``slice_bytes`` pieces; at each link-free
    instant send the READY slice with the highest priority (layer 0 highest).
    Returns time until layer 0's gradient (needed first by the next forward)
    has fully arrived — P3's target metric — plus remaining drain time."""
    order = list(range(len(layers)))[::-1]
    ready: List[Tuple[int, float]] = []   # (priority=layer index, bytes remaining)
    t = 0.0
    link_free = 0.0
    finish = 0.0
    for l in order:
        t += layers[l].t_backward_s
        ready.append((l, layers[l].grad_bytes))
        ready.sort(key=lambda x: x[0])     # low layer index = high priority
        # drain slices that fit before the next gradient is produced
        while ready and link_free < t:
            pr, rem = ready[0]
            chunk = min(slice_bytes, rem)
            start = max(link_free, t - layers[l].t_backward_s)
            link_free = start + comm_time(chunk, alpha, beta)
            rem -= chunk
            if rem <= 0:
                ready.pop(0)
            else:
                ready[0] = (pr, rem)
    # drain the rest after backward completes
    while ready:
        pr, rem = ready.pop(0)
        link_free = max(link_free, t) + comm_time(rem, alpha, beta)
    return max(t, link_free)


def iteration_time_tic(layers: Sequence[LayerProfile], alpha: float,
                       beta: float) -> float:
    """TIC (Timing-Independent Communication, Hashemi et al. 2018): order
    transfers purely by DAG position — earliest-needed-next-iteration first
    (== layer index ascending), ignoring produce times; transfers wait for
    readiness."""
    ready_at = {}
    t = 0.0
    for l in reversed(range(len(layers))):      # backward produces L-1..0
        t += layers[l].t_backward_s
        ready_at[l] = t
    link_free = 0.0
    for l in range(len(layers)):                # send layer 0 first
        start = max(ready_at[l], link_free)
        link_free = start + comm_time(layers[l].grad_bytes, alpha, beta)
    return max(t, link_free)


def iteration_time_tac(layers: Sequence[LayerProfile], alpha: float,
                       beta: float) -> float:
    """TAC (Timing-Aware Communication): like TIC but a transfer is only
    preferred if its directly-dependent compute (the next forward's use)
    cannot already be covered; approximated as shortest-remaining-compute
    first among ready transfers."""
    ready_at = sorted((sum(layers[j].t_backward_s
                           for j in range(l, len(layers))), l)
                      for l in range(len(layers)))
    link_free = 0.0
    t_total = sum(l.t_backward_s for l in layers)
    # process in order of readiness; among ready, prefer small comm first
    pending = sorted(ready_at)
    link_free = 0.0
    for ready, l in pending:
        start = max(ready, link_free)
        link_free = start + comm_time(layers[l].grad_bytes, alpha, beta)
    return max(t_total, link_free)


def wfbp_case(layers: Sequence[LayerProfile], alpha: float, beta: float) -> int:
    """Classify into the survey's Fig. 8 cases: 1 = comm fully hidden,
    2 = partially hidden, 3 = comm dominates (merging needed)."""
    tb = sum(l.t_backward_s for l in layers)
    tc = sum(comm_time(l.grad_bytes, alpha, beta) for l in layers)
    wfbp = iteration_time_wfbp(layers, alpha, beta)
    if wfbp <= tb * 1.01:
        return 1
    if wfbp < tb + tc * 0.5:
        return 2
    return 3
