"""Communication planner: per-bucket auto-tuned sync strategies (§3.3 + §4.1).

The survey's central observation is that the best communication strategy is a
function of message size, topology, and link parameters — compression wins on
slow links and big tensors, latency-optimal collectives win on small messages,
and the right fusion granularity (MG-WFBP, Shi et al. 2019) depends on the
α/β balance.  This module closes that loop: it turns the α-β cost model
(``schedule/cost.py``) and the WFBP overlap simulation (``schedule/
perf_model.py``) from analysis-only code into the runtime's decision engine.

A ``CommPlan`` is an ordered list of ``BucketPlan`` entries, each naming the
gradient leaves it fuses plus the (compressor × collective algo) pair chosen
for that bucket.  ``plan()`` searches candidate strategies per bucket across
a grid of fusion granularities and keeps the granularity whose simulated
iteration time (backward-overlap aware, generalised MG-WFBP) is smallest.
``repro.core.grad_sync.PlanExecutor`` executes the result; DESIGN.md §6
documents the schema and the ``--sync auto`` flow.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.schedule.cost import LinkParams, bucket_sync_cost_s
from repro.core.schedule.perf_model import LayerProfile

# Buckets smaller than this stay dense: at these sizes the exchange is
# latency-bound, so compression saves nothing and only adds bias (the
# survey's "small tensors are free" observation; also PowerSGD's dense
# fallback for non-matrix leaves).
DENSE_SMALL_BYTES = 64 * 1024

# Fusion granularities searched by ``plan`` (f32 bytes).  0 is excluded —
# per-leaf plans come out of the 1 MiB entry naturally when leaves are big.
BUCKET_GRID = tuple(int(m * 2**20) for m in (1, 4, 16, 32, 64, 256))


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One (compressor × algo) strategy the planner may assign to a bucket."""
    compressor: str = "none"
    compressor_args: Tuple[Tuple[str, Any], ...] = ()
    algo: str = "psum"

    @property
    def key(self) -> str:
        return f"{self.algo}/{self.compressor}"


# The fixed single-strategy baselines the auto plan is held against (the
# acceptance criterion) — shared by launch/train.py's printed table/assert
# and benchmarks/bench_planner.py so they always compare the same configs.
# Every entry must stay inside DEFAULT_CANDIDATES for the planner's
# uniform-plan sweep to guarantee auto <= fixed.
FIXED_BASELINES: Dict[str, Tuple[str, str, Tuple[Tuple[str, Any], ...]]] = {
    "psum/dense": ("none", "psum", ()),
    "ring/topk": ("topk", "ring", (("ratio", 0.01),)),
    "ring/int8": ("int8", "ring", ()),
}

DEFAULT_CANDIDATES: Tuple[Candidate, ...] = (
    Candidate("none", (), "psum"),
    Candidate("none", (), "ring"),
    Candidate("none", (), "tree"),
    Candidate("none", (), "hierarchical"),
    Candidate("int8", (), "ring"),
    Candidate("int8", (), "tree"),          # latency-bound slow links
    Candidate("qsgd", (("levels", 127),), "ring"),
    Candidate("qsgd", (("levels", 127),), "tree"),
    Candidate("topk", (("ratio", 0.01),), "ring"),
    Candidate("sign", (), "ring"),
)


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Sync strategy for one fused gradient bucket.

    ``leaves`` are indices into the flattened gradient pytree, listed in the
    order they are packed.  ``pack=False`` buckets hold exactly one leaf and
    operate on it in its natural shape (no flatten/concat) so tensor-parallel
    sharding and shape-aware compressors (PowerSGD) survive.
    """
    leaves: Tuple[int, ...]
    compressor: str = "none"
    compressor_args: Tuple[Tuple[str, Any], ...] = ()
    algo: str = "psum"
    bucket_bytes: int = 0          # dense f32 bytes fused in this bucket
    pack: bool = True
    error_feedback: bool = True
    ef_decay: float = 1.0


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """An ordered per-bucket communication schedule (DESIGN.md §6)."""
    buckets: Tuple[BucketPlan, ...]
    mean: bool = True              # divide by world size after reduce
    modeled_step_s: float = float("nan")   # simulated iteration time
    world: int = 1
    link: Optional[LinkParams] = None

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def describe(self) -> str:
        rows = []
        for j, b in enumerate(self.buckets):
            rows.append(f"bucket {j}: {len(b.leaves)} leaves, "
                        f"{b.bucket_bytes / 2**20:.2f} MiB, "
                        f"{b.algo}/{b.compressor}")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------

def profiles_from_sizes(leaf_bytes: Sequence[float],
                        t_backward_s: float) -> List[LayerProfile]:
    """LayerProfiles in *leaf (tree) order* from per-leaf gradient bytes and
    a measured total backward time, apportioned proportionally to bytes (the
    profiling granularity ``--sync auto`` actually has — XLA fuses the real
    per-layer times away)."""
    total = float(sum(leaf_bytes)) or 1.0
    return [LayerProfile(t_backward_s=t_backward_s * (b / total),
                         grad_bytes=float(b))
            for b in leaf_bytes]


def profiles_from_grads(grads, t_backward_s: float) -> List[LayerProfile]:
    """Like :func:`profiles_from_sizes`, from a gradient (or param) pytree /
    an ``eval_shape`` of one."""
    import jax
    import numpy as np
    sizes = [int(np.prod(g.shape)) * 4 for g in jax.tree.leaves(grads)]
    return profiles_from_sizes(sizes, t_backward_s)


# ---------------------------------------------------------------------------
# Plan simulation (generalised MG-WFBP with per-bucket strategies)
# ---------------------------------------------------------------------------

def _bucket_cost_s(b: BucketPlan, world: int, link: LinkParams) -> float:
    return bucket_sync_cost_s(b.compressor, b.compressor_args, b.algo,
                              b.bucket_bytes, world, link)


def plan_cost_s(plan: CommPlan, layers: Sequence[LayerProfile],
                link: LinkParams, world: int) -> float:
    """Simulated iteration time of ``plan`` on one shared link.

    Backward produces leaf gradients last-layer-first (WFBP); a bucket is
    ready when its last-produced leaf exists; ready buckets go out on the
    link in readiness order.  This is ``iteration_time_mg_wfbp`` generalised
    to heterogeneous per-bucket communication costs."""
    n = len(layers)
    produce_at = [0.0] * n
    t = 0.0
    for i in reversed(range(n)):          # backward order: leaf n-1 first
        t += layers[i].t_backward_s
        produce_at[i] = t
    t_total = t

    events = sorted(
        (max(produce_at[i] for i in b.leaves), j)
        for j, b in enumerate(plan.buckets))
    link_free = 0.0
    for ready, j in events:
        start = max(ready, link_free)
        link_free = start + _bucket_cost_s(plan.buckets[j], world, link)
    return max(t_total, link_free)


# ---------------------------------------------------------------------------
# The search
# ---------------------------------------------------------------------------

def form_bucket_indices(leaf_bytes: Sequence[float],
                        bucket_bytes: float) -> List[Tuple[int, ...]]:
    """THE greedy tensor-fusion rule, shared by ``grad_sync.bucketize`` and
    the planner (the auto-vs-fixed comparison is only valid while both form
    identical bucket boundaries): walk leaves in backward order (reversed),
    close the current bucket when adding the next leaf would exceed
    ``bucket_bytes``; ``bucket_bytes <= 0`` means one bucket per leaf."""
    order = list(range(len(leaf_bytes)))[::-1]
    buckets: List[Tuple[int, ...]] = []
    cur: List[int] = []
    cur_bytes = 0.0
    for i in order:
        sz = leaf_bytes[i]
        if cur and (bucket_bytes <= 0 or cur_bytes + sz > bucket_bytes):
            buckets.append(tuple(cur))
            cur, cur_bytes = [], 0.0
        cur.append(i)
        cur_bytes += sz
    if cur:
        buckets.append(tuple(cur))
    return buckets


def _form_buckets(layers: Sequence[LayerProfile],
                  bucket_bytes: int) -> List[Tuple[int, ...]]:
    return form_bucket_indices([l.grad_bytes for l in layers], bucket_bytes)


def _pick_candidate(n_bytes: float, world: int, link: LinkParams,
                    candidates: Sequence[Candidate],
                    dense_small_bytes: float) -> Tuple[Candidate, float]:
    """Cheapest strategy for one bucket; small/latency-bound buckets fall
    back to dense (compression cannot help a latency-bound message and its
    bias is pure loss there)."""
    pool = candidates
    if n_bytes < dense_small_bytes:
        pool = [c for c in candidates if c.compressor == "none"] \
            or list(candidates)
    best, best_cost = None, float("inf")
    for c in pool:
        cost = bucket_sync_cost_s(c.compressor, c.compressor_args, c.algo,
                                  n_bytes, world, link)
        if cost < best_cost:
            best, best_cost = c, cost
    return best, best_cost


def plan(layer_profiles: Sequence[LayerProfile], link: LinkParams, world: int,
         candidates: Sequence[Candidate] = DEFAULT_CANDIDATES,
         bucket_grid: Sequence[int] = BUCKET_GRID,
         dense_small_bytes: float = DENSE_SMALL_BYTES,
         mean: bool = True) -> CommPlan:
    """Search (compressor × algo × fusion granularity) per bucket.

    ``layer_profiles`` must be in leaf (tree) order — index i is flattened
    leaf i; backward produces them in reverse, like ``bucketize``.  Returns
    the plan with the smallest simulated iteration time; ``modeled_step_s``
    carries that time so callers can compare against fixed configurations.
    """
    if world <= 1:
        # Degenerate world: communication is free; one dense bucket.
        buckets = (BucketPlan(
            leaves=tuple(range(len(layer_profiles)))[::-1],
            compressor="none", algo="psum",
            bucket_bytes=int(sum(l.grad_bytes for l in layer_profiles))),)
        t = sum(l.t_backward_s for l in layer_profiles)
        return CommPlan(buckets=buckets, mean=mean, modeled_step_s=t,
                        world=world, link=link)

    best_plan: Optional[CommPlan] = None

    def consider(p: CommPlan):
        nonlocal best_plan
        t = plan_cost_s(p, layer_profiles, link, world)
        if best_plan is None or t < best_plan.modeled_step_s:
            best_plan = dataclasses.replace(p, modeled_step_s=t)

    for bb in bucket_grid:
        bucket_leaves = _form_buckets(layer_profiles, bb)
        sizes = [sum(layer_profiles[i].grad_bytes for i in leaves)
                 for leaves in bucket_leaves]
        # heterogeneous plan: cheapest strategy per bucket, small buckets
        # falling back to dense
        bps = []
        for leaves, n_bytes in zip(bucket_leaves, sizes):
            cand, _ = _pick_candidate(n_bytes, world, link, candidates,
                                      dense_small_bytes)
            bps.append(BucketPlan(
                leaves=leaves, compressor=cand.compressor,
                compressor_args=cand.compressor_args, algo=cand.algo,
                bucket_bytes=int(n_bytes)))
        consider(CommPlan(buckets=tuple(bps), mean=mean, world=world,
                          link=link))
        # uniform plans: one candidate everywhere — exactly the plan a fixed
        # SyncConfig induces.  Including them in the min GUARANTEES the
        # returned plan is never modeled slower than any fixed config built
        # from the candidate set at a granularity in the grid.  (In corner
        # cases — e.g. a model whose every bucket is latency-bound — a
        # uniform compressed plan can shave a few α off the heterogeneous
        # dense-fallback plan and win; the fallback is a preference of the
        # per-bucket search, not a hard constraint on the final min.)
        for cand in candidates:
            consider(CommPlan(buckets=tuple(
                BucketPlan(leaves=leaves, compressor=cand.compressor,
                           compressor_args=cand.compressor_args,
                           algo=cand.algo, bucket_bytes=int(n_bytes))
                for leaves, n_bytes in zip(bucket_leaves, sizes)),
                mean=mean, world=world, link=link))
    return best_plan


def fixed_config_plan(layer_profiles: Sequence[LayerProfile],
                      link: LinkParams, world: int, compressor: str,
                      algo: str,
                      compressor_args: Tuple[Tuple[str, Any], ...] = (),
                      bucket_bytes: int = 32 * 2**20,
                      mean: bool = True) -> CommPlan:
    """The degenerate plan a single global ``SyncConfig`` induces — every
    bucket gets the same strategy.  Used to score fixed baselines with the
    same simulator the planner optimises."""
    bps = []
    for leaves in _form_buckets(layer_profiles, bucket_bytes):
        n_bytes = sum(layer_profiles[i].grad_bytes for i in leaves)
        bps.append(BucketPlan(
            leaves=leaves, compressor=compressor,
            compressor_args=compressor_args, algo=algo,
            bucket_bytes=int(n_bytes)))
    p = CommPlan(buckets=tuple(bps), mean=mean, world=world, link=link)
    return dataclasses.replace(
        p, modeled_step_s=plan_cost_s(p, layer_profiles, link, world))
