"""Communication planner: per-bucket auto-tuned sync strategies (§3.3 + §4.1).

The survey's central observation is that the best communication strategy is a
function of message size, topology, and link parameters — compression wins on
slow links and big tensors, latency-optimal collectives win on small messages,
and the right fusion granularity (MG-WFBP, Shi et al. 2019) depends on the
α/β balance.  This module closes that loop: it turns the α-β cost model
(``schedule/cost.py``) and the WFBP overlap simulation (``schedule/
perf_model.py``) from analysis-only code into the runtime's decision engine.

A ``CommPlan`` is an ordered list of ``BucketPlan`` entries, each naming the
gradient leaves it fuses plus the (compressor × collective algo) pair chosen
for that bucket.  ``plan()`` searches candidate strategies per bucket across
a grid of fusion granularities and keeps the granularity whose simulated
iteration time (backward-overlap aware, generalised MG-WFBP) is smallest.
``repro.core.grad_sync.PlanExecutor`` executes the result; DESIGN.md §6
documents the schema and the ``--sync auto`` flow.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.parallelism import ParallelismSpec
from repro.core.schedule.cost import (CompressionCostTable, LinkParams,
                                      all_to_all_cost_s, allreduce_cost_s,
                                      bucket_sync_cost_s,
                                      shard_gather_cost_s,
                                      straggler_penalty_s)
from repro.core.schedule.perf_model import LayerProfile
from repro.core.schedule.topology import Topology, as_topology

# Every ``link`` parameter below accepts either a bare LinkParams (flat
# network, the historical model) or a Topology (tiered network, DESIGN.md
# §10) — the cost layer prices each algorithm phase on the tier it
# traverses, so the same search discovers hierarchical/2D arms exactly
# when the network is tiered.

# Buckets smaller than this stay dense: at these sizes the exchange is
# latency-bound, so compression saves nothing and only adds bias (the
# survey's "small tensors are free" observation; also PowerSGD's dense
# fallback for non-matrix leaves).
DENSE_SMALL_BYTES = 64 * 1024

# Fusion granularities searched by ``plan`` (f32 bytes).  0 is excluded —
# per-leaf plans come out of the 1 MiB entry naturally when leaves are big.
BUCKET_GRID = tuple(int(m * 2**20) for m in (1, 4, 16, 32, 64, 256))


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One (compressor × algo) strategy the planner may assign to a bucket."""
    compressor: str = "none"
    compressor_args: Tuple[Tuple[str, Any], ...] = ()
    algo: str = "psum"

    @property
    def key(self) -> str:
        return f"{self.algo}/{self.compressor}"


# The fixed single-strategy baselines the auto plan is held against (the
# acceptance criterion) — shared by launch/train.py's printed table/assert
# and benchmarks/bench_planner.py so they always compare the same configs.
# Every entry must stay inside DEFAULT_CANDIDATES for the planner's
# uniform-plan sweep to guarantee auto <= fixed.
FIXED_BASELINES: Dict[str, Tuple[str, str, Tuple[Tuple[str, Any], ...]]] = {
    "psum/dense": ("none", "psum", ()),
    "ring/topk": ("topk", "ring", (("ratio", 0.01),)),
    "ring/int8": ("int8", "ring", ()),
}

DEFAULT_CANDIDATES: Tuple[Candidate, ...] = (
    Candidate("none", (), "psum"),
    Candidate("none", (), "ring"),
    Candidate("none", (), "tree"),
    Candidate("none", (), "hierarchical"),
    Candidate("int8", (), "ring"),
    Candidate("int8", (), "tree"),          # latency-bound slow links
    Candidate("qsgd", (("levels", 127),), "ring"),
    Candidate("qsgd", (("levels", 127),), "tree"),
    Candidate("topk", (("ratio", 0.01),), "ring"),
    Candidate("sign", (), "ring"),
    # fused Pallas wires (DESIGN.md §11): the same bits as int8/topk but
    # one kernel pass per direction; int8_fused/ring_fused additionally
    # overlaps per-hop compression with the permutes inside the ring
    Candidate("int8_fused", (), "ring"),
    Candidate("int8_fused", (), "ring_fused"),
    Candidate("topk_fused", (("ratio", 0.01),), "ring"),
)

# The NON-tier-aware traversals: what a flat ring / XLA allreduce can do
# on any network.  The tiered-network benches and the CI topology suite
# both assert the tier-aware pick against a plan restricted to this pool
# — defined once here so the asserted bound and the tracked baseline
# cannot drift apart.
FLAT_RING_CANDIDATES: Tuple[Candidate, ...] = tuple(
    c for c in DEFAULT_CANDIDATES if c.algo in ("ring", "psum"))


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Sync strategy for one fused gradient bucket.

    ``leaves`` are indices into the flattened gradient pytree, listed in the
    order they are packed.  ``pack=False`` buckets hold exactly one leaf and
    operate on it in its natural shape (no flatten/concat) so tensor-parallel
    sharding and shape-aware compressors (PowerSGD) survive.
    """
    leaves: Tuple[int, ...]
    compressor: str = "none"
    compressor_args: Tuple[Tuple[str, Any], ...] = ()
    algo: str = "psum"
    bucket_bytes: int = 0          # dense f32 bytes fused in this bucket
    pack: bool = True
    error_feedback: bool = True
    ef_decay: float = 1.0
    # Dispatch to the compressor's fused one-pass kernels when it has them
    # (compression/fused.py; DESIGN.md §11).  False forces the decomposed
    # reference op chain — the comparison arm of the fused-vs-unfused
    # bit-trajectory checks.  No-op for compressors without fused hooks.
    fused: bool = True


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """An ordered per-bucket communication schedule (DESIGN.md §6).

    ``shard_state=True`` marks the sharded-DP execution mode (DESIGN.md
    §8): gradients reduce-scatter to canonical per-bucket owners, optimizer
    moments and f32 master params are partitioned 1/world, and the updated
    params all-gather back on the forward edge.  ``modeled_step_s`` then
    includes the (un-overlappable) gather tail."""
    buckets: Tuple[BucketPlan, ...]
    mean: bool = True              # divide by world size after reduce
    modeled_step_s: float = float("nan")   # simulated iteration time
    world: int = 1
    link: Optional[Any] = None     # LinkParams | Topology (the net priced)
    shard_state: bool = False

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    def describe(self) -> str:
        rows = []
        for j, b in enumerate(self.buckets):
            rows.append(f"bucket {j}: {len(b.leaves)} leaves, "
                        f"{b.bucket_bytes / 2**20:.2f} MiB, "
                        f"{b.algo}/{b.compressor}")
        return "\n".join(rows)


# ---------------------------------------------------------------------------
# Profiles
# ---------------------------------------------------------------------------

def profiles_from_sizes(leaf_bytes: Sequence[float],
                        t_backward_s: float) -> List[LayerProfile]:
    """LayerProfiles in *leaf (tree) order* from per-leaf gradient bytes and
    a measured total backward time, apportioned proportionally to bytes (the
    profiling granularity ``--sync auto`` actually has — XLA fuses the real
    per-layer times away)."""
    total = float(sum(leaf_bytes)) or 1.0
    return [LayerProfile(t_backward_s=t_backward_s * (b / total),
                         grad_bytes=float(b))
            for b in leaf_bytes]


def profiles_from_grads(grads, t_backward_s: float) -> List[LayerProfile]:
    """Like :func:`profiles_from_sizes`, from a gradient (or param) pytree /
    an ``eval_shape`` of one."""
    import jax
    import numpy as np
    sizes = [int(np.prod(g.shape)) * 4 for g in jax.tree.leaves(grads)]
    return profiles_from_sizes(sizes, t_backward_s)


# ---------------------------------------------------------------------------
# Plan simulation (generalised MG-WFBP with per-bucket strategies)
# ---------------------------------------------------------------------------

def _bucket_cost_s(b: BucketPlan, world: int, link,
                   shard_state: bool = False,
                   cost_table: Optional[CompressionCostTable] = None
                   ) -> float:
    return bucket_sync_cost_s(b.compressor, b.compressor_args, b.algo,
                              b.bucket_bytes, world, link,
                              shard_state=shard_state, cost_table=cost_table)


def shard_gather_tail_s(plan: CommPlan, link,
                        world: int) -> float:
    """Serial cost of the params all-gather a sharded plan pays after the
    optimizer step: the updated 1/p master shards must be whole on every
    rank before the next forward, so nothing hides this edge."""
    if world <= 1:
        return 0.0
    return sum(shard_gather_cost_s(b.algo, b.bucket_bytes, world, link)
               for b in plan.buckets)


def plan_cost_s(plan: CommPlan, layers: Sequence[LayerProfile],
                link, world: int,
                cost_table: Optional[CompressionCostTable] = None) -> float:
    """Simulated iteration time of ``plan`` on one shared link.

    Backward produces leaf gradients last-layer-first (WFBP); a bucket is
    ready when its last-produced leaf exists; ready buckets go out on the
    link in readiness order.  This is ``iteration_time_mg_wfbp`` generalised
    to heterogeneous per-bucket communication costs.  Sharded plans pay the
    (cheaper) reduce-scatter per bucket inside the overlap window plus the
    serial params-gather tail after it."""
    n = len(layers)
    produce_at = [0.0] * n
    t = 0.0
    for i in reversed(range(n)):          # backward order: leaf n-1 first
        t += layers[i].t_backward_s
        produce_at[i] = t
    t_total = t

    events = sorted(
        (max(produce_at[i] for i in b.leaves), j)
        for j, b in enumerate(plan.buckets))
    link_free = 0.0
    for ready, j in events:
        start = max(ready, link_free)
        link_free = start + _bucket_cost_s(plan.buckets[j], world, link,
                                           plan.shard_state,
                                           cost_table=cost_table)
    base = max(t_total, link_free)
    if plan.shard_state:
        base += shard_gather_tail_s(plan, link, world)
    return base


# ---------------------------------------------------------------------------
# The search
# ---------------------------------------------------------------------------

def form_bucket_indices(leaf_bytes: Sequence[float],
                        bucket_bytes: float) -> List[Tuple[int, ...]]:
    """THE greedy tensor-fusion rule, shared by ``grad_sync.bucketize`` and
    the planner (the auto-vs-fixed comparison is only valid while both form
    identical bucket boundaries): walk leaves in backward order (reversed),
    close the current bucket when adding the next leaf would exceed
    ``bucket_bytes``; ``bucket_bytes <= 0`` means one bucket per leaf."""
    order = list(range(len(leaf_bytes)))[::-1]
    buckets: List[Tuple[int, ...]] = []
    cur: List[int] = []
    cur_bytes = 0.0
    for i in order:
        sz = leaf_bytes[i]
        if cur and (bucket_bytes <= 0 or cur_bytes + sz > bucket_bytes):
            buckets.append(tuple(cur))
            cur, cur_bytes = [], 0.0
        cur.append(i)
        cur_bytes += sz
    if cur:
        buckets.append(tuple(cur))
    return buckets


def _form_buckets(layers: Sequence[LayerProfile],
                  bucket_bytes: int) -> List[Tuple[int, ...]]:
    return form_bucket_indices([l.grad_bytes for l in layers], bucket_bytes)


def _algo_usable(algo: str, world: int, net) -> bool:
    """Can ``algo`` actually execute at this world/topology?  The tree
    collective's distance doubling needs a power-of-two size on every
    axis (``tree.py`` raises ValueError at trace time), and mesh2d is a
    two-axis collective (both pricing and execution reject 3+-tier
    topologies) — the planner self-filters such candidates up front
    instead of returning a plan that errors at execution."""
    if algo == "tree":
        return as_topology(net, world).all_pow2
    if algo in ("mesh2d", "mesh2d_split"):
        return as_topology(net, world).n_tiers <= 2
    return True


def _usable_candidates(candidates: Sequence[Candidate], world: int,
                       net) -> List[Candidate]:
    out = [c for c in candidates if _algo_usable(c.algo, world, net)]
    if not out:
        raise ValueError(
            f"no candidate strategy can execute at world={world} "
            f"(of {[c.key for c in candidates]})")
    return out


def _pick_candidate(n_bytes: float, world: int, link,
                    candidates: Sequence[Candidate],
                    dense_small_bytes: float,
                    cost_table: Optional[CompressionCostTable] = None
                    ) -> Tuple[Candidate, float]:
    """Cheapest strategy for one bucket; small/latency-bound buckets fall
    back to dense (compression cannot help a latency-bound message and its
    bias is pure loss there)."""
    pool = candidates
    if n_bytes < dense_small_bytes:
        pool = [c for c in candidates if c.compressor == "none"] \
            or list(candidates)
    best, best_cost = None, float("inf")
    for c in pool:
        cost = bucket_sync_cost_s(c.compressor, c.compressor_args, c.algo,
                                  n_bytes, world, link,
                                  cost_table=cost_table)
        if cost < best_cost:
            best, best_cost = c, cost
    return best, best_cost


def plan(layer_profiles: Sequence[LayerProfile], link, world: int,
         candidates: Sequence[Candidate] = DEFAULT_CANDIDATES,
         bucket_grid: Sequence[int] = BUCKET_GRID,
         dense_small_bytes: float = DENSE_SMALL_BYTES,
         mean: bool = True, shard_state: bool = False,
         cost_table: Optional[CompressionCostTable] = None) -> CommPlan:
    """Search (compressor × algo × fusion granularity) per bucket.

    ``layer_profiles`` must be in leaf (tree) order — index i is flattened
    leaf i; backward produces them in reverse, like ``bucketize``.  Returns
    the plan with the smallest simulated iteration time; ``modeled_step_s``
    carries that time so callers can compare against fixed configurations.
    ``shard_state`` prices (and marks) the sharded-DP execution mode.
    ``link`` may be a tiered :class:`Topology`; candidates that cannot
    execute on it (tree on non-power-of-two axes) are filtered up front.
    ``cost_table`` replaces the analytic compression-compute term with
    MEASURED per-compressor encode/decode fits (``schedule/calibration.py``,
    recorded by ``benchmarks/bench_collectives.py --write-compression-costs``)
    — the planner's first measured input.
    """
    if world <= 1:
        # Degenerate world: communication is free; one dense bucket.
        buckets = (BucketPlan(
            leaves=tuple(range(len(layer_profiles)))[::-1],
            compressor="none", algo="psum",
            bucket_bytes=int(sum(l.grad_bytes for l in layer_profiles))),)
        t = sum(l.t_backward_s for l in layer_profiles)
        return CommPlan(buckets=buckets, mean=mean, modeled_step_s=t,
                        world=world, link=link, shard_state=shard_state)

    candidates = _usable_candidates(candidates, world, link)
    best_plan: Optional[CommPlan] = None

    def consider(p: CommPlan):
        nonlocal best_plan
        t = plan_cost_s(p, layer_profiles, link, world,
                        cost_table=cost_table)
        if best_plan is None or t < best_plan.modeled_step_s:
            best_plan = dataclasses.replace(p, modeled_step_s=t)

    for bb in bucket_grid:
        bucket_leaves = _form_buckets(layer_profiles, bb)
        sizes = [sum(layer_profiles[i].grad_bytes for i in leaves)
                 for leaves in bucket_leaves]
        # heterogeneous plan: cheapest strategy per bucket, small buckets
        # falling back to dense
        bps = []
        for leaves, n_bytes in zip(bucket_leaves, sizes):
            cand, _ = _pick_candidate(n_bytes, world, link, candidates,
                                      dense_small_bytes,
                                      cost_table=cost_table)
            bps.append(BucketPlan(
                leaves=leaves, compressor=cand.compressor,
                compressor_args=cand.compressor_args, algo=cand.algo,
                bucket_bytes=int(n_bytes)))
        consider(CommPlan(buckets=tuple(bps), mean=mean, world=world,
                          link=link, shard_state=shard_state))
        # uniform plans: one candidate everywhere — exactly the plan a fixed
        # SyncConfig induces.  Including them in the min GUARANTEES the
        # returned plan is never modeled slower than any fixed config built
        # from the candidate set at a granularity in the grid.  (In corner
        # cases — e.g. a model whose every bucket is latency-bound — a
        # uniform compressed plan can shave a few α off the heterogeneous
        # dense-fallback plan and win; the fallback is a preference of the
        # per-bucket search, not a hard constraint on the final min.)
        for cand in candidates:
            consider(CommPlan(buckets=tuple(
                BucketPlan(leaves=leaves, compressor=cand.compressor,
                           compressor_args=cand.compressor_args,
                           algo=cand.algo, bucket_bytes=int(n_bytes))
                for leaves, n_bytes in zip(bucket_leaves, sizes)),
                mean=mean, world=world, link=link, shard_state=shard_state))
    return best_plan


# ---------------------------------------------------------------------------
# The rounds axis (survey §3.1 composed with §3.2-3.3)
# ---------------------------------------------------------------------------

# Local-SGD periods searched by ``plan_rounds``.  τ=1 is the every-step arm.
TAU_GRID = (1, 2, 4, 8, 16)

# Statistical-efficiency surcharge for τ>1: local SGD needs more steps to
# reach the same loss (survey §3.1.2 — convergence holds only for bounded τ),
# which a pure wall-clock model cannot see; without it the rounds search
# degenerates to "communicate never" (τ→∞ always minimizes time/step).  Each
# τ-averaged step is charged ``1 + γ·(1 - 1/τ)`` of its modeled time — a
# crude, documented stand-in (γ ≈ 5% more steps at large τ) that makes
# every-step win when communication is already hidden by backward overlap
# and lets τ>1 win exactly when communication dominates compute.
LOCAL_SGD_STEP_INFLATION = 0.05


@dataclasses.dataclass(frozen=True)
class RoundSchedule:
    """The rounds lever of a composite strategy: WHEN reduce rounds run."""
    kind: str = "every_step"       # 'every_step' | 'local_sgd'
    period: int = 1                # τ (local_sgd); 1 for every_step

    @property
    def key(self) -> str:
        return f"{self.kind}/tau{self.period}" if self.kind == "local_sgd" \
            else self.kind


@dataclasses.dataclass(frozen=True)
class StrategyPlan:
    """A composite strategy: rounds schedule × per-bucket comm plan.

    ``modeled_step_s`` is the amortized per-step time (every-step: the
    overlap-simulated iteration; local_sgd: backward + round_cost/τ, with
    the statistical surcharge).  ``comm.modeled_step_s`` keeps its own
    meaning for the every-step arm; for τ>1 arms ``round_cost_s`` is the
    serial cost of one averaging round.  ``shard_state`` mirrors
    ``comm.shard_state`` (the memory axis of the search);
    ``opt_mem_bytes`` is the modeled per-worker optimizer-state footprint
    under that choice.

    The PARALLELISM axis (DESIGN.md §9): ``pipeline_stages > 1`` marks a
    pipeline(S, M) arm — ``comm`` then describes the DP edge of ONE stage
    (1/S of the leaves over world/S replicas), ``bubble`` carries
    (S-1)/(S-1+M), and ``pipe_p2p_s`` the per-device boundary-activation
    traffic per step.  On a tiered topology ``pipe_tier`` records the
    AXIS PLACEMENT the planner chose — which tier the pipe axis consumes
    (DESIGN.md §10): ``@node`` means "pipeline across nodes, gradient
    ring inside them"; empty means a flat network (the historical arm).

    ``tp > 1`` / ``ep > 1`` mark the intra-layer model-parallel arms
    (DESIGN.md §14): ``comm`` is the shrunken DP edge (1/tp of the grad
    bytes over world/tp replicas, or the expert-sharded equivalent),
    ``model_comm_s`` the SERIAL per-step activation traffic the mode adds
    (Megatron's per-layer activation allreduces for TP, the expert
    dispatch/combine all-to-alls for EP — nothing hides either in a
    synchronous layer stack), and ``tp_tier`` / ``ep_tier`` the tier the
    axis consumes.  The per-knob fields are consolidated in the
    :class:`~repro.core.parallelism.ParallelismSpec` view (``.parallelism``)
    — new code should read that."""
    schedule: RoundSchedule
    comm: CommPlan
    modeled_step_s: float
    round_cost_s: float
    t_backward_s: float
    shard_state: bool = False
    opt_mem_bytes: float = float("nan")
    pipeline_stages: int = 1
    micro_batches: int = 0
    bubble: float = 0.0
    pipe_p2p_s: float = 0.0
    pipe_tier: str = ""
    tp: int = 1
    tp_tier: str = ""
    ep: int = 1
    ep_tier: str = ""
    model_comm_s: float = 0.0

    @property
    def key(self) -> str:
        """Arm key in ``plan_rounds``'s arms dict (and the report table)."""
        if self.tp > 1:
            at = f"@{self.tp_tier}" if self.tp_tier else ""
            return f"tp({self.tp}){at}"
        if self.ep > 1:
            at = f"@{self.ep_tier}" if self.ep_tier else ""
            return f"ep({self.ep}){at}"
        if self.pipeline_stages > 1:
            at = f"@{self.pipe_tier}" if self.pipe_tier else ""
            return (f"pipeline(S={self.pipeline_stages},"
                    f"M={self.micro_batches}){at}")
        return self.schedule.key + ("_sharded" if self.shard_state else "")

    @property
    def parallelism(self) -> ParallelismSpec:
        """The arm's factorization as one :class:`ParallelismSpec` — the
        consolidated view ``SyncStrategy`` / ``TrainSession`` / the plan
        record speak (DESIGN.md §14)."""
        pp = int(self.pipeline_stages)
        return ParallelismSpec(
            dp=max(int(self.comm.world), 1), tp=int(self.tp),
            pp=pp, ep=int(self.ep),
            tp_tier=self.tp_tier if self.tp > 1 else "",
            pp_tier=self.pipe_tier if pp > 1 else "",
            ep_tier=self.ep_tier if self.ep > 1 else "",
            micro_batches=int(self.micro_batches) if pp > 1 else 0,
            shard_state=self.shard_state)

    def describe(self) -> str:
        shard = " [shard_state 1/p]" if self.shard_state else ""
        pipe = ""
        if self.pipeline_stages > 1:
            placed = (f", pipe axis on tier {self.pipe_tier!r}"
                      if self.pipe_tier else "")
            pipe = (f" [bubble {self.bubble:.1%}, "
                    f"p2p {self.pipe_p2p_s * 1e3:.3f} ms{placed}]")
        if self.tp > 1 or self.ep > 1:
            ax = "tp" if self.tp > 1 else "ep"
            tier = self.tp_tier if self.tp > 1 else self.ep_tier
            placed = f" on tier {tier!r}" if tier else ""
            pipe = (f" [{ax} activation comm "
                    f"{self.model_comm_s * 1e3:.3f} ms{placed}]")
        return (f"{self.key}{shard}{pipe}: "
                f"{self.modeled_step_s * 1e3:.3f} ms/step"
                f" (round {self.round_cost_s * 1e3:.3f} ms, "
                f"{self.comm.n_buckets} buckets)")


# f32 moment buffers per parameter for the registered optimizers (sharded
# mode adds the partitioned f32 master copy on top).  This is the
# worst-case DEFAULT per name; the session passes the measured count
# instead (sgd with momentum=0.0 carries NO moment state, so the name
# alone over-counts it).
OPT_MOMENTS: Dict[str, int] = {"sgd": 1, "adam": 2, "lamb": 2, "lars": 1}


def opt_state_bytes_per_worker(opt_name: str, param_bytes: float, world: int,
                               shard_state: bool,
                               moments: Optional[float] = None) -> float:
    """Modeled per-worker optimizer-state footprint: ``moments`` f32
    buffers replicated, or (moments + the f32 master copy) over the 1/p
    shard when partitioned — the ZeRO memory identity the report prints.
    ``moments`` overrides the per-name default with the measured buffer
    count (actual state bytes / param bytes)."""
    mom = OPT_MOMENTS.get(opt_name, 2) if moments is None else moments
    if not shard_state:
        return float(mom) * param_bytes
    return (mom + 1.0) * param_bytes / max(int(world), 1)


def serial_round_plan(layer_profiles: Sequence[LayerProfile],
                      link, world: int,
                      candidates: Sequence[Candidate] = DEFAULT_CANDIDATES,
                      bucket_grid: Sequence[int] = BUCKET_GRID,
                      dense_small_bytes: float = DENSE_SMALL_BYTES,
                      mean: bool = True,
                      cost_table: Optional[CompressionCostTable] = None
                      ) -> CommPlan:
    """Per-bucket plan for one UNOVERLAPPED reduce round (a local-SGD
    parameter-averaging round runs at a barrier after the optimizer step, so
    nothing hides it): minimize the serial sum of bucket costs instead of
    the WFBP-simulated iteration time.  ``modeled_step_s`` on the returned
    plan is that serial round cost."""
    if world <= 1:
        buckets = (BucketPlan(
            leaves=tuple(range(len(layer_profiles)))[::-1],
            compressor="none", algo="psum",
            bucket_bytes=int(sum(l.grad_bytes for l in layer_profiles))),)
        return CommPlan(buckets=buckets, mean=mean, modeled_step_s=0.0,
                        world=world, link=link)

    candidates = _usable_candidates(candidates, world, link)
    best: Optional[CommPlan] = None

    def consider(bps) -> None:
        nonlocal best
        total = sum(_bucket_cost_s(b, world, link, cost_table=cost_table)
                    for b in bps)
        if best is None or total < best.modeled_step_s:
            best = CommPlan(buckets=tuple(bps), mean=mean,
                            modeled_step_s=total, world=world, link=link)

    for bb in bucket_grid:
        bucket_leaves = _form_buckets(layer_profiles, bb)
        sizes = [sum(layer_profiles[i].grad_bytes for i in leaves)
                 for leaves in bucket_leaves]
        greedy = []
        for leaves, n_bytes in zip(bucket_leaves, sizes):
            cand, _ = _pick_candidate(n_bytes, world, link, candidates,
                                      dense_small_bytes,
                                      cost_table=cost_table)
            greedy.append(BucketPlan(
                leaves=leaves, compressor=cand.compressor,
                compressor_args=cand.compressor_args, algo=cand.algo,
                bucket_bytes=int(n_bytes)))
        consider(greedy)
        # uniform sweeps: the greedy pick restricts small buckets to dense;
        # keep the min over unrestricted uniform plans so the round is never
        # modeled slower than any fixed config
        for cand in candidates:
            consider([BucketPlan(leaves=leaves, compressor=cand.compressor,
                                 compressor_args=cand.compressor_args,
                                 algo=cand.algo, bucket_bytes=int(n_bytes))
                      for leaves, n_bytes in zip(bucket_leaves, sizes)])
    return best


def local_sgd_arm(round_plan: CommPlan, t_backward_s: float, tau: int,
                  inflation: float = LOCAL_SGD_STEP_INFLATION) -> StrategyPlan:
    """The τ>1 composite arm: one serial averaging round (``round_plan``,
    from :func:`serial_round_plan`, whose ``modeled_step_s`` is the round
    cost) amortized over τ steps, with the statistical surcharge.  THE
    amortization formula — shared by :func:`plan_rounds` and the pinned
    ``--local-sgd`` path so auto and pinned runs score identically."""
    rc = round_plan.modeled_step_s
    per_step = (t_backward_s + rc / tau) * (1.0 + inflation * (1 - 1 / tau))
    return StrategyPlan(
        schedule=RoundSchedule(kind="local_sgd", period=int(tau)),
        comm=round_plan, modeled_step_s=per_step, round_cost_s=rc,
        t_backward_s=t_backward_s)


# ---------------------------------------------------------------------------
# The parallelism axis (survey §3.1.3/§3.3: pipeline × data, DESIGN.md §9)
# ---------------------------------------------------------------------------

# Stage counts and micro-batch counts searched by ``plan_rounds`` when a
# ``PipelineAxis`` is supplied.  S must divide the world (the 2-D pipe×data
# mesh) and leave at least 2 DP replicas per stage.
PIPE_GRID = (2, 4, 8)
MICRO_GRID = (4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class PipelineAxis:
    """What the planner needs to price pipeline(S, M) arms: the boundary
    activation traffic.  ``global_tokens`` is batch × seq per step;
    ``bytes_per_token`` the boundary activation row (d_model × 4 for the
    f32 reference wire).  One micro-batch crossing one stage cut moves
    ``global_tokens / (world/S) / M × bytes_per_token`` bytes."""
    global_tokens: float
    bytes_per_token: float
    pipe_grid: Tuple[int, ...] = PIPE_GRID
    micro_grid: Tuple[int, ...] = MICRO_GRID


def pipeline_placements(net, world: int, n_stages: int
                        ) -> List[Tuple[str, Any, Any]]:
    """The AXIS-PLACEMENT alternatives for a pipeline(S) arm: which tier
    the pipe axis consumes (DESIGN.md §10).  Returns
    ``[(pipe_tier_name, dp_net, p2p_net), ...]`` — ``dp_net`` is the
    network the DP edge (world/S replicas) sees after the pipe axis took
    its ranks, ``p2p_net`` the link the boundary activations cross.

    On a flat network (bare LinkParams, or a one-tier Topology) there is
    exactly one placement and the name is "" — the historical arm.  On a
    tiered topology every tier whose size S divides is a placement:
    "pipeline across nodes, dense ring inside" is S on the outer tier;
    pipelining inside the node keeps p2p on the fast tier but forces the
    gradient ring across the slow one.  May return [] when S divides no
    tier (that S is simply not expressible on this topology)."""
    S = int(n_stages)
    if not isinstance(net, Topology):
        return [("", net, net)]
    if net.world != world:
        raise ValueError(f"topology world {net.world} != world {world}")
    out = []
    for ti, tier in enumerate(net.tiers):
        if tier.size % S != 0:
            continue
        placed, rest = net.place(S, ti)
        out.append(("" if net.is_flat else tier.name, rest, placed.link))
    return out


def pipeline_dp_plan(layer_profiles: Sequence[LayerProfile],
                     link, world: int, n_stages: int,
                     candidates: Sequence[Candidate] = DEFAULT_CANDIDATES,
                     bucket_grid: Sequence[int] = BUCKET_GRID,
                     dense_small_bytes: float = DENSE_SMALL_BYTES,
                     mean: bool = True,
                     dp_net=None,
                     cost_table: Optional[CompressionCostTable] = None
                     ) -> Tuple[CommPlan, List[float]]:
    """The M-independent half of a pipeline arm: balanced stage cuts plus
    the overlap-planned DP edge of the HEAVIEST stage (its leaves over
    world/S replicas).  Returns ``(dp_plan, per_stage_bytes)`` so
    :func:`plan_rounds` computes it once per S, not once per (S, M).
    ``dp_net`` is the network the DP edge sees (a placement's remaining
    topology); default: ``link`` itself (the flat arm)."""
    from repro.core.pipeline import balanced_cuts, stage_costs

    S = int(n_stages)
    if S < 2:
        raise ValueError(f"pipeline arm needs n_stages >= 2, got {S}")
    if world % S != 0 or world // S < 2:
        raise ValueError(f"world {world} does not factor into pipe({S}) x "
                         f"data(>=2)")
    if len(layer_profiles) < S:
        raise ValueError(f"cannot cut {len(layer_profiles)} leaves into "
                         f"{S} stages")
    t_bwd = sum(l.t_backward_s for l in layer_profiles)
    bytes_ = [l.grad_bytes for l in layer_profiles]
    cuts = balanced_cuts(bytes_, S)
    per_stage = stage_costs(bytes_, cuts)
    heavy = int(max(range(S), key=lambda s: per_stage[s]))
    sub = list(layer_profiles[cuts[heavy]:cuts[heavy + 1]])
    # each device still computes the full t_bwd per step (its 1/S of the
    # layers over S× micro-batches) — rescale the slice's backward times so
    # the overlap window the DP-edge plan sees stays t_bwd
    sub_t = sum(l.t_backward_s for l in sub) or 1.0
    scale = t_bwd / sub_t
    sub = [LayerProfile(t_backward_s=l.t_backward_s * scale,
                        grad_bytes=l.grad_bytes) for l in sub]
    cp = plan(sub, dp_net if dp_net is not None else link, world // S,
              candidates=candidates, bucket_grid=bucket_grid,
              dense_small_bytes=dense_small_bytes, mean=mean,
              cost_table=cost_table)
    return cp, per_stage


def pipeline_arm(layer_profiles: Sequence[LayerProfile], link,
                 world: int, n_stages: int, micro_batches: int,
                 act_bytes_mb: float,
                 candidates: Sequence[Candidate] = DEFAULT_CANDIDATES,
                 bucket_grid: Sequence[int] = BUCKET_GRID,
                 dense_small_bytes: float = DENSE_SMALL_BYTES,
                 mean: bool = True, opt_name: str = "adam",
                 opt_moments: Optional[float] = None,
                 dp_plan: Optional[Tuple[CommPlan, List[float]]] = None,
                 placement: Optional[Tuple[str, Any, Any]] = None,
                 cost_table: Optional[CompressionCostTable] = None
                 ) -> StrategyPlan:
    """Price one pipeline(S, M) composite on a pipe(S) × data(world/S) mesh.

    Per-device compute is unchanged (1/S of the layers × S× the per-replica
    batch), so the arm pays three things on top of the DP arm's backward:

      * the DP edge shrinks: each pipe rank syncs only its stage's leaves
        (the HEAVIEST stage under the balanced cut — the critical path)
        over world/S replicas, overlap-planned by the same :func:`plan`
        search, so per-bucket compression composes on the DP dimension;
      * the 1F1B bubble: the timeline stretches to (M+S-1)/M of the
        compute, so the idle charged ON TOP of the backward is
        ``(S-1)/M`` of (forward + backward) — i.e. ``bubble/(1-bubble)``
        of compute, where ``bubble = (S-1)/(S-1+M)`` is the reported
        timeline fraction (forward priced at ``PIPE_FWD_FRACTION`` ×
        backward);
      * boundary p2p: 2M transfers of one micro-batch of activations per
        device per step (M forward sends + M grad-activation sends),
        α + bytes·β each — nothing hides them in the lockstep executor.

    Memory: moments × the heaviest stage's param bytes (replicated over
    the stage's DP group) — the pipeline arm is also a memory lever, and
    can win through ``memory_budget_bytes`` like the shard arm.

    ``dp_plan`` takes a precomputed :func:`pipeline_dp_plan` result (the
    M-independent half) so grid sweeps don't redo the bucket search.
    ``placement`` is one :func:`pipeline_placements` entry — the axis→tier
    assignment of the pipe dimension on a tiered topology; default: the
    outermost-tier placement (pipeline across nodes), or the flat arm on
    a flat network.
    """
    from repro.core.pipeline import PIPE_FWD_FRACTION, bubble_fraction
    from repro.core.schedule.cost import p2p_cost_s

    S, M = int(n_stages), int(micro_batches)
    if placement is None:
        options = pipeline_placements(link, world, S)
        if not options:
            raise ValueError(f"pipeline(S={S}) fits no tier of "
                             f"{link.spec()}")
        placement = options[0]
    pipe_tier, dp_net, p2p_net = placement
    if dp_plan is None:
        dp_plan = pipeline_dp_plan(
            layer_profiles, link, world, S, candidates=candidates,
            bucket_grid=bucket_grid, dense_small_bytes=dense_small_bytes,
            mean=mean, dp_net=dp_net, cost_table=cost_table)
    cp, per_stage = dp_plan
    t_bwd = sum(l.t_backward_s for l in layer_profiles)
    bub = bubble_fraction(S, M)
    # idle relative to compute = bubble/(1-bubble) = (S-1)/M — charging
    # bubble·compute instead would under-price small-M arms by M/(M+S-1)
    idle = (S - 1) / M * (1.0 + PIPE_FWD_FRACTION) * t_bwd
    p2p = 2.0 * M * p2p_cost_s(act_bytes_mb, p2p_net)
    modeled = cp.modeled_step_s + idle + p2p
    mom = OPT_MOMENTS.get(opt_name, 2) if opt_moments is None \
        else opt_moments
    return StrategyPlan(
        schedule=RoundSchedule(), comm=cp, modeled_step_s=modeled,
        round_cost_s=sum(_bucket_cost_s(b, world // S, dp_net,
                                        cost_table=cost_table)
                         for b in cp.buckets),
        t_backward_s=t_bwd, pipeline_stages=S, micro_batches=M, bubble=bub,
        pipe_p2p_s=p2p, pipe_tier=pipe_tier,
        opt_mem_bytes=float(mom) * max(per_stage))


# ---------------------------------------------------------------------------
# The intra-layer model-parallel axes: tensor + expert (DESIGN.md §14)
# ---------------------------------------------------------------------------

# TP/EP group sizes searched by ``plan_rounds`` when the matching axis
# descriptor is supplied.  The group must divide the world and (on a tiered
# topology) some tier.
TP_GRID = (2, 4, 8)
EP_GRID = (2, 4, 8)


@dataclasses.dataclass(frozen=True)
class TensorAxis:
    """What the planner needs to price tp arms: the per-layer activation
    allreduce traffic.  ``global_tokens`` is batch × seq per step;
    ``bytes_per_token`` one activation row (d_model × 4 for the f32
    reference wire).  A tp group processes its DP replica's share —
    ``global_tokens / (world/tp)`` tokens — and pays the Megatron pattern:
    4 activation allreduces per layer per step (2 forward + 2 backward,
    one per column→row pair) over the tp axis, serial (the synchronous
    layer stack hides none of them)."""
    global_tokens: float
    bytes_per_token: float
    n_layers: int
    tp_grid: Tuple[int, ...] = TP_GRID


@dataclasses.dataclass(frozen=True)
class ExpertAxis:
    """What the planner needs to price ep arms: the expert dispatch /
    combine all-to-all traffic plus how much of the model the ep axis
    actually shards.  ``bytes_per_token`` is the dispatched activation
    row including the top-k fan-out (k × d_model × 4 for the f32 wire);
    ``expert_fraction`` the share of parameter bytes living in expert
    weights (sharded 1/ep — the rest stays replicated across ep and pays
    an extra ep-axis reduction).  Each rank dispatches its own
    ``global_tokens / world`` tokens: 4 all-to-alls per MoE layer per
    step (dispatch + combine, forward + backward)."""
    global_tokens: float
    bytes_per_token: float
    n_moe_layers: int
    expert_fraction: float = 0.9
    ep_grid: Tuple[int, ...] = EP_GRID
    variant: str = "direct"


def model_axis_placements(net, world: int, size: int
                          ) -> List[Tuple[str, Any, Any]]:
    """Tier placements for a tp/ep group of ``size`` ranks:
    ``[(tier_name, group_net, dp_net), ...]`` — ``group_net`` prices the
    group's activation collectives (the placed tier's link), ``dp_net``
    is the topology the remaining world/size DP replicas see.  Flat
    networks have the single historical placement (name "").  Mirrors
    :func:`pipeline_placements` / :func:`serving_placements`; may return
    ``[]`` when ``size`` divides no tier."""
    size = int(size)
    if size == 1 or not isinstance(net, Topology):
        return [("", net, net)]
    if net.world != world:
        raise ValueError(f"topology world {net.world} != world {world}")
    out = []
    for ti, tier in enumerate(net.tiers):
        if tier.size % size != 0:
            continue
        placed, rest = net.place(size, ti)
        out.append(("" if net.is_flat else tier.name, placed.link, rest))
    return out


def tensor_parallel_arm(layer_profiles: Sequence[LayerProfile], link,
                        world: int, tp: int, axis: TensorAxis,
                        candidates: Sequence[Candidate] = DEFAULT_CANDIDATES,
                        bucket_grid: Sequence[int] = BUCKET_GRID,
                        dense_small_bytes: float = DENSE_SMALL_BYTES,
                        mean: bool = True, opt_name: str = "adam",
                        opt_moments: Optional[float] = None,
                        placement: Optional[Tuple[str, Any, Any]] = None,
                        cost_table: Optional[CompressionCostTable] = None
                        ) -> StrategyPlan:
    """Price one tp-way tensor-parallel composite on a tp × data mesh.

    Per-rank compute is unchanged (1/tp of every matmul × tp× the tokens
    of its dp group), so the arm trades three things against plain DP:

      * the DP edge shrinks tp×: each rank owns 1/tp of every weight, so
        gradient sync moves 1/tp of the bytes over world/tp replicas, on
        the topology REMAINING after the tp axis took its tier — the
        same overlap-planned :func:`plan` search, so compression
        composes on the shrunken edge;
      * the activation edges appear: 4 allreduces per layer per step of
        the group's ``(tokens, d_model)`` activations over the tp axis
        (Megatron's column→row f/g pattern, DESIGN.md §14), priced on
        the PLACED tier and charged serially — the synchronous layer
        stack hides none of them, which is exactly why TP belongs on the
        fastest tier;
      * optimizer state shrinks tp×: moments × param_bytes/tp per rank —
        TP is a memory lever and competes through ``memory_budget_bytes``
        like the shard and pipeline arms.
    """
    tp = int(tp)
    if tp < 2:
        raise ValueError(f"tensor-parallel arm needs tp >= 2, got {tp}")
    if world % tp != 0:
        raise ValueError(f"tp={tp} does not divide world {world}")
    if placement is None:
        options = model_axis_placements(link, world, tp)
        if not options:
            raise ValueError(f"tp={tp} fits no tier of {link.spec()}")
        placement = options[0]
    tier_name, group_net, dp_net = placement
    dp = world // tp
    shards = [LayerProfile(t_backward_s=l.t_backward_s,
                           grad_bytes=l.grad_bytes / tp)
              for l in layer_profiles]
    cp = plan(shards, dp_net, dp, candidates=candidates,
              bucket_grid=bucket_grid, dense_small_bytes=dense_small_bytes,
              mean=mean, cost_table=cost_table)
    act_bytes = axis.global_tokens / dp * axis.bytes_per_token
    act_s = 4.0 * axis.n_layers * allreduce_cost_s("ring", act_bytes, tp,
                                                   group_net)
    t_bwd = sum(l.t_backward_s for l in layer_profiles)
    pb = float(sum(l.grad_bytes for l in layer_profiles))
    mom = OPT_MOMENTS.get(opt_name, 2) if opt_moments is None \
        else opt_moments
    return StrategyPlan(
        schedule=RoundSchedule(), comm=cp,
        modeled_step_s=cp.modeled_step_s + act_s,
        round_cost_s=sum(_bucket_cost_s(b, dp, dp_net,
                                        cost_table=cost_table)
                         for b in cp.buckets),
        t_backward_s=t_bwd, tp=tp, tp_tier=tier_name, model_comm_s=act_s,
        opt_mem_bytes=float(mom) * pb / tp)


def expert_parallel_arm(layer_profiles: Sequence[LayerProfile], link,
                        world: int, ep: int, axis: ExpertAxis,
                        candidates: Sequence[Candidate] = DEFAULT_CANDIDATES,
                        bucket_grid: Sequence[int] = BUCKET_GRID,
                        dense_small_bytes: float = DENSE_SMALL_BYTES,
                        mean: bool = True, opt_name: str = "adam",
                        opt_moments: Optional[float] = None,
                        placement: Optional[Tuple[str, Any, Any]] = None,
                        cost_table: Optional[CompressionCostTable] = None
                        ) -> StrategyPlan:
    """Price one ep-way expert-parallel composite.

    The ep axis shards the expert weights (``axis.expert_fraction`` of
    the param bytes) 1/ep while every rank keeps its own tokens, so:

      * the DP edge shrinks on the expert fraction: per-leaf grad bytes
        scale by ``frac/ep + (1-frac)`` over world/ep replica groups on
        the remaining topology (each expert exists on world/ep ranks);
      * the non-expert grads must ALSO cross the ep axis (they are
        replicated over it but fed by different tokens): one serial ring
        allreduce of ``(1-frac)·param_bytes`` over ep on the placed tier;
      * the dispatch/combine edges appear: 4 all-to-alls per MoE layer
        per step of each rank's ``global_tokens/world`` token rows over
        the ep axis (``cost.all_to_all_cost_s``, ring or direct variant),
        charged serially on the placed tier;
      * optimizer state shrinks on the expert fraction:
        moments × pb × (frac/ep + 1-frac).
    """
    ep = int(ep)
    if ep < 2:
        raise ValueError(f"expert-parallel arm needs ep >= 2, got {ep}")
    if world % ep != 0:
        raise ValueError(f"ep={ep} does not divide world {world}")
    if not 0.0 <= axis.expert_fraction <= 1.0:
        raise ValueError(f"expert_fraction must be in [0, 1], "
                         f"got {axis.expert_fraction}")
    if placement is None:
        options = model_axis_placements(link, world, ep)
        if not options:
            raise ValueError(f"ep={ep} fits no tier of {link.spec()}")
        placement = options[0]
    tier_name, group_net, dp_net = placement
    dp = world // ep
    frac = axis.expert_fraction
    scale = frac / ep + (1.0 - frac)
    shards = [LayerProfile(t_backward_s=l.t_backward_s,
                           grad_bytes=l.grad_bytes * scale)
              for l in layer_profiles]
    cp = plan(shards, dp_net, dp, candidates=candidates,
              bucket_grid=bucket_grid, dense_small_bytes=dense_small_bytes,
              mean=mean, cost_table=cost_table)
    pb = float(sum(l.grad_bytes for l in layer_profiles))
    a2a_bytes = axis.global_tokens / world * axis.bytes_per_token
    model_s = 4.0 * axis.n_moe_layers * all_to_all_cost_s(
        a2a_bytes, ep, group_net, axis.variant)
    if frac < 1.0:
        model_s += allreduce_cost_s("ring", (1.0 - frac) * pb, ep,
                                    group_net)
    t_bwd = sum(l.t_backward_s for l in layer_profiles)
    mom = OPT_MOMENTS.get(opt_name, 2) if opt_moments is None \
        else opt_moments
    return StrategyPlan(
        schedule=RoundSchedule(), comm=cp,
        modeled_step_s=cp.modeled_step_s + model_s,
        round_cost_s=sum(_bucket_cost_s(b, dp, dp_net,
                                        cost_table=cost_table)
                         for b in cp.buckets),
        t_backward_s=t_bwd, ep=ep, ep_tier=tier_name, model_comm_s=model_s,
        opt_mem_bytes=float(mom) * pb * scale)


def plan_rounds(layer_profiles: Sequence[LayerProfile], link,
                world: int,
                candidates: Sequence[Candidate] = DEFAULT_CANDIDATES,
                bucket_grid: Sequence[int] = BUCKET_GRID,
                tau_grid: Sequence[int] = TAU_GRID,
                dense_small_bytes: float = DENSE_SMALL_BYTES,
                inflation: float = LOCAL_SGD_STEP_INFLATION,
                mean: bool = True,
                opt_name: str = "adam",
                shard_grid: Sequence[bool] = (False, True),
                memory_budget_bytes: Optional[float] = None,
                opt_moments: Optional[float] = None,
                pipeline: Optional[PipelineAxis] = None,
                tensor: Optional[TensorAxis] = None,
                expert: Optional[ExpertAxis] = None,
                parallelism=None,
                cost_table: Optional[CompressionCostTable] = None,
                straggler_s: float = 0.0
                ) -> Tuple[StrategyPlan, Dict[str, StrategyPlan]]:
    """Search the rounds axis × the bits axis × the shard axis: every
    candidate composite is a (RoundSchedule, CommPlan) pair; returns
    (best, all_arms_by_key).

    The every-step arm reuses :func:`plan` (overlap-simulated, with its
    uniform-plan guarantee), so the winner is never modeled slower than any
    fixed single-strategy config — the planner's acceptance invariant
    carries over to composites.  τ>1 arms amortize one serial averaging
    round over τ steps and pay the ``LOCAL_SGD_STEP_INFLATION`` surcharge.

    The SHARD axis (``every_step_sharded``) trades the params-gather tail
    against per-worker optimizer memory: sharded is never modeled faster on
    wall clock (the tail cannot overlap), so it wins only through
    ``memory_budget_bytes`` — arms whose modeled per-worker optimizer state
    exceeds the budget are dropped (schedulers with diverging per-worker
    params — local SGD — inherently carry replicated-size state and drop
    with them).  If nothing fits, the minimum-memory arm is returned
    anyway (the budget is advisory, the decision record is honest).

    The PARALLELISM axis (``pipeline(S,M)``, priced when a
    :class:`PipelineAxis` is supplied): S-stage pipelining shrinks the DP
    edge S× (each pipe rank syncs 1/S of the leaves over world/S replicas)
    at the cost of the 1F1B bubble plus boundary activation p2p — it wins
    on wall clock exactly when gradient communication still dominates the
    overlapped backward AFTER the bits axis did its best, which is the
    big-model / slow-link corner both surveys call out (DESIGN.md §9).

    On a tiered :class:`Topology` the pipeline arms additionally search
    the AXIS PLACEMENT (DESIGN.md §10): one arm per (S, M, tier) with the
    pipe axis consuming that tier — p2p priced on its link, the DP edge
    planned on the remaining topology — so "pipeline across nodes, dense
    ring inside" competes directly with "hierarchical allreduce across
    both" and with pipelining inside the node.

    The MODEL axes (``tp(N)@tier`` / ``ep(N)@tier``, priced when a
    :class:`TensorAxis` / :class:`ExpertAxis` is supplied): one arm per
    (size, tier placement) via :func:`tensor_parallel_arm` /
    :func:`expert_parallel_arm` — the TP×PP×DP×EP search space of
    DESIGN.md §14, every arm priced by the same α-β model under the same
    memory budget.  (Combined tp×pp / tp×ep arms are NOT in the search
    space — each model axis competes against the others, not with them.)

    ``parallelism`` (a :class:`~repro.core.parallelism.ParallelismSpec`,
    spec string, or None) PINS the factorization instead of searching it:
    pinned axes collapse their grids to the requested (size, tier), the
    final pool is filtered to arms matching the spec exactly, and an
    unreachable spec — axis without its descriptor, size off every grid,
    tier it doesn't divide — raises loudly rather than silently planning
    something else.  ``arms`` still carries every priced arm for the
    decision record.

    ``straggler_s`` (the elastic runtime's measured worst-vs-median
    step-time skew) adds ``cost.straggler_penalty_s(straggler_s,
    rounds/step)`` to every arm: schedules that sync every step pay the
    full skew per step, local-SGD τ arms pay skew/τ — a persistent
    straggler thereby demotes the winning cadence instead of stalling the
    bus (DESIGN.md §15).  The default 0.0 prices to exactly zero, keeping
    straggler-free plans bit-identical.
    """
    if isinstance(link, Topology) and link.world != world:
        raise ValueError(f"topology world {link.world} ({link.spec()}) != "
                         f"world {world}; derive world from the topology")
    spec = None
    if parallelism is not None:
        spec = ParallelismSpec.coerce(parallelism).resolve(
            link if isinstance(link, Topology) else world)
        if spec.tp > 1 and tensor is None:
            raise ValueError(
                f"parallelism spec {spec.spec()!r} pins tp={spec.tp} but no "
                f"TensorAxis was supplied — the planner cannot price the "
                f"activation edges (pass tensor=TensorAxis(...))")
        if spec.ep > 1 and expert is None:
            raise ValueError(
                f"parallelism spec {spec.spec()!r} pins ep={spec.ep} but no "
                f"ExpertAxis was supplied — the planner cannot price the "
                f"dispatch/combine edges (pass expert=ExpertAxis(...))")
        if spec.pp > 1 and pipeline is None:
            raise ValueError(
                f"parallelism spec {spec.spec()!r} pins pp={spec.pp} but no "
                f"PipelineAxis was supplied — the planner cannot price the "
                f"bubble/p2p edges (pass pipeline=PipelineAxis(...))")
        if spec.shard_state:
            shard_grid = tuple(s for s in shard_grid if s) or (True,)
    t_bwd = sum(l.t_backward_s for l in layer_profiles)
    pb = float(sum(l.grad_bytes for l in layer_profiles))   # f32 param bytes
    arms: Dict[str, StrategyPlan] = {}
    for shard in shard_grid:
        every = plan(layer_profiles, link, world, candidates=candidates,
                     bucket_grid=bucket_grid,
                     dense_small_bytes=dense_small_bytes, mean=mean,
                     shard_state=shard, cost_table=cost_table)
        key = "every_step_sharded" if shard else "every_step"
        arms[key] = StrategyPlan(
            schedule=RoundSchedule(), comm=every,
            modeled_step_s=every.modeled_step_s,
            round_cost_s=sum(_bucket_cost_s(b, world, link, shard,
                                            cost_table=cost_table)
                             for b in every.buckets),
            t_backward_s=t_bwd, shard_state=shard,
            opt_mem_bytes=opt_state_bytes_per_worker(opt_name, pb, world,
                                                     shard, opt_moments))
    if world > 1 and any(not s for s in shard_grid):
        rp = serial_round_plan(layer_profiles, link, world,
                               candidates=candidates,
                               bucket_grid=bucket_grid,
                               dense_small_bytes=dense_small_bytes,
                               mean=mean, cost_table=cost_table)
        mem = opt_state_bytes_per_worker(opt_name, pb, world, False,
                                         opt_moments)
        for tau in tau_grid:
            if tau <= 1:
                continue
            arm = local_sgd_arm(rp, t_bwd, tau, inflation)
            arms[arm.schedule.key] = dataclasses.replace(
                arm, opt_mem_bytes=mem)
    if pipeline is not None and world > 1:
        pipe_grid = pipeline.pipe_grid
        micro_grid = pipeline.micro_grid
        if spec is not None and spec.pp > 1:
            pipe_grid = (spec.pp,)
            if spec.micro_batches:
                micro_grid = (spec.micro_batches,)
        for S in pipe_grid:
            if S < 2 or world % S != 0 or world // S < 2 \
                    or len(layer_profiles) < S:
                continue
            placements = pipeline_placements(link, world, S)
            if spec is not None and spec.pp_tier:
                placements = [p for p in placements if p[0] == spec.pp_tier]
            for placement in placements:
                # the stage cuts + DP-edge bucket search depend only on
                # (S, placement); only bubble/p2p vary with M
                dp = pipeline_dp_plan(
                    layer_profiles, link, world, S, candidates=candidates,
                    bucket_grid=bucket_grid,
                    dense_small_bytes=dense_small_bytes, mean=mean,
                    dp_net=placement[1], cost_table=cost_table)
                for M in micro_grid:
                    act = (pipeline.global_tokens / (world // S) / M
                           * pipeline.bytes_per_token)
                    arm = pipeline_arm(
                        layer_profiles, link, world, S, M, act,
                        opt_name=opt_name, opt_moments=opt_moments,
                        dp_plan=dp, placement=placement,
                        cost_table=cost_table)
                    arms[arm.key] = arm
    if tensor is not None and world > 1:
        tp_grid = tensor.tp_grid
        if spec is not None and spec.tp > 1:
            tp_grid = (spec.tp,)
        for tp in tp_grid:
            if tp < 2 or world % tp != 0:
                continue
            placements = model_axis_placements(link, world, tp)
            if spec is not None and spec.tp_tier:
                placements = [p for p in placements if p[0] == spec.tp_tier]
            for placement in placements:
                arm = tensor_parallel_arm(
                    layer_profiles, link, world, tp, tensor,
                    candidates=candidates, bucket_grid=bucket_grid,
                    dense_small_bytes=dense_small_bytes, mean=mean,
                    opt_name=opt_name, opt_moments=opt_moments,
                    placement=placement, cost_table=cost_table)
                arms[arm.key] = arm
    if expert is not None and world > 1:
        ep_grid = expert.ep_grid
        if spec is not None and spec.ep > 1:
            ep_grid = (spec.ep,)
        for ep in ep_grid:
            if ep < 2 or world % ep != 0:
                continue
            placements = model_axis_placements(link, world, ep)
            if spec is not None and spec.ep_tier:
                placements = [p for p in placements if p[0] == spec.ep_tier]
            for placement in placements:
                arm = expert_parallel_arm(
                    layer_profiles, link, world, ep, expert,
                    candidates=candidates, bucket_grid=bucket_grid,
                    dense_small_bytes=dense_small_bytes, mean=mean,
                    opt_name=opt_name, opt_moments=opt_moments,
                    placement=placement, cost_table=cost_table)
                arms[arm.key] = arm
    if straggler_s > 0.0:
        # price the straggler on every arm (the decision record stays
        # honest): rounds/step is 1 except for local-SGD's 1/τ cadence
        for key, a in list(arms.items()):
            rps = (1.0 / max(a.schedule.period, 1)
                   if a.schedule.kind == "local_sgd" else 1.0)
            arms[key] = dataclasses.replace(
                a, modeled_step_s=a.modeled_step_s
                + straggler_penalty_s(straggler_s, rps))
    pool = list(arms.values())
    if spec is not None:
        pool = [a for a in pool if _arm_matches_spec(a, spec)]
        if not pool:
            raise ValueError(
                f"parallelism spec {spec.spec()!r} matches no priced arm "
                f"on world={world} ({link.spec() if isinstance(link, Topology) else link}) "
                f"— the requested factorization is outside the search "
                f"space (combined tp×pp/tp×ep placements are not searched; "
                f"check the axis grids and tier divisibility)")
    if memory_budget_bytes is not None:
        fits = [a for a in pool if a.opt_mem_bytes <= memory_budget_bytes]
        pool = fits or [min(pool, key=lambda s: s.opt_mem_bytes)]
    best = min(pool, key=lambda s: s.modeled_step_s)
    return best, arms


def _arm_matches_spec(arm: StrategyPlan, spec: "ParallelismSpec") -> bool:
    """Exact-match filter for a pinned :class:`ParallelismSpec`: the arm
    must carry the requested (tp, pp, ep, shard) sizes, the named tiers
    when given, and the micro-batch count when set.  every_step / local
    SGD arms only match the trivial (pure-dp) spec."""
    if (arm.tp, arm.pipeline_stages, arm.ep) != (spec.tp, spec.pp, spec.ep):
        return False
    if arm.shard_state != spec.shard_state:
        return False
    if spec.tp > 1 and spec.tp_tier and arm.tp_tier != spec.tp_tier:
        return False
    if spec.ep > 1 and spec.ep_tier and arm.ep_tier != spec.ep_tier:
        return False
    if spec.pp > 1:
        if spec.pp_tier and arm.pipe_tier != spec.pp_tier:
            return False
        if spec.micro_batches and arm.micro_batches != spec.micro_batches:
            return False
    return True


def fixed_config_plan(layer_profiles: Sequence[LayerProfile],
                      link, world: int, compressor: str,
                      algo: str,
                      compressor_args: Tuple[Tuple[str, Any], ...] = (),
                      bucket_bytes: int = 32 * 2**20,
                      mean: bool = True,
                      shard_state: bool = False,
                      cost_table: Optional[CompressionCostTable] = None
                      ) -> CommPlan:
    """The degenerate plan a single global ``SyncConfig`` induces — every
    bucket gets the same strategy.  Used to score fixed baselines with the
    same simulator the planner optimises."""
    bps = []
    for leaves in _form_buckets(layer_profiles, bucket_bytes):
        n_bytes = sum(layer_profiles[i].grad_bytes for i in leaves)
        bps.append(BucketPlan(
            leaves=leaves, compressor=compressor,
            compressor_args=compressor_args, algo=algo,
            bucket_bytes=int(n_bytes)))
    p = CommPlan(buckets=tuple(bps), mean=mean, world=world, link=link,
                 shard_state=shard_state)
    return dataclasses.replace(
        p, modeled_step_s=plan_cost_s(p, layer_profiles, link, world,
                                      cost_table=cost_table))


# ---------------------------------------------------------------------------
# Serving placement (DESIGN.md §12)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """One serving arm: ``tp``-way sharded decode with its collectives on
    ``tp_tier``, replicated ``replicas`` times over the remaining world."""
    tp: int
    tp_tier: str                # "" on a flat network
    replicas: int
    step_s: float               # one decode step of one replica
    tokens_per_s: float         # batch * replicas / step_s

    def key(self) -> str:
        tier = self.tp_tier or "flat"
        return f"tp{self.tp}@{tier}x{self.replicas}"

    def describe(self) -> str:
        return (f"{self.key()}: step {self.step_s*1e3:.3f} ms, "
                f"{self.tokens_per_s:,.0f} tok/s")


def serving_placements(net, world: int, tp: int) -> List[Tuple[str, Any]]:
    """Tier placements for a tp-way decode group: ``[(tier_name,
    tp_net), ...]`` where ``tp_net`` prices the group's allreduces.  Flat
    networks have the single historical placement; on a tiered topology
    every tier tp divides is an arm (TP across nodes is expressible —
    and the planner will duly price it out of contention)."""
    if tp == 1:
        return [("", net)]
    if not isinstance(net, Topology):
        return [("", net)]
    if net.world != world:
        raise ValueError(f"topology world {net.world} != world {world}")
    out = []
    for ti, tier in enumerate(net.tiers):
        if tier.size % tp != 0:
            continue
        placed, _ = net.place(tp, ti)
        out.append(("" if net.is_flat else tier.name, placed.link))
    return out


def plan_serving(net, world: int, param_bytes: float, n_layers: int,
                 d_model: int, batch: int,
                 tp_grid: Sequence[int] = (1, 2, 4, 8, 16),
                 latency_budget_s: Optional[float] = None,
                 act_bytes: int = 2
                 ) -> Tuple[ServingPlan, List[ServingPlan]]:
    """Choose the decode sharding for a serving fleet of ``world`` chips:
    search tp degree x tier placement, price one batched decode step via
    :func:`~repro.core.schedule.cost.decode_step_cost_s`, replicate the
    chosen group over the rest of the world, and keep the arm with the
    highest aggregate tokens/s (optionally subject to a per-step latency
    budget).  Returns ``(best, all_arms)`` — the arms feed
    ``launch/report.render_serving_plan``."""
    from repro.core.schedule.cost import decode_step_cost_s
    arms: List[ServingPlan] = []
    for tp in tp_grid:
        if tp > world or world % tp != 0:
            continue
        for tier_name, tp_net in serving_placements(net, world, tp):
            step = decode_step_cost_s(param_bytes, n_layers, d_model,
                                      batch, tp, tp_net,
                                      act_bytes=act_bytes)
            replicas = world // tp
            arms.append(ServingPlan(
                tp=tp, tp_tier=tier_name, replicas=replicas, step_s=step,
                tokens_per_s=batch * replicas / step))
    if not arms:
        raise ValueError(f"no serving arm fits world={world} "
                         f"with tp_grid={tuple(tp_grid)}")
    pool = arms
    if latency_budget_s is not None:
        fits = [a for a in pool if a.step_s <= latency_budget_s]
        pool = fits or [min(pool, key=lambda a: a.step_s)]
    best = max(pool, key=lambda a: a.tokens_per_s)
    return best, arms
