from repro.core.schedule.cost import (  # noqa: F401
    LINK_PRESETS, LinkParams, allgather_cost_s, allreduce_cost_s,
    bucket_sync_cost_s, compressed_wire_bytes)
from repro.core.schedule.perf_model import (  # noqa: F401
    LayerProfile, comm_time, iteration_time_fifo, iteration_time_wfbp,
    iteration_time_mg_wfbp, iteration_time_p3, iteration_time_tic,
    iteration_time_tac, wfbp_case)
from repro.core.schedule.planner import (  # noqa: F401
    BUCKET_GRID, BucketPlan, Candidate, CommPlan, DEFAULT_CANDIDATES,
    DENSE_SMALL_BYTES, LOCAL_SGD_STEP_INFLATION, RoundSchedule, StrategyPlan,
    TAU_GRID, fixed_config_plan, plan, plan_cost_s, plan_rounds,
    profiles_from_grads, profiles_from_sizes, serial_round_plan)
