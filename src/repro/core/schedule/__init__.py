from repro.core.schedule.perf_model import (  # noqa: F401
    LayerProfile, comm_time, iteration_time_fifo, iteration_time_wfbp,
    iteration_time_mg_wfbp, iteration_time_p3, iteration_time_tic,
    iteration_time_tac, wfbp_case)
