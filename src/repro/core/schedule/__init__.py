from repro.core.schedule.cost import (  # noqa: F401
    DECODE_HBM_BW, LINK_PRESETS, CompressionCostTable, LinkParams,
    all_to_all_cost_s, allgather_cost_s, allreduce_cost_s,
    allreduce_phases, bucket_sync_cost_s, bucket_sync_phases,
    compressed_wire_bytes, decode_step_cost_s, p2p_cost_s,
    reduce_scatter_cost_s, shard_gather_cost_s, straggler_penalty_s)
from repro.core.schedule.calibration import (  # noqa: F401
    CALIBRATION_SET, AffineFit, CalibratedTopology, LinkFit,
    calibrate_topology, drift_fraction, fit_affine,
    measure_compression_costs, modeled_wall_step_s, plan_comm_error_s,
    resolve_calibration, resolve_cost_table)
from repro.core.schedule.topology import (  # noqa: F401
    TOPOLOGY_PRESETS, Tier, Topology, as_topology)
from repro.core.schedule.perf_model import (  # noqa: F401
    LayerProfile, comm_time, iteration_time_fifo, iteration_time_wfbp,
    iteration_time_mg_wfbp, iteration_time_p3, iteration_time_tic,
    iteration_time_tac, wfbp_case)
from repro.core.schedule.planner import (  # noqa: F401
    BUCKET_GRID, BucketPlan, Candidate, CommPlan, DEFAULT_CANDIDATES,
    DENSE_SMALL_BYTES, EP_GRID, ExpertAxis, LOCAL_SGD_STEP_INFLATION,
    MICRO_GRID, OPT_MOMENTS, PIPE_GRID, PipelineAxis, RoundSchedule,
    ServingPlan, StrategyPlan, TAU_GRID, TP_GRID, TensorAxis,
    expert_parallel_arm, fixed_config_plan, model_axis_placements,
    opt_state_bytes_per_worker, pipeline_arm, pipeline_placements, plan,
    plan_cost_s, plan_rounds, plan_serving, profiles_from_grads,
    profiles_from_sizes, serial_round_plan, serving_placements,
    shard_gather_tail_s, tensor_parallel_arm)
from repro.core.pipeline import (  # noqa: F401
    PIPE_FWD_FRACTION, StagedModel, aligned_order, aligned_ticks,
    balanced_cuts, bubble_fraction, schedule_1f1b, simulate_1f1b,
    stage_costs)
