"""Tiered network topology — the first-class network model (survey §4.1.2).

Real clusters are *tiered*: a fast intra-node interconnect (NVLink / TPU
ICI) under a slower inter-node fabric (Ethernet / IB).  The survey's
network-level chapter exists because collective algorithms differ in WHICH
links each phase traverses — hierarchical allreduce (Jia et al. 2018) and
2D-torus allreduce (Ying et al. 2018) were designed so the bandwidth-heavy
phases stay on the fast tier — and Zhang et al. 2020 show the flat-ring
vs hierarchical crossover only appears when inter-node bandwidth is
modeled separately.  A single ``LinkParams`` cannot express any of this.

A :class:`Topology` is an ordered tuple of :class:`Tier` entries,
**outermost (slowest, cross-node) first**, each ``(name, size, link)``.
The world size is the product of tier sizes.  ``Topology.flat(world,
link)`` is the degenerate single-tier network every pre-topology call
site used implicitly — the cost model reproduces the flat numbers
bit-for-bit on it (``tests/test_topology.py`` pins this).

The axis→tier mapping of *executed* collectives: each tier is one mesh
axis named after the tier (``launch.mesh.make_topology_mesh``), and
collectives take the axis names innermost-first
(``collectives.api.axes_for_topology``) so ``hierarchical``'s inner ring
runs on the fast tier exactly as the cost model prices it.  DESIGN.md §10.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Tier:
    """One level of the network: ``size`` members joined by ``link``.

    ``fit`` (a ``schedule.calibration.LinkFit``) is attached when the
    link was FITTED from measured collectives rather than taken from a
    preset — it carries the confidence bounds and residual the drift
    report propagates; it never participates in equality (two tiers with
    the same (α, β) price identically regardless of provenance)."""
    name: str
    size: int
    link: "LinkParams"              # repro.core.schedule.cost.LinkParams
    link_name: str = dataclasses.field(default="", compare=False)
    fit: Optional[object] = dataclasses.field(default=None, compare=False,
                                              repr=False)

    def describe(self) -> str:
        ln = self.link_name or (f"a={self.link.alpha_s:.0e}:"
                                f"b={1 / self.link.beta_s_per_byte / 1e9:g}")
        return f"{self.name}:{self.size}@{ln}"


# Canonical tiered networks, joining ``LINK_PRESETS`` the way the flat
# presets join the benchmarks: the spec strings below are what
# ``--topology`` accepts, and every ``@link`` names a LINK_PRESETS entry.
TOPOLOGY_PRESETS = {
    # the acceptance-criterion network: 4 nodes of 8 fast-ICI devices
    # under a datacenter fabric (world 32)
    "two_tier_pod": "node:4@datacenter,device:8@fast_ici",
    # two TPU pods joined by a datacenter fabric (world 512)
    "multi_pod": "pod:2@datacenter,chip:256@fast_ici",
    # a commodity cluster: 32 8-GPU boxes on slow Ethernet (world 256)
    "commodity_cluster": "node:32@commodity,device:8@fast_ici",
}


@dataclasses.dataclass(frozen=True)
class Topology:
    """An ordered stack of network tiers, outermost first.

    The single object every layer of the network surface shares: the α-β
    cost model prices each collective phase on the tier it traverses, the
    planner searches axis→tier placements over it, ``TrainSession`` builds
    the executable mesh from it, and the CLI parses it from
    ``--topology``.
    """
    tiers: Tuple[Tier, ...]

    def __post_init__(self):
        if not self.tiers:
            raise ValueError("a Topology needs at least one tier")
        names = [t.name for t in self.tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        for t in self.tiers:
            if int(t.size) < 1:
                raise ValueError(f"tier {t.name!r} has size {t.size}")

    # -- views ---------------------------------------------------------------

    @property
    def world(self) -> int:
        w = 1
        for t in self.tiers:
            w *= int(t.size)
        return w

    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def is_flat(self) -> bool:
        return len(self.tiers) == 1

    @property
    def outermost(self) -> Tier:
        return self.tiers[0]

    @property
    def innermost(self) -> Tier:
        return self.tiers[-1]

    @property
    def inner_size(self) -> int:
        """Product of all tiers below the outermost — the natural ``k``
        of hierarchical allreduce (the intra-node ring size)."""
        w = 1
        for t in self.tiers[1:]:
            w *= int(t.size)
        return w

    @property
    def all_pow2(self) -> bool:
        """Every tier size a power of two — required by the tree
        collective (distance doubling runs per axis)."""
        return all(t.size & (t.size - 1) == 0 for t in self.tiers)

    def bottleneck(self, m_bytes: float) -> Tier:
        """The tier that gates a lockstep flat traversal (ring / gather)
        moving ``m_bytes`` per step: max α + m·β.  A ring embedded across
        nodes crosses the slow fabric every step, so each synchronous step
        is paid at the slowest link it touches (Zhang et al. 2020's
        flat-ring observation).  Ties go to the outermost tier."""
        return max(self.tiers,
                   key=lambda t: (t.link.alpha_s
                                  + m_bytes * t.link.beta_s_per_byte))

    def spec(self) -> str:
        return ",".join(t.describe() for t in self.tiers)

    def describe(self) -> str:
        return self.spec()

    # -- construction --------------------------------------------------------

    @staticmethod
    def flat(world: int, link, name: str = "link",
             link_name: str = "") -> "Topology":
        """The degenerate single-tier network a bare ``LinkParams``
        denotes — reproduces the pre-topology cost model bit-for-bit."""
        return Topology((Tier(name, int(world), link, link_name),))

    @classmethod
    def from_spec(cls, spec: str) -> "Topology":
        """Parse ``"node:4@datacenter,device:8@fast_ici"`` (outermost
        first; each ``@link`` is a ``LINK_PRESETS`` name) or a
        ``TOPOLOGY_PRESETS`` key."""
        from repro.core.schedule.cost import LINK_PRESETS
        spec = spec.strip()
        if spec in TOPOLOGY_PRESETS:
            spec = TOPOLOGY_PRESETS[spec]
        tiers = []
        for part in spec.split(","):
            part = part.strip()
            try:
                name_size, link_name = part.split("@")
                name, size = name_size.split(":")
                size = int(size)
            except ValueError:
                raise ValueError(
                    f"bad tier spec {part!r} (want name:size@link, e.g. "
                    f"node:4@datacenter)") from None
            if link_name not in LINK_PRESETS:
                raise ValueError(f"unknown link preset {link_name!r} in "
                                 f"{part!r}; known: {sorted(LINK_PRESETS)}")
            tiers.append(Tier(name.strip(), size, LINK_PRESETS[link_name],
                              link_name))
        return cls(tuple(tiers))

    # -- axis placement ------------------------------------------------------

    def place(self, axis_size: int, tier_index: int
              ) -> Tuple[Tier, "Topology"]:
        """Consume an axis of ``axis_size`` ranks from tier
        ``tier_index``: returns ``(placed, remaining)`` where ``placed``
        is a tier of that size on the host tier's link (what the placed
        axis' traffic pays — e.g. pipeline p2p) and ``remaining`` is the
        topology the OTHER axes see (the tier shrunk or removed).  This
        is the planner's axis-placement primitive: "pipeline across
        nodes, dense ring inside" is ``place(S, 0)``."""
        t = self.tiers[tier_index]
        if axis_size < 1 or t.size % axis_size != 0:
            raise ValueError(f"axis of {axis_size} does not divide tier "
                             f"{t.name}:{t.size}")
        placed = Tier(t.name, int(axis_size), t.link, t.link_name, t.fit)
        rest = t.size // axis_size
        tiers = list(self.tiers)
        if rest == 1:
            del tiers[tier_index]
        else:
            tiers[tier_index] = Tier(t.name, rest, t.link, t.link_name,
                                     t.fit)
        if not tiers:        # fully consumed: a 1-rank degenerate network
            tiers = [Tier(t.name, 1, t.link, t.link_name, t.fit)]
        return placed, Topology(tuple(tiers))


def as_topology(net: Union[Topology, "LinkParams"], world: int) -> Topology:
    """Normalize the ``net`` argument every cost function takes: a
    ``Topology`` must agree with ``world`` (the deprecated ``plan_world``
    path resolves the disagreement BEFORE pricing — see api.plan_auto); a
    bare
    ``LinkParams`` becomes the flat single-tier topology.  A
    ``schedule.calibration.CalibratedTopology`` (anything carrying a
    ``.topology``) unwraps to its fitted topology, so calibrated fabrics
    drop into every cost function unchanged."""
    inner = getattr(net, "topology", None)
    if isinstance(inner, Topology):
        net = inner
    if isinstance(net, Topology):
        if net.world != int(world):
            raise ValueError(
                f"topology world {net.world} ({net.spec()}) != requested "
                f"world {world}; derive world from the topology")
        return net
    return Topology.flat(world, net)
