"""GradientSynchronizer — the survey's taxonomy as one composable step.

Every data-parallel training step runs

    grads -> [bucket] -> [error-feedback + compress] -> collective
          -> [decompress/aggregate] -> synced grads

with each stage selected by ``SyncConfig``:

  * ``compressor``: none | sign | terngrad | qsgd | int8 | topk | randomk |
    threshold | powersgd | svd                      (§3.2)
  * ``algo``: psum | ring | tree | hierarchical | mesh2d | mesh2d_split (§4.1)
  * ``error_feedback``: EF / residual accumulation  (§3.2.1 Eq. 2)
  * ``bucket_bytes``: MG-WFBP tensor fusion         (§3.3 / §4.2)

Wire semantics (DESIGN.md §5): gather-based compressors (sign, top-k, ...)
all-gather their compact payloads over the data axes and every rank
decompresses + averages — the pattern of 1-bit SGD/DGC, with collective
bytes proportional to the COMPRESSED size.  Aggregatable factorizations
(PowerSGD) allreduce their small factors directly on the selected
collective algorithm.  Must run inside a ``shard_map`` whose manual axes
are exactly ``axes``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import allreduce
from repro.core.compression import get_compressor

DENSE_SMALL = 4096  # leaves smaller than this stay dense inside PowerSGD


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    compressor: str = "none"
    compressor_args: Tuple[Tuple[str, Any], ...] = ()
    algo: str = "psum"
    error_feedback: bool = True
    ef_decay: float = 1.0
    bucket_bytes: int = 32 * 1024 * 1024   # MG-WFBP fusion granularity
    mean: bool = True                      # divide by world size after reduce

    def make_compressor(self):
        return get_compressor(self.compressor, **dict(self.compressor_args))


# ---------------------------------------------------------------------------
# Bucketing (tensor fusion, MG-WFBP / Horovod-style)
# ---------------------------------------------------------------------------

def bucketize(grads, bucket_bytes: int):
    """Split the flattened gradient pytree into ~bucket_bytes buckets.

    ``bucket_bytes == 0`` means per-leaf buckets WITHOUT concatenation-
    induced reshape: each leaf stays its own flat bucket, so a leaf's
    tensor-parallel sharding survives (flattening a TP-sharded matrix into
    a cross-leaf concat replicates it — the EF-residual memory finding in
    EXPERIMENTS.md §Perf pair 3).

    Returns (bucket_defs, pack, unpack) where bucket_defs is a list of lists
    of (leaf_index, size); buckets follow backward-pass order (last layer
    first) like WFBP — leaves are reversed so the first bucket to "arrive"
    holds the deepest layers.
    """
    leaves, treedef = jax.tree.flatten(grads)
    order = list(range(len(leaves)))[::-1]
    buckets, cur, cur_bytes = [], [], 0
    for i in order:
        sz = int(np.prod(leaves[i].shape))
        if cur and (bucket_bytes <= 0 or cur_bytes + sz * 4 > bucket_bytes):
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append((i, sz))
        cur_bytes += sz * 4
    if cur:
        buckets.append(cur)

    def pack(gs):
        ls = jax.tree.leaves(gs)
        return [jnp.concatenate([ls[i].reshape(-1).astype(jnp.float32)
                                 for i, _ in b]) for b in buckets]

    def unpack(bufs):
        ls = jax.tree.leaves(grads)
        out = [None] * len(ls)
        for buf, b in zip(bufs, buckets):
            off = 0
            for i, sz in b:
                out[i] = buf[off:off + sz].reshape(ls[i].shape).astype(ls[i].dtype)
                off += sz
        return jax.tree.unflatten(treedef, out)

    return buckets, pack, unpack


# ---------------------------------------------------------------------------
# The synchronizer
# ---------------------------------------------------------------------------

class GradientSynchronizer:
    def __init__(self, cfg: SyncConfig, axes: Sequence[str]):
        self.cfg = cfg
        self.axes = tuple(axes)
        self.comp = cfg.make_compressor()

    # -- state ---------------------------------------------------------------

    def init_state(self, grads) -> Dict[str, Any]:
        state: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
        if self._uses_ef():
            if self.cfg.compressor == "powersgd":
                state["error"] = jax.tree.map(
                    lambda g: jnp.zeros(g.shape, jnp.float32), grads)
                state["q"] = jax.tree.map(self._init_q, grads)
            elif self.cfg.bucket_bytes <= 0:
                # per-leaf EF in the leaf's natural shape: the residual
                # inherits the leaf's tensor-parallel sharding instead of
                # being replicated by a flat concat (§Perf pair-3 finding)
                state["error"] = jax.tree.map(
                    lambda g: jnp.zeros(g.shape, jnp.float32), grads)
            else:
                _, pack, _ = bucketize(grads, self.cfg.bucket_bytes)
                state["error"] = [jnp.zeros_like(b) for b in pack(grads)]
        return state

    def _uses_ef(self):
        return (self.cfg.error_feedback and self.cfg.compressor != "none")

    def _init_q(self, g):
        if g.ndim < 2 or g.size < DENSE_SMALL:
            return jnp.zeros((0,), jnp.float32)
        rank = dict(self.cfg.compressor_args).get("rank", 4)
        n, d = g.shape[0], int(np.prod(g.shape[1:]))
        r = min(rank, n, d)
        return jax.random.normal(jax.random.PRNGKey(g.ndim * 7919 + d),
                                 (d, r), jnp.float32)

    # -- wire statistics (static) ---------------------------------------------

    def payload_bits(self, grads) -> int:
        """Bits leaving one rank per step (the survey's comparison metric)."""
        if self.cfg.compressor == "powersgd":
            total = 0
            for g in jax.tree.leaves(grads):
                total += self.comp.payload_bits(g.shape)
            return total
        bucket_defs, pack, _ = bucketize(grads, self.cfg.bucket_bytes)
        return sum(self.comp.payload_bits((sum(sz for _, sz in b),))
                   for b in bucket_defs)

    # -- sync ------------------------------------------------------------------

    def __call__(self, grads, state, rng):
        """Returns (synced_grads, new_state). Must run with ``self.axes``
        manual (inside shard_map) — or on a single device where the axes
        have size 1 (degenerate, for unit tests)."""
        cfg = self.cfg
        world = 1
        for ax in self.axes:
            world *= jax.lax.axis_size(ax)
        denom = float(world) if cfg.mean else 1.0

        if cfg.compressor == "none":
            synced = jax.tree.map(
                lambda g: allreduce(g.astype(jnp.float32), cfg.algo, self.axes) / denom,
                grads)
            return synced, {**state, "step": state["step"] + 1}

        if cfg.compressor == "powersgd":
            return self._sync_powersgd(grads, state, denom)

        if cfg.bucket_bytes <= 0:
            return self._sync_per_leaf(grads, state, rng, denom)
        return self._sync_bucketed(grads, state, rng, denom)

    # Per-leaf (no packing): leaves keep their shape and TP sharding.
    def _sync_per_leaf(self, grads, state, rng, denom):
        cfg = self.cfg
        leaves, treedef = jax.tree.flatten(grads)
        errors = (jax.tree.leaves(state["error"]) if self._uses_ef()
                  else [None] * len(leaves))
        rngs = jax.random.split(rng, len(leaves))
        outs, new_errors = [], []
        for g, e, r in zip(leaves, errors, rngs):
            gf = g.astype(jnp.float32)
            corrected = gf + cfg.ef_decay * e if self._uses_ef() else gf
            payload, meta = self.comp.compress(corrected, r)
            g_hat = self.comp.decompress(payload, meta)
            new_errors.append(corrected - g_hat if self._uses_ef() else None)
            if self.comp.aggregatable:
                synced = allreduce(g_hat, cfg.algo, self.axes) / denom
            else:
                synced = self._gather_mean(payload, meta, g_hat, denom)
            outs.append(synced)
        new_state = {"step": state["step"] + 1}
        if self._uses_ef():
            new_state["error"] = jax.tree.unflatten(treedef, new_errors)
        return jax.tree.unflatten(treedef, outs), new_state

    # PowerSGD: allreduce the (P, Q) factors directly (aggregatable).
    def _sync_powersgd(self, grads, state, denom):
        cfg = self.cfg
        leaves, treedef = jax.tree.flatten(grads)
        errs, _ = jax.tree.flatten(state["error"])
        qs = jax.tree.leaves(state["q"])
        out, new_e, new_q = [], [], []
        for g, e, q in zip(leaves, errs, qs):
            gf = g.astype(jnp.float32)
            if q.size == 0:  # small leaf: dense allreduce
                synced = allreduce(gf, cfg.algo, self.axes) / denom
                out.append(synced.astype(g.dtype))
                new_e.append(e)
                new_q.append(q)
                continue
            corrected = gf + cfg.ef_decay * e
            (p_f, q_f), (shape, _) = self.comp.compress(corrected, q_prev=q)
            p_f = allreduce(p_f, cfg.algo, self.axes) / denom
            q_f = allreduce(q_f, cfg.algo, self.axes) / denom
            approx = self.comp.decompress((p_f, q_f), (shape, None))
            new_e.append(corrected - approx)
            new_q.append(q_f)
            out.append(approx.astype(g.dtype))
        return (jax.tree.unflatten(treedef, out),
                {"step": state["step"] + 1,
                 "error": jax.tree.unflatten(treedef, new_e),
                 "q": jax.tree.unflatten(treedef, new_q)})

    # Quantizers / sparsifiers: bucket, EF, compress, all-gather, average.
    def _sync_bucketed(self, grads, state, rng, denom):
        cfg = self.cfg
        _, pack, unpack = bucketize(grads, cfg.bucket_bytes)
        bufs = pack(grads)
        errors = state.get("error", [jnp.zeros_like(b) for b in bufs])
        rngs = jax.random.split(rng, len(bufs))
        synced_bufs, new_errors = [], []
        for buf, e, r in zip(bufs, errors, rngs):
            corrected = buf + cfg.ef_decay * e if self._uses_ef() else buf
            payload, meta = self.comp.compress(corrected, r)
            g_hat = self.comp.decompress(payload, meta)
            new_errors.append(corrected - g_hat if self._uses_ef() else e)
            if self.comp.aggregatable:
                synced = allreduce(g_hat, cfg.algo, self.axes) / denom
            else:
                synced = self._gather_mean(payload, meta, g_hat, denom)
            synced_bufs.append(synced)
        new_state = {"step": state["step"] + 1}
        if self._uses_ef():
            new_state["error"] = new_errors
        return unpack(synced_bufs), new_state

    def _gather_mean(self, payload, meta, g_hat, denom):
        """All-gather the compact payloads over the data axes; every rank
        decompresses and averages (1-bit SGD / DGC wire pattern).  Payload
        pytrees are gathered leaf-wise so the wire carries int8/indices,
        not dense f32.  Static metadata (e.g. shapes) passes through."""
        def is_arr(x):
            return isinstance(x, (jax.Array, jax.core.Tracer))

        def gather(x):
            if not is_arr(x):
                return x
            orig = x.shape
            for ax in self.axes:
                x = jax.lax.all_gather(x, ax)
            return x.reshape((-1,) + orig)

        def index(x, i):
            return x[i] if is_arr(x) else x

        gathered_payload = jax.tree.map(gather, payload)
        gathered_meta = jax.tree.map(gather, meta) if meta is not None else None
        world = 1
        for ax in self.axes:
            world *= jax.lax.axis_size(ax)

        def one(i):
            pl = jax.tree.map(lambda x: index(x, i), gathered_payload)
            mt = (jax.tree.map(lambda x: index(x, i), gathered_meta)
                  if gathered_meta is not None else None)
            return self.comp.decompress(pl, mt)

        total = jax.lax.fori_loop(
            0, world, lambda i, acc: acc + one(i),
            jnp.zeros(g_hat.shape, jnp.float32))
        return total / denom
