"""Gradient synchronization — the survey's taxonomy as one composable step.

Every data-parallel training step runs

    grads -> [bucket] -> [error-feedback + compress] -> collective
          -> [decompress/aggregate] -> synced grads

The execution engine is ``PlanExecutor``: it takes a ``CommPlan`` — an
ordered list of per-bucket ``BucketPlan(leaves, compressor, algo, ...)``
entries (``repro.core.schedule.planner``) — and runs a possibly
HETEROGENEOUS strategy per bucket: one bucket may go dense over psum while
another is top-k compressed over an explicit ring.  Plans come either from
the communication planner (``--sync auto``) or from a single global
``SyncConfig`` via ``plan_from_config`` (the degenerate one-entry-strategy
plan — ``GradientSynchronizer`` below keeps that legacy API).

``SyncConfig`` knobs (all become per-bucket fields of ``BucketPlan``):

  * ``compressor``: none | sign | terngrad | qsgd | int8 | topk | randomk |
    threshold | powersgd | svd                      (§3.2)
  * ``algo``: psum | ring | tree | hierarchical | mesh2d | mesh2d_split (§4.1)
  * ``error_feedback``: EF / residual accumulation  (§3.2.1 Eq. 2)
  * ``bucket_bytes``: MG-WFBP tensor fusion         (§3.3 / §4.2)

Wire semantics (DESIGN.md §5): gather-based compressors (sign, top-k, ...)
all-gather their compact payloads over the data axes and every rank
decompresses + averages — the pattern of 1-bit SGD/DGC, with collective
bytes proportional to the COMPRESSED size.  Aggregatable factorizations
(PowerSGD) allreduce their small factors directly on the selected
collective algorithm.  Must run inside a ``shard_map`` whose manual axes
are exactly ``axes``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import allreduce, local_chunk, reduce_scatter
from repro.core.compression import get_compressor
from repro.core.schedule.planner import (BucketPlan, CommPlan,
                                         form_bucket_indices)

DENSE_SMALL = 4096  # leaves smaller than this stay dense inside PowerSGD


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    compressor: str = "none"
    compressor_args: Tuple[Tuple[str, Any], ...] = ()
    algo: str = "psum"
    error_feedback: bool = True
    ef_decay: float = 1.0
    bucket_bytes: int = 32 * 1024 * 1024   # MG-WFBP fusion granularity
    mean: bool = True                      # divide by world size after reduce

    def make_compressor(self):
        return get_compressor(self.compressor, **dict(self.compressor_args))


# ---------------------------------------------------------------------------
# Bucketing (tensor fusion, MG-WFBP / Horovod-style)
# ---------------------------------------------------------------------------

def bucketize(grads, bucket_bytes: int):
    """Split the flattened gradient pytree into ~bucket_bytes buckets.

    ``bucket_bytes == 0`` means per-leaf buckets WITHOUT concatenation-
    induced reshape: each leaf stays its own flat bucket, so a leaf's
    tensor-parallel sharding survives (flattening a TP-sharded matrix into
    a cross-leaf concat replicates it — the EF-residual memory finding in
    EXPERIMENTS.md §Perf pair 3).

    Returns (bucket_defs, pack, unpack) where bucket_defs is a list of lists
    of (leaf_index, size); buckets follow backward-pass order (last layer
    first) like WFBP — leaves are reversed so the first bucket to "arrive"
    holds the deepest layers.
    """
    leaves, treedef = jax.tree.flatten(grads)
    sizes = [int(np.prod(g.shape)) for g in leaves]
    buckets = [[(i, sizes[i]) for i in idxs]
               for idxs in form_bucket_indices([s * 4 for s in sizes],
                                               bucket_bytes)]

    def pack(gs):
        ls = jax.tree.leaves(gs)
        return [jnp.concatenate([ls[i].reshape(-1).astype(jnp.float32)
                                 for i, _ in b]) for b in buckets]

    def unpack(bufs):
        ls = jax.tree.leaves(grads)
        out = [None] * len(ls)
        for buf, b in zip(bufs, buckets):
            off = 0
            for i, sz in b:
                out[i] = buf[off:off + sz].reshape(ls[i].shape).astype(ls[i].dtype)
                off += sz
        return jax.tree.unflatten(treedef, out)

    return buckets, pack, unpack


# ---------------------------------------------------------------------------
# SyncConfig -> degenerate CommPlan (the legacy single-strategy path)
# ---------------------------------------------------------------------------

def plan_from_config(cfg: SyncConfig, grads) -> CommPlan:
    """The one-strategy ``CommPlan`` a global ``SyncConfig`` induces.

    Mirrors the historical GradientSynchronizer modes exactly (so executing
    the plan is bit-for-bit the old behaviour):

      * ``compressor='none'``     — one dense bucket, leaves synced in their
                                    natural shapes (sharding survives)
      * ``powersgd``              — per-leaf unpacked buckets in tree order
                                    (factorization is shape-aware)
      * ``bucket_bytes <= 0``     — per-leaf unpacked buckets in tree order
      * otherwise                 — ``bucketize`` fusion in backward order
    """
    leaves = jax.tree.leaves(grads)
    sizes = [int(np.prod(g.shape)) for g in leaves]
    if cfg.compressor == "none":
        # per-leaf unfused dense sync, leaves in their natural shapes —
        # the historical behaviour (sharding survives, output stays f32)
        buckets: Tuple[BucketPlan, ...] = (BucketPlan(
            leaves=tuple(range(len(leaves))), compressor="none",
            algo=cfg.algo, bucket_bytes=4 * sum(sizes), pack=False,
            error_feedback=False),)
    elif cfg.compressor == "powersgd":
        buckets = tuple(BucketPlan(
            leaves=(i,), compressor="powersgd",
            compressor_args=cfg.compressor_args, algo=cfg.algo,
            bucket_bytes=4 * sizes[i], pack=False, error_feedback=True,
            ef_decay=cfg.ef_decay) for i in range(len(leaves)))
    elif cfg.bucket_bytes <= 0:
        buckets = tuple(BucketPlan(
            leaves=(i,), compressor=cfg.compressor,
            compressor_args=cfg.compressor_args, algo=cfg.algo,
            bucket_bytes=4 * sizes[i], pack=False,
            error_feedback=cfg.error_feedback, ef_decay=cfg.ef_decay)
            for i in range(len(leaves)))
    else:
        defs, _, _ = bucketize(grads, cfg.bucket_bytes)
        buckets = tuple(BucketPlan(
            leaves=tuple(i for i, _ in b), compressor=cfg.compressor,
            compressor_args=cfg.compressor_args, algo=cfg.algo,
            bucket_bytes=4 * sum(sz for _, sz in b), pack=True,
            error_feedback=cfg.error_feedback, ef_decay=cfg.ef_decay)
            for b in defs)
    return CommPlan(buckets=buckets, mean=cfg.mean)


def sharded_plan_from_config(cfg: SyncConfig, grads) -> CommPlan:
    """The plan ``--shard-state`` induces from a global ``SyncConfig``:
    like :func:`plan_from_config` but dense buckets are PACKED at the
    config's fusion granularity, because the reduce-scatter edge operates
    on fused flat buffers (a bucket is the scatter unit).

    Bit-compat note (DESIGN.md §8): ring-allreduce sums each chunk in a
    ring order determined by the chunk's position, so replicated-vs-sharded
    exactness holds per BUCKET BOUNDARY — executing this same plan on the
    replicated path (PlanExecutor's fused dense exchange) is the reference
    the conformance suite compares against; the legacy per-leaf unpacked
    dense plan differs in the last ulp."""
    if cfg.compressor != "none":
        return dataclasses.replace(plan_from_config(cfg, grads),
                                   shard_state=True)
    bb = cfg.bucket_bytes if cfg.bucket_bytes > 0 else 32 * 2**20
    defs, _, _ = bucketize(grads, bb)
    buckets = tuple(BucketPlan(
        leaves=tuple(i for i, _ in b), compressor="none", algo=cfg.algo,
        bucket_bytes=4 * sum(sz for _, sz in b), pack=True,
        error_feedback=False) for b in defs)
    return CommPlan(buckets=buckets, mean=cfg.mean, shard_state=True)


# ---------------------------------------------------------------------------
# The executor
# ---------------------------------------------------------------------------

class PlanExecutor:
    """Executes a ``CommPlan``: per-bucket (possibly heterogeneous)
    error-feedback + compression + collective exchange.

    State is carried per bucket: ``error`` holds the EF residual (flat
    buffer for packed buckets, leaf-shaped otherwise), ``q`` the PowerSGD
    warm-start factor; entries are None for buckets that need neither, and
    the keys are omitted entirely when no bucket uses them (preserving the
    legacy state schema of the single-config path)."""

    def __init__(self, plan: CommPlan, axes: Sequence[str]):
        self.plan = plan
        self.axes = tuple(axes)
        self.comps = [get_compressor(b.compressor, **dict(b.compressor_args))
                      for b in plan.buckets]
        for j, b in enumerate(plan.buckets):
            if (b.compressor == "powersgd" or
                    (not b.pack and b.compressor != "none")) \
                    and len(b.leaves) != 1:
                raise ValueError(
                    f"bucket {j}: pack=False / powersgd buckets operate on "
                    f"one leaf in its natural shape, got leaves={b.leaves}")

    @staticmethod
    def _bucket_uses_ef(b: BucketPlan) -> bool:
        return b.error_feedback and b.compressor not in ("none",)

    def _check_cover(self, n_leaves: int) -> None:
        """Every leaf must be claimed by exactly one bucket — a partial or
        overlapping plan would otherwise surface as a far-away unflatten /
        optimizer error on a None gradient."""
        claimed = sorted(i for b in self.plan.buckets for i in b.leaves)
        if claimed != list(range(n_leaves)):
            raise ValueError(
                f"CommPlan does not cover the gradient pytree exactly: "
                f"{n_leaves} leaves, bucket indices {claimed}")

    @staticmethod
    def _pack_bucket(leaves, idxs):
        return jnp.concatenate([leaves[i].reshape(-1).astype(jnp.float32)
                                for i in idxs])

    @staticmethod
    def _unpack_bucket(buf, leaves, idxs, out):
        off = 0
        for i in idxs:
            sz = int(np.prod(leaves[i].shape))
            out[i] = buf[off:off + sz].reshape(
                leaves[i].shape).astype(leaves[i].dtype)
            off += sz

    # -- state ---------------------------------------------------------------

    def _init_q(self, g, compressor_args) -> jnp.ndarray:
        if g.ndim < 2 or g.size < DENSE_SMALL:
            return jnp.zeros((0,), jnp.float32)
        rank = dict(compressor_args).get("rank", 4)
        n, d = g.shape[0], int(np.prod(g.shape[1:]))
        r = min(rank, n, d)
        return jax.random.normal(jax.random.PRNGKey(g.ndim * 7919 + d),
                                 (d, r), jnp.float32)

    def init_state(self, grads) -> Dict[str, Any]:
        leaves = jax.tree.leaves(grads)
        self._check_cover(len(leaves))
        state: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
        errors: List[Optional[jnp.ndarray]] = []
        qs: List[Optional[jnp.ndarray]] = []
        for b in self.plan.buckets:
            if b.compressor == "powersgd":
                g = leaves[b.leaves[0]]
                errors.append(jnp.zeros(g.shape, jnp.float32))
                qs.append(self._init_q(g, b.compressor_args))
                continue
            qs.append(None)
            if not self._bucket_uses_ef(b):
                errors.append(None)
            elif b.pack:
                sz = sum(int(np.prod(leaves[i].shape)) for i in b.leaves)
                errors.append(jnp.zeros((sz,), jnp.float32))
            else:
                g = leaves[b.leaves[0]]
                errors.append(jnp.zeros(g.shape, jnp.float32))
        if any(e is not None for e in errors):
            state["error"] = errors
        if any(q is not None for q in qs):
            state["q"] = qs
        return state

    # -- wire statistics (static) ---------------------------------------------

    def payload_bits(self, grads) -> int:
        """Bits leaving one rank per step (the survey's comparison metric)."""
        leaves = jax.tree.leaves(grads)
        total = 0
        for b, comp in zip(self.plan.buckets, self.comps):
            if b.pack and len(b.leaves) > 1:
                sz = sum(int(np.prod(leaves[i].shape)) for i in b.leaves)
                total += comp.payload_bits((sz,))
            else:
                total += sum(comp.payload_bits(leaves[i].shape)
                             for i in b.leaves)
        return total

    # -- sync ------------------------------------------------------------------

    def _world(self) -> float:
        world = 1
        for ax in self.axes:
            world *= jax.lax.axis_size(ax)
        return world

    def __call__(self, grads, state, rng):
        """Returns (synced_grads, new_state). Must run with ``self.axes``
        manual (inside shard_map) — or on a single device where the axes
        have size 1 (degenerate, for unit tests)."""
        plan = self.plan
        leaves, treedef = jax.tree.flatten(grads)
        self._check_cover(len(leaves))
        denom = float(self._world()) if plan.mean else 1.0
        nb = len(plan.buckets)
        rngs = jax.random.split(rng, nb) if nb else []
        errors = state.get("error", [None] * nb)
        qs = state.get("q", [None] * nb)

        out: List[Optional[jnp.ndarray]] = [None] * len(leaves)
        new_errors: List[Optional[jnp.ndarray]] = []
        new_qs: List[Optional[jnp.ndarray]] = []
        for j, (b, comp) in enumerate(zip(plan.buckets, self.comps)):
            if b.compressor == "none":
                if b.pack and len(b.leaves) > 1:
                    # fused dense exchange: ONE collective for the bucket —
                    # what the planner's cost model prices (one α per
                    # bucket, MG-WFBP)
                    buf = self._pack_bucket(leaves, b.leaves)
                    synced = allreduce(buf, b.algo, self.axes) / denom
                    self._unpack_bucket(synced, leaves, b.leaves, out)
                else:
                    # unfused: leaves keep their natural shape (and their
                    # tensor-parallel sharding)
                    for i in b.leaves:
                        out[i] = allreduce(leaves[i].astype(jnp.float32),
                                           b.algo, self.axes) / denom
                new_errors.append(errors[j])
                new_qs.append(qs[j])
            elif b.compressor == "powersgd":
                e, q, synced = self._sync_powersgd_leaf(
                    leaves[b.leaves[0]], errors[j], qs[j], b, comp, denom)
                out[b.leaves[0]] = synced
                new_errors.append(e)
                new_qs.append(q)
            elif not b.pack:
                e, synced = self._sync_buffer(
                    leaves[b.leaves[0]].astype(jnp.float32), errors[j],
                    rngs[j], b, comp, denom)
                out[b.leaves[0]] = synced      # f32, leaf-shaped
                new_errors.append(e)
                new_qs.append(None)
            else:
                buf = self._pack_bucket(leaves, b.leaves)
                e, synced = self._sync_buffer(buf, errors[j], rngs[j], b,
                                              comp, denom)
                self._unpack_bucket(synced, leaves, b.leaves, out)
                new_errors.append(e)
                new_qs.append(None)

        new_state: Dict[str, Any] = {"step": state["step"] + 1}
        if "error" in state:
            new_state["error"] = new_errors
        if "q" in state:
            new_state["q"] = new_qs
        return jax.tree.unflatten(treedef, out), new_state

    # -- sharded-DP sync (reduce-scatter edge, DESIGN.md §8) ------------------

    def sync_shards(self, grads, state, rng):
        """Sharded-DP gradient exchange: per bucket, this rank's CANONICAL
        shard of exactly the synced gradient ``__call__`` would return.

          * dense buckets: true ``reduce_scatter`` (ring / nested-ring; the
            psum algo is psum + local slice, XLA owning the wire) — chunk
            values are bit-identical to the matching allreduce slices;
          * aggregatable compressed (PowerSGD factors, qsgd): the payload
            exchange is unchanged, and the reconstructed approximation is
            sliced locally (zero extra wire);
          * gather-pattern compressed (sign/top-k/int8): the SAME compressed
            payload all-gather as replicated mode — every rank decompresses
            and keeps its owned slice of the sum — so EF residual dynamics
            are bit-identical to replicated mode (the residual corrects
            what this worker SENT, which sharding does not change).

        Returns ``(bucket_shards, new_state)`` where ``bucket_shards[j]`` is
        the (m_j,) f32 mean-gradient shard of plan bucket j; ``new_state``
        has the same schema as ``__call__``'s."""
        plan = self.plan
        leaves, _ = jax.tree.flatten(grads)
        self._check_cover(len(leaves))
        denom = float(self._world()) if plan.mean else 1.0
        nb = len(plan.buckets)
        rngs = jax.random.split(rng, nb) if nb else []
        errors = state.get("error", [None] * nb)
        qs = state.get("q", [None] * nb)

        shards: List[jnp.ndarray] = []
        new_errors: List[Optional[jnp.ndarray]] = []
        new_qs: List[Optional[jnp.ndarray]] = []
        for j, (b, comp) in enumerate(zip(plan.buckets, self.comps)):
            if b.compressor == "none":
                buf = self._pack_bucket(leaves, b.leaves)
                shards.append(reduce_scatter(buf, b.algo, self.axes) / denom)
                new_errors.append(errors[j])
                new_qs.append(qs[j])
            elif b.compressor == "powersgd":
                e, q, synced = self._sync_powersgd_leaf(
                    leaves[b.leaves[0]], errors[j], qs[j], b, comp, denom)
                # factors were already allreduced; the full approximation is
                # in hand on every rank — slice, no extra collective
                shards.append(local_chunk(
                    synced.reshape(-1).astype(jnp.float32), self.axes))
                new_errors.append(e)
                new_qs.append(q)
            else:
                buf = (self._pack_bucket(leaves, b.leaves) if b.pack
                       else leaves[b.leaves[0]].astype(jnp.float32))
                if comp.aggregatable:
                    # like _sync_buffer (fused hook included), but the
                    # dense decompressed sum goes out as a reduce-scatter
                    # instead of an allreduce
                    payload, meta, new_e, g_hat = self._compress_with_ef(
                        buf, errors[j], rngs[j], b, comp)
                    if g_hat is None:
                        g_hat = comp.decompress(payload, meta)
                    new_errors.append(new_e)
                    shards.append(
                        reduce_scatter(g_hat.reshape(-1), b.algo, self.axes)
                        / denom)
                else:
                    # gather-pattern wire: the replicated exchange verbatim
                    # (so EF residual dynamics are bit-identical), then the
                    # owner's slice of the decompressed sum
                    e, synced = self._sync_buffer(buf, errors[j], rngs[j],
                                                  b, comp, denom)
                    new_errors.append(e)
                    shards.append(local_chunk(synced.reshape(-1),
                                              self.axes))
                new_qs.append(None)

        new_state: Dict[str, Any] = {"step": state["step"] + 1}
        if "error" in state:
            new_state["error"] = new_errors
        if "q" in state:
            new_state["q"] = new_qs
        return shards, new_state

    # EF + compress of one flat/leaf-shaped f32 buffer.  Dispatches to the
    # compressor's fused one-pass hook (Pallas kernels, DESIGN.md §11)
    # when the plan allows it; otherwise runs the decomposed reference op
    # chain.  Both are bit-identical in payload and residual under jit —
    # the fused-wire conformance suites pin this.  Returns
    # (payload, meta, new_e, g_hat) with g_hat=None on the fused path
    # (the local reconstruction was folded into the kernel's residual).
    def _compress_with_ef(self, buf, e, rng, b: BucketPlan, comp):
        use_ef = self._bucket_uses_ef(b)
        if b.fused and use_ef and comp.fused_ef_compress is not None:
            payload, meta, new_e = comp.fused_ef_compress(buf, e, b.ef_decay)
            return payload, meta, new_e, None
        corrected = buf + b.ef_decay * e if use_ef else buf
        payload, meta = comp.compress(corrected, rng)
        g_hat = comp.decompress(payload, meta)
        new_e = corrected - g_hat if use_ef else e
        return payload, meta, new_e, g_hat

    # EF + compress + exchange of one flat/leaf-shaped f32 buffer.
    def _sync_buffer(self, buf, e, rng, b: BucketPlan, comp, denom):
        payload, meta, new_e, g_hat = self._compress_with_ef(
            buf, e, rng, b, comp)
        if comp.aggregatable or b.algo == "ring_fused":
            # ring_fused needs a dense f32 operand (it re-compresses per
            # hop), so gather-pattern wires also reconstruct locally and
            # ride the compressed ring instead of the payload all-gather.
            if g_hat is None:
                g_hat = comp.decompress(payload, meta)
            synced = allreduce(g_hat.astype(jnp.float32), b.algo,
                               self.axes) / denom
        else:
            synced = self._gather_mean(comp, payload, meta, buf.shape,
                                       denom, fused=b.fused)
        return new_e, synced

    # PowerSGD: allreduce the (P, Q) factors directly (aggregatable).
    def _sync_powersgd_leaf(self, g, e, q, b: BucketPlan, comp, denom):
        gf = g.astype(jnp.float32)
        if q.size == 0:  # small leaf: dense allreduce
            synced = allreduce(gf, b.algo, self.axes) / denom
            return e, q, synced.astype(g.dtype)
        corrected = gf + b.ef_decay * e
        (p_f, q_f), (shape, _) = comp.compress(corrected, q_prev=q)
        p_f = allreduce(p_f, b.algo, self.axes) / denom
        q_f = allreduce(q_f, b.algo, self.axes) / denom
        approx = comp.decompress((p_f, q_f), (shape, None))
        return corrected - approx, q_f, approx.astype(g.dtype)

    def _gather_mean(self, comp, payload, meta, shape, denom,
                     fused: bool = True):
        """All-gather the compact payloads over the data axes; every rank
        decompresses and averages (1-bit SGD / DGC wire pattern).  Payload
        pytrees are gathered leaf-wise so the wire carries int8/indices,
        not dense f32.  Static metadata (e.g. shapes) passes through.

        When the compressor provides ``fused_decode_sum`` (and the bucket
        runs fused), the per-rank decompress loop collapses into ONE
        fused dequantize+accumulate kernel pass over the gathered
        payloads — each payload read once, the dense sum written once."""
        def is_arr(x):
            return isinstance(x, (jax.Array, jax.core.Tracer))

        def gather(x):
            if not is_arr(x):
                return x
            orig = x.shape
            for ax in self.axes:
                x = jax.lax.all_gather(x, ax)
            return x.reshape((-1,) + orig)

        def index(x, i):
            return x[i] if is_arr(x) else x

        gathered_payload = jax.tree.map(gather, payload)
        gathered_meta = jax.tree.map(gather, meta) if meta is not None else None
        world = self._world()

        if fused and comp.fused_decode_sum is not None:
            return comp.fused_decode_sum(gathered_payload,
                                         gathered_meta) / denom

        def one(i):
            pl = jax.tree.map(lambda x: index(x, i), gathered_payload)
            mt = (jax.tree.map(lambda x: index(x, i), gathered_meta)
                  if gathered_meta is not None else None)
            return comp.decompress(pl, mt)

        total = jax.lax.fori_loop(
            0, world, lambda i, acc: acc + one(i),
            jnp.zeros(shape, jnp.float32))
        return total / denom


# ---------------------------------------------------------------------------
# Legacy single-config front-end (degenerate one-strategy plan)
# ---------------------------------------------------------------------------

class GradientSynchronizer:
    """Single global ``SyncConfig`` applied to every bucket — now a thin
    wrapper that lowers the config to a degenerate ``CommPlan`` (one strategy
    everywhere) and lets ``PlanExecutor`` run it.  Kept because a fixed
    config is the right tool when you already know the answer (benchmarks,
    ablations) and as the API every existing caller/test uses."""

    def __init__(self, cfg: SyncConfig, axes: Sequence[str]):
        self.cfg = cfg
        self.axes = tuple(axes)
        # eager validation (unknown compressor/args fail at construction,
        # not at the first traced call) + the legacy public attribute
        self.comp = cfg.make_compressor()
        self._executor: Optional[PlanExecutor] = None
        self._plan_key = None

    def _exec_for(self, grads) -> PlanExecutor:
        # plans depend on tree structure AND leaf shapes (bucketize)
        key = (jax.tree.structure(grads),
               tuple(g.shape for g in jax.tree.leaves(grads)))
        if self._executor is None or key != self._plan_key:
            self._executor = PlanExecutor(plan_from_config(self.cfg, grads),
                                          self.axes)
            self._plan_key = key
        return self._executor

    def init_state(self, grads) -> Dict[str, Any]:
        return self._exec_for(grads).init_state(grads)

    def payload_bits(self, grads) -> int:
        """Bits leaving one rank per step (the survey's comparison metric)."""
        return self._exec_for(grads).payload_bits(grads)

    def __call__(self, grads, state, rng):
        return self._exec_for(grads)(grads, state, rng)
