"""Compressed ring allreduce with double-buffered compress/permute overlap
(survey §4.1 × §3.2/§3.4 — the overlap chapter applied INSIDE the
collective; prototype, DESIGN.md §11).

Structure of one axis: the classic two-phase ring (``ring.py``), but every
hop's payload is per-tile int8 + f32 scales (~4× fewer wire bytes), with
re-quantization of the partial sums at each reduce-scatter hop:

  reduce-scatter, step s:  quantize own outgoing chunk -> ppermute the
                           (q, scales) payload -> dequantize + accumulate
  all-gather:              quantize the completed chunk once; circulate the
                           int8 payload p-1 hops; every rank (OWNER
                           INCLUDED) dequantizes the same payload, so all
                           ranks reconstruct identical values.

DOUBLE BUFFERING: the flat buffer is split into ``streams`` independent
sub-buffers whose per-step ops interleave in one loop.  Stream A's
quantize/dequantize has no data dependency on stream B's ppermute in the
same step, so the compiler (XLA/Mosaic) is free to overlap chunk i's
compress with chunk i-1's permute — the survey's overlap schedule at the
intra-collective level.  The schedule is expressed as op-level
independence, not enforced; measured overlap is whatever the backend
extracts (benchmarks/bench_collectives.py reports it).

ERROR SEMANTICS: lossy.  Error feedback (when the executor pairs this
algo with the ``int8_fused`` wire) corrects only the FIRST quantization —
the sender's EF'd payload; the per-hop requantization error of partial
sums is uncorrected (bounded by scale/254 per element per hop).
Requantizing a freshly-dequantized tile is near-lossless (the tile's max
realigns with scale), so at p=2 the wire degenerates to the plain
compressed exchange.  Exactness-conformance wires therefore must not use
this algo; the planner only pairs it with compressed candidates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _ring_perm(p):
    return [(i, (i + 1) % p) for i in range(p)]


def _pad_chunks(part, p: int):
    n = part.shape[0]
    m = -(-n // p)
    return jnp.pad(part, (0, m * p - n)).reshape(p, m), n


def ring_fused_allreduce(x, axis: str, *, tile: int = ops.TILE,
                         streams: int = 2):
    """Allreduce of ``x`` over one manual mesh axis on the compressed ring.
    Returns the (lossy) sum, identical on every rank."""
    p = jax.lax.axis_size(axis)
    if p == 1:
        return x
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    r = jax.lax.axis_index(axis)
    perm = _ring_perm(p)

    # independent sub-buffers (the double-buffer streams)
    bounds = [round(n * i / streams) for i in range(streams + 1)]
    parts = [flat[bounds[i]:bounds[i + 1]] for i in range(streams)
             if bounds[i + 1] > bounds[i]]
    accs, lens = [], []
    for part in parts:
        a, n0 = _pad_chunks(part, p)
        accs.append(a)
        lens.append(n0)

    # phase 1 — reduce-scatter on the int8 wire.  All streams' encodes are
    # issued before any stream's permute result is consumed: each step's
    # compress is independent of the other stream's in-flight permute.
    for s in range(p - 1):
        sends = []
        for a in accs:
            val = jnp.take(a, (r - s) % p, axis=0)
            sends.append(ops.quantize_tiles(val, tile=tile))
        for t, (q, sc) in enumerate(sends):
            qr = jax.lax.ppermute(q, axis, perm)
            scr = jax.lax.ppermute(sc, axis, perm)
            recv = ops.dequantize(qr, scr, tile=tile)
            ri = (r - s - 1) % p
            accs[t] = jax.lax.dynamic_update_index_in_dim(
                accs[t],
                jax.lax.dynamic_index_in_dim(accs[t], ri, 0, False) + recv,
                ri, 0)

    # phase 2 — all-gather of the quantized completed chunks (rank r owns
    # chunk (r+1)%p after p-1 reduce steps, like ring.py).  The owner
    # dequantizes its OWN payload too: every rank must reconstruct the
    # same values or replicas diverge.
    cur = []
    outs = []
    for a in accs:
        mine = jnp.take(a, (r + 1) % p, axis=0)
        cur.append(ops.quantize_tiles(mine, tile=tile))
        outs.append(jnp.zeros_like(a))
    idx = (r + 1) % p
    for t, (q, sc) in enumerate(cur):
        outs[t] = jax.lax.dynamic_update_index_in_dim(
            outs[t], ops.dequantize(q, sc, tile=tile), idx, 0)
    for _ in range(p - 1):
        nxt = [(jax.lax.ppermute(q, axis, perm),
                jax.lax.ppermute(sc, axis, perm)) for q, sc in cur]
        idx = (idx - 1) % p
        for t, (q, sc) in enumerate(nxt):
            outs[t] = jax.lax.dynamic_update_index_in_dim(
                outs[t], ops.dequantize(q, sc, tile=tile), idx, 0)
        cur = nxt

    out = jnp.concatenate([o.reshape(-1)[:n0]
                           for o, n0 in zip(outs, lens)])
    return out.reshape(x.shape).astype(x.dtype)
