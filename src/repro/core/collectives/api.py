"""Dispatch for the collective algorithms (survey §4.1) plus the α-β cost
model used by benchmarks and the scheduling perf model (§4.2/§4.3: message
libraries and protocols appear here only through their α (latency) and
β (inverse bandwidth) parameters — on TPU the "protocol" layer is ICI and
lives below XLA, see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.collectives.hierarchical import hierarchical_allreduce
from repro.core.collectives.mesh2d import mesh2d_allreduce
from repro.core.collectives.ring import ring_allreduce
from repro.core.collectives.tree import tree_allreduce

ALGOS = ("psum", "ring", "tree", "hierarchical", "mesh2d", "mesh2d_split")


def allreduce(x, algo: str, axes: Sequence[str]):
    """Allreduce ``x`` over one or two *manual* shard_map axes."""
    axes = tuple(axes)
    if algo == "psum":
        return jax.lax.psum(x, axes)
    if algo == "ring":
        out = x
        for ax in axes:
            out = ring_allreduce(out, ax)
        return out
    if algo == "tree":
        out = x
        for ax in axes:
            out = tree_allreduce(out, ax)
        return out
    if algo == "hierarchical":
        if len(axes) == 1:
            return ring_allreduce(x, axes[0])
        return hierarchical_allreduce(x, inner_axis=axes[0], outer_axis=axes[1])
    if algo in ("mesh2d", "mesh2d_split"):
        if len(axes) == 1:
            return ring_allreduce(x, axes[0])
        return mesh2d_allreduce(x, axes[0], axes[1], split=algo == "mesh2d_split")
    raise ValueError(f"unknown collective algo {algo!r}; known: {ALGOS}")


# ---------------------------------------------------------------------------
# α-β (latency-bandwidth) cost model — survey Fig. 10/12 comparisons and the
# §4.3 protocol study are parameter sweeps over this model.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LinkParams:
    alpha_s: float = 1e-6       # per-message latency (s)
    beta_s_per_byte: float = 1.0 / 50e9   # inverse link bandwidth (s/B)


def allreduce_cost_s(algo: str, n_bytes: float, p: int, link: LinkParams,
                     k: Optional[int] = None) -> float:
    """Predicted wall time of one allreduce of n_bytes over p ranks.

    ring:          2(p-1) steps of n/p bytes
    tree (PS):     2 log2(p) steps of n bytes
    hierarchical:  intra ring over k + inter ring over p/k on n/k shards
                   (Jia et al.: 4(k-1) + 2(p/k - 1) steps)
    mesh2d:        two perpendicular ring phases on sqrt(p) ranks
    """
    a, b = link.alpha_s, link.beta_s_per_byte
    if p <= 1:
        return 0.0
    if algo == "ring" or algo == "psum":
        return 2 * (p - 1) * (a + (n_bytes / p) * b)
    if algo == "tree":
        return 2 * np.log2(p) * (a + n_bytes * b)
    if algo == "hierarchical":
        k = k or int(np.sqrt(p))
        inner = 2 * (k - 1) * (a + (n_bytes / k) * b)
        outer = 2 * (p // k - 1) * (a + (n_bytes / k / (p // k)) * b)
        return inner + outer + 2 * (k - 1) * a  # broadcast-phase latency
    if algo in ("mesh2d", "mesh2d_split"):
        px = int(np.sqrt(p))
        py = p // px
        t = (2 * (px - 1) * (a + (n_bytes / px) * b)
             + 2 * (py - 1) * (a + (n_bytes / px / py) * b))
        return t / (2 if algo == "mesh2d_split" else 1)
    raise ValueError(algo)
