"""Dispatch for the collective algorithms (survey §4.1).

The α-β cost model that used to live here moved to
``repro.core.schedule.cost`` so the communication planner, the overlap
simulator, and the benchmarks all consume one copy; ``LinkParams`` and
``allreduce_cost_s`` are re-exported below for existing importers
(deprecated — import from ``repro.core.schedule.cost`` instead).
"""
from __future__ import annotations

from typing import Sequence

import jax

import jax.numpy as jnp

from repro.core.collectives.hierarchical import hierarchical_allreduce
from repro.core.collectives.mesh2d import mesh2d_allreduce
from repro.core.collectives.ring import (ring_all_gather_canonical,
                                         ring_allreduce,
                                         ring_reduce_scatter_canonical)
from repro.core.collectives.ring_fused import ring_fused_allreduce
from repro.core.collectives.tree import tree_allreduce
from repro.core.schedule.cost import (  # noqa: F401  (compat re-export)
    LINK_PRESETS, LinkParams, allreduce_cost_s)

# ring_fused is the LOSSY compressed-ring prototype (int8 wire with per-hop
# requantization, collectives/ring_fused.py) — every other algo sums
# exactly; tolerance-sensitive callers special-case it.
ALGOS = ("psum", "ring", "tree", "hierarchical", "mesh2d", "mesh2d_split",
         "ring_fused")


def axes_for_topology(topo) -> tuple:
    """THE axis→tier mapping of topology-dispatched collectives
    (DESIGN.md §10): shard_map axis names are the tier names, listed
    INNERMOST FIRST.  :func:`allreduce`'s two-axis algorithms take
    ``(inner, outer)`` — hierarchical runs its ring reduce-scatter /
    all-gather on ``axes[0]`` and the shard ring on ``axes[1]`` — so
    with this ordering the bandwidth-heavy inner phases run on the fast
    intra-node tier exactly as ``schedule.cost`` prices them.  Build the
    matching mesh with ``launch.mesh.make_topology_mesh`` (one axis per
    tier, outermost first, named by tier names)."""
    return tuple(t.name for t in reversed(topo.tiers))


def allreduce(x, algo: str, axes: Sequence[str]):
    """Allreduce ``x`` over one or two *manual* shard_map axes.

    For a tiered network the axes come from :func:`axes_for_topology`
    (innermost tier first); on a flat mesh they are the data axes as
    before."""
    axes = tuple(axes)
    if algo == "psum":
        return jax.lax.psum(x, axes)
    if algo == "ring":
        out = x
        for ax in axes:
            out = ring_allreduce(out, ax)
        return out
    if algo == "tree":
        out = x
        for ax in axes:
            out = tree_allreduce(out, ax)
        return out
    if algo == "hierarchical":
        if len(axes) == 1:
            return ring_allreduce(x, axes[0])
        # 3+ axes (a 3+-tier topology): the scattered shard rings over
        # every outer axis, so the reduction covers the full world
        return hierarchical_allreduce(x, inner_axis=axes[0],
                                      outer_axis=axes[1:])
    if algo == "ring_fused":
        out = x
        for ax in axes:
            out = ring_fused_allreduce(out, ax)
        return out
    if algo in ("mesh2d", "mesh2d_split"):
        if len(axes) == 1:
            return ring_allreduce(x, axes[0])
        if len(axes) > 2:
            # silently reducing over two of N axes would leave worker
            # groups diverged — mesh2d is 2-D by construction (the
            # planner filters it on such topologies: _algo_usable)
            raise ValueError(f"mesh2d is a two-axis collective, got "
                             f"axes {tuple(axes)}")
        return mesh2d_allreduce(x, axes[0], axes[1], split=algo == "mesh2d_split")
    raise ValueError(f"unknown collective algo {algo!r}; known: {ALGOS}")


# ---------------------------------------------------------------------------
# Expert-parallel edge: all-to-all along the ep axis (survey §4, DESIGN.md §14)
# ---------------------------------------------------------------------------

A2A_VARIANTS = ("direct", "ring")


def all_to_all(x, axis: str, variant: str = "direct"):
    """The expert-dispatch edge: transpose the leading dim of ``x`` across
    the manual ``axis``.  ``x`` is ``(p, m, ...)`` per rank — chunk ``j``
    is this rank's payload FOR rank ``j`` — and the output is ``(p, m,
    ...)`` where row ``j`` is the chunk received FROM rank ``j``.  Chunks
    move verbatim (no arithmetic), so both variants are bit-identical to
    the gather-and-slice reference and to each other; they differ only in
    wire schedule (``cost.all_to_all_cost_s`` prices the difference).

      * ``direct`` — XLA's fused all-to-all (one launch, all pairs
        exchange concurrently);
      * ``ring`` — p-1 explicit ``ppermute`` rotations, each moving one
        chunk one rotation further (the schedule a torus without all-pair
        connectivity executes; lowers to collective-permute, which the
        HLO conformance checks assert).

    jit-only, inside shard_map, like every collective in this module.
    Autodiff transposes to the reverse all-to-all — exactly the combine
    edge — so expert backward passes need no extra wiring."""
    p = jax.lax.axis_size(axis)
    if x.shape[0] != p:
        raise ValueError(f"all_to_all wants a leading chunk dim of "
                         f"axis_size {p}, got shape {x.shape}")
    if variant == "direct":
        return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
    if variant != "ring":
        raise ValueError(f"unknown all_to_all variant {variant!r}; "
                         f"known: {A2A_VARIANTS}")
    if p == 1:
        return x
    i = jax.lax.axis_index(axis)
    out = x                      # own chunk x[i] is already in place
    for s in range(1, p):
        # rotation s: rank r sends its chunk for rank (r+s)%p and
        # receives, from rank (r-s)%p, that rank's chunk for r
        perm = [(r, (r + s) % p) for r in range(p)]
        send = jax.lax.dynamic_index_in_dim(x, (i + s) % p, axis=0,
                                            keepdims=True)
        recv = jax.lax.ppermute(send, axis, perm)
        out = jax.lax.dynamic_update_index_in_dim(out, recv, (i - s) % p,
                                                  axis=0)
    return out


# ---------------------------------------------------------------------------
# Pipeline edge: neighbour send/recv along the pipe axis (DESIGN.md §9)
# ---------------------------------------------------------------------------

def send_recv(tree, axis: str, shift: int = 1):
    """Point-to-point edge of pipeline parallelism: every rank's payload
    moves to rank ``r + shift`` along the manual ``axis`` (``+1`` carries
    boundary activations forward, ``-1`` carries grad-activations
    backward).  The pipeline does NOT wrap: the edge ranks with no sender
    receive zeros (jax ppermute semantics), which is exactly the masked
    warmup/drain payload the 1F1B executor wants.  jit-only, like every
    shard_map collective in this repo."""
    p = jax.lax.axis_size(axis)
    if shift not in (1, -1):
        raise ValueError(f"send_recv moves one hop, got shift={shift}")
    perm = [(i, i + shift) for i in range(p) if 0 <= i + shift < p]

    def one(x):
        if not perm:                        # single-stage degenerate pipe
            return jnp.zeros_like(x)
        return jax.lax.ppermute(x, axis, perm)

    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# Sharded-DP edges: reduce_scatter / all_gather (survey §3.1.3, DESIGN.md §8)
# ---------------------------------------------------------------------------
#
# Chunking convention (shared with repro.core.shard_state's host-side twin):
# the flat buffer is padded and split NESTED over the manual axes in order —
# first into p1 chunks of m1 = ceil(n/p1), each of those into p2 chunks of
# m2 = ceil(m1/p2), ... — so the canonical owner of the chunk at flat offset
# w*m is the device at row-major mesh position w over the data axes.  The
# nesting is what lets the explicit ring variants scatter one axis at a time
# (hierarchical reduce-scatter) while agreeing bit-for-bit on WHO owns WHAT
# with the psum-based variant and with host-side state initialisation.

def nested_shard_len(n: int, axis_sizes) -> int:
    """Per-rank shard length of an n-element buffer under nested chunking."""
    m = int(n)
    for p in axis_sizes:
        m = -(-m // int(p))
    return m


def pad_to_chunks(flat, axis_sizes):
    """Reorder/pad a flat buffer to canonical chunk-major order
    ((world*m,), chunk w at [w*m, (w+1)*m)) under nested chunking."""
    arr = flat.reshape(1, -1)
    for p in axis_sizes:
        n = arr.shape[-1]
        m = -(-n // int(p))
        arr = jnp.pad(arr, [(0, 0)] * (arr.ndim - 1) + [(0, int(p) * m - n)])
        arr = arr.reshape(arr.shape[:-1] + (int(p), m))
    return arr.reshape(-1)


def my_chunk_index(axes: Sequence[str]):
    """Row-major rank index over the manual ``axes`` (the canonical shard
    this rank owns).  Must run inside shard_map."""
    w = 0
    for ax in axes:
        w = w * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
    return w


def local_chunk(flat, axes: Sequence[str], axis_sizes=None):
    """This rank's canonical chunk of an (already summed) flat buffer —
    the zero-communication fallback used when a full reduction is already
    in hand (psum algo, PowerSGD's reconstructed approximation)."""
    axes = tuple(axes)
    sizes = tuple(axis_sizes) if axis_sizes is not None else tuple(
        jax.lax.axis_size(ax) for ax in axes)
    m = nested_shard_len(flat.size, sizes)
    padded = pad_to_chunks(flat.reshape(-1), sizes)
    return jax.lax.dynamic_slice_in_dim(padded, my_chunk_index(axes) * m, m)


def reduce_scatter(x, algo: str, axes: Sequence[str]):
    """Sum a flat buffer over the manual ``axes`` and return this rank's
    canonical chunk ((m,), nested-padded).

    * ``psum``: XLA allreduce + local slice — bit-identical to the psum
      allreduce path (XLA owns the wire; on TPU it rewrites to a true
      reduce-scatter where profitable).  The α-β model prices the edge as
      a genuine reduce-scatter (cost.reduce_scatter_cost_s).
    * everything else: explicit ring reduce-scatter per axis (one axis =
      ring, the bandwidth-optimal (p-1)·n/p edge; two axes = hierarchical,
      inner ring then outer ring on the 1/p1 shard).  Chunk values are
      bit-identical to the matching slices of ``ring_allreduce``.
    """
    axes = tuple(axes)
    if algo == "psum":
        return local_chunk(jax.lax.psum(x.reshape(-1), axes), axes)
    out = x.reshape(-1)
    for ax in axes:
        out, _ = ring_reduce_scatter_canonical(out, ax)
    return out


def all_gather_shards(shard, n: int, algo: str, axes: Sequence[str]):
    """Inverse edge: every rank contributes its canonical chunk (m,) and
    gets back the full unpadded buffer (n,).  ``psum`` uses XLA's
    all-gather; other algos run the explicit ring gather per axis (inner
    axes first, undoing the nested padding level by level)."""
    axes = tuple(axes)
    sizes = [jax.lax.axis_size(ax) for ax in axes]
    lens = [int(n)]
    for p in sizes[:-1]:
        lens.append(-(-lens[-1] // p))
    out = shard.reshape(-1)
    for ax, ln in zip(reversed(axes), reversed(lens)):
        if algo == "psum":
            out = jax.lax.all_gather(out, ax, tiled=True)
        else:
            out = ring_all_gather_canonical(out, ax)
        out = out[:ln]
    return out
