"""Dispatch for the collective algorithms (survey §4.1).

The α-β cost model that used to live here moved to
``repro.core.schedule.cost`` so the communication planner, the overlap
simulator, and the benchmarks all consume one copy; ``LinkParams`` and
``allreduce_cost_s`` are re-exported below for existing importers
(deprecated — import from ``repro.core.schedule.cost`` instead).
"""
from __future__ import annotations

from typing import Sequence

import jax

from repro.core.collectives.hierarchical import hierarchical_allreduce
from repro.core.collectives.mesh2d import mesh2d_allreduce
from repro.core.collectives.ring import ring_allreduce
from repro.core.collectives.tree import tree_allreduce
from repro.core.schedule.cost import (  # noqa: F401  (compat re-export)
    LINK_PRESETS, LinkParams, allreduce_cost_s)

ALGOS = ("psum", "ring", "tree", "hierarchical", "mesh2d", "mesh2d_split")


def allreduce(x, algo: str, axes: Sequence[str]):
    """Allreduce ``x`` over one or two *manual* shard_map axes."""
    axes = tuple(axes)
    if algo == "psum":
        return jax.lax.psum(x, axes)
    if algo == "ring":
        out = x
        for ax in axes:
            out = ring_allreduce(out, ax)
        return out
    if algo == "tree":
        out = x
        for ax in axes:
            out = tree_allreduce(out, ax)
        return out
    if algo == "hierarchical":
        if len(axes) == 1:
            return ring_allreduce(x, axes[0])
        return hierarchical_allreduce(x, inner_axis=axes[0], outer_axis=axes[1])
    if algo in ("mesh2d", "mesh2d_split"):
        if len(axes) == 1:
            return ring_allreduce(x, axes[0])
        return mesh2d_allreduce(x, axes[0], axes[1], split=algo == "mesh2d_split")
    raise ValueError(f"unknown collective algo {algo!r}; known: {ALGOS}")
