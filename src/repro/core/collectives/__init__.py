from repro.core.collectives.api import (  # noqa: F401
    ALGOS, LinkParams, allreduce, allreduce_cost_s)
from repro.core.collectives.ring import (  # noqa: F401
    ring_allreduce, ring_reduce_scatter, ring_all_gather_chunks)
from repro.core.collectives.tree import tree_allreduce  # noqa: F401
from repro.core.collectives.hierarchical import hierarchical_allreduce  # noqa: F401
from repro.core.collectives.mesh2d import mesh2d_allreduce  # noqa: F401
