from repro.core.collectives.api import (  # noqa: F401
    ALGOS, LinkParams, all_gather_shards, allreduce, allreduce_cost_s,
    axes_for_topology, local_chunk, my_chunk_index, nested_shard_len,
    pad_to_chunks, reduce_scatter, send_recv)
from repro.core.collectives.ring import (  # noqa: F401
    ring_all_gather_canonical, ring_allreduce, ring_reduce_scatter,
    ring_all_gather_chunks, ring_reduce_scatter_canonical)
from repro.core.collectives.ring_fused import ring_fused_allreduce  # noqa: F401
from repro.core.collectives.tree import tree_allreduce  # noqa: F401
from repro.core.collectives.hierarchical import hierarchical_allreduce  # noqa: F401
from repro.core.collectives.mesh2d import mesh2d_allreduce  # noqa: F401
