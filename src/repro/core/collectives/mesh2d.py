"""2D-Mesh / 2D-Torus Allreduce (survey §4.1.2, Fig. 11; Ying et al. 2018;
Mikami et al. 2018).

Gradients are reduced along the two torus dimensions in sequence —
reduce-scatter along X, allreduce of the shards along Y, all-gather along X
— which is the native scheme for TPU ICI (a physical 2D/3D torus).  Ying et
al.'s throughput-doubling trick of summing the two halves of the payload on
perpendicular rings is exposed as ``split=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.collectives.hierarchical import hierarchical_allreduce


def mesh2d_allreduce(x, x_axis: str, y_axis: str, split: bool = False):
    if not split:
        return hierarchical_allreduce(x, inner_axis=x_axis, outer_axis=y_axis)
    # Ying et al: halve the payload; each half reduces on perpendicular ring
    # orders, doubling effective link throughput.
    flat = x.reshape(-1)
    n = flat.shape[0]
    h = n // 2
    a = hierarchical_allreduce(flat[:h], inner_axis=x_axis, outer_axis=y_axis)
    b = hierarchical_allreduce(flat[h:], inner_axis=y_axis, outer_axis=x_axis)
    return jnp.concatenate([a, b]).reshape(x.shape)
