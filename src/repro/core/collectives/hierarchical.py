"""Hierarchical Allreduce (survey §4.1.2, Fig. 12; Jia et al. 2018).

The paper's three phases — intra-group ring, inter-group (masters) ring,
intra-group broadcast — map onto two nested mesh axes in SPMD: a ring
reduce-scatter + all-gather inside the pod (``data`` axis), with the
inter-pod ring (``pod`` axis) run on the *scattered shards* between the two
intra-pod phases.  Because every rank participates symmetrically, the
"master" designation of the GPU formulation disappears (DESIGN.md §5), but
the traffic per link matches: 4(k-1)/k·(n/p_outer) intra + 2(p_outer-1)/
p_outer·(n/k) inter.
"""
from __future__ import annotations

import jax

from repro.core.collectives.ring import (ring_all_gather_chunks,
                                         ring_allreduce, ring_reduce_scatter)


def hierarchical_allreduce(x, inner_axis: str, outer_axis):
    """Ring RS over ``inner_axis``; ring allreduce of the shard over
    ``outer_axis`` — a single axis name or a sequence of them (a 3+-tier
    topology: the scattered shard rings over each outer axis in turn,
    innermost outer tier first, which sums over all of them); ring AG
    over ``inner_axis``."""
    outer_axes = (outer_axis,) if isinstance(outer_axis, str) else \
        tuple(outer_axis)
    p_in = jax.lax.axis_size(inner_axis)
    if p_in == 1:
        out = x
        for ax in outer_axes:
            out = ring_allreduce(out, ax)
        return out
    mine, my_idx, n = ring_reduce_scatter(x, inner_axis)
    for ax in outer_axes:
        mine = ring_allreduce(mine, ax)
    gathered = ring_all_gather_chunks(mine, my_idx, p_in, inner_axis)
    return gathered.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)
