"""Ring Allreduce (survey §4.1.2, Fig. 10; Baidu 2017; Patarasuk & Yuan 2009).

Implemented as explicit ``lax.ppermute`` steps inside a manual ``shard_map``
axis: a reduce-scatter phase (p-1 steps) followed by an all-gather phase
(p-1 steps), each moving 1/p of the payload per step — the bandwidth-optimal
2(p-1)/p · n total traffic.  The lowered HLO shows 2(p-1) collective-permute
ops, which is what the roofline collective-bytes parser measures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _ring_perm(p):
    return [(i, (i + 1) % p) for i in range(p)]


def _pad_chunks(x, p):
    flat = x.reshape(-1)
    n = flat.shape[0]
    m = -(-n // p)
    flat = jnp.pad(flat, (0, m * p - n))
    return flat.reshape(p, m), n


def ring_reduce_scatter(x, axis: str):
    """Returns (my_chunk (m,), chunk_index) — rank r ends with chunk (r+1)%p."""
    p = jax.lax.axis_size(axis)
    r = jax.lax.axis_index(axis)
    chunks, n = _pad_chunks(x, p)
    perm = _ring_perm(p)
    acc = chunks
    for s in range(p - 1):
        send_i = (r - s) % p
        val = jnp.take(acc, send_i, axis=0)
        recv = jax.lax.ppermute(val, axis, perm)
        recv_i = (r - s - 1) % p
        acc = jax.lax.dynamic_update_index_in_dim(
            acc, jax.lax.dynamic_index_in_dim(acc, recv_i, 0, False) + recv,
            recv_i, 0)
    mine = jax.lax.dynamic_index_in_dim(acc, (r + 1) % p, 0, keepdims=False)
    return mine, (r + 1) % p, n


def ring_all_gather_chunks(mine, my_index, p, axis: str):
    """Inverse phase: circulate each rank's chunk until all ranks hold all."""
    perm = _ring_perm(p)
    out = jnp.zeros((p,) + mine.shape, mine.dtype)
    out = jax.lax.dynamic_update_index_in_dim(out, mine, my_index, 0)
    cur = mine
    idx = my_index
    for _ in range(p - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        idx = (idx - 1) % p
        out = jax.lax.dynamic_update_index_in_dim(out, cur, idx, 0)
    return out


def ring_allreduce(x, axis: str):
    """Bandwidth-optimal allreduce of one tensor over a manual mesh axis."""
    p = jax.lax.axis_size(axis)
    if p == 1:
        return x
    mine, my_idx, n = ring_reduce_scatter(x, axis)
    gathered = ring_all_gather_chunks(mine, my_idx, p, axis)
    return gathered.reshape(-1)[:n].reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# Canonical-ownership variants (sharded data parallelism, DESIGN.md §8)
# ---------------------------------------------------------------------------

def ring_reduce_scatter_canonical(x, axis: str):
    """Reduce-scatter with CANONICAL ownership: rank r ends holding chunk r
    of the padded sum (m = ceil(n/p) elements).

    ``ring_reduce_scatter`` leaves rank r with chunk (r+1) % p; one extra
    ppermute hop relabels ownership without touching the values, so each
    chunk stays bit-identical to the corresponding slice of
    ``ring_allreduce`` — the property the sharded-DP conformance suite
    asserts.  Returns (my_chunk (m,), n_unpadded)."""
    p = jax.lax.axis_size(axis)
    flat = x.reshape(-1)
    if p == 1:
        return flat, flat.shape[0]
    mine, _, n = ring_reduce_scatter(flat, axis)
    # rank r holds chunk (r+1) % p, whose canonical owner is rank (r+1) % p:
    # send one hop forward (rank r receives chunk r from rank r-1).
    return jax.lax.ppermute(mine, axis, _ring_perm(p)), n


def ring_all_gather_canonical(shard, axis: str):
    """Inverse phase for canonically-owned chunks: every rank contributes
    its chunk r (m,) and ends with the full padded buffer (p*m,)."""
    p = jax.lax.axis_size(axis)
    if p == 1:
        return shard.reshape(-1)
    r = jax.lax.axis_index(axis)
    out = ring_all_gather_chunks(shard.reshape(-1), r, p, axis)
    return out.reshape(-1)
