"""Tree reduction / broadcast — the parameter-server communication pattern
(survey §4.1.1, Fig. 9) expressed as an SPMD collective.

The flat PS is reduce-to-root followed by broadcast-from-root; the tree PS
[Mai et al. 2015; Gupta et al. 2016] does both along a binary tree.  On an
SPMD TPU mesh there is no separate server process, but the *traffic pattern*
is reproducible with recursive-distance-doubling ``ppermute`` steps: log2(p)
rounds of full-payload transfers (vs. the ring's 2(p-1) rounds of 1/p each)
— exactly the latency/bandwidth trade the survey discusses.  Requires p to
be a power of two (16, 2 on the production mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _shift_perm(p, d):
    """rank r -> r - d (send towards the root at rank 0)."""
    return [(i, i - d) for i in range(p) if i - d >= 0]


def tree_reduce_to_root(x, axis: str):
    """After log2(p) rounds rank 0 holds the sum; other ranks hold garbage."""
    p = jax.lax.axis_size(axis)
    if p & (p - 1) != 0:
        # a real error, not an assert: `python -O` strips asserts and the
        # doubling loop would then silently drop ranks' contributions.
        # The planner self-filters tree candidates on such worlds
        # (schedule.planner._algo_usable) so auto plans never hit this.
        raise ValueError(f"tree collective requires a power-of-two axis "
                         f"size, got {axis!r} of {p}")
    r = jax.lax.axis_index(axis)
    acc = x
    d = 1
    while d < p:
        recv = jax.lax.ppermute(acc, axis, _shift_perm(p, d))
        # ranks that are multiples of 2d absorb partner at distance d
        take = (r % (2 * d) == 0)
        acc = jnp.where(take, acc + recv, acc)
        d *= 2
    return acc


def tree_broadcast_from_root(x, axis: str):
    p = jax.lax.axis_size(axis)
    r = jax.lax.axis_index(axis)
    d = p // 2
    acc = x
    while d >= 1:
        fwd = [(i, i + d) for i in range(p) if i + d < p]
        recv = jax.lax.ppermute(acc, axis, fwd)
        take = (r % (2 * d) == d)
        acc = jnp.where(take, recv, acc)
        d //= 2
    return acc


def tree_allreduce(x, axis: str):
    """Parameter-server pattern: reduce to rank 0, broadcast back."""
    p = jax.lax.axis_size(axis)
    if p == 1:
        return x
    return tree_broadcast_from_root(tree_reduce_to_root(x, axis), axis)
