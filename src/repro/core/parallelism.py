"""ParallelismSpec — the one object naming HOW the world is factored.

The planner grew one axis at a time — ``shard_state`` (PR 3), pipeline
``(stages, micro_batches)`` (PR 4), the plan-side ``pipe_tier`` placement
(PR 5), and now tensor/expert parallelism — each as its own knob on
``SyncStrategy`` / ``StrategyPlan`` / the CLI.  Wei et al. 2024
(PAPERS.md) frame 3D-parallelism × topology co-design as ONE decision;
this dataclass is that decision's schema: the per-axis group sizes
(``dp × tp × pp × ep`` must tile the world), the tier each model axis is
placed on (``Topology.place`` semantics, DESIGN.md §10), the pipeline's
micro-batch count, and the ZeRO shard-state flag — everything execution
and pricing need to agree on the factorization.

The spec string mirrors ``Topology.from_spec``'s grammar::

    dp=4,tp=2@fast_ici,pp=2@node,micro=8
    ep=2@device,shard

Each entry is ``axis=size[@tier]`` (``@tier`` names the topology tier
the axis consumes; meaningless for ``dp``, which takes whatever ranks
remain), plus the standalone tokens ``micro=M`` (pipeline micro-batches)
and ``shard`` (ZeRO-style optimizer-state sharding over the dp axis).
``dp=0`` (the default) means "infer": :meth:`resolve` fills it from the
world size.  DESIGN.md §14 documents the schema and the deprecation
table for the per-knob surface this replaces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Union

_AXES = ("dp", "tp", "pp", "ep")


@dataclasses.dataclass(frozen=True)
class ParallelismSpec:
    """How the world factors into dp × tp × pp × ep (+ placements).

    ``dp=0`` means *inferred*: :meth:`resolve` divides the world by the
    model axes.  ``micro_batches=0`` means the executor's default (8 for
    a real pipeline, 1 otherwise).  ``*_tier`` names the topology tier
    the axis consumes (empty = let the planner search placements / flat
    network).  ``shard_state`` is the ZeRO memory mode of the dp axis —
    it rides here because it is the same decision space (how optimizer
    state is laid out across the factored world), and because it is
    mutually exclusive with ``pp > 1`` (each is its own answer to the
    optimizer-memory axis, DESIGN.md §9)."""
    dp: int = 0
    tp: int = 1
    pp: int = 1
    ep: int = 1
    tp_tier: str = ""
    pp_tier: str = ""
    ep_tier: str = ""
    micro_batches: int = 0
    shard_state: bool = False

    def __post_init__(self):
        if self.dp < 0:
            raise ValueError(f"dp must be >= 1 (or 0 = inferred), "
                             f"got {self.dp}")
        for ax in ("tp", "pp", "ep"):
            n = int(getattr(self, ax))
            if n < 1:
                raise ValueError(f"{ax} must be >= 1, got {n}")
            tier = getattr(self, f"{ax}_tier")
            if tier and n == 1:
                raise ValueError(f"{ax}_tier={tier!r} is meaningless with "
                                 f"{ax}=1")
        if self.micro_batches < 0:
            raise ValueError(f"micro_batches must be >= 0, "
                             f"got {self.micro_batches}")
        if self.pp > 1 and self.shard_state:
            raise ValueError(
                "pp > 1 composes with replicated DP only: the sharded "
                "forward-edge all-gather and the pipeline's boundary sends "
                "are competing answers to the same memory axis — pick one "
                "(DESIGN.md §9)")

    # -- views ---------------------------------------------------------------

    @property
    def model_world(self) -> int:
        """Ranks one model replica spans: tp × pp × ep."""
        return int(self.tp) * int(self.pp) * int(self.ep)

    @property
    def world(self) -> int:
        """Total ranks (requires a resolved dp)."""
        if self.dp < 1:
            raise ValueError(f"spec {self.spec()!r} has unresolved dp=0; "
                             f"call resolve(world_or_topology) first")
        return self.dp * self.model_world

    @property
    def is_trivial(self) -> bool:
        """Pure replicated data parallelism, no micro-batching, no shard."""
        return (self.model_world == 1 and not self.shard_state
                and self.micro_batches in (0, 1))

    @property
    def has_model_axes(self) -> bool:
        return self.model_world > 1

    def spec(self) -> str:
        """The canonical spec string (``from_spec`` round-trips it)."""
        parts = []
        if self.dp:
            parts.append(f"dp={self.dp}")
        for ax in ("tp", "pp", "ep"):
            n = getattr(self, ax)
            tier = getattr(self, f"{ax}_tier")
            if n > 1:
                parts.append(f"{ax}={n}" + (f"@{tier}" if tier else ""))
        if self.micro_batches:
            parts.append(f"micro={self.micro_batches}")
        if self.shard_state:
            parts.append("shard")
        return ",".join(parts)

    def describe(self) -> str:
        return self.spec() or "dp (replicated)"

    # -- construction --------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: str) -> "ParallelismSpec":
        """Parse ``"dp=4,tp=2@fast_ici,pp=2@node,micro=8"`` (mirrors
        ``Topology.from_spec``'s grammar and error style)."""
        kw: Dict[str, Any] = {}

        def put(key, value, part):
            if key in kw:
                raise ValueError(f"duplicate axis in parallelism spec: "
                                 f"{part!r}")
            kw[key] = value

        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            if part == "shard":
                put("shard_state", True, part)
                continue
            body, _, tier = part.partition("@")
            try:
                axis, size = body.split("=")
                axis, size = axis.strip(), int(size)
            except ValueError:
                raise ValueError(
                    f"bad parallelism entry {part!r} (want axis=size[@tier]"
                    f", e.g. tp=2@fast_ici, or the tokens micro=M / shard)"
                ) from None
            if axis == "micro":
                if tier:
                    raise ValueError(f"micro takes no tier placement: "
                                     f"{part!r}")
                put("micro_batches", size, part)
            elif axis in _AXES:
                put(axis, size, part)
                if tier:
                    if axis == "dp":
                        raise ValueError(
                            f"dp takes no tier placement ({part!r}): it "
                            f"spans whatever ranks the model axes leave")
                    put(f"{axis}_tier", tier.strip(), part)
            else:
                raise ValueError(f"unknown parallelism axis {axis!r} in "
                                 f"{part!r}; known: "
                                 f"{', '.join(_AXES)}, micro")
        return cls(**kw)

    @classmethod
    def coerce(cls, value: Union["ParallelismSpec", str, None]
               ) -> "ParallelismSpec":
        """``None`` → trivial spec; a string → :meth:`from_spec`."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        return cls.from_spec(value)

    # -- validation ----------------------------------------------------------

    def resolve(self, net) -> "ParallelismSpec":
        """Validate against a world size (int) or a
        :class:`~repro.core.schedule.topology.Topology` and return the
        spec with ``dp`` filled in.  Raises loudly when the axis product
        does not tile the world or a named tier does not exist / cannot
        host the axis — the planner's divisibility guard."""
        world = int(net) if isinstance(net, int) else int(net.world)
        mw = self.model_world
        if world % mw:
            raise ValueError(
                f"parallelism spec {self.spec()!r}: model axes tp×pp×ep = "
                f"{mw} do not divide world {world}")
        dp = world // mw
        if self.dp and self.dp != dp:
            raise ValueError(
                f"parallelism spec {self.spec()!r}: dp={self.dp} × "
                f"tp={self.tp} × pp={self.pp} × ep={self.ep} = "
                f"{self.dp * mw} != world {world}")
        if not isinstance(net, int):
            names = [t.name for t in net.tiers]
            for ax in ("tp", "pp", "ep"):
                tier = getattr(self, f"{ax}_tier")
                if not tier:
                    continue
                match = [t for t in net.tiers if t.name == tier]
                if not match:
                    raise ValueError(
                        f"parallelism spec {self.spec()!r}: no tier named "
                        f"{tier!r} in topology {net.spec()} "
                        f"(tiers: {names})")
                size = int(getattr(self, ax))
                if match[0].size % size:
                    raise ValueError(
                        f"parallelism spec {self.spec()!r}: {ax}={size} "
                        f"does not divide tier {tier}:{match[0].size}")
        return dataclasses.replace(self, dp=dp)

    def validate(self, net) -> None:
        self.resolve(net)

    # -- legacy bridge (the PR 3-5 per-knob surface) -------------------------

    @classmethod
    def legacy(cls, shard_state: bool = False, pipeline_stages: int = 1,
               micro_batches: int = 1, pipe_tier: str = "") -> \
            "ParallelismSpec":
        """Build a spec from the deprecated per-knob trio (+ the plan-side
        ``pipe_tier``) — what the warned CLI shims and the
        ``SyncStrategy`` pass-through constructor produce."""
        pp = max(int(pipeline_stages), 1)
        micro = int(micro_batches)
        if pp == 1 and micro <= 1:
            micro = 0       # the executor default, not an explicit pin
        return cls(pp=pp, pp_tier=pipe_tier if pp > 1 else "",
                   micro_batches=micro, shard_state=bool(shard_state))

    # -- record schema (DESIGN.md §14) ---------------------------------------

    def to_record(self) -> Dict[str, Any]:
        """The plan-record ``parallelism`` block: additive, emitted only
        for non-trivial specs so pre-existing records keep their exact
        key set (the PR 8 schema-compat rule)."""
        rec: Dict[str, Any] = {"spec": self.spec(), "dp": int(self.dp),
                               "tp": int(self.tp), "pp": int(self.pp),
                               "ep": int(self.ep)}
        for ax in ("tp", "pp", "ep"):
            tier = getattr(self, f"{ax}_tier")
            if tier:
                rec[f"{ax}_tier"] = tier
        if self.micro_batches:
            rec["micro_batches"] = int(self.micro_batches)
        if self.shard_state:
            rec["shard_state"] = True
        return rec

    @classmethod
    def from_record(cls, rec: Dict[str, Any]) -> "ParallelismSpec":
        return cls(dp=int(rec.get("dp", 0)), tp=int(rec.get("tp", 1)),
                   pp=int(rec.get("pp", 1)), ep=int(rec.get("ep", 1)),
                   tp_tier=rec.get("tp_tier", ""),
                   pp_tier=rec.get("pp_tier", ""),
                   ep_tier=rec.get("ep_tier", ""),
                   micro_batches=int(rec.get("micro_batches", 0)),
                   shard_state=bool(rec.get("shard_state", False)))
