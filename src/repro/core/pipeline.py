"""Inter-layer pipeline parallelism (survey §3.1.3 / §3.3; GPipe, PipeDream).

The third execution mode next to replicated and sharded data parallelism:
the model is partitioned into ``S`` contiguous *stages*, the global batch is
split into ``M`` micro-batches, and stages exchange boundary activations
(forward) and grad-activations (backward) over a point-to-point
``send_recv`` edge along a ``pipe`` mesh axis.  What this trades is the
survey's central quantity: instead of every worker allreducing the FULL
gradient, each pipe rank data-parallel-syncs only its stage's 1/S of the
parameters over world/S replicas — activation-sized p2p traffic plus the
1F1B bubble buy an S× cut of the gradient wire.

This module owns the *scheduling* layer, all host-side and deterministic:

  * :func:`balanced_cuts` — contiguous S-way partition of per-cell costs
    minimizing the max stage cost (the stage-cut search; per-cell FLOPs are
    taken ∝ parameter bytes, the roofline's matmul-dominated estimate that
    ``profiles_from_sizes`` already uses for backward time);
  * :func:`schedule_1f1b` — the canonical one-forward-one-backward order
    per stage (warmup ``S-1-s`` forwards, steady 1F/1B, drain);
  * :func:`simulate_1f1b` — dependency-driven timeline of that order;
  * :func:`bubble_fraction` — ``(S-1)/(S-1+M)``, the idle fraction the
    simulation realises for uniform stages;
  * :func:`aligned_ticks` — the SPMD slot grid the executor in
    ``launch/steps.make_pipeline_train_step`` runs (see DESIGN.md §9 for
    why lockstep ppermute rendezvous doubles the warmup depth without
    changing the per-stage F/B order or the O(S) in-flight bound);
  * :class:`StagedModel` — splits a registered ``repro.models.Model`` into
    a shared (embed / final-norm / lm-head) part plus homogeneous per-stage
    layer rows, with the stage forward / loss-tail callables the executor
    composes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# t_forward / t_backward for the matmul-dominated stacks this repo models:
# profile_backward() returns 2/3 of a grad step as backward, so forward is
# half the backward.  The 1F1B bubble idles BOTH passes, which is why the
# planner's pipeline arm charges bubble * (1 + PIPE_FWD_FRACTION) * t_bwd.
PIPE_FWD_FRACTION = 0.5


def bubble_fraction(n_stages: int, micro_batches: int) -> float:
    """Idle fraction of the canonical 1F1B (and GPipe) timeline with
    uniform stages: (S-1)/(S-1+M)."""
    s, m = int(n_stages), int(micro_batches)
    if s <= 1:
        return 0.0
    if m < 1:
        raise ValueError(f"micro_batches must be >= 1, got {m}")
    return (s - 1) / (s - 1 + m)


# ---------------------------------------------------------------------------
# Stage-cut search
# ---------------------------------------------------------------------------

def balanced_cuts(costs: Sequence[float], n_stages: int) -> List[int]:
    """Contiguous partition of ``costs`` into ``n_stages`` parts minimizing
    the maximum part sum (the classic linear-partition DP) — the stage-cut
    search.  Returns boundaries ``cuts`` with ``len == n_stages + 1``,
    ``cuts[0] == 0``, ``cuts[-1] == len(costs)``; stage s covers cells
    ``costs[cuts[s]:cuts[s+1]]``.  Parts are never empty (requires
    ``len(costs) >= n_stages``)."""
    n, s = len(costs), int(n_stages)
    if s < 1:
        raise ValueError(f"n_stages must be >= 1, got {s}")
    if n < s:
        raise ValueError(f"cannot cut {n} cells into {s} stages")
    prefix = np.concatenate([[0.0], np.cumsum(np.asarray(costs, float))])
    # dp[k][i] = minimal max-part-sum splitting costs[:i] into k parts
    INF = float("inf")
    dp = [[INF] * (n + 1) for _ in range(s + 1)]
    cut = [[0] * (n + 1) for _ in range(s + 1)]
    dp[0][0] = 0.0
    for k in range(1, s + 1):
        for i in range(k, n - (s - k) + 1):
            for j in range(k - 1, i):
                if dp[k - 1][j] == INF:
                    continue
                cand = max(dp[k - 1][j], prefix[i] - prefix[j])
                if cand < dp[k][i]:
                    dp[k][i] = cand
                    cut[k][i] = j
    bounds = [n]
    i = n
    for k in range(s, 0, -1):
        i = cut[k][i]
        bounds.append(i)
    return bounds[::-1]


def stage_costs(costs: Sequence[float], cuts: Sequence[int]) -> List[float]:
    """Per-stage cost sums under ``cuts`` (from :func:`balanced_cuts`)."""
    return [float(sum(costs[cuts[s]:cuts[s + 1]]))
            for s in range(len(cuts) - 1)]


# ---------------------------------------------------------------------------
# The 1F1B schedule
# ---------------------------------------------------------------------------

def schedule_1f1b(n_stages: int, micro_batches: int
                  ) -> List[List[Tuple[str, int]]]:
    """Canonical non-interleaved 1F1B order (PipeDream-flush): stage ``s``
    runs ``S-1-s`` warmup forwards, then alternates one-forward-one-backward
    while forwards remain, then drains the outstanding backwards.  Returns
    one op list per stage, ops as ``("F", m)`` / ``("B", m)``; every stage
    emits exactly M forwards and M backwards, with at most ``S - s``
    micro-batches in flight (the memory bound that is 1F1B's point)."""
    S, M = int(n_stages), int(micro_batches)
    if S < 1 or M < 1:
        raise ValueError((S, M))
    out: List[List[Tuple[str, int]]] = []
    for s in range(S):
        warmup = min(S - 1 - s, M)
        ops: List[Tuple[str, int]] = [("F", m) for m in range(warmup)]
        nf, nb = warmup, 0
        while nb < M:
            if nf < M:
                ops.append(("F", nf))
                nf += 1
            ops.append(("B", nb))
            nb += 1
        out.append(ops)
    return out


def simulate_1f1b(n_stages: int, micro_batches: int, t_f: float, t_b: float,
                  t_send: float = 0.0) -> float:
    """Dependency-driven makespan of the canonical 1F1B order: F(m)@s needs
    F(m)@(s-1) (+ one activation send), B(m)@s needs B(m)@(s+1) (+ one
    grad-activation send) and its own F(m); each stage executes its
    :func:`schedule_1f1b` list in order on one execution unit.  For uniform
    stages and ``t_send=0`` this lands exactly on
    ``(M + S - 1) * (t_f + t_b)`` — i.e. :func:`bubble_fraction` of the
    timeline is idle."""
    S, M = int(n_stages), int(micro_batches)
    sched = schedule_1f1b(S, M)
    ptr = [0] * S
    free = [0.0] * S
    end: Dict[Tuple[str, int, int], float] = {}
    remaining = sum(len(ops) for ops in sched)
    while remaining:
        best_s, best_start = -1, float("inf")
        for s in range(S):
            if ptr[s] >= len(sched[s]):
                continue
            op, m = sched[s][ptr[s]]
            if op == "F":
                # activation arrives from the left neighbour (one send)
                dep = 0.0 if s == 0 else end.get(("F", s - 1, m))
                hop = t_send if s > 0 else 0.0
            elif s == S - 1:
                # last stage seeds the backward from its own forward
                dep = end.get(("F", s, m))
                hop = 0.0
            else:
                # grad-activation arrives from the right neighbour
                dep = end.get(("B", s + 1, m))
                hop = t_send
            if dep is None:
                continue                     # dependency not yet scheduled
            start = max(free[s], dep + hop)
            if start < best_start:
                best_s, best_start = s, start
        if best_s < 0:
            raise RuntimeError("1F1B schedule deadlocked (bug)")
        s = best_s
        op, m = sched[s][ptr[s]]
        dur = t_f if op == "F" else t_b
        end[(op, s, m)] = best_start + dur
        free[s] = best_start + dur
        ptr[s] += 1
        remaining -= 1
    return max(free)


def aligned_ticks(n_stages: int, micro_batches: int) -> int:
    """Number of slot-grid ticks the SPMD executor runs: the boundary
    ppermutes are collective rendezvous, so F-slots and B-slots are globally
    aligned; earliest-start on that grid puts F(m)@s at tick ``m + s`` and
    B(m)@s at tick ``m + 2(S-1) - s`` — T = M + 2(S-1) ticks, at most
    ``2(S-1-s) + 1`` micro-batches in flight at stage s (still O(S); see
    DESIGN.md §9)."""
    S, M = int(n_stages), int(micro_batches)
    return M + 2 * (S - 1)


def aligned_order(n_stages: int, micro_batches: int
                  ) -> List[List[Tuple[str, int]]]:
    """Per-stage op order realized by the aligned slot grid (for tests:
    same relative F order, same relative B order, F(m) before B(m) as
    :func:`schedule_1f1b`, deeper warmup)."""
    S, M = int(n_stages), int(micro_batches)
    out = []
    for s in range(S):
        ops: List[Tuple[str, int]] = []
        for k in range(aligned_ticks(S, M)):
            mf = k - s
            if 0 <= mf < M:
                ops.append(("F", mf))
            mb = k - 2 * (S - 1) + s
            if 0 <= mb < M:
                ops.append(("B", mb))
        out.append(ops)
    return out


# ---------------------------------------------------------------------------
# Staged models
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageLayout:
    """Static geometry of a staged model: ``rows`` layer rows split into
    ``n_stages`` equal groups of ``rows_per_stage`` (homogeneous SPMD
    stages: every pipe rank runs the same program on its own rows)."""
    n_stages: int
    rows: int
    rows_per_stage: int


class StagedModel:
    """Pipeline adapter for a registered ``repro.models.Model``.

    Splits params into a SHARED part (embed, final norm, lm head — carried
    replicated over the pipe axis; embed grads are owned by stage 0 and
    loss-tail grads by stage S-1, shared via one masked psum) plus
    homogeneous per-stage layer ROWS: the stack's scanned segment rows
    reshaped ``(R, ...) -> (S, R/S, ...)`` with the leading stage axis
    sharded over ``pipe``.

    Staging requires a decoder-only model whose stack is ONE scannable
    segment (homogeneous period) with ``repeats % S == 0`` — the SPMD
    executor runs the same stage program on every pipe rank, which is only
    honest when stages are structurally identical.  Heterogeneous plans
    (leading dense layers, mixed segments) are rejected with an error
    naming the offending structure.
    """

    def __init__(self, model, n_stages: int):
        import jax
        self.model = model
        self.cfg = model.cfg
        S = int(n_stages)
        if self.cfg.is_encoder_decoder:
            raise ValueError("pipeline staging supports decoder-only "
                             "models; encoder-decoder stacks have no single "
                             "layer chain to cut")
        plan = model.plan
        if len(plan) != 1:
            raise ValueError(
                f"pipeline staging requires a homogeneous scannable stack "
                f"(one segment); {self.cfg.name!r} lowers to {len(plan)} "
                f"segments {[(len(s.period), s.repeats) for s in plan]}")
        seg = plan[0]
        R = seg.repeats
        if R % S != 0:
            raise ValueError(f"stack repeats {R} not divisible by "
                             f"n_stages {S}")
        if R > 1:
            # stacked segment: leaves carry a leading (R,) axis
            pass
        elif S != 1:
            raise ValueError(f"single-row stack cannot be cut into {S} "
                             f"stages")
        self.seg = seg
        self.layout = StageLayout(n_stages=S, rows=R, rows_per_stage=R // S)
        self.aux_coef = float(self.cfg.router_aux_coef)
        self._jax = jax

    # -- params --------------------------------------------------------------

    def split(self, params):
        """params -> (shared, rows_stacked): rows leaves reshaped
        (R, ...) -> (S, R/S, ...)."""
        jax = self._jax
        shared = {k: v for k, v in params.items() if k != "stack"}
        stack = params["stack"][0]          # the single segment
        S, rps = self.layout.n_stages, self.layout.rows_per_stage
        if self.layout.rows == 1:
            rows = jax.tree.map(lambda x: x[None, None], stack)
        else:
            rows = jax.tree.map(
                lambda x: x.reshape((S, rps) + x.shape[1:]), stack)
        return shared, rows

    def merge(self, shared, rows_stacked):
        """Inverse of :meth:`split` (checkpointing / inspection)."""
        jax = self._jax
        R = self.layout.rows
        if R == 1:
            stack = jax.tree.map(lambda x: x[0, 0], rows_stacked)
        else:
            stack = jax.tree.map(
                lambda x: x.reshape((R,) + x.shape[2:]), rows_stacked)
        out = dict(shared)
        out["stack"] = [stack]
        return out

    # -- stage programs ------------------------------------------------------

    def embed_mb(self, shared, tokens):
        """Input cell: token embedding of one micro-batch (stage 0 owns the
        real value; other ranks compute it masked)."""
        return self.model._embed(shared, tokens)

    def stage_apply(self, rows, h):
        """One stage: ``rows_per_stage`` period rows applied in sequence
        (the same per-period remat policy as ``transformer.stack_train``).
        Returns (h, aux)."""
        import jax
        import jax.numpy as jnp
        from repro.models.transformer import block_train

        cfg, seg = self.cfg, self.seg
        positions = jnp.arange(h.shape[1])[None, :]
        aux_total = jnp.zeros((), jnp.float32)

        def period_fn(ps, x):
            a = jnp.zeros((), jnp.float32)
            for spec, p in zip(seg.period, ps):
                def blk(p_, h_, spec=spec):
                    return block_train(p_, cfg, spec, h_, positions)
                if len(seg.period) > 2:
                    blk = jax.checkpoint(blk)
                x, aux = blk(p, x)
                a = a + aux
            return x, a

        period_fn = jax.checkpoint(period_fn)
        for i in range(self.layout.rows_per_stage):
            ps = jax.tree.map(lambda x: x[i], rows)
            h, aux = period_fn(ps, h)
            # row-boundary barrier: fusion must not cross a potential cut
            # point, so a row's (sub)graph — and its backward — compiles
            # identically at every stage count (DESIGN.md §9)
            h = jax.lax.optimization_barrier(h)
            aux_total = aux_total + aux
        return h, aux_total

    def loss_tail(self, shared, h, tokens):
        """Head cell: final norm + chunked cross-entropy (stage S-1 owns the
        real value).  Matches ``Model.loss``'s label convention."""
        import jax.numpy as jnp
        from repro.models.layers import rmsnorm
        labels = jnp.concatenate(
            [tokens[:, 1:], -jnp.ones_like(tokens[:, :1])], axis=1)
        h = rmsnorm(shared["final_norm"], h, eps=self.cfg.norm_eps)
        return self.model._chunked_xent(shared, h, labels)


def stage_param_bytes(leaf_bytes: Sequence[float], n_stages: int
                      ) -> List[float]:
    """Per-stage parameter bytes under the balanced cut of ``leaf_bytes``
    (the planner's stage-memory and DP-edge model — leaves in tree order
    are treated as the cuttable cells)."""
    cuts = balanced_cuts(leaf_bytes, n_stages)
    return stage_costs(leaf_bytes, cuts)
