"""Quantization compressors (survey §3.2.1).

  * ``sign``      — 1-bit signSGD [Bernstein et al. 2018; Seide et al. 2014].
                    Biased; pair with error feedback (EF-signSGD,
                    Karimireddy et al. 2019).
  * ``terngrad``  — stochastic ternary {-1, 0, +1} · max|g| [Wen et al. 2017].
                    Unbiased by construction.
  * ``qsgd``      — stochastic s-level quantization with per-tensor L2 scale
                    [Alistarh et al. 2017].  Unbiased, variance bound
                    (1 + beta_{d,s})·||v||^2.
  * ``int8``      — deterministic linear int8 (the "low precision exchange"
                    baseline in the survey's Fig. 7).

Payloads are carried in the smallest JAX dtype that holds them (int8);
``payload_bits`` reports the true wire width (1 bit for sign, ~1.6 for
ternary, log2(2s+1) for QSGD) — the quantity the survey compares.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression.base import Compressor, register


def _l2(g):
    return jnp.sqrt(jnp.sum(jnp.square(g.astype(jnp.float32))))


@register("sign")
def sign_compressor(scale_mode: str = "mean_abs") -> Compressor:
    """1-bit sign quantization with a per-tensor magnitude (1-bit SGD keeps
    the column scale; we keep mean |g| which is the EF-signSGD convention)."""

    def compress(g, rng=None):
        scale = jnp.mean(jnp.abs(g.astype(jnp.float32)))
        return jnp.sign(g).astype(jnp.int8), scale

    def decompress(payload, scale):
        return payload.astype(jnp.float32) * scale

    return Compressor("sign", compress, decompress,
                      payload_bits=lambda shape: int(np.prod(shape)) * 1 + 32,
                      aggregatable=False, unbiased=False)


@register("terngrad")
def terngrad_compressor() -> Compressor:
    """g_hat = s * sign(g) ∘ b,  b ~ Bernoulli(|g| / s),  s = max|g|."""

    def compress(g, rng):
        gf = g.astype(jnp.float32)
        s = jnp.max(jnp.abs(gf))
        p = jnp.where(s > 0, jnp.abs(gf) / s, 0.0)
        b = jax.random.bernoulli(rng, p).astype(jnp.int8)
        return (jnp.sign(gf).astype(jnp.int8) * b), s

    def decompress(payload, s):
        return payload.astype(jnp.float32) * s

    return Compressor("terngrad", compress, decompress,
                      payload_bits=lambda shape: int(np.ceil(np.prod(shape) * np.log2(3))) + 32,
                      aggregatable=True, unbiased=True)


@register("qsgd")
def qsgd_compressor(levels: int = 127) -> Compressor:
    """Stochastic uniform quantization to ``levels`` positive levels (plus
    sign and zero) against the per-tensor L2 norm.  levels=127 fits int8."""
    assert 1 <= levels <= 127

    def compress(g, rng):
        gf = g.astype(jnp.float32)
        norm = _l2(gf)
        x = jnp.where(norm > 0, jnp.abs(gf) / norm * levels, 0.0)
        lo = jnp.floor(x)
        up = jax.random.bernoulli(rng, x - lo).astype(jnp.float32)
        q = (lo + up) * jnp.sign(gf)
        return q.astype(jnp.int8), norm

    def decompress(payload, norm):
        return payload.astype(jnp.float32) * (norm / levels)

    bits = int(np.ceil(np.log2(2 * levels + 1)))
    return Compressor("qsgd", compress, decompress,
                      payload_bits=lambda shape: int(np.prod(shape)) * bits + 32,
                      aggregatable=True, unbiased=True)


@register("int8")
def int8_compressor() -> Compressor:
    """Deterministic linear int8 against max|g| (biased, tiny bias)."""

    def compress(g, rng=None):
        gf = g.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30)
        return jnp.clip(jnp.round(gf / s * 127.0), -127, 127).astype(jnp.int8), s

    def decompress(payload, s):
        return payload.astype(jnp.float32) * (s / 127.0)

    return Compressor("int8", compress, decompress,
                      payload_bits=lambda shape: int(np.prod(shape)) * 8 + 32,
                      aggregatable=True, unbiased=False)
