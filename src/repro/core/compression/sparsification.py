"""Sparsification compressors (survey §3.2.2).

  * ``topk``      — transmit the k largest-|g| entries [Aji & Heafield 2017;
                    Lin et al. DGC 2017].  Pair with residual accumulation
                    (Stich et al. 2018) via GradSync's error-feedback state.
  * ``randomk``   — drop indices uniformly at random, amplify survivors by
                    d/k so the estimate stays unbiased [Wangni et al. 2018].
  * ``threshold`` — static-threshold clipping [Strom 2015]; the survey notes
                    threshold selection is brittle, which our property tests
                    demonstrate (kept for the Fig. 7 comparison).

Payloads are (values, indices) pairs; ``payload_bits`` counts 32 bits each,
the survey's convention.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression.base import Compressor, register


def _flatten(g):
    return g.reshape(-1), g.shape


@register("topk")
def topk_compressor(ratio: float = 0.01, k: int = 0) -> Compressor:
    """Keep the k = max(1, ratio·d) largest-magnitude entries."""

    def _k(d):
        return k if k else max(1, int(d * ratio))

    def compress(g, rng=None):
        flat, shape = _flatten(g.astype(jnp.float32))
        kk = _k(flat.shape[0])
        vals, idx = jax.lax.top_k(jnp.abs(flat), kk)
        return (jnp.take(flat, idx), idx.astype(jnp.int32)), shape

    def decompress(payload, shape):
        vals, idx = payload
        d = int(np.prod(shape))
        return jnp.zeros((d,), jnp.float32).at[idx].set(vals).reshape(shape)

    def bits(shape):
        d = int(np.prod(shape))
        return _k(d) * 64  # 32-bit value + 32-bit index

    return Compressor("topk", compress, decompress, bits,
                      aggregatable=False, unbiased=False)


@register("randomk")
def randomk_compressor(ratio: float = 0.01) -> Compressor:
    """Random-k with d/k amplification (unbiased)."""

    def compress(g, rng):
        flat, shape = _flatten(g.astype(jnp.float32))
        d = flat.shape[0]
        kk = max(1, int(d * ratio))
        idx = jax.random.choice(rng, d, (kk,), replace=False)
        vals = jnp.take(flat, idx) * (d / kk)
        return (vals, idx.astype(jnp.int32)), shape

    def decompress(payload, shape):
        vals, idx = payload
        d = int(np.prod(shape))
        return jnp.zeros((d,), jnp.float32).at[idx].set(vals).reshape(shape)

    def bits(shape):
        d = int(np.prod(shape))
        return max(1, int(d * ratio)) * 64

    return Compressor("randomk", compress, decompress, bits,
                      aggregatable=False, unbiased=True)


@register("threshold")
def threshold_compressor(tau: float = 1e-3) -> Compressor:
    """Static threshold [Strom 2015]: send entries with |g| >= tau.  To keep
    shapes static under jit, entries below tau are zeroed in place (the wire
    format would be sparse; payload_bits reports the *expected* occupancy,
    measured at trace time it is the worst case d)."""

    def compress(g, rng=None):
        gf = g.astype(jnp.float32)
        mask = jnp.abs(gf) >= tau
        return jnp.where(mask, gf, 0.0), None

    def decompress(payload, meta):
        return payload

    return Compressor("threshold", compress, decompress,
                      payload_bits=lambda shape: int(np.prod(shape)) * 64,
                      aggregatable=True, unbiased=False)
