"""Error feedback / residual accumulation (survey §3.2.1 Eq. 2a-2b).

    e_{t+1}   = g_t - g_hat_t            (what compression lost)
    g_hat_{t+1} = Q(g_{t+1} + e_{t+1})   (correct the next step)

For quantizers this is EF-SGD [Seide 2014; Karimireddy 2019]; for
sparsifiers it is local gradient accumulation [Strom 2015; Stich 2018;
DGC].  ``decay`` is the forgetting factor of Wu et al. 2018 (ECQ-SGD).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression.base import Compressor


def init_error_state(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def apply_with_feedback(comp: Compressor, g, e, rng, decay: float = 1.0):
    """One EF step on a single leaf.

    Returns (g_hat, e_new): the decompressed (locally reconstructed) gradient
    that enters the collective, and the updated residual.
    """
    corrected = g.astype(jnp.float32) + decay * e
    payload, meta = comp.compress(corrected, rng)
    g_hat = comp.decompress(payload, meta)
    e_new = corrected - g_hat
    return g_hat, e_new
