from repro.core.compression.base import (  # noqa: F401
    Compressor, get_compressor, identity_compressor, REGISTRY)
from repro.core.compression import (  # noqa: F401
    fused, lowrank, quantization, sparsification)
from repro.core.compression.error_feedback import (  # noqa: F401
    apply_with_feedback, init_error_state)
