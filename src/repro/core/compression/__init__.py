from repro.core.compression.base import (  # noqa: F401
    Compressor, get_compressor, identity_compressor, REGISTRY)
from repro.core.compression import quantization, sparsification, lowrank  # noqa: F401
from repro.core.compression.error_feedback import (  # noqa: F401
    apply_with_feedback, init_error_state)
