"""Compressor interface (survey §3.2).

A compressor maps a gradient leaf ``g`` to a compact payload and back:

    payload, meta = compress(g, rng)
    g_hat         = decompress(payload, meta)

``payload_bits(shape)`` reports the wire size — the quantity the survey's
compression tables compare — and ``aggregatable`` says whether payloads can
be summed directly by a reduce collective (PowerSGD factors, dense fp16) or
must be gathered and decompressed per worker first (sign bits, top-k values).

Stateful schemes (error feedback, residual accumulation, PowerSGD's warm
start) thread their state through ``init_state`` / carried by GradSync.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Compressor:
    name: str
    compress: Callable[..., Tuple[Any, Any]]       # (g, rng) -> (payload, meta)
    decompress: Callable[[Any, Any], jnp.ndarray]  # (payload, meta) -> g_hat
    payload_bits: Callable[[Tuple[int, ...]], int]
    aggregatable: bool = False                     # payloads sum correctly
    unbiased: bool = False                         # E[decompress] == g
    # Fused hot-path hooks (DESIGN.md §11) — wired only by the fused
    # compressors (compression/fused.py), None elsewhere.  When present,
    # PlanExecutor dispatches to them instead of the decomposed
    # EF-add -> compress -> decompress -> EF-update op chain:
    #   fused_ef_compress(g, e, decay) -> (payload, meta, e_new)
    #     one-pass error feedback + compress + residual update;
    #   fused_decode_sum(gathered_payload, gathered_meta) -> sum
    #     one-pass decode+accumulate of all ranks' payloads (leading
    #     world axis on every gathered leaf).
    # Both must be BIT-IDENTICAL (payload and residual) to the decomposed
    # path under jit — the fused-wire conformance suites pin this.
    fused_ef_compress: Optional[Callable[..., Tuple[Any, Any, Any]]] = None
    fused_decode_sum: Optional[Callable[[Any, Any], jnp.ndarray]] = None

    def roundtrip(self, g, rng=None):
        payload, meta = self.compress(g, rng)
        return self.decompress(payload, meta)


def identity_compressor() -> Compressor:
    return Compressor(
        name="none",
        compress=lambda g, rng=None: (g, None),
        decompress=lambda p, m: p,
        payload_bits=lambda shape: int(np.prod(shape)) * 32,
        aggregatable=True,
        unbiased=True,
    )


REGISTRY: Dict[str, Callable[..., Compressor]] = {}


def register(name: str):
    def deco(fn):
        REGISTRY[name] = fn
        return fn
    return deco


register("none")(identity_compressor)


def get_compressor(name: str, **kwargs) -> Compressor:
    if name not in REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name](**kwargs)
