"""Low-rank decomposition compressors (survey §3.2.3).

  * ``powersgd`` — rank-r power-iteration factorization [Vogels et al. 2019].
                   P = M Q;  P <- orthonormalize(P);  Q = M^T P.
                   Factors are linear in M, hence AGGREGATABLE: an allreduce
                   over (P, Q) averages the factorization across workers —
                   the property that makes PowerSGD ring-friendly, unlike
                   gather-based sparsifiers.  Warm-start Q and the error
                   buffer are threaded by GradSync.
  * ``svd``      — ATOMO-style exact rank-r SVD reference [Wang et al. 2018]
                   (expensive; used as the oracle in tests/benchmarks).

Non-matrix leaves (biases, norms) are transmitted dense, as PowerSGD does.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression.base import Compressor, register


def _as_matrix(g) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    shape = g.shape
    if g.ndim < 2:
        return g.reshape(1, -1), shape
    return g.reshape(shape[0], -1), shape


def _orthonormalize(p):
    """Gram-Schmidt (matches the PowerSGD paper; QR would also do)."""
    q, _ = jnp.linalg.qr(p)
    return q


@register("powersgd")
def powersgd_compressor(rank: int = 4) -> Compressor:
    """One power iteration per step.  meta carries the warm-start Q."""

    def compress(g, rng=None, q_prev: Optional[jnp.ndarray] = None):
        m, shape = _as_matrix(g.astype(jnp.float32))
        n, d = m.shape
        r = min(rank, n, d)
        if q_prev is None:
            key = rng if rng is not None else jax.random.PRNGKey(0)
            q_prev = jax.random.normal(key, (d, r), jnp.float32)
        p = _orthonormalize(m @ q_prev)        # (n, r)
        q = m.T @ p                            # (d, r)
        return (p, q), (shape, q)

    def decompress(payload, meta):
        p, q = payload
        shape, _ = meta
        return (p @ q.T).reshape(shape)

    def bits(shape):
        if len(shape) < 2:
            return int(np.prod(shape)) * 32
        n, d = shape[0], int(np.prod(shape[1:]))
        r = min(rank, n, d)
        return (n + d) * r * 32

    return Compressor("powersgd", compress, decompress, bits,
                      aggregatable=True, unbiased=False)


@register("svd")
def svd_compressor(rank: int = 4) -> Compressor:
    """Exact truncated SVD (ATOMO reference oracle)."""

    def compress(g, rng=None):
        m, shape = _as_matrix(g.astype(jnp.float32))
        u, s, vt = jnp.linalg.svd(m, full_matrices=False)
        r = min(rank, s.shape[0])
        return (u[:, :r] * s[:r], vt[:r]), shape

    def decompress(payload, shape):
        us, vt = payload
        return (us @ vt).reshape(shape)

    def bits(shape):
        if len(shape) < 2:
            return int(np.prod(shape)) * 32
        n, d = shape[0], int(np.prod(shape[1:]))
        r = min(rank, n, d)
        return (n + d) * r * 32

    return Compressor("svd", compress, decompress, bits,
                      aggregatable=False, unbiased=False)
