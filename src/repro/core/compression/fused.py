"""Fused compressed wires backed by the Pallas one-pass kernels (§3.2,
DESIGN.md §11).

The historical compressors in ``quantization.py``/``sparsification.py``
execute as separate XLA ops — EF add, quantize/mask, decompress, EF
update — each a full HBM round-trip over the bucket.  The two wires here
carry the SAME information but dispatch to the fused kernels in
``repro.kernels`` (compiled Pallas on TPU, the one-pass jnp lowering
elsewhere; ``kernels/dispatch.py``):

  * ``int8_fused`` — per-TILE int8 + f32 scales (the Pallas-native wire
    format, tighter than per-tensor int8).  Gather-pattern: the (q,
    scales) payload all-gathers and every rank runs ONE fused
    dequantize+accumulate pass over all payloads (``ops.dequant_accum``)
    — exactly one read per payload and one dense write per direction.
  * ``topk_fused`` — per-tile bisection top-k of the EF-corrected
    gradient (DGC-style, same semantics as the ``topk_mask`` kernel).
    The payload is the masked dense buffer, so it is aggregatable: masked
    tiles sum correctly under any reduce collective.

The UNFUSED methods (``compress``/``decompress``) execute the identical
op sequence as decomposed jnp (``kernels/ref.py``) — they are the
reference path the conformance suite pins the fused hooks against
(bit-identical payloads and EF residuals under jit), and what runs when a
``BucketPlan`` sets ``fused=False``.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.compression.base import Compressor, register
from repro.kernels import ops
from repro.kernels import ref as kref


def _flat32(g):
    return g.reshape(-1).astype(jnp.float32)


@register("int8_fused")
def int8_fused_compressor(tile: int = ops.TILE) -> Compressor:
    """Per-tile int8 against max|corrected| per tile.  Payload
    ``(q int8 (n,), scales f32 (ceil(n/tile),))``; meta is the original
    leaf shape (static)."""
    tile = int(tile)

    def compress(g, rng=None):
        q, scales = kref.quantize_tiles_ref(_flat32(g), tile=tile)
        return (q, scales), tuple(g.shape)

    def decompress(payload, shape):
        q, scales = payload
        return kref.dequantize_ref(q, scales, tile=tile).reshape(shape)

    def fused_ef_compress(g, e, decay):
        q, e_new, scales = ops.quantize_ef(_flat32(g), _flat32(e),
                                           decay=float(decay), tile=tile)
        return (q, scales), tuple(g.shape), e_new.reshape(g.shape)

    def fused_decode_sum(gathered_payload, shape):
        q, scales = gathered_payload        # (w, n) int8, (w, ntiles) f32
        return ops.dequant_accum(q, scales, tile=tile).reshape(shape)

    def payload_bits(shape):
        n = int(np.prod(shape))
        return n * 8 + 32 * int(-(-n // tile))

    return Compressor("int8_fused", compress, decompress, payload_bits,
                      aggregatable=False, unbiased=False,
                      fused_ef_compress=fused_ef_compress,
                      fused_decode_sum=fused_decode_sum)


@register("topk_fused")
def topk_fused_compressor(ratio: float = 0.01, tile: int = ops.TILE,
                          iters: int = 16) -> Compressor:
    """Per-tile bisection top-k (the topk_mask kernel's semantics, NOT the
    exact sort oracle).  The payload keeps the kept values dense-in-place,
    so payloads from different ranks sum correctly (aggregatable) while
    ``payload_bits`` reports the survey's (value, index) wire size."""
    ratio, tile, iters = float(ratio), int(tile), int(iters)

    def compress(g, rng=None):
        y = kref.topk_mask_bisect_ref(_flat32(g), ratio=ratio, tile=tile,
                                      iters=iters)
        return y.reshape(g.shape), None

    def decompress(payload, meta):
        return payload

    def fused_ef_compress(g, e, decay):
        y, e_new = ops.topk_ef(_flat32(g), _flat32(e), ratio=ratio,
                               tile=tile, iters=iters, decay=float(decay))
        return y.reshape(g.shape), None, e_new.reshape(g.shape)

    def payload_bits(shape):
        n = int(np.prod(shape))
        k = max(1, int(tile * ratio))
        return min(n, int(-(-n // tile)) * k) * 64   # f32 value + i32 index

    return Compressor("topk_fused", compress, decompress, payload_bits,
                      aggregatable=True, unbiased=False,
                      fused_ef_compress=fused_ef_compress)
