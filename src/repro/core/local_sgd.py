"""Periodic communication (survey §3.1.2): local SGD / model averaging.

Workers run ``tau`` purely-local optimizer steps, then average model
parameters over the data axes (K-AVG / PR-SGD / Local SGD; tau=1 is vanilla
parallel SGD, tau=T is one-shot averaging).  ``post_local`` delays the first
local phase (Stich's post-local SGD: synchronize every step during warmup).

The trainer holds two compiled programs — ``local_step`` (no collective) and
``average_params`` — and alternates them; the communication-rounds count is
exactly T/tau, the quantity in the survey's Table 2.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.collectives import allreduce


@dataclasses.dataclass(frozen=True)
class LocalSGDConfig:
    period: int = 1          # tau; 1 = vanilla parallel SGD
    post_local_after: int = 0  # sync every step for the first N steps
    algo: str = "psum"


@dataclasses.dataclass(frozen=True)
class AsymmetricPushPullConfig:
    """Dean et al. 2012 (survey §3.1.2): workers PUSH gradients every
    ``n_push`` steps and FETCH parameters every ``n_fetch`` steps, decoupling
    the two directions of worker-server traffic."""
    n_push: int = 1
    n_fetch: int = 1

    def __post_init__(self):
        if self.n_push < 1 or self.n_fetch < 1:
            raise ValueError(f"push/fetch cadences must be >= 1, got "
                             f"n_push={self.n_push} n_fetch={self.n_fetch}")

    def should_push(self, step: int) -> bool:
        return (step + 1) % self.n_push == 0

    def should_fetch(self, step: int) -> bool:
        return (step + 1) % self.n_fetch == 0

    def rounds(self, total_steps: int) -> dict:
        return {"push": sum(self.should_push(t) for t in range(total_steps)),
                "fetch": sum(self.should_fetch(t) for t in range(total_steps))}


def average_params(params, axes: Sequence[str], algo: str = "psum"):
    """Model averaging collective (runs inside shard_map over ``axes``)."""
    world = 1
    for ax in axes:
        world *= jax.lax.axis_size(ax)

    def avg(p):
        return (allreduce(p.astype(jnp.float32), algo, tuple(axes))
                / world).astype(p.dtype)

    return jax.tree.map(avg, params)


def should_sync(step: int, cfg: LocalSGDConfig) -> bool:
    """Python-side schedule decision (the trainer alternates compiled fns)."""
    if step < cfg.post_local_after:
        return True
    return (step + 1) % cfg.period == 0


def communication_rounds(total_steps: int, cfg: LocalSGDConfig) -> int:
    return sum(1 for t in range(total_steps) if should_sync(t, cfg))
