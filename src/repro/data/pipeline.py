"""Synthetic sharded token pipeline.

Deterministic, seekable, host-side generation of LM batches (and stub frame
embeddings for the audio arch): each global step's batch is a pure function
of (seed, step), so every data-parallel host can slice its own shard without
coordination and checkpoints can resume mid-stream.  Mirrors the structure
of a real pipeline (shard -> batch -> device layout) without shipping a
tokenizer; examples use a tiny synthetic "language" whose bigram structure
gives optimizers something learnable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embedding_dim: int = 0     # >0: also emit "src" frame embeddings (audio stub)
    structured: bool = True    # learnable bigram structure vs uniform noise


class SyntheticPipeline:
    """``batch(step)`` -> {"tokens": (B, T) int32 [, "src": (B, T, d) f32]}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # a fixed sparse bigram transition table: next ~ (a * cur + b) % V
        # with noise — cheap, stationary, and learnable by a tiny model.
        self._a = int(rng.integers(3, 17)) * 2 + 1
        self._b = int(rng.integers(1, cfg.vocab_size))

    def batch(self, step: int, host_id: int = 0, num_hosts: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        local = cfg.global_batch // num_hosts
        rng = np.random.default_rng((cfg.seed, step, host_id))
        if cfg.structured:
            first = rng.integers(0, cfg.vocab_size, size=(local, 1))
            toks = [first]
            cur = first
            for _ in range(cfg.seq_len - 1):
                noise = rng.integers(0, cfg.vocab_size, size=(local, 1))
                flip = rng.random((local, 1)) < 0.1
                nxt = (self._a * cur + self._b) % cfg.vocab_size
                cur = np.where(flip, noise, nxt)
                toks.append(cur)
            tokens = np.concatenate(toks, axis=1).astype(np.int32)
        else:
            tokens = rng.integers(0, cfg.vocab_size,
                                  size=(local, cfg.seq_len), dtype=np.int32)
        out = {"tokens": tokens}
        if cfg.embedding_dim:
            out["src"] = rng.standard_normal(
                (local, cfg.seq_len, cfg.embedding_dim)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
