"""Elastic fault-tolerant step loop (DESIGN.md §15).

:class:`ElasticRuntime` supervises a :class:`~repro.api.TrainSession`
through a replayable :class:`~repro.elastic.faults.FaultSchedule`:

  * **membership changes** (kill / restore) trigger in-process
    resharding — checkpoint the live session through the portable
    leaf-shaped format, rebuild a fresh session on the
    :func:`~repro.elastic.reshard.surviving_topology`, restore, and (when
    planning is on) re-run the planner search on the surviving fabric.
    No process restart: the loss trajectory continues from the exact
    saved step, and the synthetic data pipeline replays the exact batch
    sequence because batches are a pure function of the step index.
  * **slowdowns** feed a straggler watch: when the worst worker's modeled
    step time exceeds the median by ``straggler_factor`` for
    ``straggler_patience`` consecutive steps, the runtime DEMOTES the
    global round cadence instead of letting the bus stall — first via the
    installed scheduler's ``backpressure`` hook (stretch τ / the LAG
    threshold / push-pull cadences), escalating to a straggler-priced
    re-plan (``TrainSession.replan_now``) when the scheduler has no
    cadence to stretch.

Step execution goes through an injectable executor so fault traces are
replayable without wall clocks: the default :class:`SimulatedExecutor`
runs the REAL training step (losses are genuine) but models per-worker
times from the schedule's slow factors — the same trace always produces
the same trajectory AND the same recovery decisions.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Callable, Dict, List, Optional, Set

from repro.core.schedule.topology import Topology
from repro.elastic.faults import FaultSchedule
from repro.elastic.reshard import surviving_topology


@dataclasses.dataclass(frozen=True)
class StepOutcome:
    """One executed step: the (real) loss plus modeled per-worker wall
    times — the straggler watch's input."""
    loss: float
    worker_times_s: Dict[int, float]


class SimulatedExecutor:
    """Default step executor: real ``step_once`` loss, modeled per-worker
    times (``base_step_s`` scaled by each worker's slow factor).  Pure in
    the trace — no wall clocks — so elastic runs replay bit-for-bit."""

    def __init__(self, base_step_s: float = 0.1):
        self.base_step_s = float(base_step_s)

    def __call__(self, session, step: int, alive: Set[int],
                 slow: Dict[int, float]) -> StepOutcome:
        loss = session.step_once()
        times = {w: self.base_step_s * float(slow.get(w, 1.0))
                 for w in sorted(alive)}
        return StepOutcome(loss=loss, worker_times_s=times)


@dataclasses.dataclass(frozen=True)
class ReshardEvent:
    """One runtime decision, for the report table and the bench suite."""
    step: int
    kind: str                 # "reshard" | "backpressure" | "replan"
    old_world: int
    new_world: int
    topology: str             # surviving Topology spec
    plan_key: str = ""        # installed plan after the event ("" = none)
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Runtime knobs.  ``topology`` is the LAUNCH fabric (spec string,
    preset name, or Topology); its world must equal the fault schedule's.
    ``plan`` re-runs ``plan_auto`` on every reshard so the installed
    strategy always matches the surviving fabric; ``t_backward_s`` pins
    the backward profile those searches use (wall-clock-free replays).
    ``continuity_max_jump`` bounds the allowed loss jump across a reshard
    — resharding through the portable checkpoint is bit-exact, so any
    jump beyond numerical noise is a restore bug and fails loudly."""
    topology: Any
    checkpoint_dir: str
    plan: bool = False
    link: Any = "fast_ici"
    t_backward_s: Optional[float] = 0.05
    plan_kwargs: Optional[Dict[str, Any]] = None
    straggler_factor: float = 2.0
    straggler_patience: int = 2
    backpressure_factor: float = 2.0
    base_step_s: float = 0.1
    continuity_max_jump: float = 1.0


class ElasticRuntime:
    """Supervised elastic step loop over fresh ``TrainSession`` builds.

    ``session_factory`` returns a FRESH, un-built session (same seed and
    config every call — determinism is the factory's contract); the
    runtime applies the surviving topology, restores the checkpoint, and
    re-plans.  Round counters (``grad_rounds`` etc.) aggregate across
    every session generation, so the honest-accounting contract survives
    resharding."""

    def __init__(self, session_factory: Callable[[], Any],
                 schedule: FaultSchedule, cfg: ElasticConfig,
                 executor: Optional[Callable[..., StepOutcome]] = None):
        self.factory = session_factory
        self.schedule = schedule
        self.cfg = cfg
        self.executor = executor or SimulatedExecutor(cfg.base_step_s)
        self.topology: Topology = (
            Topology.from_spec(cfg.topology)
            if isinstance(cfg.topology, str) else cfg.topology)
        if self.topology.world != schedule.world:
            raise ValueError(
                f"fault schedule is against world={schedule.world} but the "
                f"topology {self.topology.spec()!r} has world="
                f"{self.topology.world}")
        self.alive: Set[int] = set(range(schedule.world))
        self.slow: Dict[int, float] = {}
        self.losses: List[float] = []
        self.events: List[ReshardEvent] = []
        self._retired = {"grad_rounds": 0, "param_rounds": 0,
                         "control_rounds": 0}
        self._streak = 0
        self._acted_on: Optional[frozenset] = None
        self.session = self._spawn(self.topology, restore_from=None)

    # -- aggregated counters -------------------------------------------------

    @property
    def grad_rounds(self) -> int:
        return self._retired["grad_rounds"] + self.session.grad_rounds

    @property
    def param_rounds(self) -> int:
        return self._retired["param_rounds"] + self.session.param_rounds

    @property
    def control_rounds(self) -> int:
        return self._retired["control_rounds"] + self.session.control_rounds

    @property
    def comm_rounds(self) -> int:
        return self.grad_rounds + self.param_rounds

    @property
    def plan_key(self) -> str:
        p = self.session.planned
        return p["strategy_plan"].key if p else ""

    # -- session lifecycle ---------------------------------------------------

    def _spawn(self, topo: Topology, restore_from: Optional[str]):
        s = self.factory()
        s.apply_topology(topo)
        if restore_from is not None:
            s.load_checkpoint(restore_from)
        if self.cfg.plan:
            s.plan_auto(self.cfg.link, t_backward_s=self.cfg.t_backward_s,
                        **(self.cfg.plan_kwargs or {}))
        return s

    def _ckpt_path(self) -> str:
        os.makedirs(self.cfg.checkpoint_dir, exist_ok=True)
        return os.path.join(self.cfg.checkpoint_dir, "elastic")

    def _reshard(self, step: int) -> None:
        old_world = self.session.world if self.session.topology is None \
            else self.session.topology.world
        dead = set(range(self.schedule.world)) - self.alive
        new_topo = surviving_topology(self.topology, dead)
        path = self._ckpt_path()
        self.session.save_checkpoint(path)
        for k in self._retired:
            self._retired[k] += getattr(self.session, k)
        self.session = self._spawn(new_topo, restore_from=path)
        self.events.append(ReshardEvent(
            step=step, kind="reshard", old_world=old_world,
            new_world=new_topo.world, topology=new_topo.spec(),
            plan_key=self.plan_key,
            note=f"dead={sorted(dead)}" if dead else "fleet restored"))
        self._streak = 0
        self._acted_on = None

    # -- straggler watch -----------------------------------------------------

    def _watch_stragglers(self, out: StepOutcome, step: int) -> None:
        times = sorted(out.worker_times_s.values())
        if len(times) < 2:
            self._streak = 0
            return
        med = times[len(times) // 2]
        worst = times[-1]
        if med <= 0.0 or worst < self.cfg.straggler_factor * med:
            self._streak = 0
            return
        self._streak += 1
        episode = frozenset(self.slow.items())
        if self._streak < self.cfg.straggler_patience \
                or episode == self._acted_on:
            return
        self._acted_on = episode
        self._streak = 0
        skew_s = worst - med
        old_world = self.topology.world - \
            (self.schedule.world - len(self.alive))
        sess = self.session
        sched = sess.strategy.scheduler if sess.strategy is not None \
            else None
        if sched is not None and sched.supports_backpressure \
                and sched.backpressure(self.cfg.backpressure_factor):
            self.events.append(ReshardEvent(
                step=step, kind="backpressure", old_world=old_world,
                new_world=old_world, topology="", plan_key=self.plan_key,
                note=f"{sched.name} cadence /"
                     f"{self.cfg.backpressure_factor:g} "
                     f"(skew {skew_s * 1e3:.0f} ms)"))
            return
        if sess.planned is not None:
            ev = sess.replan_now(straggler_s=skew_s,
                                 t_backward_s=self.cfg.t_backward_s)
            self.events.append(ReshardEvent(
                step=step, kind="replan", old_world=old_world,
                new_world=old_world, topology="",
                plan_key=ev["new_key"],
                note=("installed" if ev["applied"] else ev["note"])
                + f" (skew {skew_s * 1e3:.0f} ms)"))
            return
        self.events.append(ReshardEvent(
            step=step, kind="backpressure", old_world=old_world,
            new_world=old_world, topology="", plan_key=self.plan_key,
            note=f"no cadence lever (skew {skew_s * 1e3:.0f} ms); "
                 f"straggler tolerated"))

    # -- the supervised loop -------------------------------------------------

    def run(self, steps: int) -> List[float]:
        """Drive the session to ``steps`` total steps under the fault
        schedule; returns every loss executed by THIS call."""
        out: List[float] = []
        while self.session.step < steps:
            step = self.session.step
            changed = False
            for e in self.schedule.events_at(step):
                if e.kind == "kill":
                    self.alive.discard(e.worker)
                    self.slow.pop(e.worker, None)
                    changed = True
                elif e.kind == "restore":
                    self.alive.add(e.worker)
                    changed = True
                else:                                  # slow
                    self.slow[e.worker] = e.factor
            if changed:
                self._reshard(step)
            prev = self.losses[-1] if self.losses else None
            o = self.executor(self.session, step, self.alive, self.slow)
            loss = float(o.loss)
            if not math.isfinite(loss):
                raise RuntimeError(
                    f"loss diverged to {loss} at step {step} "
                    f"(world {len(self.alive)})")
            if changed and prev is not None \
                    and abs(loss - prev) > self.cfg.continuity_max_jump:
                raise RuntimeError(
                    f"loss discontinuity across reshard at step {step}: "
                    f"{prev:.4f} -> {loss:.4f} (max allowed jump "
                    f"{self.cfg.continuity_max_jump}) — restore bug")
            self.losses.append(loss)
            out.append(loss)
            self._watch_stragglers(o, step)
        return out
