"""Deterministic fault injection for the elastic runtime (DESIGN.md §15).

Preemption in real fleets is a stochastic external event; reproducing a
recovery bug requires replaying the *exact* fault sequence.  A
:class:`FaultSchedule` pins that sequence up front — every kill, slowdown
and restore carries the step index it fires at — so an elastic run is a
pure function of (model seed, data seed, fault schedule).  The schedule is
serializable both ways (compact spec strings for CLI flags, JSON for
committed trace files) and the seeded :meth:`FaultSchedule.random`
constructor makes fuzzing replayable: the trace that found a bug IS the
regression test.

Fault kinds:

  * ``kill``    — worker leaves the fleet at the start of the step
                  (preemption / hardware loss).  Triggers resharding.
  * ``restore`` — a previously-killed worker (or a fresh replacement at
                  the same rank) rejoins.  Triggers resharding.
  * ``slow``    — worker stays in the fleet but runs ``factor``× slower
                  (thermal throttle, noisy neighbour).  Does NOT trigger
                  resharding — it feeds the straggler watch instead.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Sequence, Tuple, Union

import numpy as np

VALID_KINDS = ("kill", "slow", "restore")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault, firing at the START of ``step`` against rank ``worker``.

    ``factor`` is only meaningful for ``slow`` (wall-clock multiplier for
    that worker's step time, > 1) — and for ``restore``, where it is
    ignored and a restored worker runs at nominal speed again.
    """
    step: int
    worker: int
    kind: str
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in VALID_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {VALID_KINDS}")
        if self.step < 0 or self.worker < 0:
            raise ValueError(f"step and worker must be >= 0, got "
                             f"step={self.step} worker={self.worker}")
        if self.kind == "slow" and not self.factor > 1.0:
            raise ValueError(f"slow factor must be > 1, got {self.factor}")

    def describe(self) -> str:
        """Compact spec form: ``kill:3@5`` / ``slow:1x4@3`` /
        ``restore:3@9`` (kind:worker[xfactor]@step)."""
        fac = (f"x{self.factor:g}" if self.kind == "slow" else "")
        return f"{self.kind}:{self.worker}{fac}@{self.step}"


def _parse_event(tok: str) -> FaultEvent:
    try:
        kind, rest = tok.split(":", 1)
        body, step = rest.rsplit("@", 1)
        factor = 1.0
        if "x" in body:
            w, f = body.split("x", 1)
            factor = float(f)
        else:
            w = body
        return FaultEvent(step=int(step), worker=int(w), kind=kind.strip(),
                          factor=factor)
    except ValueError as e:
        if "fault kind" in str(e) or "factor" in str(e) or ">= 0" in str(e):
            raise
        raise ValueError(
            f"cannot parse fault spec {tok!r}: expected "
            f"kind:worker[xfactor]@step, e.g. kill:3@5 or slow:1x4@3") \
            from e


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An ordered, validated sequence of :class:`FaultEvent` against a
    fleet of ``world`` workers (ranks 0..world-1).

    Validation replays liveness: kills must target live workers, restores
    dead ones, slowdowns live ones, and at least one worker must survive
    every prefix of the schedule — an impossible trace fails at
    construction, not 40 steps into a run.
    """
    events: Tuple[FaultEvent, ...]
    world: int

    def __post_init__(self):
        if self.world < 1:
            raise ValueError(f"world must be >= 1, got {self.world}")
        ordered = tuple(sorted(self.events,
                               key=lambda e: (e.step, e.worker)))
        object.__setattr__(self, "events", ordered)
        alive = set(range(self.world))
        for e in ordered:
            if e.worker >= self.world:
                raise ValueError(f"{e.describe()}: worker {e.worker} out "
                                 f"of range for world={self.world}")
            if e.kind == "kill":
                if e.worker not in alive:
                    raise ValueError(f"{e.describe()}: worker already dead")
                alive.discard(e.worker)
                if not alive:
                    raise ValueError(f"{e.describe()}: schedule leaves no "
                                     f"survivors")
            elif e.kind == "restore":
                if e.worker in alive:
                    raise ValueError(f"{e.describe()}: worker is not dead")
                alive.add(e.worker)
            else:                                      # slow
                if e.worker not in alive:
                    raise ValueError(f"{e.describe()}: cannot slow a dead "
                                     f"worker")

    # -- queries -------------------------------------------------------------

    def events_at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]

    @property
    def last_step(self) -> int:
        return max((e.step for e in self.events), default=-1)

    # -- (de)serialization ---------------------------------------------------

    def spec(self) -> str:
        """Round-trippable compact form, ``,``-joined event specs."""
        return ",".join(e.describe() for e in self.events)

    @classmethod
    def from_spec(cls, spec: str, world: int) -> "FaultSchedule":
        toks = [t.strip() for t in spec.split(",") if t.strip()]
        return cls(events=tuple(_parse_event(t) for t in toks), world=world)

    def to_json(self) -> Dict[str, Any]:
        return {"world": self.world,
                "events": [dataclasses.asdict(e) for e in self.events]}

    @classmethod
    def from_json(cls, src: Union[str, Dict[str, Any]]) -> "FaultSchedule":
        """Build from a dict or a path to a JSON trace file."""
        if isinstance(src, str):
            with open(src) as f:
                src = json.load(f)
        return cls(events=tuple(FaultEvent(**e) for e in src["events"]),
                   world=int(src["world"]))

    # -- seeded fuzzing ------------------------------------------------------

    @classmethod
    def random(cls, world: int, steps: int, n_faults: int,
               seed: int = 0) -> "FaultSchedule":
        """A replayable random schedule: kills, matched restores two-plus
        steps later when room remains, occasional slowdowns.  Same seed →
        same trace, so a fuzzed failure is immediately a regression test."""
        rng = np.random.default_rng(seed)
        alive = set(range(world))
        events: List[FaultEvent] = []
        for _ in range(n_faults):
            step = int(rng.integers(1, max(steps - 1, 2)))
            roll = rng.random()
            if roll < 0.5 and len(alive) > 1:
                w = int(rng.choice(sorted(alive)))
                events.append(FaultEvent(step=step, worker=w, kind="kill"))
                alive.discard(w)
                back = step + 2 + int(rng.integers(0, 3))
                if back < steps:
                    events.append(FaultEvent(step=back, worker=w,
                                             kind="restore"))
                    alive.add(w)
            elif alive:
                w = int(rng.choice(sorted(alive)))
                events.append(FaultEvent(
                    step=step, worker=w, kind="slow",
                    factor=float(2 + 2 * rng.random())))
        # replay-order sanity: drop events invalidated by reordering
        ordered, live = [], set(range(world))
        for e in sorted(events, key=lambda e: (e.step, e.worker)):
            if e.kind == "kill" and e.worker in live and len(live) > 1:
                ordered.append(e)
                live.discard(e.worker)
            elif e.kind == "restore" and e.worker not in live:
                ordered.append(e)
                live.add(e.worker)
            elif e.kind == "slow" and e.worker in live:
                ordered.append(e)
        return cls(events=tuple(ordered), world=world)


def replay_world_sizes(schedule: FaultSchedule,
                       steps: int) -> Tuple[List[int], List[int]]:
    """Pure host-side replay: per-step fleet size over ``steps`` steps and
    the list of steps whose membership CHANGED (reshard points).  Used by
    the bench suite to pin recovery counts without running a model."""
    alive = set(range(schedule.world))
    sizes, changes = [], []
    for s in range(steps):
        before = len(alive)
        for e in schedule.events_at(s):
            if e.kind == "kill":
                alive.discard(e.worker)
            elif e.kind == "restore":
                alive.add(e.worker)
        if len(alive) != before:
            changes.append(s)
        sizes.append(len(alive))
    return sizes, changes
