"""Elastic fault-tolerant training runtime (DESIGN.md §15): survive
preemption, reshard across world changes without restart, and demote the
sync cadence under stragglers instead of stalling the bus."""
from repro.elastic.faults import (  # noqa: F401
    FaultEvent, FaultSchedule, replay_world_sizes)
from repro.elastic.reshard import surviving_topology  # noqa: F401
from repro.elastic.runtime import (  # noqa: F401
    ElasticConfig, ElasticRuntime, ReshardEvent, SimulatedExecutor,
    StepOutcome)
