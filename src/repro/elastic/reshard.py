"""Topology survival: rebuild the fabric description after preemption.

When workers die the planner must re-search on the fabric that is
actually left, not the one the job launched with (DESIGN.md §15).  This
module maps (old Topology, dead ranks) → surviving Topology, preserving
as much tier structure as the loss pattern allows:

  * flat fabric — just shrink the single tier;
  * uniform loss, d dead per outermost group (d < inner) — every group
    keeps the same shrunken inner stack, so the tiered shape survives
    with the inner size reduced (the inner tiers collapse to one tier of
    the survivors on the innermost — fastest — link, because a partial
    group no longer factorizes over the inner tier product);
  * whole groups lost — drop them, keep the inner stack intact, shrink
    (or drop) the outer tier;
  * anything irregular — fall back to a single flat tier of all
    survivors on the OUTERMOST (slowest) link: a conservative model, it
    over-prices but never under-prices the surviving fabric.

Ranks are row-major over the tier sizes outermost-first, matching
``Topology``'s convention: rank // inner_size = outermost group index.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Set

from repro.core.schedule.topology import Tier, Topology


def surviving_topology(topo: Topology, dead: Iterable[int]) -> Topology:
    """Topology of the survivors after removing ranks ``dead``."""
    dead_set: Set[int] = {int(d) for d in dead}
    world = topo.world
    bad = sorted(d for d in dead_set if d < 0 or d >= world)
    if bad:
        raise ValueError(f"dead ranks {bad} out of range for "
                         f"world={world}")
    n_live = world - len(dead_set)
    if n_live < 1:
        raise ValueError("no survivors: cannot build a topology of 0 "
                         "workers")
    if not dead_set:
        return topo

    if topo.is_flat:
        t = topo.tiers[0]
        return Topology(tiers=(dataclasses.replace(t, size=n_live),))

    outer = topo.tiers[0]
    inner = topo.inner_size               # product of tiers[1:]
    per_group = [0] * outer.size
    for d in dead_set:
        per_group[d // inner] += 1

    uniq = set(per_group)
    innermost = topo.tiers[-1]
    if len(uniq) == 1:
        # uniform partial loss: every group keeps inner - d survivors
        d = per_group[0]                  # 0 < d < inner (dead_set nonempty)
        return Topology(tiers=(
            outer,
            Tier(name=innermost.name, size=inner - d,
                 link=innermost.link, link_name=innermost.link_name,
                 fit=innermost.fit)))
    if uniq <= {0, inner}:
        # whole groups gone, the rest untouched
        live_groups = sum(1 for d in per_group if d == 0)
        if live_groups == 1:
            return Topology(tiers=topo.tiers[1:])
        return Topology(tiers=(dataclasses.replace(outer,
                                                   size=live_groups),)
                        + topo.tiers[1:])

    # irregular loss: conservative flat fallback on the slowest link
    return Topology(tiers=(
        Tier(name="survivors", size=n_live, link=outer.link,
             link_name=outer.link_name, fit=outer.fit),))
