"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the artifacts.

    PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import json
import os
import re
from typing import Optional

from repro.configs import SHAPES, get_config
from repro.launch.paths import ARTIFACTS, EXPERIMENTS
from repro.launch.roofline import (NOTES, load_records, model_flops_per_device,
                                   render_table, terms)


def dryrun_table() -> str:
    lines = ["| arch | shape | mesh | compile s | HBM/chip GiB (args+temp) | "
             "dot TF/chip | wire GB/chip | collectives (AG/AR/RS/A2A/CP) |",
             "|---|---|---|---|---|---|---|---|"]
    for mesh in ("16x16", "2x16x16"):
        for rec in load_records(mesh):
            mem = rec["memory_analysis"]
            hbm = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 2**30
            cc = rec["hlo"]["collective_counts"]
            counts = "/".join(str(cc.get(k, 0)) for k in (
                "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"))
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"{rec['compile_s']:.0f} | {hbm:.1f} | "
                f"{rec['hlo']['dot_flops_per_device']/1e12:.2f} | "
                f"{rec['hlo']['collective_wire_bytes_per_device']/1e9:.2f} | "
                f"{counts} |")
    return "\n".join(lines)


def variants_table() -> str:
    """Baseline vs optimized-variant comparison across all lowered variants."""
    import glob
    lines = ["| arch | shape | variant | dot TF/chip | wire GB/chip | "
             "HBM GiB | Δwire vs baseline |",
             "|---|---|---|---|---|---|---|"]
    base = {}
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "*_16x16_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        key = (rec["arch"], rec["shape"])
        if rec["variant"] == "baseline":
            base[key] = rec
        else:
            rows.append(rec)
    for rec in rows:
        key = (rec["arch"], rec["shape"])
        b = base.get(key)
        mem = rec["memory_analysis"]
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        wire = rec["hlo"]["collective_wire_bytes_per_device"]
        delta = ""
        if b:
            bw = b["hlo"]["collective_wire_bytes_per_device"]
            delta = f"{bw / wire:.0f}× less" if wire < bw else \
                f"{wire / bw:.2f}× more"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['variant']} | "
            f"{rec['hlo']['dot_flops_per_device']/1e12:.3f} | "
            f"{wire/1e9:.3f} | {hbm:.1f} | {delta} |")
    return "\n".join(lines)


def tier_cost_breakdown(plan) -> dict:
    """Serial per-tier cost of a plan's buckets: sum each bucket's phase
    costs (``cost.bucket_sync_phases``) grouped by the tier the phase
    traverses, plus the ``"compute"`` compress/decompress time — the
    per-tier rows of the plan table and the plan record (DESIGN.md §10).
    Keys follow tier order (outermost first), then compute."""
    from repro.core.schedule import Topology
    from repro.core.schedule.cost import bucket_sync_phases

    out: dict = {}
    if isinstance(plan.link, Topology):
        for t in plan.link.tiers:
            out[t.name] = 0.0
    for b in plan.buckets:
        for name, secs in bucket_sync_phases(
                b.compressor, b.compressor_args, b.algo, b.bucket_bytes,
                plan.world, plan.link, shard_state=plan.shard_state):
            out[name] = out.get(name, 0.0) + secs
    return out


def render_comm_plan(plan, baselines=None, t_backward_s=None,
                     total_label="modeled iteration",
                     auto_step_s=None) -> str:
    """Markdown rendering of a ``CommPlan`` (``--sync auto``, DESIGN.md §6):
    one row per bucket plus the plan's modeled time next to the fixed
    baselines the planner had to beat.  ``total_label`` names what
    ``plan.modeled_step_s`` is (an iteration for every-step plans, one
    reduce round for τ>1 round plans); ``auto_step_s`` overrides the
    denominator of the speedup column (the composite's AMORTIZED per-step
    time — dividing iteration baselines by a single round cost would
    overstate the win).

    On a tiered topology (DESIGN.md §10) the header lists every tier's
    (α, β) and the table grows PER-TIER BREAKDOWN rows: the serial sum
    of each bucket phase's cost, grouped by the tier it traverses (plus
    the compress/decompress compute) — the survey's "which link is the
    bottleneck" question answered per plan."""
    from repro.core.schedule import Topology
    from repro.core.schedule.cost import bucket_sync_cost_s

    world, link = plan.world, plan.link
    tiered = isinstance(link, Topology) and not link.is_flat
    lines = ["### Communication plan (auto-tuned)", ""]
    if tiered:
        tier_txt = " → ".join(
            f"{t.name}:{t.size} (α={t.link.alpha_s:.2e} s, "
            f"β⁻¹={1 / t.link.beta_s_per_byte / 1e9:.2f} GB/s)"
            for t in link.tiers)
        lines.append(f"world={world}, topology {tier_txt}"
                     + (f", measured backward {t_backward_s * 1e3:.1f} ms"
                        if t_backward_s else ""))
        lines.append("")
    elif link is not None:
        if isinstance(link, Topology):
            link = link.tiers[0].link      # flat topology: one tier's link
        lines.append(f"world={world}, α={link.alpha_s:.2e} s, "
                     f"β⁻¹={1 / link.beta_s_per_byte / 1e9:.2f} GB/s"
                     + (f", measured backward {t_backward_s * 1e3:.1f} ms"
                        if t_backward_s else ""))
        lines.append("")
    lines += ["| bucket | leaves | MiB | strategy | modeled comm |",
              "|---|---|---|---|---|"]
    for j, b in enumerate(plan.buckets):
        cost = ""
        if link is not None:
            # shard_state matters: sharded dense buckets pay the (half)
            # reduce-scatter inside the overlap window, not the allreduce
            c = bucket_sync_cost_s(b.compressor, b.compressor_args, b.algo,
                                   b.bucket_bytes, world, link,
                                   shard_state=plan.shard_state)
            cost = f"{c * 1e6:.1f} µs"
        lines.append(f"| {j} | {len(b.leaves)} | "
                     f"{b.bucket_bytes / 2**20:.2f} | "
                     f"{b.algo}/{b.compressor} | {cost} |")
    if tiered:
        for name, secs in tier_cost_breakdown(plan).items():
            lines.append(f"| — | — | — | tier {name} (all buckets, serial) "
                         f"| {secs * 1e6:.1f} µs |")
    if plan.shard_state and link is not None:
        from repro.core.schedule.planner import shard_gather_tail_s
        tail = shard_gather_tail_s(plan, link, world)
        lines.append(f"| — | — | — | params all-gather tail (serial) | "
                     f"{tail * 1e6:.1f} µs |")
    lines += ["", f"{total_label}: {plan.modeled_step_s * 1e3:.3f} ms"]
    if baselines:
        step_s = plan.modeled_step_s if auto_step_s is None else auto_step_s
        lines += ["", "| fixed config | modeled iteration | auto speedup |",
                  "|---|---|---|"]
        for name, bp in sorted(baselines.items()):
            ratio = bp.modeled_step_s / max(step_s, 1e-12)
            lines.append(f"| {name} | {bp.modeled_step_s * 1e3:.3f} ms | "
                         f"{ratio:.2f}× |")
    return "\n".join(lines)


def render_strategy_plan(sp, arms=None, baselines=None,
                         t_backward_s=None) -> str:
    """Markdown rendering of a composite ``StrategyPlan`` (``--sync auto``
    over rounds × bits × overlap, DESIGN.md §7): the rounds-axis arms the
    planner scored, then the winning per-bucket comm plan next to the fixed
    baselines it must beat."""
    # only local_sgd arms carry a distinct per-round cost; for every_step /
    # pinned lag / push-pull the comm plan's time IS the iteration
    round_like = sp.schedule.kind == "local_sgd"
    detail = (f"one reduce round: {sp.round_cost_s * 1e3:.3f} ms, "
              if round_like else "")
    shard = " + shard_state (optimizer state 1/p)" if sp.shard_state else ""
    lines = ["### Sync strategy (auto-tuned: rounds × bits × overlap"
             " × shard × parallelism)", "",
             f"chosen arm: **{sp.key}{shard}** — "
             f"modeled {sp.modeled_step_s * 1e3:.3f} ms/step "
             f"({detail}backward {sp.t_backward_s * 1e3:.3f} ms)"]
    if sp.tp > 1 or sp.ep > 1:
        ax, n, tier = (("tp", sp.tp, sp.tp_tier) if sp.tp > 1
                       else ("ep", sp.ep, sp.ep_tier))
        wire = ("4 activation allreduces/layer, Megatron wire"
                if ax == "tp" else "4 all-to-alls/MoE layer "
                "(dispatch+combine, fwd+bwd)")
        placed = f" placed on tier {tier!r}" if tier else ""
        lines.append(
            f"parallelism: {sp.parallelism.spec()} — {ax}={n}{placed}, "
            f"model-axis comm {sp.model_comm_s * 1e3:.3f} ms/step "
            f"({wire}); the comm plan below is the DP edge over "
            f"world/{ax} replicas")
    if sp.pipeline_stages > 1:
        placed = (f" (pipe axis placed on tier {sp.pipe_tier!r}, DP edge "
                  f"on the remaining tiers)" if sp.pipe_tier else "")
        lines.append(
            f"pipeline: {sp.pipeline_stages} stages × {sp.micro_batches} "
            f"micro-batches — bubble {sp.bubble:.1%} "
            f"((S−1)/(S−1+M)), boundary p2p "
            f"{sp.pipe_p2p_s * 1e3:.3f} ms/step{placed}, per-stage opt "
            f"state {sp.opt_mem_bytes / 2**20:.1f} MiB/worker; the comm "
            f"plan below is the DP edge of the heaviest stage over "
            f"world/S replicas")
    if sp.shard_state and sp.opt_mem_bytes == sp.opt_mem_bytes:
        repl = (arms or {}).get("every_step")
        vs = (f" (replicated would be {repl.opt_mem_bytes / 2**20:.1f} MiB)"
              if repl is not None and repl.opt_mem_bytes ==
              repl.opt_mem_bytes else "")
        lines.append(f"optimizer state/worker: "
                     f"{sp.opt_mem_bytes / 2**20:.1f} MiB{vs}")

    def _mem(a):
        return (f"{a.opt_mem_bytes / 2**20:.1f} MiB"
                if a.opt_mem_bytes == a.opt_mem_bytes else "—")

    if arms and len(arms) > 1:
        lines += ["", "| arm | round cost | modeled /step | "
                  "opt state/worker |", "|---|---|---|---|"]
        for key, a in sorted(arms.items(),
                             key=lambda kv: kv[1].modeled_step_s):
            mark = " ←" if key == sp.key else ""
            lines.append(f"| {key}{mark} | {a.round_cost_s * 1e3:.3f} ms | "
                         f"{a.modeled_step_s * 1e3:.3f} ms | {_mem(a)} |")
    lines += ["", render_comm_plan(
        sp.comm, baselines=baselines, t_backward_s=t_backward_s,
        total_label=("modeled reduce round" if round_like
                     else "modeled iteration"),
        auto_step_s=sp.modeled_step_s)]
    return "\n".join(lines)


def render_serving_plan(best, arms, arch: str = "", batch: int = 0,
                        latency_budget_s=None) -> str:
    """Markdown rendering of a serving placement search
    (``planner.plan_serving``, DESIGN.md §12): every tp × tier arm the
    planner priced, best-throughput arm marked."""
    hdr = f" — {arch}" if arch else ""
    budget = (f", latency budget {latency_budget_s * 1e3:.2f} ms/step"
              if latency_budget_s is not None else "")
    lines = [f"### Serving placement (tp × tier × replicas){hdr}", "",
             f"chosen arm: **{best.key()}** — {best.step_s * 1e3:.3f} "
             f"ms/step, {best.tokens_per_s:,.0f} tok/s"
             f" at decode batch {batch}{budget}" if batch else
             f"chosen arm: **{best.key()}** — {best.step_s * 1e3:.3f} "
             f"ms/step, {best.tokens_per_s:,.0f} tok/s{budget}",
             "", "| arm | step | aggregate tok/s |", "|---|---|---|"]
    for a in sorted(arms, key=lambda a: -a.tokens_per_s):
        mark = " ←" if a.key() == best.key() else ""
        lines.append(f"| {a.key()}{mark} | {a.step_s * 1e3:.3f} ms | "
                     f"{a.tokens_per_s:,.0f} |")
    return "\n".join(lines)


def _write_plan_record(rec: dict, arch: str) -> str:
    from repro.launch.paths import COMM_PLANS
    os.makedirs(COMM_PLANS, exist_ok=True)
    path = os.path.join(COMM_PLANS, f"{arch}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def save_comm_plan(plan, arch: str) -> str:
    """Write the plan record under artifacts/comm_plans/ (called by the
    ``--sync auto`` path); returns the file path."""
    return _write_plan_record(comm_plan_record(plan), arch)


def save_strategy_plan(sp, arch: str, calibration=None, drift=None) -> str:
    """Write the composite-strategy record (rounds schedule + comm plan)
    under artifacts/comm_plans/; returns the file path.  ``calibration``
    (a ``CalibratedTopology``) and ``drift`` (``TrainSession.
    drift_report()``) add their blocks ONLY when present, so records
    written without them keep the exact pre-calibration schema."""
    rec = comm_plan_record(sp.comm)
    rec["schedule"] = {"kind": sp.schedule.kind, "period": sp.schedule.period}
    rec["modeled_step_s"] = sp.modeled_step_s
    rec["round_cost_s"] = sp.round_cost_s
    rec["t_backward_s"] = sp.t_backward_s
    rec["shard_state"] = sp.shard_state
    if sp.pipeline_stages > 1:
        rec["pipeline"] = {"stages": sp.pipeline_stages,
                           "micro_batches": sp.micro_batches,
                           "bubble_fraction": sp.bubble,
                           "p2p_cost_s": sp.pipe_p2p_s}
        if sp.pipe_tier:
            rec["pipeline"]["pipe_tier"] = sp.pipe_tier
    par = sp.parallelism
    if not par.is_trivial:
        # additive block (DESIGN.md §14): pure-dp records keep their exact
        # pre-existing key set — the PR 8 schema-compat rule
        rec["parallelism"] = par.to_record()
        if sp.model_comm_s:
            rec["parallelism"]["model_comm_s"] = sp.model_comm_s
    if sp.opt_mem_bytes == sp.opt_mem_bytes:   # not NaN
        rec["opt_mem_bytes_per_worker"] = sp.opt_mem_bytes
    if calibration is not None:
        cal = calibration.to_json()
        cal.pop("samples", None)    # raw timings live in the .cal file
        rec["calibration"] = cal
    if drift is not None:
        rec["drift"] = drift
    return _write_plan_record(rec, arch)


def render_drift_table(drift: dict) -> str:
    """The modeled↔measured closing table (``--calibrate`` /
    ``--replan-drift-pct`` epilogue): per-arm predicted wall step vs this
    run's measured median, drift %, and the error-budget verdict."""
    meas = drift["measured_step_s"]
    lines = [f"modeled vs measured ({drift['steps_measured']} steps, "
             f"median {meas * 1e3:.1f} ms/step):",
             "| arm | modeled ms | wall ms | measured ms | drift |",
             "|---|---|---|---|---|"]
    chosen = drift["plan_key"]
    for key, a in sorted(drift["arms"].items(),
                         key=lambda kv: kv[1]["modeled_wall_step_s"]):
        mark = " ←" if key == chosen else ""
        lines.append(f"| {key}{mark} | {a['modeled_step_s'] * 1e3:.1f} | "
                     f"{a['modeled_wall_step_s'] * 1e3:.1f} | "
                     f"{meas * 1e3:.1f} | {a['drift_pct']:+.1f}% |")
    err = drift["fit_error_s"]
    verdict = "within" if drift["within_fit_error"] else "OUTSIDE"
    lines.append(
        f"chosen arm drift {drift['drift_pct']:+.1f}% — {verdict} the "
        f"±{err * 1e3:.1f} ms error budget (comm fit "
        f"{drift['comm_fit_err_s'] * 1e3:.2f} + backward spread "
        f"{drift['t_backward_err_s'] * 1e3:.1f} + measurement spread "
        f"{drift['measured_spread_s'] * 1e3:.1f})")
    if drift["replans"]:
        for e in drift["replan_events"]:
            lines.append(f"replan @step {e['step']}: drift "
                         f"{e['drift_frac'] * 100:+.1f}% → {e['new_key']}"
                         + (" (installed)" if e["applied"]
                            else f" ({e['note']})"))
    return "\n".join(lines)


def render_elastic_events(events) -> str:
    """The elastic runtime's decision log (``--elastic`` epilogue): every
    reshard, backpressure demotion, and straggler re-plan with the world
    transition and surviving topology (DESIGN.md §15)."""
    if not events:
        return "elastic: no membership changes or straggler actions"
    lines = [f"elastic events ({len(events)}):",
             "| step | event | world | topology / plan | note |",
             "|---|---|---|---|---|"]
    for e in events:
        world = (f"{e.old_world}→{e.new_world}"
                 if e.new_world != e.old_world else f"{e.old_world}")
        what = e.topology or e.plan_key or "—"
        lines.append(f"| {e.step} | {e.kind} | {world} | {what} | "
                     f"{e.note} |")
    return "\n".join(lines)


def render_sharded_memory(layout, opt_name: str, moments=None) -> str:
    """One-line per-worker memory report for a sharded-DP run (the ZeRO
    identity the acceptance criterion checks): partitioned moments + f32
    master shards vs the replicated moments footprint.  ``moments`` is the
    session's MEASURED buffer count (overrides the per-name default)."""
    rep = layout.opt_bytes_per_worker(opt_name, sharded=False,
                                      moments=moments)
    sh = layout.opt_bytes_per_worker(opt_name, sharded=True,
                                     moments=moments)
    if sh <= rep:
        verdict = f"{rep / max(sh, 1):.2f}× smaller"
    elif rep <= 0:
        # e.g. sgd with momentum=0: no replicated moment state at all —
        # a ratio is meaningless, the master shard is the whole cost
        verdict = ("pure master-shard cost (this optimizer keeps no "
                   "moment state)")
    else:
        # small worlds: the f32 master copy is added with little or no 1/p
        # benefit to divide it by — say so instead of "0.67x smaller"
        verdict = (f"{sh / max(rep, 1):.2f}× LARGER (world="
                   f"{layout.world}: the f32 master shard outweighs the "
                   f"1/p split)")
    return (f"optimizer state/worker: {sh / 2**20:.2f} MiB sharded "
            f"(master+moments over world={layout.world}) vs "
            f"{rep / 2**20:.2f} MiB replicated — {verdict}; params "
            f"{layout.param_bytes() / 2**20:.2f} MiB f32")


def render_moe_drops(dropped: float, routed: float,
                     capacity_factor: float) -> str:
    """One-line MoE capacity report for a training run: how many routed
    token-choices overflowed an expert's capacity buffer and were dropped
    (the silent signal loss the drop tap surfaces, DESIGN.md §14)."""
    if routed <= 0:
        return "moe capacity: no tokens routed"
    frac = dropped / routed
    verdict = ("no overflow" if dropped == 0 else
               f"raise capacity_factor ({capacity_factor:g}) to shed drops")
    return (f"moe capacity: dropped {dropped:.0f}/{routed:.0f} routed "
            f"token-choices ({frac:.1%}) — {verdict}")


def render_pipeline_stages(staged, params_split, micro_batches: int,
                           moments: Optional[float] = None) -> str:
    """Per-stage rows for an EXECUTED pipeline run (DESIGN.md §9): stage
    param/optimizer bytes (homogeneous stages — every stage holds R/S
    identical rows plus the replicated shared cells) and the 1F1B bubble
    of the configured (S, M)."""
    import jax
    import numpy as np

    from repro.core.pipeline import bubble_fraction
    lay = staged.layout
    S, M = lay.n_stages, int(micro_batches)
    mom = 2.0 if moments is None else float(moments)
    shared_b = sum(np.asarray(x).nbytes
                   for x in jax.tree.leaves(params_split["shared"]))
    rows_b = sum(np.asarray(x).nbytes
                 for x in jax.tree.leaves(params_split["rows"]))
    per_stage = rows_b / S + shared_b
    lines = [f"pipeline: {S} stages × {lay.rows_per_stage} layer rows, "
             f"{M} micro-batches — bubble {bubble_fraction(S, M):.1%} "
             f"((S−1)/(S−1+M))",
             "| stage | layer rows | params MiB | opt state MiB |",
             "|---|---|---|---|"]
    for s in range(S):
        lines.append(f"| {s} | {lay.rows_per_stage} | "
                     f"{per_stage / 2**20:.2f} | "
                     f"{mom * per_stage / 2**20:.2f} |")
    lines.append(f"(each stage replicates the shared cells — "
                 f"{shared_b / 2**20:.2f} MiB of embed/norm/head — and "
                 f"holds {rows_b / S / 2**20:.2f} MiB of its own rows)")
    return "\n".join(lines)


def comm_plan_record(plan) -> dict:
    """JSON-serialisable record of a plan (written by ``save_comm_plan``).
    Tiered plans additionally record the topology and the per-tier cost
    breakdown; flat plans keep the exact pre-topology schema."""
    from repro.core.schedule import Topology

    rec = {
        "world": plan.world,
        "modeled_step_s": plan.modeled_step_s,
        "shard_state": plan.shard_state,
        "n_buckets": plan.n_buckets,
        "buckets": [{
            "leaves": list(b.leaves),
            "bytes": b.bucket_bytes,
            "compressor": b.compressor,
            "compressor_args": dict(b.compressor_args),
            "algo": b.algo,
            "pack": b.pack,
        } for b in plan.buckets],
    }
    if isinstance(plan.link, Topology) and not plan.link.is_flat:
        rec["topology"] = {
            "spec": plan.link.spec(),
            "tiers": [{"name": t.name, "size": t.size,
                       "alpha_s": t.link.alpha_s,
                       "beta_s_per_byte": t.link.beta_s_per_byte}
                      for t in plan.link.tiers],
            "tier_cost_s": tier_cost_breakdown(plan),
        }
    return rec


def inject(markdown: str, marker: str, content: str) -> str:
    return markdown.replace(f"<!-- {marker} -->",
                            f"<!-- {marker} -->\n\n{content}\n")


def main():
    with open(EXPERIMENTS) as f:
        doc = f.read()
    # strip anything previously injected after the markers? keep simple:
    # the markers are written once; we regenerate the whole file section by
    # replacing marker -> marker+table only if table not yet present.
    recs = load_records("16x16")
    roof = render_table(recs)
    notes = "\n".join(
        f"- **{r['arch']} × {r['shape']}**: dominant="
        f"{terms(r, get_config(r['arch']), SHAPES[r['shape']])['dominant']}"
        for r in recs)
    doc = inject(doc, "DRYRUN_TABLE", dryrun_table())
    doc = inject(doc, "ROOFLINE_TABLE", roof + "\n\n" + notes)
    doc = inject(doc, "PERF_LOG", "### All lowered variants vs baseline\n\n"
                 + variants_table())
    with open(EXPERIMENTS, "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
