"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the artifacts.

    PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import json
import os
import re

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import ARTIFACTS
from repro.launch.roofline import (NOTES, load_records, model_flops_per_device,
                                   render_table, terms)

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "EXPERIMENTS.md")


def dryrun_table() -> str:
    lines = ["| arch | shape | mesh | compile s | HBM/chip GiB (args+temp) | "
             "dot TF/chip | wire GB/chip | collectives (AG/AR/RS/A2A/CP) |",
             "|---|---|---|---|---|---|---|---|"]
    for mesh in ("16x16", "2x16x16"):
        for rec in load_records(mesh):
            mem = rec["memory_analysis"]
            hbm = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 2**30
            cc = rec["hlo"]["collective_counts"]
            counts = "/".join(str(cc.get(k, 0)) for k in (
                "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"))
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"{rec['compile_s']:.0f} | {hbm:.1f} | "
                f"{rec['hlo']['dot_flops_per_device']/1e12:.2f} | "
                f"{rec['hlo']['collective_wire_bytes_per_device']/1e9:.2f} | "
                f"{counts} |")
    return "\n".join(lines)


def variants_table() -> str:
    """Baseline vs optimized-variant comparison across all lowered variants."""
    import glob
    lines = ["| arch | shape | variant | dot TF/chip | wire GB/chip | "
             "HBM GiB | Δwire vs baseline |",
             "|---|---|---|---|---|---|---|"]
    base = {}
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "*_16x16_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        key = (rec["arch"], rec["shape"])
        if rec["variant"] == "baseline":
            base[key] = rec
        else:
            rows.append(rec)
    for rec in rows:
        key = (rec["arch"], rec["shape"])
        b = base.get(key)
        mem = rec["memory_analysis"]
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        wire = rec["hlo"]["collective_wire_bytes_per_device"]
        delta = ""
        if b:
            bw = b["hlo"]["collective_wire_bytes_per_device"]
            delta = f"{bw / wire:.0f}× less" if wire < bw else \
                f"{wire / bw:.2f}× more"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['variant']} | "
            f"{rec['hlo']['dot_flops_per_device']/1e12:.3f} | "
            f"{wire/1e9:.3f} | {hbm:.1f} | {delta} |")
    return "\n".join(lines)


def inject(markdown: str, marker: str, content: str) -> str:
    return markdown.replace(f"<!-- {marker} -->",
                            f"<!-- {marker} -->\n\n{content}\n")


def main():
    with open(EXPERIMENTS) as f:
        doc = f.read()
    # strip anything previously injected after the markers? keep simple:
    # the markers are written once; we regenerate the whole file section by
    # replacing marker -> marker+table only if table not yet present.
    recs = load_records("16x16")
    roof = render_table(recs)
    notes = "\n".join(
        f"- **{r['arch']} × {r['shape']}**: dominant="
        f"{terms(r, get_config(r['arch']), SHAPES[r['shape']])['dominant']}"
        for r in recs)
    doc = inject(doc, "DRYRUN_TABLE", dryrun_table())
    doc = inject(doc, "ROOFLINE_TABLE", roof + "\n\n" + notes)
    doc = inject(doc, "PERF_LOG", "### All lowered variants vs baseline\n\n"
                 + variants_table())
    with open(EXPERIMENTS, "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
