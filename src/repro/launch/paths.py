"""Shared artifact locations.

Import-safe by construction: ``dryrun.py`` must set XLA_FLAGS (512 fake host
devices) before jax initializes, so nothing that merely needs these paths may
import ``dryrun`` — reporting tools importing ``dryrun.ARTIFACTS`` used to
silently drag a 512-device CPU backend into training processes.
"""
from __future__ import annotations

import os

_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")

ARTIFACTS = os.path.join(_ROOT, "artifacts", "dryrun")
COMM_PLANS = os.path.join(_ROOT, "artifacts", "comm_plans")
EXPERIMENTS = os.path.join(_ROOT, "EXPERIMENTS.md")
