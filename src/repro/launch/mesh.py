"""Production mesh definitions (TPU v5e pods).

Kept as FUNCTIONS so importing this module never touches JAX device state —
``dryrun.py`` must set XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

CHIPS_PER_POD = 256

# hardware constants (roofline):
PEAK_FLOPS_BF16 = 197e12          # per chip, bf16
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over host devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def make_topology_mesh(topo):
    """The executable twin of a ``schedule.topology.Topology``: one mesh
    axis per tier, OUTERMOST first, each named after its tier — so an
    8-device host realises ``node:2@datacenter,device:4@fast_ici`` as a
    (2, 4) mesh with axes ("node", "device").  Collectives dispatch over
    ``collectives.axes_for_topology(topo)`` (the same names, innermost
    first), which is what maps hierarchical's inner ring onto the fast
    tier (DESIGN.md §10).  Tiered execution is pure DP: no model axis."""
    return jax.make_mesh(tuple(t.size for t in topo.tiers),
                         tuple(t.name for t in topo.tiers),
                         axis_types=(AxisType.Auto,) * len(topo.tiers))


def make_pipe_mesh(pipe: int = 1, data: int = 1):
    """2-D pipeline × data mesh (DESIGN.md §9): stage s of a pipelined
    model lives on mesh row ``pipe=s``, replicated ``data`` ways for the
    DP gradient edge.  The ``pipe`` axis is deliberately NOT in
    :func:`data_axes` — gradient collectives never cross stage cuts."""
    return jax.make_mesh((pipe, data), ("pipe", "data"),
                         axis_types=(AxisType.Auto,) * 2)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
