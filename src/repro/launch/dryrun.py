import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import/init: the dry-run builds the production
# 16x16 (and 2x16x16 multi-pod) mesh out of placeholder host devices.

DOC = """Multi-pod dry-run (deliverable e): for every (architecture x input shape
x mesh), jit the step function with production shardings, ``.lower()``,
``.compile()``, and record memory analysis, cost analysis, and the parsed
HLO roofline inputs as JSON artifacts under artifacts/dryrun/.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse  # noqa: E402
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCHS, SHAPES, applicable_shapes, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import Model
from repro.optim import make_optimizer

from repro.launch.paths import ARTIFACTS  # noqa: E402


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
        return {k: int(getattr(m, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes") if hasattr(m, k)}
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}


def _cost_dict(compiled):
    try:
        c = compiled.cost_analysis()
        return {k: float(v) for k, v in c.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:
        return {"error": str(e)}


MICROBATCHES = 4  # gradient accumulation for train shapes (memory budget)


def lower_pair(arch: str, shape_name: str, multi_pod: bool = False,
               save_hlo: bool = False, variant: str = "baseline",
               microbatches: int = MICROBATCHES):
    """Lower + compile one (arch, shape, mesh) and return the record."""
    cfg = get_config(arch)
    if variant == "chunkwise":
        import dataclasses
        cfg = dataclasses.replace(cfg, mlstm_parallel=True)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = mesh.devices.size
    from repro.models.sharding_ctx import set_mesh_ctx
    set_mesh_ctx(mesh, ("pod", "data") if multi_pod else ("data",))

    t0 = time.time()
    params = model.abstract_params()
    phase_rules = "train" if shape.phase == "train" else "serve"
    if variant == "zero1" and shape.phase == "train":
        phase_rules = "serve"   # ZeRO-1: params replicated over data (TP only)
    pspec = model.partition_specs(phase_rules, multi_pod=multi_pod)
    in_specs = model.input_specs(shape)
    in_pspec = model.input_partition_specs(shape, multi_pod=multi_pod)

    if shape.phase == "train" and variant.startswith("comm_"):
        # the survey's §3.2+§4.1 technique at production scale: shard_map
        # manual over the data axes, compressed payload + explicit ring
        from repro.core import SyncConfig
        from repro.launch.steps import make_comm_optimized_train_step
        compressor = variant.split("_", 1)[1]        # comm_int8, comm_sign...
        opt = make_optimizer("adam", lr=1e-4)
        opt_state = jax.eval_shape(opt.init, params)
        pspec = model.partition_specs("serve", multi_pod=multi_pod)
        ospec = {k: pspec for k in opt_state}
        axes = ("pod", "data") if multi_pod else ("data",)
        step_fn, sync, init_sync_state = make_comm_optimized_train_step(
            model, opt,
            SyncConfig(compressor=compressor, algo="ring", bucket_bytes=0),
            mesh, axes)
        sync_state = jax.eval_shape(init_sync_state, params)
        sspec = jax.tree.map(lambda s: NamedSharding(mesh, P(axes)), sync_state)
        jitted = jax.jit(
            step_fn,
            in_shardings=(_named(mesh, pspec), _named(mesh, ospec), sspec,
                          _named(mesh, in_pspec), NamedSharding(mesh, P()),
                          NamedSharding(mesh, P())),
            donate_argnums=(0, 1, 2))
        args = (params, opt_state, sync_state, in_specs,
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
    elif shape.phase == "train":
        opt = make_optimizer("adam", lr=1e-4)
        opt_state = jax.eval_shape(opt.init, params)
        # optimizer state mirrors params, except ZeRO-1 which shards it over
        # the data axes too (the ZeRO-1 memory trade)
        ostate_rules = model.partition_specs("train", multi_pod=multi_pod) \
            if variant == "zero1" else pspec
        ospec = {k: ostate_rules for k in opt_state}
        step_fn = make_train_step(model, opt, microbatches=microbatches)
        jitted = jax.jit(
            step_fn,
            in_shardings=(_named(mesh, pspec), _named(mesh, ospec),
                          _named(mesh, in_pspec), NamedSharding(mesh, P())),
            out_shardings=(_named(mesh, pspec), _named(mesh, ospec),
                           NamedSharding(mesh, P())),
            donate_argnums=(0, 1))
        args = (params, opt_state, in_specs,
                jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.phase == "prefill":
        step_fn = make_prefill_step(model)
        jitted = jax.jit(
            step_fn,
            in_shardings=(_named(mesh, pspec), _named(mesh, in_pspec)))
        args = (params, in_specs)
    else:  # decode
        step_fn = make_decode_step(
            model,
            mla_absorb=variant in ("mla_absorb", "optimized"),
            moe_dispatch=variant in ("moe_dispatch", "optimized"))
        cache_spec = in_pspec["cache"]
        jitted = jax.jit(
            step_fn,
            in_shardings=(_named(mesh, pspec),
                          _named(mesh, in_pspec["tokens"]),
                          _named(mesh, cache_spec),
                          NamedSharding(mesh, P())),
            out_shardings=(None, _named(mesh, cache_spec)),
            donate_argnums=(2,))
        args = (params, in_specs["tokens"], in_specs["cache"], in_specs["pos"])

    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    txt = compiled.as_text()
    stats = hlo_analysis.analyze(txt, total_devices=ndev)
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16", "devices": ndev,
        "phase": shape.phase,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": _mem_dict(compiled),
        "cost_analysis": _cost_dict(compiled),
        "hlo": {
            "dot_flops_per_device": stats.dot_flops,
            "memory_bytes_per_device": stats.memory_bytes,
            "collective_operand_bytes": stats.collective_operand_bytes,
            "collective_wire_bytes_per_device": stats.collective_wire_bytes,
            "collective_counts": stats.collective_counts,
            "num_while_loops": len(stats.while_trip_counts),
            "while_trip_counts_top": sorted(stats.while_trip_counts)[-8:],
        },
        "hlo_chars": len(txt),
    }
    if save_hlo:
        os.makedirs(ARTIFACTS, exist_ok=True)
        with open(os.path.join(
                ARTIFACTS, f"{arch}_{shape_name}_{rec['mesh']}_{variant}.hlo"), "w") as f:
            f.write(txt)
    return rec


def save_record(rec):
    os.makedirs(ARTIFACTS, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}_{rec['variant']}.json"
    with open(os.path.join(ARTIFACTS, name), "w") as f:
        json.dump(rec, f, indent=1)
    return name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=MICROBATCHES)
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ALL_ARCHS:
            for s in applicable_shapes(get_config(a)):
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in pairs:
        mesh_name = "2x16x16" if args.multi_pod else "16x16"
        fname = f"{arch}_{shape}_{mesh_name}_{args.variant}.json"
        if args.skip_existing and os.path.exists(os.path.join(ARTIFACTS, fname)):
            print(f"[skip] {fname}")
            continue
        try:
            rec = lower_pair(arch, shape, multi_pod=args.multi_pod,
                             save_hlo=args.save_hlo, variant=args.variant,
                             microbatches=args.microbatches)
            save_record(rec)
            mem = rec["memory_analysis"]
            print(f"[ok] {arch} {shape} {mesh_name}: compile={rec['compile_s']}s "
                  f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"dotF={rec['hlo']['dot_flops_per_device']:.3e} "
                  f"wireB={rec['hlo']['collective_wire_bytes_per_device']:.3e}",
                  flush=True)
        except Exception:
            failures += 1
            print(f"[FAIL] {arch} {shape} {mesh_name}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
