"""Roofline analysis (deliverable g): read the dry-run JSON artifacts and
derive the three per-step roofline terms per (arch, shape, mesh):

    compute    = dot_FLOPs_per_chip   / 197e12        (bf16 peak)
    memory     = HLO_bytes_per_chip   / 819e9         (HBM bandwidth)
    collective = wire_bytes_per_chip  / 50e9          (ICI per-link)

plus MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens
(prefill/decode), the useful-compute ratio, the dominant term, and a note on
what would move it.  Emits the EXPERIMENTS.md §Roofline markdown table.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

from repro.configs import SHAPES, get_config
from repro.launch.paths import ARTIFACTS
from repro.launch.mesh import CHIPS_PER_POD, HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def count_active_params(cfg) -> int:
    """Parameters touched per token: routed experts scaled by top_k/E."""
    import jax
    from repro.models.layers import ParamDesc
    from repro.models.model import Model
    total = 0
    for leaf in jax.tree.leaves(Model(cfg).param_desc(),
                                is_leaf=lambda x: isinstance(x, ParamDesc)):
        n = int(np.prod(leaf.shape))
        if "experts" in (leaf.axes or ()):
            n = int(n * cfg.top_k / max(cfg.num_experts, 1))
        total += n
    return total


def model_flops_per_device(cfg, shape, devices: int) -> float:
    n_active = count_active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.phase in
                                   ("train", "prefill") else 1)
    mult = 6.0 if shape.phase == "train" else 2.0
    return mult * n_active * tokens / devices


def analytic_memory_bytes(cfg, shape, devices: int) -> float:
    """TPU-semantics HBM traffic model (fusion-aware napkin numbers; the
    HLO-parsed byte counts in the artifacts are upper bounds at CPU fusion
    granularity and overcount what a TPU keeps in VMEM — flash-attention
    intermediates above all).

      train:   params fwd+bwd reads (bf16) + grads f32 r/w + adam m,v f32
               r/w + param update w  ≈ 30 B/param(local)
               + remat-doubled activation traffic: 2 · L · tok_local · d
                 · 2 B · C  (C ≈ 12 block-sized tensor r/w per layer)
               + 4 gradient-accumulation microbatch re-reads of params
      prefill: params read + activation writes (single pass)
      decode:  params read + the whole KV cache / recurrent state read once
    """
    import jax
    from repro.models.model import Model
    n_params = cfg.num_params()
    tp = 16
    tokens_local = shape.global_batch * shape.seq_len / devices
    L, d = cfg.num_layers, cfg.d_model
    if shape.phase == "train":
        p_local = n_params / devices           # FSDP over all axes
        act = 2 * L * tokens_local * d * 2 * 12
        return 30 * p_local + 4 * 2 * p_local + act
    p_local = n_params * 2 / tp               # bf16, TP-only at serve time
    if shape.phase == "prefill":
        act = L * tokens_local * d * 2 * 12
        return p_local + act
    # decode: read the cache once per step
    model = Model(cfg)
    cache = model.init_cache(shape.global_batch, shape.seq_len,
                             src_len=shape.seq_len if cfg.is_encoder_decoder else 0)
    cache_bytes = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                      for l in jax.tree.leaves(cache))
    shard = devices if shape.global_batch > 1 else tp
    return p_local + cache_bytes / shard


def terms(rec, cfg=None, shape=None) -> dict:
    h = rec["hlo"]
    compute = h["dot_flops_per_device"] / PEAK_FLOPS_BF16
    if cfg is not None and shape is not None:
        memory = analytic_memory_bytes(cfg, shape, rec["devices"]) / HBM_BW
    else:
        memory = h.get("memory_bytes_per_device", 0.0) / HBM_BW
    coll = h["collective_wire_bytes_per_device"] / ICI_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", coll), key=lambda t: t[1])[0]
    return {"compute_s": compute, "memory_s": memory, "collective_s": coll,
            "dominant": dom,
            "hlo_memory_s_upper": h.get("memory_bytes_per_device", 0.0) / HBM_BW}


NOTES = {
    "compute": "compute-bound: reduce rectangle-waste in flash attention "
               "(triangular schedule) or shrink redundant remat recompute",
    "memory": "memory-bound: raise arithmetic intensity (fuse scans / larger "
              "chunk blocks, bf16 stacks, absorbed projections)",
    "collective": "collective-bound: compress the payload (§3.2), change the "
                  "algorithm (ring/hierarchical §4.1), or reshard to cut "
                  "all-gather volume",
}


def load_records(mesh: str, variant: str = "baseline"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, f"*_{mesh}_{variant}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def render_table(recs) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_TF/chip | useful ratio | HBM GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        t = terms(rec, cfg, shape)
        mf = model_flops_per_device(cfg, shape, rec["devices"])
        hf = rec["hlo"]["dot_flops_per_device"]
        ratio = mf / hf if hf else float("nan")
        mem = rec["memory_analysis"]
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)) / 2**30
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | {t['dominant']} | "
            f"{mf/1e12:.2f} | {ratio:.2f} | {hbm:.1f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    recs = load_records(args.mesh, args.variant)
    if not recs:
        raise SystemExit(f"no dry-run artifacts for mesh {args.mesh} in {ARTIFACTS}")
    print(render_table(recs))
    print()
    for rec in recs:
        t = terms(rec, get_config(rec["arch"]), SHAPES[rec["shape"]])
        print(f"- {rec['arch']} x {rec['shape']}: dominant={t['dominant']} -> "
              f"{NOTES[t['dominant']]}")


if __name__ == "__main__":
    main()
