"""Serving launcher (DESIGN.md §12): the thin CLI over the production
serving engine — paged KV cache, continuous batching, optional
multi-replica routing — with the classic one-shot batched generate kept
as a mode (and as the bit-identity reference).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --batch 4 --prompt-len 32 --gen 16 --engine continuous

    # serving trace: Poisson arrivals, 2 replicas, placement plan
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
        --requests 16 --rate 50 --replicas 2 --plan --topology two_tier_pod
"""
from __future__ import annotations

import argparse
import time
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduced
from repro.models import Model


class GenerateSession:
    """Holds the jitted prefill/decode programs for one model so repeated
    ``generate`` calls never recompile (they used to build fresh ``jax.jit``
    wrappers per request)."""

    def __init__(self, model: Model):
        from repro.models.sharding_ctx import mesh_ctx
        self.model = model

        # Trace under a cleared activation-sharding context: the ctx is
        # process-global (set by the training launcher) and a leaked mesh
        # would bake sharding constraints into the serving programs (see
        # Engine._build_jits).
        def prefill_fn(params, batch, *, max_len):
            with mesh_ctx(None, ()):
                return model.prefill(params, batch, max_len=max_len)

        def decode_fn(params, tok, cache, pos):
            with mesh_ctx(None, ()):
                return model.decode_step(params, tok, cache, pos)

        self._prefill = jax.jit(prefill_fn, static_argnames=("max_len",))
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

    def compile_counts(self):
        return {"prefill": self._prefill._cache_size(),
                "decode": self._decode._cache_size()}

    def generate(self, params, prompts, gen: int, max_len: int, rng,
                 src=None, temperature: float = 0.0):
        """prompts: (B, P) int32. Returns (B, gen) sampled tokens."""
        B, Plen = prompts.shape
        batch = {"tokens": prompts}
        if src is not None:
            batch["src"] = src
        logits, cache = self._prefill(params, batch, max_len=max_len)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out = [tok]
        for i in range(gen - 1):
            logits, cache = self._decode(params, tok, cache,
                                         jnp.asarray(Plen + i, jnp.int32))
            if temperature > 0:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, logits[:, -1] / temperature)
                tok = tok[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, -1],
                                 axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        return jnp.concatenate(out, axis=1)


_SESSIONS: "weakref.WeakKeyDictionary[Model, GenerateSession]" = \
    weakref.WeakKeyDictionary()


def session_for(model: Model) -> GenerateSession:
    s = _SESSIONS.get(model)
    if s is None:
        s = GenerateSession(model)
        _SESSIONS[model] = s
    return s


def generate(model: Model, params, prompts, gen: int, max_len: int, rng,
             src=None, temperature: float = 0.0):
    """prompts: (B, P) int32. Returns (B, gen) sampled tokens.  Compiled
    programs are cached per model via :func:`session_for`."""
    return session_for(model).generate(params, prompts, gen, max_len, rng,
                                       src=src, temperature=temperature)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="serve a reduced config: continuous batching engine, "
                    "static batching, or one-shot generate")
    ap.add_argument("--arch", choices=ALL_ARCHS, default="gemma-2b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="CPU-runnable reduced config (--no-reduced for "
                         "the full one)")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode batch (engine slot count)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--engine",
                    choices=("continuous", "static", "oneshot"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=0,
                    help="trace length (default: --batch requests)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, req/s (0 = all at t=0)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="KV length per slot (default prompt+gen)")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--pages", type=int, default=0,
                    help="KV pool pages (0 = fully provisioned)")
    ap.add_argument("--quantize", choices=("none", "int8"), default="none",
                    help="int8 paged KV (lossy)")
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan", action="store_true",
                    help="print the tp x tier serving placement search")
    ap.add_argument("--topology", default="two_tier_pod",
                    help="topology preset or spec for --plan")
    ap.add_argument("--latency-budget-ms", type=float, default=0.0)
    return ap


def _print_plan(cfg, args):
    from repro.core.schedule import (TOPOLOGY_PRESETS, Topology,
                                     plan_serving)
    from repro.launch.report import render_serving_plan
    from repro.models.model import count_params
    spec = TOPOLOGY_PRESETS.get(args.topology, args.topology)
    net = Topology.from_spec(spec)
    budget = (args.latency_budget_ms / 1e3
              if args.latency_budget_ms > 0 else None)
    best, arms = plan_serving(
        net, net.world, count_params(cfg) * 2.0, cfg.num_layers,
        cfg.d_model, batch=args.batch, latency_budget_s=budget)
    print(render_serving_plan(best, arms, arch=cfg.name, batch=args.batch,
                              latency_budget_s=budget))
    return best


def main(argv=None):
    from repro.serve import (Engine, MultiReplicaServer, Request,
                             ServeConfig, run_static)
    from repro.serve.engine import latency_summary, poisson_trace

    args = build_parser().parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.plan:
        _print_plan(cfg, args)
    model = Model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    max_len = args.max_len or (args.prompt_len + args.gen)
    if args.engine == "continuous":
        # pages tile the slot exactly: round the KV length up to a page
        max_len = -(-max_len // args.page_size) * args.page_size
    n_req = args.requests or args.batch
    engine_kind = args.engine
    src = None
    if cfg.embedding_inputs:
        # encoder-decoder: no paged decode path — one-shot reference only
        engine_kind = "oneshot"
        src = jax.random.normal(rng, (args.batch, args.prompt_len,
                                      cfg.d_model))

    t0 = time.time()
    if engine_kind == "oneshot":
        prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        toks = generate(model, params, prompts, args.gen, max_len, rng,
                        src=src, temperature=args.temperature)
        dt = time.time() - t0
        print(f"arch={cfg.name} engine=oneshot generated {toks.shape} in "
              f"{dt:.2f}s ({args.batch * args.gen / dt:.1f} tok/s)")
        print("sample:", np.asarray(toks[0])[:16])
        assert np.isfinite(np.asarray(toks)).all()
        return toks

    if args.rate > 0:
        requests = poisson_trace(n_req, 1.0 / args.rate, args.prompt_len,
                                 [args.gen], cfg.vocab_size,
                                 seed=args.seed)
        for r in requests:
            r.temperature = args.temperature
    else:
        trng = np.random.default_rng(args.seed)
        requests = [Request(
            rid=i,
            prompt=trng.integers(0, cfg.vocab_size,
                                 size=(args.prompt_len,)).astype(np.int32),
            max_new=args.gen, arrival_s=0.0,
            temperature=args.temperature) for i in range(n_req)]

    if engine_kind == "static":
        comps = run_static(model, params, requests, args.batch, max_len)
    else:
        scfg = ServeConfig(
            max_batch=args.batch, max_len=max_len,
            page_size=args.page_size, n_pages=args.pages or None,
            quantize=None if args.quantize == "none" else args.quantize,
            seed=args.seed)
        if args.replicas > 1:
            srv = MultiReplicaServer(
                [Engine(model, params, scfg) for _ in range(args.replicas)])
            comps = srv.run(requests)
        else:
            comps = Engine(model, params, scfg).run(requests)
    dt = time.time() - t0
    s = latency_summary(comps)
    print(f"arch={cfg.name} engine={engine_kind} replicas={args.replicas} "
          f"requests={len(comps)} tokens={s['tokens']} in {dt:.2f}s")
    print(f"  tokens/s={s['tokens_per_s']:.1f} p50={s['p50_s'] * 1e3:.2f}ms "
          f"p99={s['p99_s'] * 1e3:.2f}ms "
          f"ttft={s['mean_ttft_s'] * 1e3:.2f}ms (trace time)")
    toks = np.stack([c.tokens for c in comps])
    print("sample:", toks[0][:16])
    assert np.isfinite(toks).all()
    return toks


if __name__ == "__main__":
    main()
