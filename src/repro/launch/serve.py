"""Serving launcher: batched prefill + decode with KV caches / recurrent
state.  CPU-runnable on reduced configs; the same step functions lower to
the production mesh in dryrun.py (decode shapes).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, reduced
from repro.models import Model
from repro.models.transformer import materialize_cache


def generate(model: Model, params, prompts, gen: int, max_len: int, rng,
             src=None, temperature: float = 0.0):
    """prompts: (B, P) int32. Returns (B, gen) sampled tokens."""
    cfg = model.cfg
    B, Plen = prompts.shape
    batch = {"tokens": prompts}
    if src is not None:
        batch["src"] = src
    logits, cache = jax.jit(model.prefill, static_argnames=("max_len",))(
        params, batch, max_len=max_len)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(gen - 1):
        logits, cache = decode(params, tok, cache, jnp.asarray(Plen + i, jnp.int32))
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits[:, -1] / temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="gemma-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    src = None
    if cfg.embedding_inputs:
        src = jax.random.normal(rng, (args.batch, args.prompt_len, cfg.d_model))
    max_len = args.prompt_len + args.gen
    t0 = time.time()
    toks = generate(model, params, prompts, args.gen, max_len, rng, src=src,
                    temperature=args.temperature)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("sample:", np.asarray(toks[0])[:16])
    assert np.isfinite(np.asarray(toks)).all()
    return toks


if __name__ == "__main__":
    main()
