"""Training launcher — a thin CLI over ``repro.api.TrainSession``.

Modes (the survey's taxonomy, selectable from the CLI; every flag below maps
onto a ``SyncStrategy`` = round scheduler × per-round reducer, DESIGN.md §7):

  * --sync vanilla                 BSP data-parallel, dense psum (baseline)
  * --sync comm                    every-step sync through --compressor/
                                   --algo/--bucket-mb/--no-error-feedback
  * --sync auto                    communication planner: profile one step,
                                   search (rounds schedule x per-bucket
                                   compressor x algo x fusion) against the
                                   --link α-β model, run the winning
                                   composite (DESIGN.md §6/§7)
  * --local-sgd TAU                periodic averaging (+ --post-local N);
                                   with --sync comm the averaging round
                                   itself is compressed (anchor-delta)
  * --lag THRESH                   lazily aggregated gradients (host
                                   dispatch; skipped rounds cost only the
                                   8-byte trigger probe)
  * --push-pull N_PUSH N_FETCH     Dean-style asymmetric push/pull cadences

Runs on whatever devices exist (CPU: 1-device mesh; the same code drives the
production mesh).  Example (the e2e driver, deliverable b):

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
        --steps 200 --batch 8 --seq 128 --sync comm --compressor topk --algo ring
"""
from __future__ import annotations

import argparse
import dataclasses
import os

from repro.api import SessionConfig, TrainSession
from repro.configs import ALL_ARCHS
from repro.core import (ParallelismSpec, SyncConfig, SyncStrategy,
                        get_scheduler, make_strategy)
from repro.core.schedule import LINK_PRESETS
from repro.launch.report import render_strategy_plan, save_strategy_plan


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--optimizer", default="adam",
                    choices=["sgd", "adam", "lars", "lamb"])
    ap.add_argument("--data-parallel", type=int, default=0)
    ap.add_argument("--sync", default="vanilla",
                    choices=["vanilla", "comm", "auto"])
    ap.add_argument("--compressor", default="none")
    ap.add_argument("--algo", default="psum")
    ap.add_argument("--bucket-mb", type=float, default=32.0)
    ap.add_argument("--no-error-feedback", action="store_true")
    ap.add_argument("--topology", default="",
                    help="tiered network model (DESIGN.md §10): a spec "
                         "'node:4@datacenter,device:8@fast_ici' (outermost "
                         "tier first, @link names a --link preset) or a "
                         "TOPOLOGY_PRESETS name.  The planner prices every "
                         "collective phase on the tier it traverses and "
                         "searches pipe/tp/ep-axis placements; its world "
                         "is the tier-size product.  "
                         "When it matches this host's device count the "
                         "mesh is rebuilt one-axis-per-tier so collectives "
                         "dispatch axis→tier")
    ap.add_argument("--link", default="fast_ici", choices=sorted(LINK_PRESETS),
                    help="α-β regime the planner optimizes for (--sync "
                         "auto).  Legacy FLAT network shim: builds "
                         "Topology.flat; superseded by --topology")
    ap.add_argument("--alpha", type=float, default=None,
                    help="override link latency α in seconds (--sync auto; "
                         "flat shim, ignored under --topology)")
    ap.add_argument("--beta-gbps", type=float, default=None,
                    help="override link bandwidth in GB/s (--sync auto; "
                         "flat shim, ignored under --topology)")
    ap.add_argument("--plan-backward-ms", type=float, default=0.0,
                    help="plan for this per-step backward time instead of "
                         "measuring (model a TPU's backward from a laptop; "
                         "--sync auto)")
    ap.add_argument("--compression-costs", default="", metavar="PATH",
                    help="measured per-compressor encode/decode cost table "
                         "(JSON recorded by benchmarks/bench_collectives.py "
                         "--write-compression-costs); replaces the analytic "
                         "compression-compute term in --sync auto's model "
                         "(DESIGN.md §11)")
    ap.add_argument("--parallelism", default="", metavar="SPEC",
                    help="the whole parallelism axis in one spec "
                         "(DESIGN.md §14): "
                         "'dp=4,tp=2@device,pp=2@node,micro=8,shard' — "
                         "dp/tp/pp/ep group sizes with optional @tier "
                         "placements (tier names from --topology), plus "
                         "the micro=M and shard tokens.  Subsumes the "
                         "deprecated --shard-state/--pipeline-stages/"
                         "--micro-batches trio; under --sync auto the "
                         "planner prices every arm but only spec-matching "
                         "arms may win (impossible specs fail loudly)")
    ap.add_argument("--shard-state", action="store_true",
                    help="DEPRECATED shim for --parallelism '...,shard'. "
                         "Sharded data parallelism (ZeRO-style): gradients "
                         "reduce-scatter per bucket, optimizer moments + "
                         "f32 master params partitioned 1/p over the data "
                         "axes, params all-gathered on the forward edge")
    ap.add_argument("--memory-budget-gb", type=float, default=None,
                    help="per-worker optimizer-state budget for --sync auto"
                         ": arms that do not fit are dropped, which is how "
                         "the shard axis wins (it never wins on wall clock)")
    ap.add_argument("--pipeline-stages", type=int, default=1, metavar="S",
                    help="DEPRECATED shim for --parallelism 'pp=S'. "
                         "Pipeline parallelism (DESIGN.md §9): cut the "
                         "model into S stages on a pipe x data mesh and "
                         "run 1F1B micro-batching; the gradient sync "
                         "(--compressor/--algo, or the planner's pick "
                         "under --sync auto) runs on the DP dimension "
                         "only, per layer row")
    ap.add_argument("--micro-batches", type=int, default=0, metavar="M",
                    help="DEPRECATED shim for --parallelism 'micro=M'. "
                         "Micro-batches per step (default: 8 in pipeline "
                         "mode, 1 otherwise; bubble fraction "
                         "(S-1)/(S-1+M); the global batch must split into "
                         "DP shards x M).  M>1 with --pipeline-stages 1 "
                         "runs micro-batched gradient accumulation "
                         "through the same executor")
    ap.add_argument("--local-sgd", type=int, default=0, metavar="TAU")
    ap.add_argument("--post-local", type=int, default=0)
    ap.add_argument("--lag", type=float, default=0.0, metavar="THRESH")
    ap.add_argument("--push-pull", type=int, nargs=2, default=None,
                    metavar=("N_PUSH", "N_FETCH"),
                    help="push gradients every N_PUSH steps, fetch (average) "
                         "parameters every N_FETCH steps")
    ap.add_argument("--calibrate", action="store_true",
                    help="time real collectives on this host's mesh before "
                         "planning and fit per-tier α/β (with confidence "
                         "bounds) — --sync auto then prices every arm on "
                         "the FITTED fabric instead of the presets, and "
                         "the plan record gains calibration + drift blocks")
    ap.add_argument("--replan-drift-pct", type=float, default=0.0,
                    metavar="PCT",
                    help="re-run the planner mid-training when the "
                         "measured step time drifts more than PCT%% from "
                         "the modeled wall step (checked every "
                         "--replan-every steps; 0 = off, the default)")
    ap.add_argument("--replan-every", type=int, default=25,
                    help="steps between drift checks for "
                         "--replan-drift-pct (default 25)")
    ap.add_argument("--elastic", action="store_true",
                    help="supervised fault-tolerant step loop (DESIGN.md "
                         "§15): survive worker preemption by resharding "
                         "through the portable checkpoint — no process "
                         "restart — and demote the sync cadence under "
                         "stragglers.  Requires --topology (its world is "
                         "the fleet the fault trace runs against); "
                         "composes with vanilla/comm/auto and pinned "
                         "rounds schedulers, not with pipeline stages")
    ap.add_argument("--fault-trace", default="", metavar="SPEC_OR_PATH",
                    help="deterministic fault schedule for --elastic: a "
                         "compact spec 'kill:3@5,slow:1x4@3,restore:3@9' "
                         "(kind:worker[xfactor]@step) or a path to a JSON "
                         "trace file (FaultSchedule.to_json).  Empty = "
                         "no faults (the supervised loop still runs)")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args(argv)


def scheduler_from_args(args):
    """The rounds axis a user pinned explicitly (None -> every step, or the
    planner's choice under --sync auto)."""
    picked = [f for f, on in (("--lag", args.lag > 0),
                              ("--local-sgd", args.local_sgd > 1),
                              ("--push-pull", args.push_pull is not None))
              if on]
    if len(picked) > 1:
        raise SystemExit(f"pick one rounds schedule, got {picked}")
    if args.lag > 0:
        return get_scheduler("lag", threshold=args.lag)
    if args.local_sgd > 1:
        return get_scheduler("local_sgd", period=args.local_sgd,
                             post_local_after=args.post_local)
    if args.push_pull is not None:
        return get_scheduler("push_pull", n_push=args.push_pull[0],
                             n_fetch=args.push_pull[1])
    return None


def resolve_cli_parallelism(args):
    """Fold the CLI's parallelism surface — the unified ``--parallelism``
    spec and the deprecated ``--shard-state``/``--pipeline-stages``/
    ``--micro-batches`` shims — into ``(par_spec, shard, pipe, micro)``.
    Mixing the spec with a shim is a loud SystemExit; a shim alone warns
    and builds the equivalent spec via :meth:`ParallelismSpec.legacy`."""
    legacy_used = [f for f, on in
                   (("--shard-state", args.shard_state),
                    ("--pipeline-stages", args.pipeline_stages != 1),
                    ("--micro-batches", args.micro_batches != 0)) if on]
    if args.parallelism:
        if legacy_used:
            raise SystemExit(
                f"--parallelism subsumes {', '.join(legacy_used)}; fold "
                f"them into the spec (e.g. 'dp=4,pp=2,micro=8,shard')")
        try:
            par_spec = ParallelismSpec.from_spec(args.parallelism)
        except ValueError as e:
            raise SystemExit(f"--parallelism: {e}")
        if par_spec.pp > 1 and not par_spec.micro_batches:
            # the executor's pipeline default (bubble (S-1)/(S-1+M))
            par_spec = dataclasses.replace(par_spec, micro_batches=8)
        return (par_spec, par_spec.shard_state, par_spec.pp,
                par_spec.micro_batches or 1)
    if legacy_used:
        print(f"warning: {', '.join(legacy_used)} deprecated; use "
              f"--parallelism (e.g. 'dp=4,pp=2,micro=8,shard')",
              flush=True)
    shard = args.shard_state
    pipe = args.pipeline_stages
    if pipe < 1:
        raise SystemExit(f"--pipeline-stages must be >= 1, got {pipe}")
    micro = args.micro_batches or (8 if pipe > 1 else 1)
    if pipe > 1 and shard:
        raise SystemExit("--pipeline-stages and --shard-state are "
                         "competing answers to the optimizer-memory "
                         "axis; pick one (DESIGN.md §9)")
    par_spec = ParallelismSpec.legacy(shard_state=shard,
                                      pipeline_stages=pipe,
                                      micro_batches=micro)
    return par_spec, shard, pipe, micro


def run_elastic(args, scfg):
    """``--elastic``: drive the session through the supervised
    fault-tolerant loop instead of a bare ``run()``.  Fresh sessions (and
    fresh scheduler instances — backpressure mutates scheduler config)
    come from a factory so resharding rebuilds from scratch every time."""
    import tempfile

    from repro.elastic import ElasticConfig, ElasticRuntime, FaultSchedule
    from repro.launch.report import render_elastic_events

    if not args.topology:
        raise SystemExit("--elastic needs --topology: the tier-size "
                         "product is the fleet the fault trace runs "
                         "against")
    _, shard, pipe, micro = resolve_cli_parallelism(args)
    if pipe > 1 or micro > 1:
        raise SystemExit("--elastic resharding composes with replicated "
                         "and sharded DP; pipeline/micro-batched builds "
                         "cannot restore mid-run (DESIGN.md §15)")

    def factory():
        s = TrainSession(SessionConfig(**dataclasses.asdict(scfg)))
        scheduler = scheduler_from_args(args)
        if args.sync == "comm":
            sync_cfg = SyncConfig(
                compressor=args.compressor, algo=args.algo,
                error_feedback=not args.no_error_feedback,
                bucket_bytes=int(args.bucket_mb * 2**20))
            s.strategy = make_strategy(
                scheduler if scheduler is not None else "every_step",
                axes=s.axes, sync=sync_cfg)
        elif scheduler is not None:
            s.strategy = SyncStrategy(scheduler=scheduler)
        return s

    from repro.core.schedule import Topology
    topo = Topology.from_spec(args.topology)
    trace = args.fault_trace
    if trace and os.path.exists(trace):
        schedule = FaultSchedule.from_json(trace)
        if schedule.world != topo.world:
            raise SystemExit(
                f"fault trace {trace} is against world={schedule.world} "
                f"but --topology {topo.spec()!r} has world={topo.world}")
    else:
        schedule = FaultSchedule.from_spec(trace, world=topo.world)
    cfg = ElasticConfig(
        topology=topo, checkpoint_dir=tempfile.mkdtemp(prefix="elastic_"),
        plan=(args.sync == "auto"), link=args.link,
        t_backward_s=(args.plan_backward_ms / 1e3
                      if args.plan_backward_ms > 0 else 0.05))
    rt = ElasticRuntime(factory, schedule, cfg)
    losses = rt.run(args.steps)
    print(render_elastic_events(rt.events), flush=True)
    if args.checkpoint:
        rt.session.save_checkpoint(args.checkpoint)
        print("checkpoint written:", args.checkpoint)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) | "
          f"steps {rt.session.step}, comm rounds {rt.comm_rounds} "
          f"(grad {rt.grad_rounds}, param {rt.param_rounds}), "
          f"{len(rt.events)} elastic events")
    return losses


def main(argv=None):
    args = parse_args(argv)
    scfg = SessionConfig(
        arch=args.arch, reduced=args.reduced, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr, warmup=args.warmup,
        optimizer=args.optimizer, data_parallel=args.data_parallel)
    if args.elastic:
        return run_elastic(args, scfg)
    if args.fault_trace:
        raise SystemExit("--fault-trace only applies under --elastic")
    scheduler = scheduler_from_args(args)
    par_spec, shard, pipe, micro = resolve_cli_parallelism(args)
    if shard and scheduler is not None:
        raise SystemExit("shard_state partitions optimizer state, which "
                         "requires every-step gradient sync; drop "
                         "--local-sgd/--lag/--push-pull")
    pipe_mode = pipe > 1 or micro > 1
    if pipe_mode and scheduler is not None:
        raise SystemExit("pipeline stages / micro-batches require "
                         "every-step gradient sync; drop "
                         "--local-sgd/--lag/--push-pull")
    session = TrainSession(scfg)
    if args.topology:
        superseded = [f for f, on in (("--link", args.link != "fast_ici"),
                                      ("--alpha", args.alpha is not None),
                                      ("--beta-gbps",
                                       args.beta_gbps is not None))
                      if on]
        if superseded:
            print(f"warning: --topology models the network per tier; "
                  f"ignoring flat link flags {', '.join(superseded)}",
                  flush=True)
        topo = session.apply_topology(args.topology)
        if session.tiered_mesh:
            print(f"topology: {topo.spec()} (tiered mesh, axes "
                  f"{'x'.join(t.name for t in topo.tiers)})", flush=True)
        else:
            print(f"topology: {topo.spec()} (planning model; executing on "
                  f"the flat {session.world}-worker host mesh)", flush=True)

    if args.sync == "auto":
        ignored = []
        if args.compressor != "none":
            ignored.append("--compressor")
        if args.algo != "psum":
            ignored.append("--algo")
        if args.bucket_mb != 32.0:
            ignored.append("--bucket-mb")
        if args.no_error_feedback:
            ignored.append("--no-error-feedback")
        if ignored:
            print(f"warning: --sync auto chooses per-bucket strategies; "
                  f"ignoring {', '.join(ignored)}", flush=True)
        cal = None
        if args.calibrate:
            cal = session.calibrate()
            print(cal.describe(), flush=True)
        if args.parallelism and scheduler is not None:
            raise SystemExit("--parallelism pins arms of --sync auto's "
                             "free search; a pinned rounds scheduler "
                             "bypasses that search — drop one")
        plan_kw = dict(
            link=args.link, alpha=args.alpha, beta_gbps=args.beta_gbps,
            t_backward_s=(args.plan_backward_ms / 1e3
                          if args.plan_backward_ms > 0 else None),
            memory_budget_gb=args.memory_budget_gb,
            compression_costs=args.compression_costs or None,
            calibration=cal)
        if args.parallelism:
            sp = session.plan_auto(parallelism=par_spec, **plan_kw)
        else:
            sp = session.plan_auto(
                scheduler=scheduler,
                shard_state=(True if shard else None),
                pipeline_stages=(pipe if pipe > 1 else None),
                micro_batches=(micro if pipe > 1 else None),
                **plan_kw)
        if pipe <= 1 and micro > 1:
            # S=1 accumulation rides the winning arm when it composes
            session.apply_micro_batching(micro)
        print(render_strategy_plan(
            sp, arms=session.planned["arms"],
            baselines=session.planned["baselines"],
            t_backward_s=session.planned["t_backward_s"]), flush=True)
        plan_path = save_strategy_plan(sp, args.arch)
        print(f"plan record: {plan_path}", flush=True)
        best_fixed = min(p.modeled_step_s
                         for p in session.planned["baselines"].values())
        unconstrained = (scheduler is None and not shard
                         and args.memory_budget_gb is None and pipe <= 1
                         and par_spec.is_trivial)
        if unconstrained and sp.modeled_step_s > best_fixed + 1e-12:
            # a memory budget / pinned shard axis may legitimately force an
            # arm that is modeled slower than the replicated baselines —
            # the auto<=fixed guarantee holds only for the free search
            raise RuntimeError(
                f"planner regression: auto strategy modeled "
                f"{sp.modeled_step_s:.6f}s > best fixed baseline "
                f"{best_fixed:.6f}s")
    elif args.sync == "comm":
        sync_cfg = SyncConfig(
            compressor=args.compressor, algo=args.algo,
            error_feedback=not args.no_error_feedback,
            bucket_bytes=int(args.bucket_mb * 2**20))
        session.strategy = make_strategy(
            scheduler if scheduler is not None else "every_step",
            axes=session.axes, sync=sync_cfg, parallelism=par_spec)
    elif pipe_mode or shard or not par_spec.is_trivial:
        # vanilla + a parallelism spec: dense psum wires on the DP edge,
        # pipeline/micro-batching/partitioned state per the spec
        session.strategy = make_strategy(
            "every_step", axes=session.axes, parallelism=par_spec)
    elif scheduler is not None:
        # vanilla + an explicit rounds schedule: dense reducers
        session.strategy = SyncStrategy(scheduler=scheduler)
    # else: strategy None -> vanilla BSP (pjit, XLA collectives)

    if args.calibrate and args.sync != "auto":
        print("warning: --calibrate fits the link model --sync auto plans "
              "with; without --sync auto the fit is printed but unused",
              flush=True)
        print(session.calibrate().describe(), flush=True)
    if args.replan_drift_pct > 0:
        if args.sync != "auto" or scheduler is not None or pipe_mode \
                or shard:
            raise SystemExit("--replan-drift-pct re-runs the free planner "
                             "search; it requires --sync auto without a "
                             "pinned scheduler/pipeline/shard axis")
        session.enable_replan(args.replan_drift_pct,
                              check_every=args.replan_every)
    if session.strategy is not None:
        print(f"strategy: {session.strategy.describe()}", flush=True)
    losses = session.run(args.steps, log_every=args.log_every)
    drift = session.drift_report()
    if drift is not None and (args.calibrate or args.replan_drift_pct > 0):
        from repro.launch.report import render_drift_table
        print(render_drift_table(drift), flush=True)
        if args.sync == "auto":
            # re-write the record with the post-run calibration + drift
            # blocks (the pre-run write keeps the base schema)
            plan_path = save_strategy_plan(
                session.planned["strategy_plan"], args.arch,
                calibration=session.calibration, drift=drift)
            print(f"plan record (with drift): {plan_path}", flush=True)
    if getattr(session, "layout", None) is not None:
        from repro.launch.report import render_sharded_memory
        print(render_sharded_memory(session.layout, args.optimizer,
                                    moments=session.opt_moments),
              flush=True)
    if session.routed_tokens:
        from repro.launch.report import render_moe_drops
        print(render_moe_drops(session.dropped_tokens, session.routed_tokens,
                               session.model_cfg.capacity_factor),
              flush=True)
    if getattr(session, "staged", None) is not None:
        from repro.launch.report import render_pipeline_stages
        print(render_pipeline_stages(
            session.staged, session._params,
            session.strategy.micro_batches, moments=session.opt_moments),
            flush=True)

    if args.checkpoint:
        session.save_checkpoint(args.checkpoint)
        print("checkpoint written:", args.checkpoint)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) "
          f"steps/s {args.steps / session.wall_s:.2f} | {session.summary()}")
    return losses


if __name__ == "__main__":
    main()
