"""Training launcher.

Modes (the survey's taxonomy, selectable from the CLI):
  * --sync vanilla                 BSP data-parallel, dense psum (baseline)
  * --sync comm                    GradientSynchronizer: --compressor/--algo/
                                   --bucket-mb/--no-error-feedback
  * --sync auto                    communication planner: profile one step,
                                   search per-bucket (compressor x algo x
                                   fusion) against the --link α-β model,
                                   then run the planned step (DESIGN.md §6)
  * --local-sgd TAU                periodic model averaging (+ --post-local N)
  * --lag THRESH                   lazily aggregated gradients (host dispatch)

Runs on whatever devices exist (CPU: 1-device mesh; the same code drives the
production mesh).  Example (the e2e driver, deliverable b):

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
        --steps 200 --batch 8 --seq 128 --sync comm --compressor topk --algo ring
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import save as save_ckpt
from repro.configs import ALL_ARCHS, get_config, reduced
from repro.core import (GradientSynchronizer, LAGConfig, LocalSGDConfig,
                        SyncConfig, average_params, init_lag_state,
                        lag_trigger, should_sync)
from repro.core.schedule import (LINK_PRESETS, LinkParams, fixed_config_plan,
                                 plan as plan_comm, profiles_from_grads)
from repro.core.schedule.planner import FIXED_BASELINES
from repro.data import DataConfig, SyntheticPipeline
from repro.launch.mesh import data_axes, make_host_mesh
from repro.launch.report import render_comm_plan, save_comm_plan
from repro.launch.steps import (make_comm_optimized_train_step,
                                make_planned_train_step, make_train_step)
from repro.models import Model
from repro.models.sharding_ctx import set_mesh_ctx
from repro.optim import make_optimizer, warmup_cosine


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg)
    n_dev = len(jax.devices())
    dp = args.data_parallel or n_dev
    mesh = make_host_mesh(data=dp, model=n_dev // dp)
    set_mesh_ctx(mesh, ("data",))
    lr = warmup_cosine(args.lr, args.warmup, args.steps)
    opt = make_optimizer(args.optimizer, lr=lr)
    return cfg, model, mesh, opt


def resolve_link(args) -> LinkParams:
    link = LINK_PRESETS[args.link]
    alpha = link.alpha_s if args.alpha is None else args.alpha
    beta = link.beta_s_per_byte if args.beta_gbps is None \
        else 1.0 / (args.beta_gbps * 1e9)
    return LinkParams(alpha_s=alpha, beta_s_per_byte=beta)


def plan_for_training(model, params, data, mesh, axes, args):
    """``--sync auto``: profile one step, then search per-bucket strategies.

    Profiling measures the wall time of one jitted grad step (compile
    excluded) and apportions it across gradient leaves by size — the
    granularity we actually have on TPU, where XLA fuses per-layer times
    away.  The planner then optimizes the simulated WFBP iteration time
    under the chosen α-β link model; the result is printed through
    ``report.render_comm_plan`` next to the fixed baselines it must beat.
    """
    mesh_world = 1
    for a in axes:
        mesh_world *= mesh.shape[a]
    world = args.plan_world or mesh_world
    link = resolve_link(args)

    # Profile the PER-DEVICE backward: the planned shard_map step computes
    # global_batch / mesh_world per device, so time that slice — timing the
    # full global batch would inflate t_backward by the data-parallel
    # factor and make the planner over-hide communication.
    grad_fn = jax.jit(lambda p, b: jax.grad(model.loss)(p, b))
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    n_global = jax.tree.leaves(batch)[0].shape[0]
    per_dev = max(1, n_global // mesh_world)
    batch = jax.tree.map(lambda x: x[:per_dev], batch)
    jax.block_until_ready(grad_fn(params, batch))          # compile
    t0 = time.time()
    jax.block_until_ready(grad_fn(params, batch))
    t_backward = (time.time() - t0) * (2.0 / 3.0)  # bwd ≈ 2/3 of grad step

    profiles = profiles_from_grads(params, t_backward)
    comm_plan = plan_comm(profiles, link, world)
    baselines = {
        name: fixed_config_plan(profiles, link, world, comp, algo,
                                compressor_args=cargs)
        for name, (comp, algo, cargs) in FIXED_BASELINES.items()}
    print(render_comm_plan(comm_plan, baselines=baselines,
                           t_backward_s=t_backward), flush=True)
    plan_path = save_comm_plan(comm_plan, args.arch)
    print(f"plan record: {plan_path}", flush=True)
    best_fixed = min(p.modeled_step_s for p in baselines.values())
    if comm_plan.modeled_step_s > best_fixed + 1e-12:
        raise RuntimeError(
            f"planner regression: auto plan modeled "
            f"{comm_plan.modeled_step_s:.6f}s > best fixed baseline "
            f"{best_fixed:.6f}s")
    return comm_plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--optimizer", default="adam",
                    choices=["sgd", "adam", "lars", "lamb"])
    ap.add_argument("--data-parallel", type=int, default=0)
    ap.add_argument("--sync", default="vanilla",
                    choices=["vanilla", "comm", "auto"])
    ap.add_argument("--compressor", default="none")
    ap.add_argument("--algo", default="psum")
    ap.add_argument("--bucket-mb", type=float, default=32.0)
    ap.add_argument("--no-error-feedback", action="store_true")
    ap.add_argument("--link", default="fast_ici", choices=sorted(LINK_PRESETS),
                    help="α-β regime the planner optimizes for (--sync auto)")
    ap.add_argument("--alpha", type=float, default=None,
                    help="override link latency α in seconds (--sync auto)")
    ap.add_argument("--beta-gbps", type=float, default=None,
                    help="override link bandwidth in GB/s (--sync auto)")
    ap.add_argument("--plan-world", type=int, default=0,
                    help="plan for this world size instead of the mesh's "
                         "(model a pod from a laptop)")
    ap.add_argument("--local-sgd", type=int, default=0, metavar="TAU")
    ap.add_argument("--post-local", type=int, default=0)
    ap.add_argument("--lag", type=float, default=0.0, metavar="THRESH")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, model, mesh, opt = build(args)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    opt_state = opt.init(params)
    step_i = jnp.zeros((), jnp.int32)

    data = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        embedding_dim=cfg.d_model if cfg.embedding_inputs else 0))

    axes = data_axes(mesh)
    sync_cfg = SyncConfig(
        compressor=args.compressor, algo=args.algo,
        error_feedback=not args.no_error_feedback,
        bucket_bytes=int(args.bucket_mb * 2**20))

    if args.sync == "comm":
        step_fn, synchronizer, init_sync_state = make_comm_optimized_train_step(
            model, opt, sync_cfg, mesh, axes)
        sync_state = init_sync_state(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
    elif args.sync == "auto":
        ignored = []
        if args.compressor != "none":
            ignored.append("--compressor")
        if args.algo != "psum":
            ignored.append("--algo")
        if args.bucket_mb != 32.0:
            ignored.append("--bucket-mb")
        if args.no_error_feedback:
            ignored.append("--no-error-feedback")
        if ignored:
            print(f"warning: --sync auto chooses per-bucket strategies; "
                  f"ignoring {', '.join(ignored)}", flush=True)
        comm_plan = plan_for_training(model, params, data, mesh, axes, args)
        step_fn, executor, init_sync_state = make_planned_train_step(
            model, comm_plan, opt, mesh, axes)
        sync_state = init_sync_state(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1, 2))
    else:
        base = make_train_step(model, opt)
        jit_step = jax.jit(base, donate_argnums=(0, 1))
        sync_state = None

    # local-SGD variant: an extra compiled program for the averaging round
    avg_fn = None
    if args.local_sgd > 1:
        local_cfg = LocalSGDConfig(period=args.local_sgd,
                                   post_local_after=args.post_local)

        def avg(params):
            f = jax.shard_map(lambda p: average_params(p, axes),
                              mesh=mesh, in_specs=P(), out_specs=P(),
                              axis_names=set(axes), check_vma=False)
            return f(params)
        avg_fn = jax.jit(avg)

    lag_state = init_lag_state(params) if args.lag > 0 else None
    losses, t0, rounds = [], time.time(), 0
    for step in range(args.steps):
        batch = jax.tree.map(jnp.asarray, data.batch(step))
        step_i = jnp.asarray(step, jnp.int32)
        if args.sync in ("comm", "auto"):
            params, opt_state, sync_state, loss = jit_step(
                params, opt_state, sync_state, batch, step_i,
                jax.random.fold_in(rng, step))
            rounds += 1
        else:
            params, opt_state, loss = jit_step(params, opt_state, batch, step_i)
            rounds += 1
        if avg_fn is not None and should_sync(step, local_cfg):
            params = avg_fn(params)
        losses.append(float(loss))
        if step % args.log_every == 0:
            dt = (time.time() - t0) / max(step, 1)
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"({dt*1e3:.0f} ms/step, comm rounds {rounds})", flush=True)

    if args.checkpoint:
        save_ckpt(args.checkpoint, {"params": params, "opt": opt_state},
                  step=args.steps)
        print("checkpoint written:", args.checkpoint)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) "
          f"steps/s {args.steps/(time.time()-t0):.2f}")
    return losses


if __name__ == "__main__":
    main()
