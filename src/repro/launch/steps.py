"""Step functions wired for pjit: vanilla (paper-baseline BSP data parallel,
XLA-inserted collectives) and comm-optimized (shard_map manual over the data
axes with the GradientSynchronizer's explicit compress + collective path).

The vanilla step with FSDP sharding is what every (arch x shape) baseline
dry-run lowers; the comm-optimized step is the paper's §3/§4 machinery and
is exercised on archs whose parameters fit a pure DP+TP layout.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import GradientSynchronizer, PlanExecutor, SyncConfig
from repro.core.schedule.planner import CommPlan
from repro.models import Model
from repro.optim import apply_updates, make_optimizer


# ---------------------------------------------------------------------------
# Vanilla BSP step (survey §2.4.1 baseline) — pjit/XLA collectives
# ---------------------------------------------------------------------------

def make_train_step(model: Model, optimizer, microbatches: int = 1):
    """BSP train step.  ``microbatches > 1`` runs gradient accumulation: the
    global batch is split along dim 0 and forward/backward runs as a scan,
    bounding activation memory at 1/M of the full batch (survey §3.1.1 —
    accumulation is how large-batch recipes actually execute) while keeping
    the optimizer step and gradient synchronization per-step identical."""
    def train_step(params, opt_state, batch, step):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            B = jax.tree.leaves(batch)[0].shape[0]
            assert B % microbatches == 0, (B, microbatches)
            mb = B // microbatches

            def body(acc, i):
                tot_loss, g_acc = acc
                bslice = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0),
                    batch)
                l, g = jax.value_and_grad(model.loss)(params, bslice)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), g_acc, g)
                return (tot_loss + l, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), jnp.arange(microbatches))
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model: Model, mla_absorb: bool = False,
                     moe_dispatch: bool = False):
    def decode_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos,
                                 mla_absorb=mla_absorb,
                                 moe_dispatch=moe_dispatch)

    return decode_step


# ---------------------------------------------------------------------------
# Comm-optimized step (survey §3 + §4) — manual data axes via shard_map
# ---------------------------------------------------------------------------

def make_comm_optimized_train_step(model: Model, optimizer, sync: SyncConfig,
                                   mesh, data_axes: Sequence[str] = ("data",)):
    """Per-shard loss/backward; gradient exchange through the
    GradientSynchronizer (compression + explicit collective algorithm).

    Params must be laid out replicated over the data axes (pure DP+TP):
    use ``model.partition_specs('serve')`` which shards over 'model' only.
    The 'model' mesh axis stays auto — XLA partitions tensor-parallel math
    inside the shard_map body.
    """
    synchronizer = GradientSynchronizer(sync, tuple(data_axes))
    return _make_synced_train_step(model, optimizer, synchronizer, mesh,
                                   data_axes)


def make_planned_train_step(model: Model, plan: CommPlan, optimizer, mesh,
                            data_axes: Sequence[str] = ("data",)):
    """Like :func:`make_comm_optimized_train_step` but driven by a
    ``CommPlan`` (heterogeneous per-bucket strategies, ``--sync auto``):
    the PlanExecutor may compress one bucket over an explicit ring while the
    next goes dense over psum."""
    executor = PlanExecutor(plan, tuple(data_axes))
    return _make_synced_train_step(model, optimizer, executor, mesh,
                                   data_axes)


def _world_of(mesh, data_axes: Sequence[str]) -> int:
    world = 1
    for a in data_axes:
        world *= mesh.shape[a]
    return world


def broadcast_worker_state(tree, world: int):
    """Give every leaf a leading device axis of length ``world`` (to be
    sharded over the data axes): the layout of anything carried PER WORKER —
    EF residuals, and params/optimizer state under strategies with local
    phases (local SGD, push-pull), where workers genuinely diverge."""
    return jax.tree.map(
        lambda s: jnp.broadcast_to(s, (world,) + s.shape), tree)


def worker_view(tree):
    """Worker-0 slice of a per-worker tree (checkpointing / inspection)."""
    return jax.tree.map(lambda s: s[0], tree)


def _make_synced_train_step(model: Model, optimizer, synchronizer, mesh,
                            data_axes: Sequence[str],
                            per_worker_params: bool = False):
    """Shared shard_map step around any grad-sync engine exposing
    ``init_state(grads)`` and ``__call__(grads, state, rng)``.

    ``per_worker_params=True`` carries params/optimizer state with a leading
    per-worker axis (push-pull: gradients are synced but parameters have
    diverged during local phases, so they may differ across workers)."""
    world = _world_of(mesh, data_axes)

    def body(params, opt_state, sync_state, batch, step, rng):
        from repro.models.sharding_ctx import manual_region
        # error-feedback state is PER WORKER: it arrives with a leading
        # device axis of length 1 (sharded over the data axes) — strip it,
        # use it, put it back.  This both matches EF semantics and shards
        # the f32 residual (a full parameter copy) across the data axes
        # instead of replicating it (§Perf pair-3 iteration 5 finding).
        sync_state = jax.tree.map(lambda s: s[0], sync_state)
        if per_worker_params:
            params = jax.tree.map(lambda s: s[0], params)
            opt_state = jax.tree.map(lambda s: s[0], opt_state)
        with manual_region(data_axes):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, sync_state = synchronizer(grads, sync_state, rng)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        # local losses differ per shard only through data; report the mean
        loss = jax.lax.pmean(loss, tuple(data_axes))
        sync_state = jax.tree.map(lambda s: s[None], sync_state)
        if per_worker_params:
            params = jax.tree.map(lambda s: s[None], params)
            opt_state = jax.tree.map(lambda s: s[None], opt_state)
        return params, opt_state, sync_state, loss

    # Specs describe only the MANUAL (data) axes: params / optimizer state
    # are replicated across them (P() prefix); the batch and the EF state
    # are sharded.  The 'model' axis stays auto — its tensor-parallel
    # layout comes from the jit in_shardings outside this shard_map.
    batch_spec = {"tokens": P(tuple(data_axes), None)}
    state_spec = P(tuple(data_axes))
    p_spec = state_spec if per_worker_params else P()

    def step_fn(params, opt_state, sync_state, batch, step, rng):
        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(p_spec, p_spec, state_spec, batch_spec, P(), P()),
            out_specs=(p_spec, p_spec, state_spec, P(), ),
            axis_names=set(data_axes), check_vma=False)
        return f(params, opt_state, sync_state, batch, step, rng)

    def init_sync_state(params):
        """Per-worker EF state with a leading device axis (shard over data).
        Takes the PLAIN params pytree (no worker axis) in either mode."""
        return broadcast_worker_state(synchronizer.init_state(params), world)

    return step_fn, synchronizer, init_sync_state


# ---------------------------------------------------------------------------
# Sharded data parallelism (ZeRO-style, DESIGN.md §8)
# ---------------------------------------------------------------------------

def make_sharded_train_step(model: Model, executor, layout, sharded_opt,
                            mesh, data_axes: Sequence[str] = ("data",)):
    """Sharded-DP step: gradients reduce-scatter per bucket to canonical
    owners (``PlanExecutor.sync_shards``), each rank updates only its (m,)
    slice of f32 master params + optimizer moments (``sharded_opt``, from
    ``repro.optim.make_sharded_optimizer``), and the updated master shards
    all-gather back into full params for the next forward.

    Params enter and leave REPLICATED over the data axes (the forward needs
    them whole); what is partitioned — the ~2-3× params of optimizer state —
    is carried as per-bucket shard rows with a leading device axis of length
    world, sharded over the data axes (each device holds exactly its own
    (1, m) slice): ``{"master": [rows...], "opt": <moments of rows>}``.

    Bit-compatibility (the conformance suite's promise): for dense fp32
    plans on psum/ring, params and reconstructed optimizer state match the
    replicated ``_make_synced_train_step`` path bit-for-bit — the scatter
    chunks equal the allreduce slices, the elementwise update commutes with
    slicing, and the gather moves exact values.
    """
    world = _world_of(mesh, data_axes)
    axes = tuple(data_axes)
    if tuple(b.leaves for b in executor.plan.buckets) != \
            tuple(b.leaves for b in layout.buckets):
        raise ValueError("ShardLayout does not match the executor's plan "
                         "buckets — build it with ShardLayout.from_plan on "
                         "the same CommPlan")
    batch_spec = {"tokens": P(tuple(data_axes), None)}
    state_spec = P(tuple(data_axes))

    def body(params, opt_rows, sync_state, batch, step, rng):
        from repro.core.collectives import all_gather_shards
        from repro.models.sharding_ctx import manual_region
        sync_state = jax.tree.map(lambda s: s[0], sync_state)
        opt = jax.tree.map(lambda s: s[0], opt_rows)
        with manual_region(data_axes):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        gshards, sync_state = executor.sync_shards(grads, sync_state, rng)
        updates, inner = sharded_opt.update(gshards, opt["opt"],
                                            opt["master"], step)
        # the add mirrors apply_updates on the replicated path (masters ARE
        # the f32 params); XLA's per-graph FMA contraction of this add is
        # the one place the two modes may differ in the last ulp — see the
        # conformance suite's tolerance notes (DESIGN.md §8)
        masters = [m + u for m, u in zip(opt["master"], updates)]

        # forward edge: gather the updated 1/p master shards back to full
        # params (in the leaves' own dtypes)
        leaves = jax.tree.leaves(params)
        out = [None] * len(leaves)
        for b, bl, shard in zip(executor.plan.buckets, layout.buckets,
                                masters):
            full = all_gather_shards(shard, bl.n, b.algo, axes)
            off = 0
            for i, sz in zip(bl.leaves, bl.sizes):
                out[i] = full[off:off + sz].reshape(
                    leaves[i].shape).astype(leaves[i].dtype)
                off += sz
        new_params = jax.tree.unflatten(jax.tree.structure(params), out)

        loss = jax.lax.pmean(loss, tuple(data_axes))
        lead = lambda t: jax.tree.map(lambda s: s[None], t)
        return (new_params, lead({"master": masters, "opt": inner}),
                lead(sync_state), loss)

    def step_fn(params, opt_rows, sync_state, batch, step, rng):
        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(), state_spec, state_spec, batch_spec, P(), P()),
            out_specs=(P(), state_spec, state_spec, P()),
            axis_names=set(data_axes), check_vma=False)
        return f(params, opt_rows, sync_state, batch, step, rng)

    def init_opt_rows(params):
        """Partitioned state: per-bucket f32 master rows (world, m) sliced
        canonically from the current params, plus the sharded optimizer's
        moments over them (zeros, same geometry)."""
        masters = layout.shard_rows(params)
        return {"master": masters, "opt": sharded_opt.init(masters)}

    def init_sync_state(params):
        return broadcast_worker_state(executor.init_state(params), world)

    return step_fn, init_opt_rows, init_sync_state


# ---------------------------------------------------------------------------
# Pipeline parallelism (1F1B micro-batching over a pipe axis, DESIGN.md §9)
# ---------------------------------------------------------------------------

def pipe_spec_tree(template, pipe_axis: str = "pipe"):
    """Per-leaf PartitionSpec tree for pipeline-mode state: any leaf under a
    ``"rows"`` key (per-stage layer rows, or optimizer moments over them)
    carries the leading stage axis sharded over ``pipe``; everything else
    (embed / final norm / lm head and their moments) is replicated."""
    def spec(path, _):
        if any(getattr(e, "key", None) == "rows" for e in path):
            return P(pipe_axis)
        return P()
    return jax.tree_util.tree_map_with_path(spec, template)


def unstack_rows(rows_local, rows_per_stage: int):
    """Stage rows (R/S, ...) -> list of R/S per-row trees: the DP gradient
    edge syncs PER LAYER ROW so compression granularity (int8 scales, top-k
    masks, EF residuals) is identical for every stage count — the
    bit-compatibility contract of the conformance suite (DESIGN.md §9)."""
    return [jax.tree.map(lambda x, i=i: x[i], rows_local)
            for i in range(rows_per_stage)]


def restack_rows(row_trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *row_trees)


def merge_opt_rows(state, rows: int):
    """Leaf-shaped view of pipeline optimizer state: wherever the state
    mirrors the stage tree (``{"shared": ..., "rows": [per-row trees,
    leaves (S, ...)]}``), stack the per-row entries back into the stack's
    ``(R, ...)`` leaves (row r lives at stage r // (R/S), slot r % (R/S)
    — the same stage-major order ``StagedModel.split`` cuts).  Shared by
    ``TrainSession.full_opt_state`` and the conformance checks, so the
    checkpoint merge and the bit-exactness comparison cannot drift."""
    def merge(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "rows" and isinstance(v, list):
                    st = jax.tree.map(lambda *xs: jnp.stack(xs, axis=1), *v)
                    out[k] = jax.tree.map(
                        lambda x: x.reshape((rows,) + x.shape[2:]), st)
                else:
                    out[k] = merge(v)
            return out
        if isinstance(node, list):
            return [merge(x) for x in node]
        return node

    return merge(state)


def make_pipeline_train_step(staged, optimizer, engine, mesh,
                             micro_batches: int,
                             data_axes: Sequence[str] = ("data",),
                             pipe_axis: str = "pipe"):
    """1F1B pipeline-parallel train step on a ``pipe × data`` mesh.

    ``staged`` is a :class:`repro.core.pipeline.StagedModel` (or any object
    with the same ``layout`` / ``split`` / ``embed_mb`` / ``stage_apply`` /
    ``loss_tail`` / ``aux_coef`` surface).  Params travel as
    ``{"shared": ..., "rows": ...}`` — shared replicated, rows with a
    leading (S,) stage axis sharded over ``pipe``.

    The body runs the 1F1B dataflow on an aligned slot grid of
    ``T = M + 2(S-1)`` ticks (``pipeline.aligned_ticks``): every tick each
    pipe rank executes one masked forward slot and one masked backward
    slot, then boundary payloads move one hop by ``send_recv`` (activations
    forward, grad-activations backward).  The ppermute is a rendezvous, so
    the slots are globally aligned — per-stage op order matches the
    canonical ``schedule_1f1b`` (warmup, steady 1F/1B, drain) with at most
    ``2(S-1-s)+1`` micro-batches in flight; backward slots rematerialize
    the stage forward from the buffered boundary input, exactly the remat
    policy the stack already uses per period (DESIGN.md §9).

    Gradients accumulate over micro-batches in ascending order (bit-equal
    to scan accumulation), shared-cell grads are combined across stages by
    one masked psum (adding exact zeros), and the DP edge syncs the
    per-row-unstacked pytree through ``engine`` over ``data_axes`` only —
    so per-bucket compression composes on the DP dimension of the 2-D
    mesh.  The optimizer then updates stage-locally (elementwise
    optimizers are bit-identical to the single-stage update restricted to
    the stage; layerwise norms see per-row leaves).
    """
    from repro.core.collectives import send_recv

    S = staged.layout.n_stages
    if mesh.shape[pipe_axis] != S:
        raise ValueError(f"mesh pipe axis {mesh.shape[pipe_axis]} != "
                         f"staged n_stages {S}")
    M = int(micro_batches)
    if M < 1:
        raise ValueError(f"micro_batches must be >= 1, got {M}")
    T = M + 2 * (S - 1)
    W = 2 * S - 1                        # live window of buffered F inputs
    axes = tuple(data_axes)
    rps = staged.layout.rows_per_stage
    world = _world_of(mesh, axes) * S    # sync/EF state is per (pipe, data)

    def body(params, opt_state, sync_state, batch, step, rng):
        from repro.models.sharding_ctx import manual_region
        with manual_region((pipe_axis,) + axes):
            return _body(params, opt_state, sync_state, batch, step, rng)

    def _body(params, opt_state, sync_state, batch, step, rng):
        shared = params["shared"]
        rows = jax.tree.map(lambda s: s[0], params["rows"])     # (R/S, ...)
        opt = jax.tree_util.tree_map_with_path(
            lambda p, s: s[0] if any(getattr(e, "key", None) == "rows"
                                     for e in p) else s, opt_state)
        sync_state_l = jax.tree.map(lambda s: s[0], sync_state)

        s_idx = jax.lax.axis_index(pipe_axis)
        is_first = s_idx == 0
        is_last = s_idx == S - 1
        tokens = batch["tokens"]                    # per-DP-shard slice
        b_dp, seq = tokens.shape
        assert b_dp % M == 0, (b_dp, M)
        toks_mb = tokens.reshape(M, b_dp // M, seq)

        def sel_mb(m):
            return jax.lax.dynamic_index_in_dim(
                toks_mb, jnp.clip(m, 0, M - 1), 0, keepdims=False)

        def stage_fwd(rows_, payload):
            h, aux = staged.stage_apply(rows_, payload["h"])
            return {"h": h, "aux": payload["aux"] + aux}

        def fwd_and_loss(rows_, shared_, payload, toks):
            out = stage_fwd(rows_, payload)
            l = (staged.loss_tail(shared_, out["h"], toks)
                 + staged.aux_coef * out["aux"])
            return out, l

        f32 = jnp.float32
        zero_payload = {
            "h": jnp.zeros_like(staged.embed_mb(shared, sel_mb(
                jnp.zeros((), jnp.int32)))),
            "aux": jnp.zeros((), f32)}
        buf = jax.tree.map(
            lambda x: jnp.zeros((W,) + x.shape, x.dtype), zero_payload)
        recv_f = zero_payload
        recv_b = zero_payload
        g_shared = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), shared)
        g_rows = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), rows)
        loss_sum = jnp.zeros((), f32)

        def masked_add(acc, g, m):
            return jax.tree.map(
                lambda a, d: a + jnp.where(m, d.astype(f32), 0.0), acc, g)

        for k in range(T):
            # ---- forward slot: F(k - s) ----
            m_f = k - s_idx
            x_first = {"h": staged.embed_mb(shared, sel_mb(m_f)),
                       "aux": jnp.zeros((), f32)}
            x_in = jax.tree.map(lambda a, b: jnp.where(is_first, a, b),
                                x_first, recv_f)
            # stage-interface barrier (paired with the per-row barriers in
            # stage_apply): the embed/recv select must not fuse into the
            # stage body, or the S=1 and S>1 backward graphs diverge in
            # the last ulp (DESIGN.md §9)
            x_in = jax.lax.optimization_barrier(x_in)
            out = stage_fwd(rows, x_in)
            buf = jax.tree.map(lambda b_, x: b_.at[k % W].set(x), buf, x_in)

            # ---- backward slot: B(k - 2(S-1) + s) on the F input buffered
            # at tick k - 2(S-1) + 2s (rematerialized forward) ----
            m_b = k - 2 * (S - 1) + s_idx
            valid_b = (m_b >= 0) & (m_b < M)
            k_f = k - 2 * (S - 1) + 2 * s_idx
            x_b = jax.tree.map(
                lambda b_: jax.lax.dynamic_index_in_dim(
                    b_, jnp.mod(k_f, W), 0, keepdims=False), buf)
            toks_b = sel_mb(m_b)
            (out_b, l_b), vjp = jax.vjp(
                lambda r_, s_, x_: fwd_and_loss(r_, s_, x_, toks_b),
                rows, shared, x_b)
            # incoming grad-activation (zeros for the last stage, whose
            # backward is seeded by the loss cotangent instead)
            ct_out = jax.tree.map(
                lambda t: jnp.where(valid_b & ~is_last, t,
                                    jnp.zeros((), t.dtype)), recv_b)
            ct_l = jnp.where(valid_b & is_last, jnp.ones((), l_b.dtype),
                             jnp.zeros((), l_b.dtype))
            d_rows, d_shared, d_x = vjp((ct_out, ct_l))
            g_rows = masked_add(g_rows, d_rows, valid_b)
            g_shared = masked_add(g_shared, d_shared, valid_b)
            # chain the input cotangent into the embedding (stage 0 owns it)
            ct_emb = jax.tree.map(
                lambda t: jnp.where(valid_b & is_first, t,
                                    jnp.zeros((), t.dtype)), d_x["h"])
            _, vjp_e = jax.vjp(
                lambda s_: staged.embed_mb(s_, toks_b), shared)
            (d_emb,) = vjp_e(ct_emb)
            g_shared = masked_add(g_shared, d_emb, valid_b & is_first)
            loss_sum = loss_sum + jnp.where(valid_b & is_last, l_b, 0.0)

            # ---- boundary exchange: one hop each way ----
            if S > 1:
                recv_f = send_recv(out, pipe_axis, +1)
                recv_b = send_recv(d_x, pipe_axis, -1)

        # shared cells: stage 0 holds the embed grads, stage S-1 the
        # loss-tail grads, everyone else exact zeros — one psum combines
        g_shared = jax.tree.map(lambda g: jax.lax.psum(g, pipe_axis),
                                g_shared)
        inv_m = 1.0 / M
        g_shared = jax.tree.map(lambda g: g * inv_m, g_shared)
        g_rows = jax.tree.map(lambda g: g * inv_m, g_rows)

        # DP edge: per-row granularity, data axes only (stage-count
        # invariant compression — DESIGN.md §9)
        gtree = {"shared": g_shared, "rows": unstack_rows(g_rows, rps)}
        synced, sync_state_l = engine(gtree, sync_state_l, rng)
        # barrier: stop XLA fusing optimizer math into the gradient /
        # collective chain, which would let per-graph fusion choices leak
        # into the update arithmetic (same idiom as transformer._boundary)
        synced = jax.lax.optimization_barrier(synced)

        # the optimizer ALSO runs on the per-row-unstacked tree: every
        # row's update subgraph then has the same shapes at every stage
        # count, which (with the explicit-wire sync) makes params and
        # moments bit-exact across stage counts — updating the fused
        # (R/S, ...) stack instead lets XLA compile the elementwise chain
        # differently per shape (DESIGN.md §9)
        p_un = {"shared": shared, "rows": unstack_rows(rows, rps)}
        updates, opt = optimizer.update(synced, opt, p_un, step)
        p_un = apply_updates(p_un, updates)

        loss = jax.lax.psum(loss_sum, pipe_axis) * inv_m
        loss = jax.lax.pmean(loss, axes)

        lead_rows = jax.tree_util.tree_map_with_path(
            lambda p, s: s[None] if any(getattr(e, "key", None) == "rows"
                                        for e in p) else s, opt)
        return ({"shared": p_un["shared"],
                 "rows": jax.tree.map(lambda s: s[None],
                                      restack_rows(p_un["rows"]))},
                lead_rows,
                jax.tree.map(lambda s: s[None], sync_state_l), loss)

    batch_spec = {"tokens": P(axes, None)}
    state_spec = P((pipe_axis,) + axes)
    params_spec = {"shared": P(), "rows": P(pipe_axis)}

    def step_fn(params, opt_state, sync_state, batch, step, rng):
        opt_spec = pipe_spec_tree(opt_state, pipe_axis)
        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(params_spec, opt_spec, state_spec, batch_spec, P(),
                      P()),
            out_specs=(params_spec, opt_spec, state_spec, P()),
            axis_names={pipe_axis} | set(axes), check_vma=False)
        return f(params, opt_state, sync_state, batch, step, rng)

    def init_opt_state(split_params):
        """Optimizer state over the per-row-unstacked stage tree, rows
        leaves carrying the leading (S,) stage axis (sharded over pipe):
        ``{"shared": ..., "rows": [row_0, ..., row_{R/S-1}]}`` where row i
        holds stage-s's i-th layer row at index s."""
        rows = split_params["rows"]          # (S, R/S, ...)
        template = {
            "shared": split_params["shared"],
            "rows": [jax.tree.map(lambda x, i=i: x[:, i], rows)
                     for i in range(rps)]}
        return optimizer.init(template)

    def init_sync_state(split_params):
        """Per-(pipe, data)-rank reducer state over the UNSTACKED gradient
        pytree (shared + one entry per layer row)."""
        rows_local = jax.tree.map(lambda s: s[0], split_params["rows"])
        template = {"shared": split_params["shared"],
                    "rows": unstack_rows(rows_local, rps)}
        return broadcast_worker_state(engine.init_state(template), world)

    return step_fn, init_opt_state, init_sync_state


# ---------------------------------------------------------------------------
# Strategy phase programs (SyncStrategy sessions — DESIGN.md §7)
# ---------------------------------------------------------------------------

def make_local_train_step(model: Model, optimizer, mesh,
                          data_axes: Sequence[str] = ("data",)):
    """Purely-local step: per-shard loss/backward/update with NO gradient
    collective (the skip program of local SGD / push-pull).  Params and
    optimizer state carry a leading per-worker axis sharded over the data
    axes, so workers genuinely diverge between rounds — the legacy
    ``--local-sgd`` path ran the BSP step, whose XLA-inserted gradient
    allreduce made the later averaging a no-op on real meshes.  Only the
    scalar loss is pmean-ed (reporting)."""
    batch_spec = {"tokens": P(tuple(data_axes), None)}
    state_spec = P(tuple(data_axes))

    def body(params, opt_state, batch, step):
        from repro.models.sharding_ctx import manual_region
        params = jax.tree.map(lambda s: s[0], params)
        opt_state = jax.tree.map(lambda s: s[0], opt_state)
        with manual_region(data_axes):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        loss = jax.lax.pmean(loss, tuple(data_axes))
        params = jax.tree.map(lambda s: s[None], params)
        opt_state = jax.tree.map(lambda s: s[None], opt_state)
        return params, opt_state, loss

    def step_fn(params, opt_state, batch, step):
        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(state_spec, state_spec, batch_spec, P()),
            out_specs=(state_spec, state_spec, P()),
            axis_names=set(data_axes), check_vma=False)
        return f(params, opt_state, batch, step)

    return step_fn


def make_param_round_step(reducer, mesh, data_axes: Sequence[str] = ("data",),
                          algo: str = "psum"):
    """One parameter-reduce round (local SGD averaging / push-pull fetch).

    ``reducer=None``: plain dense ``average_params`` on ``algo``.  Otherwise
    the round moves the params-minus-anchor DELTA through the reducer (a
    ``PlanExecutor`` — per-bucket compression + error feedback) and rebuilds
    ``params = anchor + reduced_delta``; the anchor (the parameters agreed
    at the last round, identical on every worker) is what keeps compressed
    periodic averaging sound — compressing raw parameter values would, e.g.
    under top-k, zero most of the model.

    Returns ``round_fn(params_w, anchor, red_state, rng) -> (params_w,
    anchor, red_state)`` where ``params_w``/``red_state`` carry the leading
    per-worker axis and ``anchor`` is replicated (None when reducer is None).
    """
    from repro.core import average_params
    state_spec = P(tuple(data_axes))

    if reducer is None:
        def avg_body(params):
            p = jax.tree.map(lambda s: s[0], params)
            p = average_params(p, tuple(data_axes), algo)
            return jax.tree.map(lambda s: s[None], p)

        def round_fn(params, anchor, red_state, rng):
            f = jax.shard_map(avg_body, mesh=mesh, in_specs=(state_spec,),
                              out_specs=state_spec,
                              axis_names=set(data_axes), check_vma=False)
            return f(params), anchor, red_state

        return round_fn

    def body(params, anchor, red_state, rng):
        p = jax.tree.map(lambda s: s[0], params)
        rs = jax.tree.map(lambda s: s[0], red_state)
        delta = jax.tree.map(
            lambda x, a: x.astype(jnp.float32) - a.astype(jnp.float32),
            p, anchor)
        reduced, rs = reducer(delta, rs, rng)   # mean over world (plan.mean)
        # params keep their ORIGINAL dtype (bf16 stays bf16); the f32 anchor
        # is rebuilt FROM the cast result so it equals what workers actually
        # hold entering the next local phase — otherwise the cast error
        # would sit in every future delta as a constant offset
        new_p = jax.tree.map(lambda a, d, x: (a + d).astype(x.dtype),
                             anchor, reduced, p)
        new_anchor = jax.tree.map(lambda x: x.astype(jnp.float32), new_p)
        return (jax.tree.map(lambda s: s[None], new_p), new_anchor,
                jax.tree.map(lambda s: s[None], rs))

    def round_fn(params, anchor, red_state, rng):
        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(state_spec, P(), state_spec, P()),
            out_specs=(state_spec, P(), state_spec),
            axis_names=set(data_axes), check_vma=False)
        return f(params, anchor, red_state, rng)

    return round_fn


def make_lag_programs(model: Model, optimizer, synchronizer, mesh,
                      data_axes: Sequence[str] = ("data",)):
    """The three LAG programs (host dispatch, DESIGN.md §5/§7):

      * ``probe(params, batch, g_last) -> (loss, grads_w, delta, scale)`` —
        per-shard backward plus the two globally psum-ed scalars of LAG's
        trigger; the 8-byte scalars are the ONLY wire traffic of a skipped
        round.  ``grads_w`` returns per-worker (leading axis, sharded).
      * ``sync_apply(params, opt_state, sync_state, grads_w, step, rng)``
        — reduce this step's gradients through the strategy's reducer and
        update; also returns the synchronized gradient (the new ``g_last``).
      * ``reuse_apply(params, opt_state, g_last, step)`` — apply the last
        synchronized gradient with no collective at all.
    """
    batch_spec = {"tokens": P(tuple(data_axes), None)}
    state_spec = P(tuple(data_axes))
    axes = tuple(data_axes)

    def probe_body(params, batch, g_last):
        from repro.models.sharding_ctx import manual_region
        with manual_region(data_axes):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)

        def sq(t):
            return sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                       for l in jax.tree.leaves(t))

        delta = jax.lax.psum(
            sq(jax.tree.map(lambda a, b: a.astype(jnp.float32) - b,
                            grads, g_last)), axes)
        scale = jax.lax.psum(sq(grads), axes)
        loss = jax.lax.pmean(loss, axes)
        return (loss, jax.tree.map(lambda g: g[None], grads), delta, scale)

    def probe(params, batch, g_last):
        f = jax.shard_map(
            probe_body, mesh=mesh,
            in_specs=(P(), batch_spec, P()),
            out_specs=(P(), state_spec, P(), P()),
            axis_names=set(data_axes), check_vma=False)
        return f(params, batch, g_last)

    def sync_body(params, opt_state, sync_state, grads_w, step, rng):
        g = jax.tree.map(lambda s: s[0], grads_w)
        ss = jax.tree.map(lambda s: s[0], sync_state)
        synced, ss = synchronizer(g, ss, rng)
        updates, opt_state = optimizer.update(synced, opt_state, params, step)
        params = apply_updates(params, updates)
        return (params, opt_state, jax.tree.map(lambda s: s[None], ss),
                synced)

    def sync_apply(params, opt_state, sync_state, grads_w, step, rng):
        f = jax.shard_map(
            sync_body, mesh=mesh,
            in_specs=(P(), P(), state_spec, state_spec, P(), P()),
            out_specs=(P(), P(), state_spec, P()),
            axis_names=set(data_axes), check_vma=False)
        return f(params, opt_state, sync_state, grads_w, step, rng)

    def reuse_apply(params, opt_state, g_last, step):
        updates, opt_state = optimizer.update(g_last, opt_state, params, step)
        return apply_updates(params, updates), opt_state

    return probe, sync_apply, reuse_apply


# ---------------------------------------------------------------------------
# Sharding assembly for pjit dry-runs / training
# ---------------------------------------------------------------------------

def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
