"""Step functions wired for pjit: vanilla (paper-baseline BSP data parallel,
XLA-inserted collectives) and comm-optimized (shard_map manual over the data
axes with the GradientSynchronizer's explicit compress + collective path).

The vanilla step with FSDP sharding is what every (arch x shape) baseline
dry-run lowers; the comm-optimized step is the paper's §3/§4 machinery and
is exercised on archs whose parameters fit a pure DP+TP layout.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import GradientSynchronizer, PlanExecutor, SyncConfig
from repro.core.schedule.planner import CommPlan
from repro.models import Model
from repro.optim import apply_updates, make_optimizer


# ---------------------------------------------------------------------------
# Vanilla BSP step (survey §2.4.1 baseline) — pjit/XLA collectives
# ---------------------------------------------------------------------------

def make_train_step(model: Model, optimizer, microbatches: int = 1):
    """BSP train step.  ``microbatches > 1`` runs gradient accumulation: the
    global batch is split along dim 0 and forward/backward runs as a scan,
    bounding activation memory at 1/M of the full batch (survey §3.1.1 —
    accumulation is how large-batch recipes actually execute) while keeping
    the optimizer step and gradient synchronization per-step identical."""
    def train_step(params, opt_state, batch, step):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            B = jax.tree.leaves(batch)[0].shape[0]
            assert B % microbatches == 0, (B, microbatches)
            mb = B // microbatches

            def body(acc, i):
                tot_loss, g_acc = acc
                bslice = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0),
                    batch)
                l, g = jax.value_and_grad(model.loss)(params, bslice)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), g_acc, g)
                return (tot_loss + l, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), jnp.arange(microbatches))
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model: Model, mla_absorb: bool = False,
                     moe_dispatch: bool = False):
    def decode_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos,
                                 mla_absorb=mla_absorb,
                                 moe_dispatch=moe_dispatch)

    return decode_step


# ---------------------------------------------------------------------------
# Comm-optimized step (survey §3 + §4) — manual data axes via shard_map
# ---------------------------------------------------------------------------

def make_comm_optimized_train_step(model: Model, optimizer, sync: SyncConfig,
                                   mesh, data_axes: Sequence[str] = ("data",)):
    """Per-shard loss/backward; gradient exchange through the
    GradientSynchronizer (compression + explicit collective algorithm).

    Params must be laid out replicated over the data axes (pure DP+TP):
    use ``model.partition_specs('serve')`` which shards over 'model' only.
    The 'model' mesh axis stays auto — XLA partitions tensor-parallel math
    inside the shard_map body.
    """
    synchronizer = GradientSynchronizer(sync, tuple(data_axes))
    return _make_synced_train_step(model, optimizer, synchronizer, mesh,
                                   data_axes)


def make_planned_train_step(model: Model, plan: CommPlan, optimizer, mesh,
                            data_axes: Sequence[str] = ("data",)):
    """Like :func:`make_comm_optimized_train_step` but driven by a
    ``CommPlan`` (heterogeneous per-bucket strategies, ``--sync auto``):
    the PlanExecutor may compress one bucket over an explicit ring while the
    next goes dense over psum."""
    executor = PlanExecutor(plan, tuple(data_axes))
    return _make_synced_train_step(model, optimizer, executor, mesh,
                                   data_axes)


def _make_synced_train_step(model: Model, optimizer, synchronizer, mesh,
                            data_axes: Sequence[str]):
    """Shared shard_map step around any grad-sync engine exposing
    ``init_state(grads)`` and ``__call__(grads, state, rng)``."""
    world = 1
    for a in data_axes:
        world *= mesh.shape[a]

    def body(params, opt_state, sync_state, batch, step, rng):
        from repro.models.sharding_ctx import manual_region
        # error-feedback state is PER WORKER: it arrives with a leading
        # device axis of length 1 (sharded over the data axes) — strip it,
        # use it, put it back.  This both matches EF semantics and shards
        # the f32 residual (a full parameter copy) across the data axes
        # instead of replicating it (§Perf pair-3 iteration 5 finding).
        sync_state = jax.tree.map(lambda s: s[0], sync_state)
        with manual_region():
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, sync_state = synchronizer(grads, sync_state, rng)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        # local losses differ per shard only through data; report the mean
        loss = jax.lax.pmean(loss, tuple(data_axes))
        sync_state = jax.tree.map(lambda s: s[None], sync_state)
        return params, opt_state, sync_state, loss

    # Specs describe only the MANUAL (data) axes: params / optimizer state
    # are replicated across them (P() prefix); the batch and the EF state
    # are sharded.  The 'model' axis stays auto — its tensor-parallel
    # layout comes from the jit in_shardings outside this shard_map.
    batch_spec = {"tokens": P(tuple(data_axes), None)}
    state_spec = P(tuple(data_axes))

    def step_fn(params, opt_state, sync_state, batch, step, rng):
        f = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), state_spec, batch_spec, P(), P()),
            out_specs=(P(), P(), state_spec, P(), ),
            axis_names=set(data_axes), check_vma=False)
        return f(params, opt_state, sync_state, batch, step, rng)

    def init_sync_state(params):
        """Per-worker EF state with a leading device axis (shard over data)."""
        one = synchronizer.init_state(params)
        return jax.tree.map(
            lambda s: jnp.broadcast_to(s, (world,) + s.shape), one)

    return step_fn, synchronizer, init_sync_state


# ---------------------------------------------------------------------------
# Sharding assembly for pjit dry-runs / training
# ---------------------------------------------------------------------------

def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
