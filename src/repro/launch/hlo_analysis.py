"""Roofline-term extraction from compiled (post-SPMD-partitioning) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
structurally undercounts programs that scan over layers/time (all of ours).
This module re-derives the three roofline inputs from the HLO text itself:

  * matmul FLOPs   — every ``dot`` op: 2 · |out| · (contracted dims),
  * collective bytes — all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute operand (and wire) bytes,
  * loop correction — ops inside ``while`` bodies are multiplied by the trip
    count parsed from the loop condition's comparison constant, propagated
    through the call graph (fusions, nested loops).

Cross-checked in tests against ``cost_analysis()`` on unrolled programs and
against the analytic per-arch calculator (launch/analytic.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*->.*\{\s*$")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _parse_shape(text: str) -> Tuple[Optional[str], int]:
    """First 'dtype[a,b,c]' in text -> (dtype, numel). Tuples: sum handled
    by callers via parse_all_shapes."""
    m = _SHAPE_RE.search(text)
    if not m:
        return None, 0
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return None, 0
    numel = 1
    for d in dims.split(","):
        if d:
            numel *= int(d)
    return dt, numel


def _shape_bytes(text: str) -> int:
    dt, numel = _parse_shape(text)
    return numel * _DTYPE_BYTES.get(dt, 0) if dt else 0


@dataclasses.dataclass
class Instruction:
    name: str
    line: str
    op: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    # name -> full def text (for operand shape lookup)
    defs: Dict[str, str]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line)
        if mc and line.endswith("{"):
            cur = Computation(mc.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, rhs = md.group(1), md.group(2)
        op = ""
        # op token: word before '(' after shape spec
        mo = re.search(r"\}?\s*([\w\-]+)\(", rhs)
        if mo:
            op = mo.group(1)
        cur.defs[name] = rhs
        cur.instructions.append(Instruction(name, rhs, op))
    return comps, entry


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    comp = comps.get(cond_name)
    if not comp:
        return 1
    consts = []
    for ins in comp.instructions:
        consts += [int(x) for x in _CONST_RE.findall(ins.line)]
    return max(consts) if consts else 1


def _dot_flops(comp: Computation, ins: Instruction) -> int:
    out_dt, out_numel = _parse_shape(ins.line)
    if out_numel == 0:
        return 0
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not mdims:
        return 0
    cdims = [int(x) for x in mdims.group(1).split(",") if x]
    # lhs operand shape
    ops = _OPERAND_RE.findall(ins.line.split("dot(", 1)[1])
    if not ops:
        return 0
    lhs_def = comp.defs.get(ops[0], "")
    m = _SHAPE_RE.search(lhs_def if lhs_def else "")
    if not m:
        return 0
    dims = [int(x) for x in m.group(2).split(",") if x]
    contracted = 1
    for c in cdims:
        if c < len(dims):
            contracted *= dims[c]
    return 2 * out_numel * contracted


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    return total_devices


def _collective_bytes(comp: Computation, ins: Instruction,
                      total_devices: int) -> Tuple[int, float]:
    """Returns (operand_bytes, wire_bytes_per_chip)."""
    inner = ins.line.split(ins.op + "(", 1)
    operands = _OPERAND_RE.findall(inner[1].split(")")[0]) if len(inner) > 1 else []
    op_bytes = 0
    for o in operands:
        d = comp.defs.get(o)
        if d:
            op_bytes += _shape_bytes(d)
    p = max(_group_size(ins.line, total_devices), 1)
    if ins.op == "all-reduce":
        wire = 2.0 * op_bytes * (p - 1) / p
    elif ins.op == "all-gather":
        wire = float(op_bytes) * (p - 1)
    elif ins.op in ("reduce-scatter", "all-to-all"):
        wire = float(op_bytes) * (p - 1) / p
    else:  # collective-permute
        wire = float(op_bytes)
    return op_bytes, wire


@dataclasses.dataclass
class HLOStats:
    dot_flops: float = 0.0
    collective_operand_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    memory_bytes: float = 0.0          # operand+output bytes of top-level ops
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)


_SKIP_MEM_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                 "bitcast", "copy", "after-all", "partition-id", "replica-id",
                 "while", "conditional", "call"}


def _instruction_mem_bytes(comp: "Computation", ins: "Instruction") -> int:
    """HLO bytes-accessed approximation: output bytes + operand bytes, with
    fusions counted as one op (their internals never touch HBM).  Control /
    aliasing ops are skipped."""
    if ins.op in _SKIP_MEM_OPS or not ins.op:
        return 0
    total = _shape_bytes(ins.line)
    args = ins.line.split(ins.op + "(", 1)
    if len(args) > 1:
        for o in _OPERAND_RE.findall(args[1].split(")")[0]):
            d = comp.defs.get(o)
            if d:
                total += _shape_bytes(d)
    return total


def analyze(text: str, total_devices: int = 1) -> HLOStats:
    comps, entry = parse_hlo(text)
    stats = HLOStats()
    seen_while: List[int] = []

    def walk(comp_name: str, mult: float, stack: Tuple[str, ...]):
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        stack = stack + (comp_name,)
        for ins in comp.instructions:
            stats.memory_bytes += mult * _instruction_mem_bytes(comp, ins)
            if ins.op == "dot":
                stats.dot_flops += mult * _dot_flops(comp, ins)
            elif ins.op in COLLECTIVE_OPS:
                ob, wb = _collective_bytes(comp, ins, total_devices)
                stats.collective_operand_bytes += mult * ob
                stats.collective_wire_bytes += mult * wb
                stats.collective_counts[ins.op] = (
                    stats.collective_counts.get(ins.op, 0) + int(mult))
            if ins.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mcnd = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = _trip_count(comps, mcnd.group(1)) if mcnd else 1
                seen_while.append(trips)
                if mb:
                    walk(mb.group(1), mult * max(trips, 1), stack)
            else:
                for callee in _CALL_ATTR_RE.findall(ins.line):
                    if "condition" in ins.line and callee in ins.line.split("condition=")[-1]:
                        continue
                    walk(callee, mult, stack)
    walk(entry, 1.0, ())
    stats.while_trip_counts = seen_while
    return stats
