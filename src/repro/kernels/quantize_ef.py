"""Fused error-feedback + int8 quantization Pallas kernel (survey §3.2.1).

One HBM->VMEM pass per (8·128-aligned) tile computes

    corrected = g + e                      (error feedback, Eq. 2)
    scale     = max|corrected| per tile
    q         = round(corrected / scale · 127)  -> int8 payload
    e_new     = corrected - q · scale / 127     (residual)

The GPU formulation is three kernels (EF add, max-reduce, quantize) with
three HBM round-trips; on TPU we tile so each block's scale is computed in
VMEM and everything is written once (DESIGN.md §5).  Per-TILE scales (vs
per-tensor) are the TPU-friendly choice and also tighten the quantization
error; the wire format is (int8[tile], f32 scale per tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

TILE = 8 * 128  # VPU-aligned flat tile


def _kernel(g_ref, e_ref, q_ref, e_new_ref, scale_ref, *, decay: float):
    g = g_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    corrected = g + decay * e
    scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-30)
    q = jnp.clip(jnp.round(corrected / scale * 127.0), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    e_new_ref[...] = corrected - q * (scale / 127.0)
    scale_ref[0] = scale


def quantize_ef_pallas(g, e, *, decay: float = 1.0, tile: int = TILE,
                       interpret: bool = True):
    """g, e: flat (n,) arrays (pad to a tile multiple before calling).
    Returns (q int8 (n,), e_new f32 (n,), scales f32 (n/tile,))."""
    n = g.shape[0]
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    kernel = functools.partial(_kernel, decay=decay)
    q, e_new, scales = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int8),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n // tile,), jnp.float32)],
        interpret=interpret,
    )(g, e)
    return q, e_new, scales


def dequantize(q, scales, tile: int = TILE):
    n = q.shape[0]
    s = jnp.repeat(scales, tile)[:n]
    return q.astype(jnp.float32) * (s / 127.0)
