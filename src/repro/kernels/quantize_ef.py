"""Fused error-feedback + int8 quantization Pallas kernels (survey §3.2.1).

One HBM->VMEM pass per (8·128-aligned) tile computes

    corrected = g + e                      (error feedback, Eq. 2)
    scale     = max|corrected| per tile
    q         = round(corrected / scale · 127)  -> int8 payload
    e_new     = corrected - q · scale / 127     (residual)

The GPU formulation is three kernels (EF add, max-reduce, quantize) with
three HBM round-trips; on TPU we tile so each block's scale is computed in
VMEM and everything is written once (DESIGN.md §5/§11).  Per-TILE scales
(vs per-tensor) are the TPU-friendly choice and also tighten the
quantization error; the wire format is (int8[tile], f32 scale per tile).

Non-tile-multiple lengths are zero-padded to the next tile boundary and
the outputs sliced back: appended zeros cannot raise a tile's max|·|
scale, cannot win a top-k bisection round against any non-zero value, and
quantize to q=0 with e_new=0 — so the partial tile's scale and residual
are exactly what ``ref.py`` computes (pinned by the ragged parity tests).

The decode side is ``dequant_accum_pallas``: unpack + accumulate of all
gathered payloads in ONE pass per output tile (the gather-pattern wire
reads each payload once and writes the dense sum once — the one-read /
one-write contract of DESIGN.md §11).

``interpret=None`` (the default) resolves via ``dispatch.resolve_interpret``:
compiled on TPU, interpreter elsewhere.  Callers must not hardcode it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret

TILE = 8 * 128  # VPU-aligned flat tile


def _pad_to_tile(x, tile: int):
    """Zero-pad a flat array to the next tile multiple (no-op if aligned)."""
    n = x.shape[0]
    m = -(-n // tile) * tile
    if m != n:
        x = jnp.pad(x, (0, m - n))
    return x


def _kernel(g_ref, e_ref, q_ref, e_new_ref, scale_ref, *, decay: float):
    g = g_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    corrected = g + decay * e
    scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-30)
    q = jnp.clip(jnp.round(corrected / scale * 127.0), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    e_new_ref[...] = corrected - q * (scale / 127.0)
    scale_ref[0] = scale


def quantize_ef_pallas(g, e, *, decay: float = 1.0, tile: int = TILE,
                       interpret=None):
    """g, e: flat (n,) arrays, any length (zero-padded to a tile multiple
    internally).  Returns (q int8 (n,), e_new f32 (n,),
    scales f32 (ceil(n/tile),))."""
    interpret = resolve_interpret(interpret)
    n = g.shape[0]
    g = _pad_to_tile(g, tile)
    e = _pad_to_tile(e, tile)
    m = g.shape[0]
    grid = (m // tile,)
    kernel = functools.partial(_kernel, decay=decay)
    q, e_new, scales = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), jnp.int8),
                   jax.ShapeDtypeStruct((m,), jnp.float32),
                   jax.ShapeDtypeStruct((m // tile,), jnp.float32)],
        interpret=interpret,
    )(g, e)
    return q[:n], e_new[:n], scales


def _q_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    q = jnp.clip(jnp.round(x / scale * 127.0), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[0] = scale


def quantize_pallas(x, *, tile: int = TILE, interpret=None):
    """Per-tile int8 quantization WITHOUT error feedback — the per-hop
    requantization step of the compressed ring (``collectives/ring_fused``).
    x: flat (n,), any length.  Returns (q int8 (n,), scales (ceil(n/tile),))."""
    interpret = resolve_interpret(interpret)
    n = x.shape[0]
    x = _pad_to_tile(x, tile)
    m = x.shape[0]
    q, scales = pl.pallas_call(
        _q_kernel,
        grid=(m // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), jnp.int8),
                   jax.ShapeDtypeStruct((m // tile,), jnp.float32)],
        interpret=interpret,
    )(x)
    return q[:n], scales


def _accum_kernel(q_ref, s_ref, out_ref):
    # q_ref: (w, tile) int8, s_ref: (w, 1) f32 — one output tile, all ranks
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.sum(q * (s_ref[...] / 127.0), axis=0)


def dequant_accum_pallas(q, scales, *, tile: int = TILE, interpret=None):
    """Fused dequantize + accumulate: the decode side of the gathered int8
    wire.  q: (w, n) int8 payloads from w ranks, scales: (w, ceil(n/tile))
    f32.  Returns the (n,) f32 SUM of the dequantized payloads — each
    payload element is read once and the dense sum written once."""
    interpret = resolve_interpret(interpret)
    w, n = q.shape
    ntiles = -(-n // tile)
    m = ntiles * tile
    assert scales.shape == (w, ntiles), (scales.shape, (w, ntiles))
    if m != n:
        q = jnp.pad(q, ((0, 0), (0, m - n)))
    out = pl.pallas_call(
        _accum_kernel,
        grid=(ntiles,),
        in_specs=[pl.BlockSpec((w, tile), lambda i: (0, i)),
                  pl.BlockSpec((w, 1), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(q, scales)
    return out[:n]


def dequantize(q, scales, tile: int = TILE):
    n = q.shape[0]
    s = jnp.repeat(scales, tile)[:n]
    return q.astype(jnp.float32) * (s / 127.0)
