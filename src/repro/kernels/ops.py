"""Jitted public wrappers for the Pallas kernels — THE hot-path entry
points (`core/compression/fused.py` and `collectives/ring_fused.py` call
these, never the kernels directly).

Every communication kernel dispatches over three implementations
(``kernels/dispatch.py``):

  * ``pallas``    — compiled Pallas (TPU default): one HBM pass per tile;
  * ``interpret`` — the same kernel body under the Pallas interpreter —
                    the correctness path tests pin against ref.py, far too
                    slow for realistic sizes off-TPU;
  * ``xla``       — the identical op sequence as vectorized jnp
                    (``ref.py``'s reference lowerings), bit-identical to
                    ``interpret`` under jit — the off-TPU default, so the
                    CPU/GPU hot path is still a fused one-pass XLA fusion
                    rather than the Python interpreter.

``impl=None`` resolves to the backend default (``pallas`` on TPU, ``xla``
elsewhere); the ``REPRO_KERNELS_IMPL`` env var overrides it.  The
dispatch-flag regression tests pin that no caller hardcodes interpret
mode (the historical ``interpret=True`` default bug).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dispatch import on_tpu, resolve_impl
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize_ef import (dequant_accum_pallas, dequantize,
                                       quantize_ef_pallas, quantize_pallas)
from repro.kernels.topk_mask import topk_ef_pallas, topk_mask_pallas

TILE = 8 * 128


def _on_tpu() -> bool:
    return on_tpu()


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "q_blk", "kv_blk"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, q_blk: int = 128, kv_blk: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, q_blk=q_blk, kv_blk=kv_blk,
                                  interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("decay", "tile", "impl"))
def _quantize_ef(g, e, decay, tile, impl):
    if impl == "xla":
        return _ref.quantize_ef_ref(g, e, decay=decay, tile=tile)
    return quantize_ef_pallas(g, e, decay=decay, tile=tile,
                              interpret=impl == "interpret")


def quantize_ef(g, e, *, decay: float = 1.0, tile: int = TILE, impl=None):
    """Fused EF + per-tile int8 quantize: (q, e_new, scales)."""
    return _quantize_ef(g, e, decay, tile, resolve_impl(impl))


@functools.partial(jax.jit, static_argnames=("tile", "impl"))
def _quantize_tiles(x, tile, impl):
    if impl == "xla":
        return _ref.quantize_tiles_ref(x, tile=tile)
    return quantize_pallas(x, tile=tile, interpret=impl == "interpret")


def quantize_tiles(x, *, tile: int = TILE, impl=None):
    """Per-tile int8 quantize without EF (ring_fused hop encode)."""
    return _quantize_tiles(x, tile, resolve_impl(impl))


@functools.partial(jax.jit, static_argnames=("tile", "impl"))
def _dequant_accum(q, scales, tile, impl):
    if impl == "xla":
        return _ref.dequant_accum_ref(q, scales, tile=tile)
    return dequant_accum_pallas(q, scales, tile=tile,
                                interpret=impl == "interpret")


def dequant_accum(q, scales, *, tile: int = TILE, impl=None):
    """Fused dequantize + accumulate of gathered payloads: q (w, n) int8,
    scales (w, ceil(n/tile)) -> (n,) f32 sum (one read per payload, one
    dense write — the decode half of the one-read/one-write contract)."""
    return _dequant_accum(q, scales, tile, resolve_impl(impl))


@functools.partial(jax.jit, static_argnames=("ratio", "tile", "iters",
                                             "impl"))
def _topk_mask(x, ratio, tile, iters, impl):
    if impl == "xla":
        return _ref.topk_mask_bisect_ref(x, ratio=ratio, tile=tile,
                                         iters=iters)
    return topk_mask_pallas(x, ratio=ratio, tile=tile, iters=iters,
                            interpret=impl == "interpret")


def topk_mask(x, *, ratio: float = 0.01, tile: int = TILE, iters: int = 16,
              impl=None):
    """Per-tile bisection top-k mask (no EF)."""
    return _topk_mask(x, ratio, tile, iters, resolve_impl(impl))


@functools.partial(jax.jit, static_argnames=("ratio", "tile", "iters",
                                             "decay", "impl"))
def _topk_ef(g, e, ratio, tile, iters, decay, impl):
    if impl == "xla":
        return _ref.topk_ef_ref(g, e, ratio=ratio, tile=tile, iters=iters,
                                decay=decay)
    return topk_ef_pallas(g, e, ratio=ratio, tile=tile, iters=iters,
                          decay=decay, interpret=impl == "interpret")


def topk_ef(g, e, *, ratio: float = 0.01, tile: int = TILE, iters: int = 16,
            decay: float = 1.0, impl=None):
    """Fused EF + top-k mask + residual: (y, e_new), y + e_new = g + decay·e."""
    return _topk_ef(g, e, ratio, tile, iters, decay, resolve_impl(impl))


__all__ = ["flash_attention", "quantize_ef", "quantize_tiles", "topk_mask",
           "topk_ef", "dequant_accum", "dequantize", "TILE"]
