"""Jitted public wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container,
unit tests) they execute in interpret mode, which runs the same kernel body
and BlockSpec pipeline in Python — the correctness contract the test suite
enforces against the ref.py oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize_ef import dequantize, quantize_ef_pallas
from repro.kernels.topk_mask import topk_mask_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "q_blk", "kv_blk"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, q_blk: int = 128, kv_blk: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  softcap=softcap, q_blk=q_blk, kv_blk=kv_blk,
                                  interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("decay", "tile"))
def quantize_ef(g, e, *, decay: float = 1.0, tile: int = 8 * 128):
    return quantize_ef_pallas(g, e, decay=decay, tile=tile,
                              interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("ratio", "tile", "iters"))
def topk_mask(x, *, ratio: float = 0.01, tile: int = 8 * 128, iters: int = 16):
    return topk_mask_pallas(x, ratio=ratio, tile=tile, iters=iters,
                            interpret=not _on_tpu())


__all__ = ["flash_attention", "quantize_ef", "topk_mask", "dequantize"]
