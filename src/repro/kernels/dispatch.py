"""Backend dispatch for the Pallas kernels (the `_on_tpu` contract).

Three implementations of every communication kernel exist:

  * ``pallas``    — the compiled Pallas kernel (TPU; one HBM pass per tile);
  * ``interpret`` — the same kernel body under the Pallas interpreter
                    (correctness path on CPU/GPU; impractically slow at
                    realistic sizes, so it is for tests, not the hot path);
  * ``xla``       — a pure-jnp lowering of the identical op sequence
                    (``kernels/ref.py``), bit-identical to the interpreted
                    kernel under jit — the off-TPU hot path.

``resolve_impl(None)`` picks ``pallas`` on TPU and ``xla`` elsewhere; the
``REPRO_KERNELS_IMPL`` environment variable overrides the default (used by
the parity tests and for forcing interpret mode off-TPU).  The historical
bug this module fixes: the kernels defaulted to ``interpret=True``
UNCONDITIONALLY, so even a TPU run executed the Python interpreter —
``resolve_interpret(None)`` now follows the backend.
"""
from __future__ import annotations

import os

import jax

IMPL_ENV = "REPRO_KERNELS_IMPL"
IMPLS = ("pallas", "interpret", "xla")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret) -> bool:
    """``None`` means "follow the backend": compiled on TPU, interpreter
    elsewhere.  An explicit bool is honoured as given."""
    if interpret is None:
        return not on_tpu()
    return bool(interpret)


def resolve_impl(impl=None) -> str:
    """Resolve an implementation choice: explicit argument, then the
    ``REPRO_KERNELS_IMPL`` env override, then the backend default."""
    if impl is None:
        impl = os.environ.get(IMPL_ENV, "").strip() or None
    if impl is None:
        impl = "pallas" if on_tpu() else "xla"
    if impl not in IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r}; known: {IMPLS}")
    return impl
