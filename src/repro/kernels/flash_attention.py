"""Pallas TPU flash attention with sliding-window and logit-softcap support.

Schedule: grid (batch*kv_heads*group, num_q_blocks, num_kv_blocks); the last
grid dimension is sequential ("arbitrary"), carrying the running softmax
(m, l, acc) in VMEM scratch across kv blocks — the streaming form of
models/attention.flash_attention, with BlockSpecs pinning one (q_blk, hd)
query tile and one (kv_blk, hd) key/value tile in VMEM per step.  MXU
alignment: q_blk/kv_blk multiples of 128 at production shapes (tests sweep
smaller, unaligned-but-valid tile sizes too); hd is the lane dimension.

The pure-jnp oracle is ``repro.kernels.ref.flash_attention_ref``; on CPU the
kernel runs with interpret=True (correctness), on TPU compiled.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            softcap: Optional[float], window: Optional[int], causal: bool,
            kv_blk: int, nk: int, scale: float):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (q_blk, hd)
    k = k_ref[0].astype(jnp.float32)            # (kv_blk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_blk = q.shape[0]
    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * kv_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
        if not causal:
            mask &= (k_pos - q_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           q_blk: int = 128, kv_blk: int = 128,
                           interpret: bool = True):
    """q: (B, T, H, hd); k, v: (B, S, KV, hd), H = KV * G.
    Returns (B, T, H, hd)."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_blk = min(q_blk, T)
    kv_blk = min(kv_blk, S)
    assert T % q_blk == 0 and S % kv_blk == 0
    nq, nk = T // q_blk, S // kv_blk
    scale = 1.0 / np.sqrt(hd)

    # (B*KV*G, T, hd) query layout; kv broadcast across the group
    qr = q.reshape(B, T, KV, G, hd).transpose(0, 2, 3, 1, 4).reshape(
        B * KV * G, T, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    grid = (B * KV * G, nq, nk)
    kernel = functools.partial(_kernel, softcap=softcap, window=window,
                               causal=causal, kv_blk=kv_blk, nk=nk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_blk, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_blk, hd), lambda b, qi, ki, G=G: (b // G, ki, 0)),
            pl.BlockSpec((1, kv_blk, hd), lambda b, qi, ki, G=G: (b // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, T, hd), q.dtype),
        scratch_shapes=[
            # running softmax state lives across the sequential kv dimension
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, G, T, hd).transpose(0, 3, 1, 2, 4).reshape(
        B, T, H, hd)
