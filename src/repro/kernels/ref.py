"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps).

Two kinds of function live here:

  * ORACLES — independent formulations tests compare against with a
    tolerance (``flash_attention_ref``) or an exactness bound
    (``topk_mask_ref``, the true sort-based per-tile top-k the bisection
    kernel approximates);
  * REFERENCE LOWERINGS — the kernels' op sequences re-expressed as plain
    vectorized jnp (``quantize_ef_ref``, ``topk_ef_ref``,
    ``quantize_tiles_ref``, ``dequant_accum_ref``).  Under ``jax.jit``
    these are bit-identical to the interpreted Pallas kernels, which makes
    them double as the off-TPU hot path (``ops.py`` dispatches to them as
    the ``xla`` impl) AND the exactness reference the fused-wire
    conformance suites pin payloads and EF residuals against.

Ragged lengths follow the kernels' pad-and-slice contract: inputs are
zero-padded to the tile boundary, tiles computed, outputs sliced back to
n — zero pads cannot change a tile's max|·| scale and cannot be kept by a
positive bisection threshold, so the partial tile's scale/residual are
unaffected (DESIGN.md §11).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

TILE = 8 * 128


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None):
    """Naive O(T^2) attention; q: (B,T,H,hd), k/v: (B,S,KV,hd)."""
    from repro.models.attention import attention_reference
    return attention_reference(q, k, v, causal=causal, window=window,
                               softcap=softcap)


def _pad_blocks(x, tile: int):
    """Zero-pad a flat array to the tile boundary and reshape to
    (ntiles, tile) f32 blocks."""
    n = x.shape[0]
    m = -(-n // tile) * tile
    if m != n:
        x = jnp.pad(x, (0, m - n))
    return x.astype(jnp.float32).reshape(m // tile, tile)


def quantize_ef_ref(g, e, *, decay: float = 1.0, tile: int = TILE):
    """Per-tile EF + int8 quantization: the quantize_ef kernel's op
    sequence.  g, e: flat (n,), any length.  Returns (q int8 (n,),
    e_new f32 (n,), scales f32 (ceil(n/tile),))."""
    n = g.shape[0]
    blocks = _pad_blocks(g, tile) + decay * _pad_blocks(e, tile)
    scales = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-30)
    q = jnp.clip(jnp.round(blocks / scales[:, None] * 127.0), -127, 127)
    e_new = blocks - q * (scales[:, None] / 127.0)
    return (q.reshape(-1)[:n].astype(jnp.int8), e_new.reshape(-1)[:n],
            scales)


def quantize_tiles_ref(x, *, tile: int = TILE):
    """Per-tile int8 quantization without EF (the ring_fused hop step and
    the unfused int8_fused wire).  Returns (q int8 (n,), scales)."""
    n = x.shape[0]
    blocks = _pad_blocks(x, tile)
    scales = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-30)
    q = jnp.clip(jnp.round(blocks / scales[:, None] * 127.0), -127, 127)
    return q.reshape(-1)[:n].astype(jnp.int8), scales


def dequantize_ref(q, scales, *, tile: int = TILE):
    """Inverse of quantize_tiles_ref (biased by the rounding, bound
    scale/254 per element)."""
    n = q.shape[0]
    s = jnp.repeat(scales, tile)[:n]
    return q.astype(jnp.float32) * (s / 127.0)


def dequant_accum_ref(q, scales, *, tile: int = TILE):
    """The dequant_accum kernel's op sequence: q (w, n) int8 payloads,
    scales (w, ceil(n/tile)) — returns the (n,) f32 sum of the dequantized
    payloads (summed over the rank axis, like the kernel)."""
    w, n = q.shape
    ntiles = -(-n // tile)
    m = ntiles * tile
    if m != n:
        q = jnp.pad(q, ((0, 0), (0, m - n)))
    q3 = q.astype(jnp.float32).reshape(w, ntiles, tile)
    out = jnp.sum(q3 * (scales[:, :, None] / 127.0), axis=0)
    return out.reshape(-1)[:n]


def _bisect_threshold_ref(ax, k: int, iters: int):
    """The topk kernels' bisection, verbatim (see topk_mask._bisect_threshold
    — the op sequences must stay identical for the xla impl to be
    bit-identical to the interpreted kernel)."""
    hi = jnp.max(ax)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ax >= mid).astype(jnp.int32))
        return jnp.where(cnt > k, mid, lo), jnp.where(cnt > k, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def topk_mask_bisect_ref(x, *, ratio: float = 0.01, tile: int = TILE,
                         iters: int = 16):
    """The topk_mask KERNEL's bisection semantics as vectorized jnp (the
    xla impl) — distinct from :func:`topk_mask_ref`, the exact oracle."""
    n = x.shape[0]
    dtype = x.dtype
    k = max(1, int(tile * ratio))
    blocks = _pad_blocks(x, tile)

    def one(b):
        ax = jnp.abs(b)
        hi = _bisect_threshold_ref(ax, k, iters)
        return jnp.where(ax >= hi, b, 0.0)

    return jax.vmap(one)(blocks).reshape(-1)[:n].astype(dtype)


def topk_ef_ref(g, e, *, ratio: float = 0.01, tile: int = TILE,
                iters: int = 16, decay: float = 1.0):
    """The fused topk_ef kernel's op sequence: EF add + bisection mask +
    residual in one vectorized pass.  Returns (y (n,), e_new (n,)) f32
    with y + e_new == g + decay·e."""
    n = g.shape[0]
    k = max(1, int(tile * ratio))
    blocks = _pad_blocks(g, tile) + decay * _pad_blocks(e, tile)

    def one(b):
        ax = jnp.abs(b)
        hi = _bisect_threshold_ref(ax, k, iters)
        keep = ax >= hi
        return jnp.where(keep, b, 0.0), jnp.where(keep, 0.0, b)

    y, e_new = jax.vmap(one)(blocks)
    return y.reshape(-1)[:n], e_new.reshape(-1)[:n]


def topk_mask_ref(x, *, ratio: float = 0.01, tile: int = TILE):
    """EXACT per-tile top-k oracle (the kernel's bisection approximates
    this; tests bound the difference).  Ragged lengths pad like the
    kernel."""
    n = x.shape[0]
    k = max(1, int(tile * ratio))
    blocks = _pad_blocks(x, tile)

    def one(b):
        thresh = jnp.sort(jnp.abs(b))[-k]
        return jnp.where(jnp.abs(b) >= thresh, b, 0)

    return jax.vmap(one)(blocks).reshape(-1)[:n].astype(x.dtype)
