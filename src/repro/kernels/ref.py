"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None):
    """Naive O(T^2) attention; q: (B,T,H,hd), k/v: (B,S,KV,hd)."""
    from repro.models.attention import attention_reference
    return attention_reference(q, k, v, causal=causal, window=window,
                               softcap=softcap)


def quantize_ef_ref(g, e, *, decay: float = 1.0, tile: int = 8 * 128):
    """Per-tile EF + int8 quantization oracle. g, e: flat (n,)."""
    n = g.shape[0]
    corrected = (g.astype(jnp.float32) + decay * e.astype(jnp.float32))
    blocks = corrected.reshape(n // tile, tile)
    scales = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-30)
    q = jnp.clip(jnp.round(blocks / scales[:, None] * 127.0), -127, 127)
    e_new = blocks - q * (scales[:, None] / 127.0)
    return (q.reshape(n).astype(jnp.int8), e_new.reshape(n), scales)


def topk_mask_ref(x, *, ratio: float = 0.01, tile: int = 8 * 128):
    """EXACT per-tile top-k oracle (the kernel's bisection approximates
    this; tests bound the difference)."""
    n = x.shape[0]
    k = max(1, int(tile * ratio))
    blocks = x.reshape(n // tile, tile)

    def one(b):
        thresh = jnp.sort(jnp.abs(b))[-k]
        return jnp.where(jnp.abs(b) >= thresh, b, 0)

    return jax.vmap(one)(blocks).reshape(n)
