"""Block-local top-k sparsification Pallas kernels (survey §3.2.2).

Exact global top-k needs a full sort across HBM — hostile to the TPU memory
hierarchy.  Following DGC's sampled-threshold argument, each VMEM tile keeps
its own top ceil(k·tile/n) elements, found by BISECTING a threshold on |x|
inside the tile (``iters`` rounds of compare+popcount, no sort, fully
vectorized on the VPU).  The deviation from exact per-tile top-k is bounded
by the bisection resolution (2^-iters · max|x|) and tested against the
exact oracle.

``topk_ef_pallas`` is the fused hot-path variant: the error-feedback add,
the bisection mask, and the residual update happen in ONE pass —

    corrected = g + decay · e
    y         = corrected where kept, else 0     (the payload)
    e_new     = corrected where dropped, else 0  (the residual)

so a top-k bucket reads g and e once and writes y and e_new once
(DESIGN.md §11).  Ragged lengths are zero-padded to the tile boundary:
a zero pad entry can never beat a non-zero threshold in the bisection
(|0| >= mid is false for mid > 0), and in an all-zero tile it contributes
y = e_new = 0 either way, so sliced outputs match ``ref.py`` exactly.

``interpret=None`` resolves via ``dispatch.resolve_interpret`` (compiled
on TPU, interpreter elsewhere) — callers must not hardcode it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret
from repro.kernels.quantize_ef import _pad_to_tile

TILE = 8 * 128


def _bisect_threshold(ax, k: int, iters: int):
    """Shared bisection: the threshold ``hi`` such that |x| >= hi keeps
    (approximately) the top-k entries of one tile."""
    hi = jnp.max(ax)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ax >= mid).astype(jnp.int32))
        # too many kept -> raise threshold
        return jnp.where(cnt > k, mid, lo), jnp.where(cnt > k, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def _kernel(x_ref, y_ref, *, k: int, iters: int):
    x = x_ref[...].astype(jnp.float32)
    ax = jnp.abs(x)
    hi = _bisect_threshold(ax, k, iters)
    y_ref[...] = jnp.where(ax >= hi, x, 0.0).astype(y_ref.dtype)


def topk_mask_pallas(x, *, ratio: float = 0.01, tile: int = TILE,
                     iters: int = 16, interpret=None):
    """x: flat (n,), any length (zero-padded to a tile multiple).  Returns
    x with all but the (approximately) top ratio·tile entries per tile
    zeroed."""
    interpret = resolve_interpret(interpret)
    n = x.shape[0]
    x = _pad_to_tile(x, tile)
    m = x.shape[0]
    k = max(1, int(tile * ratio))
    kernel = functools.partial(_kernel, k=k, iters=iters)
    out = pl.pallas_call(
        kernel,
        grid=(m // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=interpret,
    )(x)
    return out[:n]


def _ef_kernel(g_ref, e_ref, y_ref, e_new_ref, *, k: int, iters: int,
               decay: float):
    g = g_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    corrected = g + decay * e
    ax = jnp.abs(corrected)
    hi = _bisect_threshold(ax, k, iters)
    keep = ax >= hi
    y_ref[...] = jnp.where(keep, corrected, 0.0)
    e_new_ref[...] = jnp.where(keep, 0.0, corrected)


def topk_ef_pallas(g, e, *, ratio: float = 0.01, tile: int = TILE,
                   iters: int = 16, decay: float = 1.0, interpret=None):
    """Fused EF + top-k mask + residual: g, e flat (n,), any length.
    Returns (y f32 (n,), e_new f32 (n,)) with y + e_new == g + decay·e."""
    interpret = resolve_interpret(interpret)
    n = g.shape[0]
    g = _pad_to_tile(g, tile)
    e = _pad_to_tile(e, tile)
    m = g.shape[0]
    k = max(1, int(tile * ratio))
    kernel = functools.partial(_ef_kernel, k=k, iters=iters, decay=decay)
    y, e_new = pl.pallas_call(
        kernel,
        grid=(m // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((tile,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((m,), jnp.float32),
                   jax.ShapeDtypeStruct((m,), jnp.float32)],
        interpret=interpret,
    )(g, e)
    return y[:n], e_new[:n]
