"""Block-local top-k sparsification mask Pallas kernel (survey §3.2.2).

Exact global top-k needs a full sort across HBM — hostile to the TPU memory
hierarchy.  Following DGC's sampled-threshold argument, each VMEM tile keeps
its own top ceil(k·tile/n) elements, found by BISECTING a threshold on |x|
inside the tile (``iters`` rounds of compare+popcount, no sort, fully
vectorized on the VPU).  The deviation from exact per-tile top-k is bounded
by the bisection resolution (2^-iters · max|x|) and tested against the
exact oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 8 * 128


def _kernel(x_ref, y_ref, *, k: int, iters: int):
    x = x_ref[...].astype(jnp.float32)
    ax = jnp.abs(x)
    hi = jnp.max(ax)
    lo = jnp.zeros_like(hi)
    # bisect t so that count(|x| >= t) ~= k
    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ax >= mid).astype(jnp.int32))
        # too many kept -> raise threshold
        return jnp.where(cnt > k, mid, lo), jnp.where(cnt > k, hi, mid)
    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    y_ref[...] = jnp.where(ax >= hi, x, 0.0).astype(y_ref.dtype)


def topk_mask_pallas(x, *, ratio: float = 0.01, tile: int = TILE,
                     iters: int = 16, interpret: bool = True):
    """x: flat (n,), n a multiple of tile.  Returns x with all but the
    (approximately) top ratio·tile entries per tile zeroed."""
    n = x.shape[0]
    assert n % tile == 0, (n, tile)
    k = max(1, int(tile * ratio))
    kernel = functools.partial(_kernel, k=k, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)
