"""Paged KV-cache manager (DESIGN.md §12).

The decode cache of every architecture family is a pytree whose leaves
split two ways (``transformer.stack_cache_meta``):

  * **paged** leaves — attention K/V, MLA latents — have a per-position
    length dim.  Instead of a dense ``(max_batch, L, ...)`` block per
    slot, positions live in a GLOBAL pool of fixed-size pages
    ``(n_pages, page_size, ...)`` (stacked segments: ``(R, n_pages,
    page_size, ...)``), and each serving slot owns a host-side page table
    mapping logical page -> physical page.  Pages are allocated at
    admission (enough for ``prompt + max_new`` tokens) and freed at
    retirement, so short requests hold few pages and the pool, not the
    slot count, bounds admission.
  * **state** leaves — recurrent h/conv/C, xLSTM states — are carried
    whole per slot: pool shape == linear shape at ``max_batch``.

Page 0 is the reserved TRASH page: unallocated table entries point at it
and masked (inactive-slot) writes land on it.  Its garbage is never read
— the decode-side validity masks multiply stale scores by exactly 0.0
(``NEG_INF`` -> softmax 0), which is the masking contract that makes the
paged view bit-identical to the dense cache.

Optional int8 KV quantization (``quantize="int8"``) stores paged leaves
as ``{"q": int8, "s": f32 per-token scales}`` through the
``kernels/ops.py`` quantize wire (one tile per token entry, inheriting
its pad-and-mask contract).  Quantized serving is LOSSY — the
bit-identity guarantee applies to the unquantized pool only.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import (CacheLeafMeta, materialize_cache,
                                      stack_cache_meta)

TRASH_PAGE = 0


class PageAllocator:
    """Host-side page bookkeeping for ONE table group (all cache leaves
    sharing length ``length``): a LIFO free list over the global pool plus
    per-slot page tables.  Invariants (``check()``): page 0 is never
    handed out, no page is owned twice, and free + owned + trash always
    partition the pool."""

    def __init__(self, n_pages: int, page_size: int, length: int,
                 max_batch: int):
        if length % page_size:
            raise ValueError(f"page_size {page_size} must divide cache "
                             f"length {length}")
        if n_pages < 2:
            raise ValueError("pool needs at least one page beyond trash")
        self.page_size = int(page_size)
        self.length = int(length)
        self.pages_per_slot = length // page_size
        self.n_pages = int(n_pages)
        self.max_batch = int(max_batch)
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._owned: List[List[int]] = [[] for _ in range(max_batch)]
        self._table = np.full((max_batch, self.pages_per_slot), TRASH_PAGE,
                              np.int32)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        """Pages to cover ``n_tokens`` positions — capped at the group's
        table width (ring/window groups wrap instead of growing)."""
        return min(-(-int(n_tokens) // self.page_size), self.pages_per_slot)

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= len(self._free)

    def alloc(self, slot: int, n_tokens: int) -> List[int]:
        if self._owned[slot]:
            raise RuntimeError(f"slot {slot} already owns pages "
                               f"{self._owned[slot]}")
        n = self.pages_needed(n_tokens)
        if n > len(self._free):
            raise RuntimeError(f"out of pages: need {n}, free "
                               f"{len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        self._owned[slot] = pages
        self._table[slot] = TRASH_PAGE
        self._table[slot, :n] = pages
        return pages

    def free(self, slot: int) -> int:
        pages = self._owned[slot]
        self._owned[slot] = []
        self._free.extend(reversed(pages))
        self._table[slot] = TRASH_PAGE
        return len(pages)

    def live_pages(self) -> Set[int]:
        return {p for owned in self._owned for p in owned}

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def table(self) -> np.ndarray:
        """(max_batch, pages_per_slot) int32 logical->physical map;
        unallocated entries point at the trash page."""
        return self._table.copy()

    def check(self) -> None:
        live = self.live_pages()
        assert TRASH_PAGE not in live, "trash page was handed out"
        assert TRASH_PAGE not in self._free, "trash page on the free list"
        assert len(live) + len(self._free) + 1 == self.n_pages, (
            f"page leak: {len(live)} live + {len(self._free)} free + trash "
            f"!= {self.n_pages}")
        flat = [p for owned in self._owned for p in owned]
        assert len(flat) == len(set(flat)), "page owned by two slots"


def _quant(x):
    """Symmetric int8 through the ``kernels/ops`` quantize wire: one tile
    per last-axis row (tile = trailing dim), inheriting the wire's
    pad-and-mask contract.  Returns (q ``x.shape`` int8, scales
    ``x.shape[:-1]`` f32)."""
    from repro.kernels import ops
    q, s = ops.quantize_tiles(x.astype(jnp.float32).reshape(-1),
                              tile=x.shape[-1])
    return q.reshape(x.shape), s.reshape(x.shape[:-1])


def _dequant(q, s, dtype):
    """Inverse through the same wire (``ops.dequantize``): q ``(...,
    rest)`` int8, s ``(...)`` per-row scales."""
    from repro.kernels import ops
    flat = ops.dequantize(q.reshape(-1), s.reshape(-1), tile=q.shape[-1])
    return flat.reshape(q.shape).astype(dtype)


class PagedDecodeCache:
    """Device pool + host allocators for one model's decode cache.

    The pure device functions (``gather`` / ``write_prefill`` /
    ``scatter_token``) take the pool pytree as an argument and return the
    updated pool, so the engine can fold them into its compiled
    prefill-write and decode-step programs; the allocators are plain host
    state driving admission control.
    """

    def __init__(self, model, max_batch: int, max_len: int, page_size: int,
                 n_pages: Optional[int] = None, dtype=None,
                 quantize: Optional[str] = None, build_pool: bool = True):
        from repro.models.model import _dtype as resolve_dtype
        cfg = model.cfg
        if cfg.is_encoder_decoder:
            raise NotImplementedError("paged serving covers decoder-only "
                                      "stacks (no cross-attention cache)")
        if quantize not in (None, "int8"):
            raise ValueError(f"unknown KV quantization {quantize!r}")
        dtype = dtype or resolve_dtype(cfg.compute_dtype)
        self.model = model
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.page_size = int(page_size)
        self.quantize = quantize
        self.dtype = dtype
        self.specs = model.init_cache(max_batch, max_len, dtype=dtype)
        self.meta = stack_cache_meta(cfg, model.plan, max_batch, max_len,
                                     dtype)

        lengths = sorted({m.length for m in jax.tree.leaves(
            self.meta, is_leaf=lambda x: isinstance(x, CacheLeafMeta))
            if m.kind == "paged"})
        self.allocators: Dict[int, PageAllocator] = {}
        for L in lengths:
            full = 1 + max_batch * (L // page_size)
            self.allocators[L] = PageAllocator(
                n_pages if n_pages is not None else full,
                page_size, L, max_batch)
        self.pool = self._build_pool() if build_pool else None

    # -- pool construction --------------------------------------------------

    def _leaf_map(self, fn, *trees):
        """tree.map over (meta, *aligned trees) with meta leaves opaque."""
        return jax.tree.map(fn, self.meta, *trees,
                            is_leaf=lambda x: isinstance(x, CacheLeafMeta))

    def _build_pool(self):
        page = self.page_size

        def pool_spec(m, s):
            if m.kind == "state":
                return s
            np_ = self.allocators[m.length].n_pages
            if m.batch_axis == 1:
                shape = (s.shape[0], np_, page) + s.shape[3:]
            else:
                shape = (np_, page) + s.shape[2:]
            return jax.ShapeDtypeStruct(shape, s.dtype)

        pool = materialize_cache(self._leaf_map(pool_spec, self.specs))
        if self.quantize == "int8":
            def quantized(m, p):
                if m.kind == "state":
                    return p
                return {"q": jnp.zeros(p.shape, jnp.int8),
                        "s": jnp.zeros(p.shape[:-1], jnp.float32)}
            pool = self._leaf_map(quantized, pool)
        return pool

    def can_admit(self, n_tokens: int) -> bool:
        return all(a.can_admit(n_tokens) for a in self.allocators.values())

    def alloc(self, slot: int, n_tokens: int) -> None:
        for a in self.allocators.values():
            a.alloc(slot, n_tokens)

    def free(self, slot: int) -> int:
        return sum(a.free(slot) for a in self.allocators.values())

    def tables(self) -> Dict[int, jnp.ndarray]:
        """{length: (max_batch, pages_per_slot) int32} device page tables
        — one table per length group, shared by every leaf of that L."""
        return {L: jnp.asarray(a.table())
                for L, a in self.allocators.items()}

    def check(self) -> None:
        for a in self.allocators.values():
            a.check()

    # -- pure device functions (fold into the engine's jitted steps) --------

    def _split(self, p, m):
        """(values_leaf, scales_leaf_or_None) view of a pool leaf."""
        if self.quantize == "int8" and m.kind == "paged":
            return p["q"], p["s"]
        return p, None

    def gather(self, pool, tables):
        """Pool -> linear ``(max_batch, L, ...)`` cache view through the
        page tables: the pytree ``model.decode_step`` consumes.  State
        leaves pass through; garbage gathered from trash/beyond-``pos``
        pages is neutralized by the decode validity masks."""
        B = self.max_batch

        def g(m, p):
            if m.kind == "state":
                return p
            vals, scales = self._split(p, m)
            t = tables[m.length]                       # (B, pps)
            if m.batch_axis == 1:
                x = vals[:, t]                         # (R, B, pps, page, ...)
                out = x.reshape((x.shape[0], B, m.length) + x.shape[4:])
                if scales is not None:
                    s = scales[:, t].reshape(out.shape[:-1])
                    out = _dequant(out, s, self.dtype)
                return out
            x = vals[t]                                # (B, pps, page, ...)
            out = x.reshape((B, m.length) + x.shape[3:])
            if scales is not None:
                s = scales[t].reshape(out.shape[:-1])
                out = _dequant(out, s, self.dtype)
            return out

        return self._leaf_map(g, pool)

    def write_prefill(self, pool, cache_row, table_row, slot):
        """Write one request's prefill cache (linear, batch=1) into its
        pages and state row.  ``table_row``: {length: (pps,) int32};
        ``slot``: traced scalar int32.  Unallocated table entries point at
        trash, so short allocations spill harmlessly."""
        page = self.page_size

        def w(m, p, c):
            if m.kind == "state":
                if m.batch_axis == 1:
                    return p.at[:, slot].set(c[:, 0].astype(p.dtype))
                return p.at[slot].set(c[0].astype(p.dtype))
            tr = table_row[m.length]                   # (pps,)
            pps = tr.shape[0]
            vals, scales = self._split(p, m)
            if m.batch_axis == 1:
                rows = c[:, 0]                         # (R, L, ...)
                rows = rows.reshape((rows.shape[0], pps, page)
                                    + rows.shape[2:])
            else:
                rows = c[0].reshape((pps, page) + c.shape[2:])
            if scales is None:
                if m.batch_axis == 1:
                    return p.at[:, tr].set(rows.astype(p.dtype))
                return p.at[tr].set(rows.astype(p.dtype))
            q, s = _quant(rows)
            if m.batch_axis == 1:
                return {"q": vals.at[:, tr].set(q),
                        "s": scales.at[:, tr].set(s)}
            return {"q": vals.at[tr].set(q), "s": scales.at[tr].set(s)}

        return self._leaf_map(w, pool, cache_row)

    def scatter_token(self, pool, linear, pos, tables, active):
        """Write the decode step's new entries back: paged leaves scatter
        the per-row entry at ``pos[b] % L`` into ``(page, offset)`` through
        the table — inactive rows are routed to the trash page — and state
        leaves adopt the updated linear rows wholesale (inactive rows hold
        garbage that the next admission's prefill write overwrites)."""
        B = self.max_batch
        page = self.page_size
        rows = jnp.arange(B)

        def s_(m, p, lin):
            if m.kind == "state":
                return lin.astype(p.dtype)
            L = m.length
            slot = pos % L                              # (B,)
            page_idx = slot // page
            off = slot % page
            t = tables[L]
            phys = jnp.take_along_axis(t, page_idx[:, None], axis=1)[:, 0]
            phys = jnp.where(active, phys, TRASH_PAGE)
            vals, scales = self._split(p, m)
            if m.batch_axis == 1:
                entry = lin[:, rows, slot]              # (R, B, ...)
            else:
                entry = lin[rows, slot]                 # (B, ...)
            if scales is None:
                if m.batch_axis == 1:
                    return p.at[:, phys, off].set(entry.astype(p.dtype))
                return p.at[phys, off].set(entry.astype(p.dtype))
            q, s = _quant(entry)
            if m.batch_axis == 1:
                return {"q": vals.at[:, phys, off].set(q),
                        "s": scales.at[:, phys, off].set(s)}
            return {"q": vals.at[phys, off].set(q),
                    "s": scales.at[phys, off].set(s)}

        return self._leaf_map(s_, pool, linear)
